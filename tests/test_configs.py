"""Config exactness: every assigned architecture matches the assignment
table verbatim, and the shape tables expose all 40 cells."""
import pytest

from repro.configs.registry import ARCHS, cells, get_arch
from repro.configs.shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES


def test_lm_configs_exact():
    c = get_arch("moonshot-v1-16b-a3b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.moe_experts, c.moe_top_k) == (
        48, 2048, 16, 16, 1408, 163840, 64, 6)
    c = get_arch("phi3.5-moe-42b-a6.6b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.moe_experts, c.moe_top_k) == (
        32, 4096, 32, 8, 6400, 32064, 16, 2)
    c = get_arch("stablelm-1.6b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (24, 2048, 32, 32, 5632, 100352)
    c = get_arch("gemma2-27b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (46, 4608, 32, 16, 36864, 256000)
    assert c.sliding_window == 4096 and c.attn_softcap == 50.0
    assert c.final_softcap == 30.0
    c = get_arch("qwen2.5-14b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 5120, 40, 8, 13824, 152064)
    assert c.qkv_bias


def test_gnn_configs_exact():
    c = get_arch("mace").config()
    assert (c.n_layers, c.channels, c.l_max, c.correlation, c.n_rbf) == (
        2, 128, 2, 3, 8)
    c = get_arch("pna").config()
    assert (c.n_layers, c.d_hidden) == (4, 75)
    c = get_arch("gin-tu").config()
    assert (c.n_layers, c.d_hidden) == (5, 64)
    c = get_arch("gat-cora").config()
    assert (c.n_layers, c.d_hidden, c.n_heads) == (2, 8, 8)


def test_recsys_config_exact():
    c = get_arch("din").config()
    assert c.embed_dim == 18 and c.seq_len == 100
    assert c.attn_hidden == (80, 40) and c.mlp_hidden == (200, 80)
    assert c.n_items >= 10**6  # taxonomy: huge sparse tables


def test_shape_tables_exact():
    s = LM_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (
        32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (
        32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (
        524288, 1)
    g = GNN_SHAPES
    assert (g["full_graph_sm"].n_nodes, g["full_graph_sm"].n_edges,
            g["full_graph_sm"].d_feat) == (2708, 10556, 1433)
    assert (g["minibatch_lg"].n_nodes, g["minibatch_lg"].n_edges) == (
        232_965, 114_615_892)
    assert g["minibatch_lg"].fanout == (15, 10)
    assert (g["ogb_products"].n_nodes, g["ogb_products"].n_edges,
            g["ogb_products"].d_feat) == (2_449_029, 61_859_140, 100)
    assert (g["molecule"].nodes_per_graph, g["molecule"].edges_per_graph,
            g["molecule"].batch_graphs) == (30, 64, 128)
    r = RECSYS_SHAPES
    assert r["train_batch"].batch == 65_536
    assert r["serve_p99"].batch == 512
    assert r["serve_bulk"].batch == 262_144
    assert r["retrieval_cand"].n_candidates == 1_000_000


def test_cell_count():
    runnable = cells()
    skipped = [c for c in cells(include_skipped=True) if c not in runnable]
    assert len(runnable) + len(skipped) == 40  # the assigned 40 cells
    assert len(skipped) == 4  # long_500k on the 4 full-attention archs
    assert all(s == "long_500k" for _, s in skipped)


def test_param_counts_sane():
    """Param counts should land near the arch names' advertised sizes."""
    assert abs(get_arch("stablelm-1.6b").config().param_count() / 1.6e9 - 1) < 0.25
    assert abs(get_arch("qwen2.5-14b").config().param_count() / 14e9 - 1) < 0.25
    assert abs(get_arch("gemma2-27b").config().param_count() / 27e9 - 1) < 0.25
    # moonshot: the assigned table (48L x 64e x d_ff 1408, all-MoE) gives
    # 28B total — the real Moonlight shares/structures experts differently,
    # but the assignment numbers are the contract. Active ~= 4B ~ "a3b".
    m = get_arch("moonshot-v1-16b-a3b").config()
    assert abs(m.active_param_count() / 3e9 - 1) < 0.5
    p = get_arch("phi3.5-moe-42b-a6.6b").config()
    assert abs(p.param_count() / 42e9 - 1) < 0.3
    assert abs(p.active_param_count() / 6.6e9 - 1) < 0.3
