"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 real device;
multi-device behavior is tested via subprocesses (see test_distributed.py)
and the production meshes only via launch/dryrun.py."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_graph(n, avg_deg, seed=0):
    from repro.core.csr import from_edges

    r = np.random.default_rng(seed)
    m = n * avg_deg // 2
    e = r.integers(0, n, size=(m, 2))
    return from_edges(e, n, undirected=True)


def powerlaw_graph(n, avg_deg, seed=0):
    from repro.graphs.datasets import powerlaw_graph as plg

    return plg(n, avg_deg, seed=seed)
