"""Observability plane: span tracer, labeled metric registry, ledger
adapters, stat-merge edge cases, and the trace/metric validators CI
runs against every ``--trace``/``--metrics`` smoke."""
import dataclasses
import json
import types

import numpy as np
import pytest

from conftest import powerlaw_graph

from repro.core.cache import (
    CacheStats,
    merge_cache_stats,
    merge_counter_dataclasses,
)
from repro.core.runtime import ProviderStats, ShardedRuntime
from repro.obs import trace as obs_trace
from repro.obs.metrics import (
    MetricRegistry,
    fold_trace,
    imbalance,
    load_snapshot,
    record_collective_ledger,
    record_latency,
    record_reconciliation,
    record_runtime,
)
from repro.obs.validate import validate_metrics, validate_trace
from repro.serving.metrics import LatencyRecorder
from repro.streaming import DynamicCSR


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    obs_trace.disable_tracing()


def _runtime(p=4, n=80, seed=0):
    csr = powerlaw_graph(n, 5, seed=seed)
    store = DynamicCSR.from_csr(csr)
    return ShardedRuntime(store, p), store


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
def test_disabled_tracing_is_a_shared_noop():
    assert obs_trace.get_tracer() is None
    s1 = obs_trace.span("fetch_rows", rank=2, cat="runtime", n=9)
    s2 = obs_trace.span("all_to_all")
    assert s1 is s2  # one shared null object: no per-call allocation
    with s1 as s:
        s.set(bytes=123)  # late-arg attachment must also be a no-op
    obs_trace.instant("cache_admit", key=1)
    obs_trace.counter("queue_depth", 5)
    assert not obs_trace.fine_enabled()
    assert obs_trace.get_tracer() is None


def test_span_nesting_ranks_and_export(tmp_path):
    tracer = obs_trace.enable_tracing()
    with obs_trace.span("stream_batch", rank=0, cat="streaming", n=4):
        with obs_trace.span("intersect_kernel", rank=0, pairs=7):
            pass
        with obs_trace.span("fetch_rows", rank=0, n=2):
            pass
    with obs_trace.span("fetch_rows", rank=3, n=1):
        pass
    obs_trace.counter("queue_depth", 2, rank=1)
    obs_trace.instant("cache_invalidate", rank=1, n=3)
    assert obs_trace.disable_tracing() is tracer
    assert len(tracer) == 6

    chrome = tracer.to_chrome()
    assert validate_trace(chrome) == []
    names = [e["name"] for e in chrome["traceEvents"] if e["ph"] == "X"]
    assert set(names) == {"stream_batch", "intersect_kernel", "fetch_rows"}
    # rank -> tid lane (+1), so Perfetto gets one swim-lane per rank
    lanes = {e["tid"] for e in chrome["traceEvents"] if e["ph"] == "X"}
    assert lanes == {1, 4}
    # thread_name metadata names each rank lane
    th = {e["tid"]: e["args"]["name"] for e in chrome["traceEvents"]
          if e["ph"] == "M" and e["name"] == "thread_name"}
    assert th[1] == "rank 0" and th[4] == "rank 3"

    path = tmp_path / "t.json"
    tracer.export(str(path))
    with open(path) as f:
        assert validate_trace(json.load(f)) == []


def test_phase_totals_roll_up_time_calls_bytes():
    tracer = obs_trace.enable_tracing()
    for _ in range(3):
        with obs_trace.span("all_to_all", payload_bytes=100, wire_bytes=50):
            pass
    with obs_trace.span("fetch_rows", n=5):
        pass
    obs_trace.disable_tracing()
    tot = tracer.phase_totals()
    assert tot["all_to_all"]["calls"] == 3
    assert tot["all_to_all"]["bytes"] == 3 * 150  # every *bytes arg sums
    assert tot["all_to_all"]["total_s"] > 0
    assert tot["fetch_rows"] == pytest.approx(tot["fetch_rows"] | {
        "calls": 1, "bytes": 0.0})


def test_span_set_attaches_late_args():
    tracer = obs_trace.enable_tracing()
    with obs_trace.span("residency_patch") as s:
        s.set(bytes=77, admits=2)
    obs_trace.disable_tracing()
    (ev,) = tracer.events
    assert ev["args"] == {"bytes": 77, "admits": 2}


def test_fine_mode_gates_per_entry_instants():
    obs_trace.enable_tracing()
    assert not obs_trace.fine_enabled()
    obs_trace.disable_tracing()
    tracer = obs_trace.enable_tracing(fine=True)
    assert obs_trace.fine_enabled()
    obs_trace.instant("cache_admit", key=4, bytes=64)
    obs_trace.disable_tracing()
    assert [e["ph"] for e in tracer.events] == ["i"]


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------
def test_registry_semantics_and_snapshot_roundtrip(tmp_path):
    reg = MetricRegistry()
    reg.counter("hits", 2, rank=0, tier="host_cache")
    reg.counter("hits", 3, rank=0, tier="host_cache")  # counters add
    reg.counter("hits", 5, rank=1, tier="host_cache")
    reg.gauge("load_imbalance", 2.0, tier="host")
    reg.gauge("load_imbalance", 1.5, tier="host")  # gauges overwrite
    reg.observe("latency_s", [0.1, 0.2, 0.3], tier="serving")
    assert reg.get_counter("hits", rank=0, tier="host_cache") == 5
    assert reg.total("hits", tier="host_cache") == 10
    assert reg.total("hits", rank=1) == 5
    assert reg.get_gauge("load_imbalance", tier="host") == 1.5
    assert reg.get_gauge("nope") is None
    assert reg.ranks() == [0, 1]

    path = tmp_path / "m.json"
    reg.save(str(path))
    snap = load_snapshot(str(path))
    assert snap == reg.to_dict()
    (h,) = snap["histograms"]
    assert h["count"] == 3 and h["sum"] == pytest.approx(0.6)
    assert h["p50"] == pytest.approx(0.2)  # 'lower': an observed value

    bad = tmp_path / "bad.json"
    bad.write_text('{"schema": "other/v9"}')
    with pytest.raises(ValueError):
        load_snapshot(str(bad))


def test_imbalance_definition():
    assert imbalance([3, 3, 3, 3]) == 1.0
    assert imbalance([4, 0, 0, 0]) == 4.0
    assert imbalance([]) == 0.0
    assert imbalance([0, 0]) == 0.0  # no load => 0, not NaN


# ---------------------------------------------------------------------------
# stat merges (the aggregation primitives the adapters lean on)
# ---------------------------------------------------------------------------
def test_merge_cache_stats_empty_list_is_zero():
    merged = merge_cache_stats([])
    assert merged == CacheStats()
    for f in dataclasses.fields(CacheStats):
        assert getattr(merged, f.name) == 0


def test_merge_cache_stats_single_rank_is_identity():
    one = CacheStats(gets=7, hits=4, misses=3, bytes_hit=64)
    merged = merge_cache_stats([one])
    assert merged == one
    assert merged is not one  # a fresh aggregate, not the input


def test_merge_mixed_zero_and_nonzero_counters():
    merged = merge_cache_stats([
        CacheStats(),
        CacheStats(gets=5, hits=5, bytes_hit=10),
        CacheStats(gets=2, misses=2, comm_time=0.5),
        CacheStats(),
    ])
    assert (merged.gets, merged.hits, merged.misses) == (7, 5, 2)
    assert merged.bytes_hit == 10
    assert merged.comm_time == pytest.approx(0.5)


def test_merge_counter_dataclasses_covers_every_provider_field():
    a = ProviderStats(local_reads=1, remote_reads=2, cache_hits=1,
                      cache_misses=1, bytes_fetched=100, modeled_comm_s=0.1,
                      tenant_requests={"t0": 3}, tenant_bytes_fetched={"t0": 64})
    b = ProviderStats(local_reads=4, device_hits=3, bytes_fetched=50,
                      tenant_requests={"t0": 1, "t1": 2})
    merged = merge_counter_dataclasses(ProviderStats, [a, b])
    for f in dataclasses.fields(ProviderStats):
        va, vb, vm = (getattr(x, f.name) for x in (a, b, merged))
        if isinstance(va, dict):
            expect = dict(va)
            for k, v in vb.items():
                expect[k] = expect.get(k, 0) + v
            assert vm == expect, f.name
        else:
            assert vm == va + vb, f.name


def test_aggregate_stats_equals_per_rank_sums_p4():
    rt, store = _runtime(p=4)
    for rank in range(4):
        rt.fetch_rows(rank, range(store.n))
    agg = rt.aggregate_stats()
    for f in dataclasses.fields(ProviderStats):
        vals = [getattr(s, f.name) for s in rt.stats]
        if isinstance(vals[0], dict):
            want = {}
            for d in vals:
                for k, v in d.items():
                    want[k] = want.get(k, 0) + v
            assert getattr(agg, f.name) == want, f.name
        else:
            assert getattr(agg, f.name) == pytest.approx(sum(vals)), f.name
    cagg = rt.merged_cache_stats()
    for f in dataclasses.fields(CacheStats):
        want = sum(getattr(c.stats, f.name) for c in rt.caches)
        assert getattr(cagg, f.name) == pytest.approx(want), f.name


# ---------------------------------------------------------------------------
# adapters + validator on a real runtime
# ---------------------------------------------------------------------------
def _fake_ledger(rt, *, bytes_off=0):
    return types.SimpleNamespace(
        rows_shipped=np.asarray(rt.serve_rows, np.int64),
        bytes_payload=sum(s.bytes_fetched for s in rt.stats) + bytes_off,
        bytes_on_wire=10_000,
        n_collectives=2,
        n_pairs=11,
        device_wall_s=0.01,
    )


def test_record_runtime_snapshot_satisfies_invariants():
    rt, store = _runtime(p=4)
    for rank in range(4):
        rt.fetch_rows(rank, range(0, store.n, 1 + rank))
    reg = MetricRegistry()
    record_runtime(reg, rt)
    snap = reg.to_dict()
    assert validate_metrics(snap) == []
    assert reg.get_gauge("load_imbalance", tier="host") > 0
    assert reg.get_gauge("serve_matrix_skew", tier="wire") > 0
    # the anchor: every row each rank asked for is accounted once
    assert reg.total("row_requests", tier="host") == sum(
        s.local_reads + s.remote_reads for s in rt.stats
    )


def test_reconciliation_agreement_and_mismatch():
    rt, store = _runtime(p=4)
    for rank in range(4):
        rt.fetch_rows(rank, range(store.n))

    reg = MetricRegistry()
    record_runtime(reg, rt)
    record_collective_ledger(reg, _fake_ledger(rt))
    record_reconciliation(reg, rt, _fake_ledger(rt))
    assert reg.get_gauge("rma_agreement", tier="wire") == 1.0
    assert validate_metrics(reg.to_dict()) == []

    reg2 = MetricRegistry()
    record_runtime(reg2, rt)
    record_collective_ledger(reg2, _fake_ledger(rt, bytes_off=8))
    record_reconciliation(reg2, rt, _fake_ledger(rt, bytes_off=8))
    assert reg2.get_gauge("rma_agreement", tier="wire") == 0.0
    bad = validate_metrics(reg2.to_dict())
    assert any("rma_bytes" in m for m in bad)
    assert any("rma_agreement" in m for m in bad)


def test_reconciliation_without_ledger_records_nothing():
    rt, _ = _runtime(p=2)
    reg = MetricRegistry()
    record_reconciliation(reg, rt, None)
    assert reg.get_gauge("rma_agreement", tier="wire") is None


def test_fold_trace_adds_the_time_dimension():
    tracer = obs_trace.enable_tracing()
    with obs_trace.span("all_to_all", payload_bytes=64):
        pass
    with obs_trace.span("all_to_all", payload_bytes=36):
        pass
    obs_trace.disable_tracing()
    reg = MetricRegistry()
    fold_trace(reg, tracer)
    assert reg.get_counter("phase_calls", phase="all_to_all") == 2
    assert reg.get_counter("phase_bytes", phase="all_to_all") == 100
    assert reg.get_counter("phase_time_s", phase="all_to_all") > 0


# ---------------------------------------------------------------------------
# latency recorder: division guards + per-class breakdowns
# ---------------------------------------------------------------------------
def test_empty_recorder_rates_are_zero_not_nan():
    s = LatencyRecorder().summary()
    assert s.count == 0
    assert s.shed_rate == 0.0
    assert s.throughput_qps == 0.0


def test_zero_wall_reports_zero_throughput():
    rec = LatencyRecorder()
    rec.record(0.010)
    s = rec.summary()
    assert s.wall_s == 0.0
    assert s.throughput_qps == 0.0  # "unknown", not served / 1e-12


def test_per_class_latency_and_shed_breakdown():
    rec = LatencyRecorder()
    for ms in (1, 2, 3):
        rec.record(ms * 1e-3, cls="lcc")
    rec.record(9e-3, cls="count")
    rec.record(5e-3)  # unclassified: overall only
    rec.record_shed("deadline", 2, cls="count")
    rec.record_wall(0.5)

    assert rec.classes() == ["count", "lcc"]
    by = rec.by_class()
    assert len(by["lcc"]) == 3 and by["count"] == [9e-3]
    by["lcc"].append(99.0)  # defensive copy: must not leak back
    assert len(rec.by_class()["lcc"]) == 3

    overall = rec.summary()
    assert overall.count == 5
    assert overall.shed == 2
    assert overall.shed_rate == pytest.approx(2 / 7)

    per = rec.summary_by_class()
    assert per["lcc"].count == 3 and per["lcc"].shed == 0
    assert per["count"].count == 1 and per["count"].shed == 2
    assert per["count"].shed_rate == pytest.approx(2 / 3)
    # wall clock is shared across classes: no per-class throughput claim
    assert per["lcc"].wall_s == 0.0 and per["lcc"].throughput_qps == 0.0


def test_provider_hit_rate_division_guards():
    st = ProviderStats()
    assert st.hit_rate == 0.0
    assert st.remote_hit_rate == 0.0
    st = ProviderStats(remote_reads=10, cache_hits=6, cache_misses=2,
                       device_hits=2)
    assert st.hit_rate == pytest.approx(6 / 8)  # of host-cache lookups
    assert st.remote_hit_rate == pytest.approx(8 / 10)  # either tier


# ---------------------------------------------------------------------------
# validator negative paths
# ---------------------------------------------------------------------------
def test_validator_rejects_overlapping_spans():
    trace = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 0, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0, "pid": 0, "tid": 1},
    ]}
    bad = validate_trace(trace)
    assert len(bad) == 1 and "overlaps" in bad[0]
    # same intervals on different lanes are fine (ranks run concurrently)
    trace["traceEvents"][1]["tid"] = 2
    assert validate_trace(trace) == []


def test_validator_requires_ts_except_on_metadata():
    trace = {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "x"}},
        {"name": "a", "ph": "X", "dur": 1.0, "pid": 0, "tid": 1},
    ]}
    bad = validate_trace(trace)
    assert len(bad) == 1 and "'a'" in bad[0] and "ts" in bad[0]


def test_validator_flags_unbalanced_host_counters():
    rt, store = _runtime(p=2)
    rt.fetch_rows(0, range(store.n))
    reg = MetricRegistry()
    record_runtime(reg, rt)
    reg.counter("cache_misses", 1, rank=0, tier="host")  # cook the books
    bad = validate_metrics(reg.to_dict())
    assert any("remote row requests" in m for m in bad)
