"""Per-kernel interpret-mode validation: sweep shapes/dtypes, assert
allclose vs the pure-jnp oracle in kernels/ref.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def pad_sorted(rng, e, w, sentinel, max_fill=None):
    out = np.full((e, w), sentinel, np.int32)
    for i in range(e):
        k = rng.integers(0, (max_fill or w) + 1)
        vals = np.unique(rng.integers(0, sentinel, size=k))
        out[i, : len(vals)] = vals
    return out


@pytest.mark.parametrize("e,wa,wb,block_e", [
    (128, 16, 32, 64),
    (256, 64, 128, 128),
    (128, 8, 200, 128),  # non-multiple-of-128 width
])
def test_intersect_count(e, wa, wb, block_e):
    rng = np.random.default_rng(0)
    sent = 4096
    a = jnp.asarray(pad_sorted(rng, e, wa, sent))
    b = jnp.asarray(pad_sorted(rng, e, wb, sent))
    got = ops.intersect_count(a, b, sentinel=sent, block_e=block_e,
                              interpret=True)
    want = ref.intersect_count_ref(a, b, sentinel=sent)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("e,w,block_e", [(256, 8, 128), (512, 33, 256)])
def test_bitmap_popcount(e, w, block_e):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
    got = ops.bitmap_intersect_count(a, b, block_e=block_e, interpret=True)
    want = ref.bitmap_intersect_count_ref(a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,b,l,mode,dtype", [
    (64, 16, 16, 4, "sum", np.float32),
    (128, 32, 8, 7, "mean", np.float32),
    (64, 8, 16, 3, "sum", np.float16),
])
def test_embedding_bag(n, d, b, l, mode, dtype):
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(n, d)).astype(dtype))
    ids = jnp.asarray(rng.integers(0, n, size=(b, l)).astype(np.int32))
    mask = jnp.asarray(rng.random((b, l)) < 0.8)
    got = ops.embedding_bag(table, ids, mask, mode=mode, block_b=4,
                            interpret=True)
    want = ref.embedding_bag_ref(table, ids, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("e,d,n,block_e,rows", [
    (512, 16, 64, 128, 32),
    (1024, 64, 200, 512, 128),
])
def test_segment_sum_sorted(e, d, n, block_e, rows):
    rng = np.random.default_rng(3)
    seg = np.sort(rng.integers(0, n, size=e)).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32))
    got = ops.segment_sum_sorted(vals, jnp.asarray(seg), num_segments=n,
                                 block_e=block_e, rows=rows, interpret=True)
    want = ref.segment_sum_sorted_ref(vals, jnp.asarray(seg), num_segments=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
def test_flash_attention_kernel(causal, window, softcap):
    rng = np.random.default_rng(4)
    b, s, dh = 2, 256, 32
    q = jnp.asarray(rng.normal(size=(b, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, dh)).astype(np.float32))
    from repro.kernels.flash_attention import flash_attention

    got = flash_attention(q, k, v, scale=0.2, causal=causal, window=window,
                          softcap=softcap, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.2, causal=causal,
                                   window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_wrapper():
    rng = np.random.default_rng(5)
    b, s, kh, g, dh = 1, 128, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kh, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kh, dh)).astype(np.float32))
    got = ops.flash_attention_gqa(q, k, v, scale=0.25, block_q=64,
                                  block_k=64, interpret=True)
    from repro.models.attention import flash_attention_jnp

    want = flash_attention_jnp(q, k, v, scale=0.25, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bitmap_vs_rows_cross_check():
    """bitmap kernel == intersect kernel on the same underlying sets."""
    from repro.core.csr import rows_to_bitmap_words

    rng = np.random.default_rng(6)
    e, w, sent = 128, 24, 512
    a = pad_sorted(rng, e, w, sent)
    b = pad_sorted(rng, e, w, sent)
    c1 = ops.intersect_count(jnp.asarray(a), jnp.asarray(b), sentinel=sent,
                             block_e=64, interpret=True)
    wa = jnp.asarray(rows_to_bitmap_words(a, sent))
    wb = jnp.asarray(rows_to_bitmap_words(b, sent))
    c2 = ops.bitmap_intersect_count(wa, wb, block_e=64, interpret=True)
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
