"""Checkpointing + fault tolerance: save/restore round-trips, async saves,
restart-resume determinism, elastic re-sharding, straggler detection."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.tokens import TokenStream
from repro.distributed.fault_tolerance import (
    StragglerMonitor,
    TrainRunner,
    elastic_restore,
)
from repro.models import transformer as tfm
from repro.train import train_loop as tl
from repro.train.checkpoint import CheckpointManager, flatten_tree, unflatten_tree
from repro.train.optimizer import adamw


def tiny_cfg():
    return tfm.TransformerConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=128, dtype=jnp.float32, remat=False,
    )


def test_flatten_roundtrip():
    tree = {"a": {"b": np.arange(6).reshape(2, 3)}, "c": [np.ones(4)]}
    flat = flatten_tree(tree)
    back = unflatten_tree(tree, flat)
    assert np.array_equal(back["a"]["b"], tree["a"]["b"])
    assert np.array_equal(back["c"][0], tree["c"][0])


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cfg = tiny_cfg()
    params = tfm.init_params(cfg, jax.random.key(0))
    cm.save(10, {"params": params}, meta={"next_step": 10})
    got, meta = cm.restore({"params": params})
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_keep_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    x = {"w": np.ones(3)}
    for s in (1, 2, 3, 4):
        cm.save(s, x)
    assert cm.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # only last 2 kept


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    fut = cm.save_async(7, {"w": np.arange(5)})
    fut.result(timeout=30)
    got, meta = cm.restore({"w": np.zeros(5)})
    assert np.array_equal(got["w"], np.arange(5))


def test_restart_resumes_identically(tmp_path):
    """Train 6 steps straight vs train 3 + restart + 3: identical params."""
    cfg = tiny_cfg()
    opt = adamw(lr=1e-3)
    stream = TokenStream(cfg.vocab, 4, 16, seed=0)
    step_fn = jax.jit(tl.make_lm_train_step(cfg, opt))

    def fresh():
        p = tfm.init_params(cfg, jax.random.key(1))
        return p, opt.init(p)

    # straight run
    p, s = fresh()
    for i in range(6):
        p, s, _ = step_fn(p, s, stream.batch_at(i))
    straight = jax.tree.leaves(p)

    # interrupted run
    cm = CheckpointManager(str(tmp_path))
    p, s = fresh()
    for i in range(3):
        p, s, _ = step_fn(p, s, stream.batch_at(i))
    cm.save(3, {"params": p, "opt_state": s}, meta={"next_step": 3})
    # "restart": reload from disk
    p2, s2 = fresh()
    state, meta = cm.restore({"params": p2, "opt_state": s2})
    p2, s2 = state["params"], state["opt_state"]
    for i in range(meta["next_step"], 6):
        p2, s2, _ = step_fn(p2, s2, stream.batch_at(i))
    resumed = jax.tree.leaves(p2)
    for a, b in zip(straight, resumed):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore against a different sharding (elastic restart path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    cm = CheckpointManager(str(tmp_path))
    w = np.arange(16, dtype=np.float32).reshape(4, 4)
    cm.save(1, {"w": w})
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    sh = {"w": NamedSharding(mesh, P())}
    got, _ = elastic_restore(cm, {"w": w}, sh)
    assert np.array_equal(np.asarray(got["w"]), w)
    assert got["w"].sharding == sh["w"]


def test_train_runner_with_ckpt(tmp_path):
    cfg = tiny_cfg()
    opt = adamw(lr=1e-3)
    stream = TokenStream(cfg.vocab, 4, 16, seed=3)
    params = tfm.init_params(cfg, jax.random.key(0))
    runner = TrainRunner(
        step_fn=jax.jit(tl.make_lm_train_step(cfg, opt)),
        data_fn=stream.batch_at,
        ckpt=CheckpointManager(str(tmp_path)),
        ckpt_every=4,
    )
    params, opt_state, log = runner.run(
        params, opt.init(params), start_step=0, n_steps=8
    )
    assert len(log) == 8
    assert runner.ckpt.latest_step() == 8


def test_straggler_monitor():
    m = StragglerMonitor(window=16, threshold=2.0)
    for i in range(10):
        m.record(i, 0.1)
    m.record(10, 0.5)  # 5x median
    assert m.straggler_suspected
    m2 = StragglerMonitor()
    m2.record(0, 0.1, per_device={"d0": 0.1, "d1": 0.1, "d2": 0.9})
    assert m2.straggler_suspected
