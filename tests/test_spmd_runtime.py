"""SPMD execution of the sharded runtime's rank views.

In-process: the executor vs a numpy oracle and the loop-vs-spmd
field-for-field property at p=1 (the suite sees one device). Multi
device: a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(jax pins the device count at first init, and the rest of the suite must
see 1 device) runs the same property at p in {4, 8}, with and without
the device-resident tier — answers, per-rank cache stats, serve matrix,
coherence ledgers, and residency stats must all agree, and the measured
collective traffic must equal the modeled serve-matrix delta.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------------------
# executor vs oracle (p=1 in-process)
# --------------------------------------------------------------------------
class _FakeStore:
    def __init__(self, rows):
        self.rows = rows

    def row(self, v):
        return self.rows[int(v)]


@pytest.mark.parametrize("use_kernel", [False, True])
def test_executor_matches_oracle_p1(use_kernel):
    from repro.core.partition import partition_1d
    from repro.distributed.spmd_runtime import (
        ShardWork,
        SpmdIntersectExecutor,
    )

    rng = np.random.default_rng(3)
    n = 32
    rows = {
        v: np.sort(
            rng.choice(n, size=int(rng.integers(0, 9)), replace=False)
        ).astype(np.int32)
        for v in range(n)
    }
    store = _FakeStore(rows)
    part = partition_1d(n, 1)
    a = rng.integers(0, n, size=20).astype(np.int64)
    b = rng.integers(0, n, size=20).astype(np.int64)
    held = {int(v): rows[int(v)] for v in np.unique(np.concatenate([a, b]))}
    ex = SpmdIntersectExecutor(part, n, use_kernel=use_kernel)
    counts, unit = ex.run(
        [ShardWork(0, a, b, held)], store
    )
    want = np.array(
        [
            len(np.intersect1d(rows[int(x)], rows[int(y)]))
            for x, y in zip(a, b)
        ],
        np.int64,
    )
    assert np.array_equal(counts[0], want)
    assert unit.rows_shipped.sum() == 0  # p=1: nothing is remote


def test_executor_empty_unit_is_free():
    from repro.core.partition import partition_1d
    from repro.distributed.spmd_runtime import (
        ShardWork,
        SpmdIntersectExecutor,
    )

    part = partition_1d(16, 1)
    ex = SpmdIntersectExecutor(part, 16)
    z = np.zeros(0, np.int64)
    counts, unit = ex.run([ShardWork(0, z, z, {})], _FakeStore({}))
    assert counts[0].size == 0 and unit.n_collectives == 0


def test_ensure_host_devices_preserves_existing_flags(monkeypatch):
    from repro.distributed.spmd_runtime import ensure_host_devices

    monkeypatch.setenv("XLA_FLAGS", "--xla_foo=7")
    ensure_host_devices(1)
    flags = os.environ["XLA_FLAGS"]
    assert "--xla_foo=7" in flags  # user flag survived
    assert "--xla_force_host_platform_device_count=1" in flags
    # an explicit external device-count directive always wins
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=1 --xla_bar=2"
    )
    ensure_host_devices(1)
    assert os.environ["XLA_FLAGS"] == (
        "--xla_force_host_platform_device_count=1 --xla_bar=2"
    )


def test_ensure_host_devices_parses_existing_value(monkeypatch):
    """An externally pinned device count is parsed, not just detected:
    a larger pin satisfies the request untouched; a smaller pin fails
    early with a message naming the conflicting value."""
    from repro.distributed.spmd_runtime import ensure_host_devices

    # larger external pin: honored verbatim (no second directive
    # appended, no override) — the suite's jax is already pinned to one
    # device, so probe non-strict
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    ensure_host_devices(4, strict=False)
    assert os.environ["XLA_FLAGS"] == (
        "--xla_force_host_platform_device_count=8"
    )
    # smaller external pin: early, specific error naming the pinned
    # value (not a late generic jax device shortage)
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_bar=1 --xla_force_host_platform_device_count=2"
    )
    with pytest.raises(RuntimeError, match=r"pins.*=2.*smaller"):
        ensure_host_devices(4)
    assert os.environ["XLA_FLAGS"].count(
        "--xla_force_host_platform_device_count"
    ) == 1
    # whitespace around '=' still parses as an existing directive
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_force_host_platform_device_count = 16"
    )
    ensure_host_devices(1)
    assert os.environ["XLA_FLAGS"] == (
        "--xla_force_host_platform_device_count = 16"
    )


# --------------------------------------------------------------------------
# property: loop-mode and spmd-mode executions agree field-for-field
# --------------------------------------------------------------------------
def _provider_stats(runtime):
    return [dataclasses.asdict(s) for s in runtime.stats]


def _device_stats(runtime):
    """Per-view residency stats (replicated: one view with rank=-1;
    per_rank scope: one view per rank)."""
    return [
        (dv.rank, dataclasses.asdict(dv.stats))
        for dv in runtime.device_views()
    ]


def _ledger_dict(led):
    """CollectiveLedger as comparable counters — wall-clock fields are
    timing, not semantics, so they are excluded from equality."""
    d = led.to_dict()
    d.pop("device_wall_s", None)
    d.pop("overlap_wait_s", None)
    return d


def _run_serving(
    execution, p, seed, device_slots=0, pipeline=False,
    device_scope="replicated",
):
    from repro.graphs.rmat import rmat_graph
    from repro.serving import LiveQueryService
    from repro.serving.workload import read_write_stream

    csr = rmat_graph(7, 8, seed=seed)
    svc = LiveQueryService(
        csr,
        p=p,
        cross_rank=True,
        execution=execution,
        device_slots=device_slots,
        device_width=256,
        pipeline=pipeline,
        device_scope=device_scope,
    )
    results = []
    for ev in read_write_stream(
        lambda: svc.store.degrees,
        csr.n,
        n_events=10,
        write_frac=0.3,
        queries_per_event=24,
        updates_per_event=24,
        kind="zipf",
        seed=seed,
    ):
        if ev.is_update:
            svc.apply_updates(ev.update)
        else:
            results.extend(svc.scheduler.run(ev.queries))
    svc.verify()
    return svc, results


def _results_agree(r_l, r_s):
    assert len(r_l) == len(r_s) and len(r_l) > 0
    for a, b in zip(r_l, r_s):
        assert a.query == b.query and a.value == b.value
        assert (a.ids is None) == (b.ids is None)
        if a.ids is not None:
            assert np.array_equal(a.ids, b.ids)


def _serving_agrees(p, seed, device_slots=0, device_scope="replicated"):
    svc_l, r_l = _run_serving(
        "loop", p, seed, device_slots, device_scope=device_scope
    )
    svc_s, r_s = _run_serving(
        "spmd", p, seed, device_slots, device_scope=device_scope
    )
    _results_agree(r_l, r_s)
    # per-rank cache stats, serve matrix, coherence ledger: identical
    assert _provider_stats(svc_l.runtime) == _provider_stats(svc_s.runtime)
    assert np.array_equal(svc_l.runtime.serve_rows, svc_s.runtime.serve_rows)
    assert (
        svc_l.runtime.invalidations_sent == svc_s.runtime.invalidations_sent
    )
    assert svc_l.engine.n_pairs_total == svc_s.engine.n_pairs_total
    assert svc_l.engine.n_pairs_raw == svc_s.engine.n_pairs_raw
    assert svc_l.engine.n_pairs_resident == svc_s.engine.n_pairs_resident
    if device_slots:
        assert _device_stats(svc_l.runtime) == _device_stats(svc_s.runtime)
    # measured collective traffic == modeled serve matrix (cumulative)
    led = svc_s.engine.spmd.ledger
    assert np.array_equal(led.rows_shipped, svc_s.runtime.serve_rows)
    assert led.bytes_payload == sum(
        s.bytes_fetched for s in svc_s.runtime.stats
    )
    return True


def _serving_pipeline_agrees(p, seed, device_slots=0):
    """Pipelined (double-buffered windows) SPMD serving is bit-exact vs
    the unpipelined SPMD path, ledger field-for-field included."""
    svc_u, r_u = _run_serving("spmd", p, seed, device_slots)
    svc_p, r_p = _run_serving(
        "spmd", p, seed, device_slots, pipeline=True
    )
    _results_agree(r_u, r_p)
    assert _provider_stats(svc_u.runtime) == _provider_stats(svc_p.runtime)
    assert np.array_equal(svc_u.runtime.serve_rows, svc_p.runtime.serve_rows)
    assert svc_u.engine.n_pairs_total == svc_p.engine.n_pairs_total
    assert svc_u.engine.n_pairs_resident == svc_p.engine.n_pairs_resident
    assert _ledger_dict(svc_u.engine.spmd.ledger) == (
        _ledger_dict(svc_p.engine.spmd.ledger)
    )
    return True


def _run_streaming(
    execution, p, seed, device_slots=0, pipeline=False,
    device_scope="replicated",
):
    from repro.graphs.rmat import rmat_stream
    from repro.streaming import StreamingCacheCoherence, StreamingLCCEngine

    n = 1 << 7
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=p, cache_rows=32
    )
    eng = StreamingLCCEngine.empty(
        n, coherence=coh, execution=execution, pipeline=pipeline
    )
    if device_slots:
        eng.runtime.enable_device_tier(device_slots, 256, scope=device_scope)
    batch_results = []
    for batch in rmat_stream(
        7, 8, batch_size=256, delete_frac=0.2, seed=seed
    ):
        batch_results.append(eng.apply_batch(batch))
    eng.verify()
    return eng, batch_results

def _streaming_agrees(p, seed, device_slots=0, device_scope="replicated"):
    e_l, br_l = _run_streaming(
        "loop", p, seed, device_slots, device_scope=device_scope
    )
    e_s, br_s = _run_streaming(
        "spmd", p, seed, device_slots, device_scope=device_scope
    )
    assert br_l == br_s  # BatchResult dataclasses, field-for-field
    assert np.array_equal(e_l.t, e_s.t)
    assert np.array_equal(e_l.lcc, e_s.lcc)
    assert np.array_equal(e_l.shard_pairs, e_s.shard_pairs)
    assert e_l.oo_host_rows == e_s.oo_host_rows
    assert e_l.oo_host_bytes == e_s.oo_host_bytes
    assert e_l.oo_resident_pairs == e_s.oo_resident_pairs
    assert _provider_stats(e_l.runtime) == _provider_stats(e_s.runtime)
    if device_slots:
        assert _device_stats(e_l.runtime) == _device_stats(e_s.runtime)
    assert e_s.spmd.ledger.n_pairs == e_s.delta_pairs_total
    return True


def _streaming_pipeline_agrees(p, seed, device_slots=0):
    """Pipelined SPMD streaming (overlapped delete/insert phase
    dispatches) is bit-exact vs the unpipelined SPMD path."""
    e_u, br_u = _run_streaming("spmd", p, seed, device_slots)
    e_p, br_p = _run_streaming(
        "spmd", p, seed, device_slots, pipeline=True
    )
    assert br_u == br_p
    assert np.array_equal(e_u.t, e_p.t)
    assert np.array_equal(e_u.lcc, e_p.lcc)
    assert np.array_equal(e_u.shard_pairs, e_p.shard_pairs)
    assert _provider_stats(e_u.runtime) == _provider_stats(e_p.runtime)
    assert _ledger_dict(e_u.spmd.ledger) == _ledger_dict(e_p.spmd.ledger)
    return True


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_serving_loop_vs_spmd_p1(seed):
    assert _serving_agrees(1, seed)


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_loop_vs_spmd_p1(seed):
    assert _streaming_agrees(1, seed)


def test_streaming_loop_vs_spmd_p1_device_tier():
    assert _streaming_agrees(1, 0, device_slots=32)


@pytest.mark.parametrize("seed", [0, 1])
def test_serving_pipeline_p1(seed):
    assert _serving_pipeline_agrees(1, seed)


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_pipeline_p1(seed):
    assert _streaming_pipeline_agrees(1, seed)


def test_serving_loop_vs_spmd_p1_device_per_rank():
    assert _serving_agrees(1, 0, device_slots=32, device_scope="per_rank")


# --------------------------------------------------------------------------
# resident buffer: steady-state reuse and invalidation
# --------------------------------------------------------------------------
def test_resident_buffer_reuse_and_invalidation():
    """Re-running a unit over the same rows uploads only what changed:
    the second unit's rows come from the resident device buffer
    (upload_bytes_saved > 0, few patches), and an invalidate() forces a
    re-upload whose counts track the mutated store, not the stale
    mirror."""
    from repro.core.partition import partition_1d
    from repro.distributed.spmd_runtime import (
        ShardWork,
        SpmdIntersectExecutor,
    )

    rng = np.random.default_rng(11)
    n = 32
    rows = {
        v: np.sort(
            rng.choice(n, size=int(rng.integers(1, 9)), replace=False)
        ).astype(np.int32)
        for v in range(n)
    }
    store = _FakeStore(rows)
    part = partition_1d(n, 1)
    a = rng.integers(0, n, size=24).astype(np.int64)
    b = rng.integers(0, n, size=24).astype(np.int64)
    held = {int(v): rows[int(v)] for v in np.unique(np.concatenate([a, b]))}

    def oracle():
        return np.array(
            [
                len(np.intersect1d(rows[int(x)], rows[int(y)]))
                for x, y in zip(a, b)
            ],
            np.int64,
        )

    ex = SpmdIntersectExecutor(part, n)
    counts1, unit1 = ex.run([ShardWork(0, a, b, held)], store)
    assert np.array_equal(counts1[0], oracle())
    assert unit1.bytes_uploaded > 0  # cold: everything ships
    assert unit1.upload_bytes_saved == 0

    counts2, unit2 = ex.run([ShardWork(0, a, b, held)], store)
    assert np.array_equal(counts2[0], oracle())
    assert unit2.upload_bytes_saved > 0  # warm: resident rows reused
    assert unit2.bytes_uploaded == 0  # nothing changed -> no patch
    assert unit2.upload_bytes_saved == unit1.bytes_uploaded

    # mutate one row in place (same width — the sharpest case: the
    # buffer cannot tell from geometry alone, only invalidate() marks
    # it stale)
    v = int(a[0])
    old = rows[v]
    new = old
    while np.array_equal(new, old):
        new = np.sort(
            rng.choice(n, size=old.size, replace=False)
        ).astype(np.int32)
    rows[v] = new
    held[v] = new
    ex.invalidate([v])
    counts3, unit3 = ex.run([ShardWork(0, a, b, held)], store)
    assert np.array_equal(counts3[0], oracle())  # fresh, not stale
    assert unit3.bytes_uploaded == new.size * 4  # only the one patch
    assert unit3.n_patches == 1


# --------------------------------------------------------------------------
# multi-device: the same property at p in {4, 8} on 8 host devices
# --------------------------------------------------------------------------
MULTIDEV_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json
import sys
sys.path.insert(0, {test_dir!r})
from test_spmd_runtime import _serving_agrees, _streaming_agrees

from test_spmd_runtime import (
    _serving_pipeline_agrees,
    _streaming_pipeline_agrees,
)

out = {{}}
for p in (4, 8):
    out[f"serving_p{{p}}"] = _serving_agrees(p, seed=0)
    out[f"streaming_p{{p}}"] = _streaming_agrees(p, seed=0)
    out[f"serving_p{{p}}_pipeline"] = _serving_pipeline_agrees(p, seed=0)
    out[f"streaming_p{{p}}_pipeline"] = _streaming_pipeline_agrees(p, seed=0)
out["serving_p4_seed1"] = _serving_agrees(4, seed=1)
out["streaming_p4_seed1"] = _streaming_agrees(4, seed=1)
out["serving_p4_device"] = _serving_agrees(4, seed=0, device_slots=32)
out["streaming_p4_device"] = _streaming_agrees(4, seed=0, device_slots=32)
out["serving_p4_device_per_rank"] = _serving_agrees(
    4, seed=0, device_slots=32, device_scope="per_rank"
)
print(json.dumps(out))
"""


def test_multidevice_loop_vs_spmd():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    script = MULTIDEV_SCRIPT.format(
        test_dir=os.path.dirname(os.path.abspath(__file__))
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert res and all(res.values()), res
