import numpy as np
import pytest

from repro.core.cache import (
    ClampiCache,
    NetworkModel,
    StaticDegreeCache,
    build_static_degree_cache,
)


def test_hit_miss_basics():
    c = ClampiCache(1024, 16)
    assert not c.get(1, 100)  # compulsory miss
    assert c.get(1, 100)  # hit
    assert c.stats.gets == 2 and c.stats.hits == 1
    assert c.stats.compulsory_misses == 1


def test_capacity_eviction_lru():
    c = ClampiCache(100, 100)
    c.get(1, 60)
    c.get(2, 60)  # must evict 1 (LRU)
    assert 2 in c.entries and 1 not in c.entries
    assert c.stats.evictions == 1
    assert not c.get(1, 60)  # capacity miss (seen before, not compulsory)
    assert c.stats.compulsory_misses == 2 and c.stats.misses == 3


def test_lru_order_respected():
    c = ClampiCache(100, 100)
    c.get(1, 40)
    c.get(2, 40)
    c.get(1, 40)  # touch 1 -> 2 is LRU
    c.get(3, 40)  # evicts 2
    assert 1 in c.entries and 3 in c.entries and 2 not in c.entries


def test_degree_score_protects_hubs():
    """Paper §III-B2: high-degree entries survive floods of low-degree ones."""
    c = ClampiCache(100, 100)
    c.get(99, 50, score=1000.0)  # hub
    for k in range(20):
        c.get(k, 30, score=1.0)  # low-degree flood
    assert 99 in c.entries, "hub must not be evicted by low-score entries"


def test_user_score_refuses_worse_entries():
    c = ClampiCache(100, 2)
    c.get(1, 40, score=10.0)
    c.get(2, 40, score=10.0)
    c.get(3, 40, score=1.0)  # lower score than every resident -> refused
    assert 3 not in c.entries and len(c.entries) == 2


def test_fragmentation_coalescing():
    c = ClampiCache(100, 100)
    c.get(1, 30)
    c.get(2, 30)
    c.get(3, 30)
    # evict middle by touching 1 and 3
    c.get(1, 30)
    c.get(3, 30)
    c.get(4, 40)  # needs eviction of 2 (LRU); 30+10 tail free -> must coalesce
    assert 4 in c.entries
    total_free = sum(s for _, s in c.free)
    assert total_free == 100 - c.used_bytes


def test_transparent_mode_flushes_on_epoch():
    c = ClampiCache(1024, 16, mode="transparent")
    c.get(1, 100)
    c.close_epoch()
    assert not c.get(1, 100)  # flushed
    c2 = ClampiCache(1024, 16, mode="always")
    c2.get(1, 100)
    c2.close_epoch()
    assert c2.get(1, 100)  # persists across epochs


def test_oversize_entry_not_cached():
    c = ClampiCache(50, 16)
    c.get(1, 100)
    assert 1 not in c.entries and c.stats.evictions == 0


def test_static_degree_cache():
    deg = np.array([1, 9, 3, 7, 5])
    sc = build_static_degree_cache(deg, 2)
    assert set(sc.vertex_ids.tolist()) == {1, 3}  # top-2 degrees
    slots = sc.slot_of(np.array([0, 1, 3, 4]))
    assert slots[0] == -1 and slots[3] == -1
    assert slots[1] >= 0 and slots[2] >= 0


def test_network_model():
    net = NetworkModel(alpha=2e-6, beta=1e-10)
    assert net.remote(0) == pytest.approx(2e-6)
    assert net.remote(10**6) == pytest.approx(2e-6 + 1e-4)
