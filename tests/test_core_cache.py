import numpy as np
import pytest

from repro.core.cache import (
    ClampiCache,
    NetworkModel,
    StaticDegreeCache,
    build_static_degree_cache,
)


def test_hit_miss_basics():
    c = ClampiCache(1024, 16)
    assert not c.get(1, 100)  # compulsory miss
    assert c.get(1, 100)  # hit
    assert c.stats.gets == 2 and c.stats.hits == 1
    assert c.stats.compulsory_misses == 1


def test_capacity_eviction_lru():
    c = ClampiCache(100, 100)
    c.get(1, 60)
    c.get(2, 60)  # must evict 1 (LRU)
    assert 2 in c.entries and 1 not in c.entries
    assert c.stats.evictions == 1
    assert not c.get(1, 60)  # capacity miss (seen before, not compulsory)
    assert c.stats.compulsory_misses == 2 and c.stats.misses == 3


def test_lru_order_respected():
    c = ClampiCache(100, 100)
    c.get(1, 40)
    c.get(2, 40)
    c.get(1, 40)  # touch 1 -> 2 is LRU
    c.get(3, 40)  # evicts 2
    assert 1 in c.entries and 3 in c.entries and 2 not in c.entries


def test_degree_score_protects_hubs():
    """Paper §III-B2: high-degree entries survive floods of low-degree ones."""
    c = ClampiCache(100, 100)
    c.get(99, 50, score=1000.0)  # hub
    for k in range(20):
        c.get(k, 30, score=1.0)  # low-degree flood
    assert 99 in c.entries, "hub must not be evicted by low-score entries"


def test_user_score_refuses_worse_entries():
    c = ClampiCache(100, 2)
    c.get(1, 40, score=10.0)
    c.get(2, 40, score=10.0)
    c.get(3, 40, score=1.0)  # lower score than every resident -> refused
    assert 3 not in c.entries and len(c.entries) == 2


def test_fragmentation_coalescing():
    c = ClampiCache(100, 100)
    c.get(1, 30)
    c.get(2, 30)
    c.get(3, 30)
    # evict middle by touching 1 and 3
    c.get(1, 30)
    c.get(3, 30)
    c.get(4, 40)  # needs eviction of 2 (LRU); 30+10 tail free -> must coalesce
    assert 4 in c.entries
    total_free = sum(s for _, s in c.free)
    assert total_free == 100 - c.used_bytes


def test_transparent_mode_flushes_on_epoch():
    c = ClampiCache(1024, 16, mode="transparent")
    c.get(1, 100)
    c.close_epoch()
    assert not c.get(1, 100)  # flushed
    c2 = ClampiCache(1024, 16, mode="always")
    c2.get(1, 100)
    c2.close_epoch()
    assert c2.get(1, 100)  # persists across epochs


def test_oversize_entry_not_cached():
    c = ClampiCache(50, 16)
    c.get(1, 100)
    assert 1 not in c.entries and c.stats.evictions == 0


def test_static_degree_cache():
    deg = np.array([1, 9, 3, 7, 5])
    sc = build_static_degree_cache(deg, 2)
    assert set(sc.vertex_ids.tolist()) == {1, 3}  # top-2 degrees
    slots = sc.slot_of(np.array([0, 1, 3, 4]))
    assert slots[0] == -1 and slots[3] == -1
    assert slots[1] >= 0 and slots[2] >= 0


def test_network_model():
    net = NetworkModel(alpha=2e-6, beta=1e-10)
    assert net.remote(0) == pytest.approx(2e-6)
    assert net.remote(10**6) == pytest.approx(2e-6 + 1e-4)


# ---------------------------------------------------------------------------
# Serving-path edge cases: fragmentation-aware victim selection under
# mixed entry sizes, adaptive table resize, invalidate x degree scores.
# ---------------------------------------------------------------------------
def _frag_scenario(weight):
    """Layout [A|B|C|D] of 25B each; B invalidated -> free hole [25,50).
    Ages at the deciding get: A=1, C=2, D=3 (D is the strict-LRU victim);
    A and C sit adjacent to the hole (positional gain 2), D does not."""
    c = ClampiCache(100, 100, positional_weight=weight)
    for k, size in ((1, 25), (2, 25), (3, 25), (4, 25)):
        c.get(k, size)
    assert c.invalidate(2)
    c.get(3, 25)  # touch C
    c.get(1, 25)  # touch A
    c.get(5, 50)  # needs 50 contiguous
    return c


def test_fragmentation_victim_mixed_sizes():
    """The positional bonus must steer eviction toward the entry whose
    removal coalesces with existing free space, sparing the strict-LRU
    victim when evicting it would NOT produce a usable hole."""
    c = _frag_scenario(weight=10.0)
    # evicting C merges [25,50)+[50,75) into one 50B hole: one eviction,
    # and the strict-LRU victim D survives.
    assert 5 in c.entries and 3 not in c.entries
    assert 4 in c.entries, "LRU victim must be spared by positional score"
    assert c.stats.evictions == 1
    assert sum(s for _, s in c.free) == 100 - c.used_bytes

    # control: positional_weight=0 degenerates to pure LRU — D goes
    # first (useless 25B hole at the tail), forcing a second eviction.
    c0 = _frag_scenario(weight=0.0)
    assert 5 in c0.entries and 4 not in c0.entries
    assert c0.stats.evictions == 2


def test_adaptive_resize_flushes():
    """§II-F adaptive heuristic: when evictions dominate, the table is
    grown and the cache flushed (so good initial sizing matters)."""
    c = ClampiCache(1 << 12, 2, adaptive=True)
    for k in range(40):
        c.get(k % 8, 64)
    assert c.table_slots > 2, "table must grow under slot-conflict churn"
    assert c.stats.flushes >= 1, "resize must flush (paper §II-F)"
    # flush empties residency but not history: misses keep accruing
    assert c.used_bytes <= c.capacity
    assert c.stats.evictions > 0


def test_adaptive_resize_not_triggered_when_sized_right():
    c = ClampiCache(1 << 12, 64, adaptive=True)
    for k in range(40):
        c.get(k % 8, 64)
    assert c.table_slots == 64 and c.stats.flushes == 0


def test_invalidate_interacts_with_degree_scores():
    """Invalidation must free both the table slot and the buffer space
    even for score-protected hubs, re-opening admission for entries the
    hub's score previously refused."""
    c = ClampiCache(100, 1)
    c.get(99, 60, score=1000.0)  # hub occupies the only slot
    c.get(1, 30, score=1.0)  # refused: scores worse than every victim
    assert 1 not in c.entries and 99 in c.entries
    assert c.invalidate(99)
    assert c.stats.invalidations == 1 and not c.entries
    assert c.free == [(0, 100)]  # buffer space reclaimed + coalesced
    c.get(1, 30, score=1.0)  # now admissible
    assert 1 in c.entries
    # re-read of the invalidated hub is a miss (coherence refetch) but
    # NOT a compulsory miss (it was seen before)
    misses0 = c.stats.misses
    comp0 = c.stats.compulsory_misses
    assert not c.get(99, 60, score=1000.0)
    assert c.stats.misses == misses0 + 1
    assert c.stats.compulsory_misses == comp0


def test_invalidate_many_counts():
    c = ClampiCache(1 << 10, 16)
    for k in range(4):
        c.get(k, 32)
    assert c.invalidate_many([0, 2, 7]) == 2  # 7 was never cached
    assert c.stats.invalidations == 2
    assert c.contains(1) and c.contains(3)
    assert not c.contains(0)
