import numpy as np
import networkx as nx
import jax.numpy as jnp
import pytest

from repro.core.csr import from_edges, to_padded_rows
from repro.core.triangles import (
    global_triangle_count,
    lcc_scores,
    triangles_per_vertex,
    triangles_padded_jnp,
    lcc_from_counts_jnp,
)
from conftest import random_graph, powerlaw_graph


def nx_of(csr):
    g = nx.Graph()
    g.add_nodes_from(range(csr.n))
    src, dst = csr.edge_list()
    g.add_edges_from(zip(src.tolist(), dst.tolist()))
    return g


@pytest.mark.parametrize("maker,seed", [
    (random_graph, 0), (random_graph, 1), (powerlaw_graph, 2),
])
def test_triangles_vs_networkx(maker, seed):
    csr = maker(120, 8, seed=seed)
    g = nx_of(csr)
    want = np.array([nx.triangles(g, v) for v in range(csr.n)])
    got = triangles_per_vertex(csr)
    assert np.array_equal(got, want)


def test_global_count_vs_networkx():
    csr = random_graph(100, 10, seed=5)
    g = nx_of(csr)
    want = sum(nx.triangles(g).values()) // 3
    assert global_triangle_count(csr) == want


def test_lcc_vs_networkx():
    csr = powerlaw_graph(150, 8, seed=7)
    g = nx_of(csr)
    want = np.array([nx.clustering(g, v) for v in range(csr.n)])
    got = lcc_scores(csr)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_upper_only_counts_each_triangle_once_per_edge():
    csr = random_graph(80, 8, seed=3)
    t_upper = triangles_per_vertex(csr, upper_only=True)
    # sum over vertices of upper-only per-edge counts = 3 * #triangles
    assert t_upper.sum() == 3 * global_triangle_count(csr)


@pytest.mark.parametrize("method", ["bsearch", "pairwise"])
def test_padded_jnp_path(method):
    csr = random_graph(90, 8, seed=11)
    rows = jnp.asarray(to_padded_rows(csr))
    deg = jnp.asarray(csr.degrees.astype(np.int32))
    t = triangles_padded_jnp(rows, deg, csr.n, method=method)
    want = triangles_per_vertex(csr)
    assert np.array_equal(np.asarray(t), want)
    lcc = lcc_from_counts_jnp(t, deg)
    np.testing.assert_allclose(np.asarray(lcc), lcc_scores(csr), rtol=1e-6)
