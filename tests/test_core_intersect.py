import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import intersect as it


def sorted_unique(rng, hi, k):
    return np.unique(rng.integers(0, hi, size=k))


@pytest.mark.parametrize("seed", range(5))
def test_scalar_methods_agree(seed):
    rng = np.random.default_rng(seed)
    a = sorted_unique(rng, 500, rng.integers(0, 80))
    b = sorted_unique(rng, 500, rng.integers(0, 200))
    want = len(np.intersect1d(a, b))
    assert it.ssi_scalar(a, b) == want
    assert it.binary_search_scalar(a, b) == want
    assert it.hybrid_scalar(a, b) == want
    assert it.count_bsearch_np(a, b) == want
    assert it.count_pairwise_np(a, b) == want


def test_eq3_rule():
    # balanced lists -> SSI; skewed -> binary search
    assert it.eq3_ssi_faster(100, 128)
    assert not it.eq3_ssi_faster(2, 4096)


def pad(a, w, sent):
    out = np.full(w, sent, np.int32)
    out[: len(a)] = a
    return out


@pytest.mark.parametrize("method", ["bsearch", "pairwise"])
def test_jnp_counts_match_oracle(method):
    rng = np.random.default_rng(42)
    sent = 1000
    wa, wb = 32, 64
    rows_a, rows_b, want = [], [], []
    for _ in range(50):
        a = sorted_unique(rng, sent, rng.integers(0, wa))
        b = sorted_unique(rng, sent, rng.integers(0, wb))
        rows_a.append(pad(a, wa, sent))
        rows_b.append(pad(b, wb, sent))
        want.append(len(np.intersect1d(a, b)))
    rows_a = jnp.asarray(np.stack(rows_a))
    rows_b = jnp.asarray(np.stack(rows_b))
    if method == "bsearch":
        got = it.count_bsearch_jnp(rows_a, rows_b, sent)
    else:
        got = it.count_pairwise_jnp(rows_a, rows_b, sent)
    assert np.array_equal(np.asarray(got), np.array(want))


def test_hybrid_jnp_matches():
    rng = np.random.default_rng(3)
    sent = 500
    w = 48
    rows_a, rows_b, want = [], [], []
    for _ in range(30):
        a = sorted_unique(rng, sent, rng.integers(1, w))
        b = sorted_unique(rng, sent, rng.integers(1, w))
        rows_a.append(pad(a, w, sent))
        rows_b.append(pad(b, w, sent))
        want.append(len(np.intersect1d(a, b)))
    got = it.count_hybrid_jnp(
        jnp.asarray(np.stack(rows_a)),
        jnp.asarray(np.stack(rows_b)),
        jnp.asarray([int((r < sent).sum()) for r in rows_a]),
        jnp.asarray([int((r < sent).sum()) for r in rows_b]),
        sent,
    )
    assert np.array_equal(np.asarray(got), np.array(want))


def test_bitmap_count():
    from repro.core.csr import rows_to_bitmap_words

    rng = np.random.default_rng(9)
    sent = 256
    rows_a, rows_b, want = [], [], []
    for _ in range(20):
        a = sorted_unique(rng, sent, 30)
        b = sorted_unique(rng, sent, 50)
        rows_a.append(pad(a, 40, sent))
        rows_b.append(pad(b, 64, sent))
        want.append(len(np.intersect1d(a, b)))
    wa = rows_to_bitmap_words(np.stack(rows_a), sent)
    wb = rows_to_bitmap_words(np.stack(rows_b), sent)
    got = it.count_bitmap_jnp(jnp.asarray(wa), jnp.asarray(wb))
    assert np.array_equal(np.asarray(got), np.array(want))
