"""MoE dispatch correctness: dense capacity dispatch vs oracle, and the
shard_map local-EP path vs the dense path on 8 host devices."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.moe import moe_apply, moe_init

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def oracle(p, x, e, k):
    """No-capacity oracle: every token runs its top-k experts."""
    logits = np.asarray(x, np.float32) @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :k]
    out = np.zeros_like(np.asarray(x, np.float32))
    for t in range(x.shape[0]):
        gates = probs[t, top[t]]
        gates = gates / gates.sum()
        for g, ei in zip(gates, top[t]):
            xi = np.asarray(x[t], np.float32)
            h = (xi @ np.asarray(p["w_gate"][ei], np.float32))
            h = h / (1 + np.exp(-h)) * (xi @ np.asarray(p["w_up"][ei], np.float32))
            out[t] += g * (h @ np.asarray(p["w_down"][ei], np.float32))
    return out


def test_moe_dense_matches_oracle_no_drops():
    rng = np.random.default_rng(0)
    t, d, f, e, k = 32, 16, 32, 4, 2
    p = moe_init(jax.random.key(0), d, f, e, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
    # capacity factor big enough that nothing drops
    y = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=float(e))
    want = oracle(p, x, e, k)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_bounded():
    """With tiny capacity, outputs are a subset (dropped tokens -> 0)."""
    rng = np.random.default_rng(1)
    t, d, f, e, k = 64, 8, 16, 4, 1
    p = moe_init(jax.random.key(1), d, f, e, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
    y_small = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=0.25)
    y_big = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=float(e))
    zeroed = np.where(np.abs(np.asarray(y_small)).sum(-1) < 1e-9)[0]
    assert len(zeroed) > 0, "tiny capacity must drop some tokens"
    kept = np.where(np.abs(np.asarray(y_small)).sum(-1) >= 1e-9)[0]
    np.testing.assert_allclose(
        np.asarray(y_small)[kept], np.asarray(y_big)[kept],
        rtol=2e-4, atol=2e-4,
    )


LOCAL_EP_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.models.moe import moe_apply, moe_apply_local_ep, moe_init
from repro.models.transformer import AxisRules

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
t, d, f, e, k = 64, 16, 32, 8, 2
p = moe_init(jax.random.key(0), d, f, e, dtype=jnp.float32)
x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
rules = AxisRules(data=("data",), model=("model",), mesh=mesh)

with mesh:
    dense = jax.jit(lambda p_, x_: moe_apply(
        p_, x_, n_experts=e, top_k=k, capacity_factor=float(e)))(p, x)
    lep = jax.jit(lambda p_, x_: moe_apply_local_ep(
        p_, x_, n_experts=e, top_k=k, capacity_factor=float(e),
        rules=rules, mesh=mesh))(p, x)
    # grads must also agree
    def loss_dense(p_):
        return jnp.sum(moe_apply(p_, x, n_experts=e, top_k=k,
                                 capacity_factor=float(e)) ** 2)
    def loss_lep(p_):
        return jnp.sum(moe_apply_local_ep(p_, x, n_experts=e, top_k=k,
                                          capacity_factor=float(e),
                                          rules=rules, mesh=mesh) ** 2)
    gd = jax.jit(jax.grad(loss_dense))(p)
    gl = jax.jit(jax.grad(loss_lep))(p)

ok_fwd = bool(np.allclose(np.asarray(dense), np.asarray(lep),
                          rtol=1e-4, atol=1e-4))
errs = {kk: float(np.abs(np.asarray(gd[kk]) - np.asarray(gl[kk])).max())
        for kk in gd}
ok_bwd = all(v < 1e-3 for v in errs.values())
print(json.dumps({"ok_fwd": ok_fwd, "ok_bwd": ok_bwd, "errs": errs}))
"""


def test_local_ep_matches_dense_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", LOCAL_EP_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert res["ok_fwd"], res
    assert res["ok_bwd"], res
