import numpy as np
import pytest

from repro.core.csr import (
    from_edges,
    random_relabel,
    remove_low_degree,
    rows_to_bitmap_words,
    to_padded_rows,
)
from conftest import random_graph


def test_from_edges_simple():
    e = np.array([[0, 1], [1, 2], [2, 0], [0, 0], [1, 2]])  # loop + dup
    g = from_edges(e, 3, undirected=True)
    assert g.n == 3 and g.m == 6
    assert list(g.row(0)) == [1, 2]
    assert list(g.row(1)) == [0, 2]
    assert list(g.row(2)) == [0, 1]


def test_rows_sorted_dedup():
    g = random_graph(200, 10, seed=1)
    for v in range(g.n):
        r = g.row(v)
        assert np.all(np.diff(r) > 0), "rows must be sorted strictly"
        assert v not in r, "no self loops"


def test_remove_low_degree():
    # path graph 0-1-2 plus isolated 3: ends have degree 1
    e = np.array([[0, 1], [1, 2]])
    g = from_edges(e, 4, undirected=True)
    g2, keep = remove_low_degree(g)
    assert g2.n == 1 and keep.tolist() == [1]
    assert g2.m == 0  # neighbors of 1 were removed


def test_random_relabel_preserves_structure():
    g = random_graph(150, 8, seed=2)
    g2 = random_relabel(g, seed=7)
    assert g2.n == g.n and g2.m == g.m
    assert np.array_equal(np.sort(g.degrees), np.sort(g2.degrees))
    for v in range(g2.n):
        r = g2.row(v)
        assert np.all(np.diff(r) > 0)


def test_padded_rows_sentinel():
    g = random_graph(64, 6, seed=3)
    w = g.max_degree + 3
    rows = to_padded_rows(g, w)
    assert rows.shape == (64, w)
    for v in range(g.n):
        d = g.degrees[v]
        assert np.array_equal(rows[v, :d], g.row(v))
        assert np.all(rows[v, d:] == g.n)
        assert np.all(np.diff(rows[v]) >= 0)  # stays sorted with sentinel


def test_bitmap_words_roundtrip():
    rows = np.array([[1, 5, 33, 64, 100], [0, 2, 3, 100, 100]], np.int32)
    words = rows_to_bitmap_words(rows, 100)  # ids >= 100 dropped
    assert words.shape == (2, 4)
    got0 = {w * 32 + b for w in range(4) for b in range(32) if words[0, w] >> b & 1}
    assert got0 == {1, 5, 33, 64}
    got1 = {w * 32 + b for w in range(4) for b in range(32) if words[1, w] >> b & 1}
    assert got1 == {0, 2, 3}
