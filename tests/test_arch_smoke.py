"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (the assignment's requirement
for each of the 10 assigned archs)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_arch, list_archs
from repro.configs.inputs import make_smoke_batch
from repro.train.optimizer import adamw
from repro.train import train_loop as tl

LM_ARCHS = ["moonshot-v1-16b-a3b", "phi3.5-moe-42b-a6.6b", "stablelm-1.6b",
            "gemma2-27b", "qwen2.5-14b"]
GNN_ARCHS = ["mace", "pna", "gin-tu", "gat-cora"]

rng = np.random.default_rng(0)


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))), "NaN/Inf"


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_train_step(arch_id):
    from repro.models import transformer as tfm

    cfg, batch = make_smoke_batch(arch_id, "lm_train", rng)
    params = tfm.init_params(cfg, jax.random.key(0))
    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(tl.make_lm_train_step(cfg, opt))
    params, opt_state, metrics = step(params, opt_state,
                                      {k: jnp.asarray(v) for k, v in batch.items()})
    assert np.isfinite(float(metrics["loss"]))
    _finite(params)
    # loss should be near log(vocab) at init
    assert float(metrics["loss"]) < np.log(cfg.vocab) + 2.0


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_prefill_decode(arch_id):
    from repro.models import transformer as tfm

    cfg, batch = make_smoke_batch(arch_id, "lm_prefill", rng)
    params = tfm.init_params(cfg, jax.random.key(1))
    tokens = jnp.asarray(batch["tokens"])
    b, s = tokens.shape
    max_len = s + 8
    prefill = jax.jit(tl.make_lm_prefill_step(cfg, max_len=max_len))
    logits, cache = prefill(params, tokens)
    assert logits.shape == (b, cfg.vocab)
    _finite(logits)
    decode = jax.jit(tl.make_lm_decode_step(cfg))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = decode(params, nxt, jnp.int32(s), cache)
    assert logits2.shape == (b, cfg.vocab)
    _finite(logits2)


def test_lm_decode_matches_train_logits():
    """Greedy decode logits == teacher-forced logits at the same positions
    (pins KV-cache correctness, incl. gemma2's local/global ring cache)."""
    from repro.models import transformer as tfm

    for arch_id in ["gemma2-27b", "qwen2.5-14b"]:
        cfg, batch = make_smoke_batch(arch_id, "lm_train", rng)
        cfg_nr = cfg  # remat already off in smoke
        params = tfm.init_params(cfg_nr, jax.random.key(2))
        tokens = jnp.asarray(batch["tokens"])[:2, :16]
        full = tfm.forward_train(params, tokens, cfg_nr)
        # prefill on the first 8, decode tokens 8..15 one by one
        logits, cache = tfm.forward_prefill(
            params, tokens[:, :8], cfg_nr, max_len=16
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, 7]), rtol=2e-2, atol=2e-2
        )
        for t in range(8, 16):
            logits, cache = tfm.forward_decode(
                params, tokens[:, t], jnp.int32(t), cache, cfg_nr
            )
            if t < 15:
                np.testing.assert_allclose(
                    np.asarray(logits), np.asarray(full[:, t]),
                    rtol=2e-2, atol=2e-2,
                )


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_train_step(arch_id):
    arch = get_arch(arch_id)
    cfg, batch = make_smoke_batch(arch_id, "gnn_train", rng)
    mod = {
        "mace": "repro.models.gnn.mace",
        "pna": "repro.models.gnn.pna",
        "gin-tu": "repro.models.gnn.gin",
        "gat-cora": "repro.models.gnn.gat",
    }[arch_id]
    import importlib

    m = importlib.import_module(mod)
    params = m.init_params(cfg, jax.random.key(0))
    opt = adamw(lr=1e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    step = jax.jit(tl.make_gnn_train_step(m.apply, cfg, opt),
                   static_argnames=())
    jb = {k: (jnp.asarray(v) if not np.isscalar(v) else v)
          for k, v in batch.items()}
    params, opt_state, metrics = step(params, opt_state, jb)
    assert np.isfinite(float(metrics["loss"]))
    _finite(params)


def test_din_train_and_serve():
    from repro.models.recsys import din

    cfg, batch = make_smoke_batch("din", "recsys_train", rng)
    params = din.init_params(cfg, jax.random.key(0))
    opt = adamw(lr=1e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    step = jax.jit(tl.make_recsys_train_step(din.apply, cfg, opt))
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt_state, metrics = step(params, opt_state, jb)
    assert np.isfinite(float(metrics["loss"]))
    serve = jax.jit(tl.make_recsys_serve_step(din.apply, cfg))
    probs = serve(params, jb)
    assert probs.shape == (batch["label"].shape[0],)
    assert np.all((np.asarray(probs) >= 0) & (np.asarray(probs) <= 1))


def test_din_retrieval():
    from repro.models.recsys import din

    cfg, batch = make_smoke_batch("din", "retrieval", rng)
    params = din.init_params(cfg, jax.random.key(0))
    step = jax.jit(tl.make_retrieval_step(din.retrieval_score, cfg, top_k=10))
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    vals, idx = step(params, jb)
    assert vals.shape == (10,) and idx.shape == (10,)
    assert np.all(np.diff(np.asarray(vals)) <= 1e-6)  # sorted desc


def test_all_assigned_archs_registered():
    assert set(list_archs(assigned_only=True)) == set(LM_ARCHS) | set(
        GNN_ARCHS
    ) | {"din"}
