"""Streaming subsystem correctness: after ANY sequence of insert/delete
batches, incremental triangle counts and LCC must exactly match a
from-scratch recount on the compacted graph.

Property-style via seeded randomized trials (no hypothesis dependency —
the tier-1 suite must run on the base image). Covers duplicate edges,
delete-of-nonexistent, insert-of-existing, delete+reinsert in one batch,
empty batches, compaction, the kernel vs mask cross-check, and the cache
coherence hooks.
"""
import numpy as np
import pytest

from conftest import powerlaw_graph

from repro.core.cache import (
    ClampiCache,
    build_static_degree_cache,
    refresh_static_degree_cache,
)
from repro.core.csr import CSRGraph, from_edges
from repro.core.triangles import lcc_scores, triangles_per_vertex
from repro.graphs.rmat import rmat_stream
from repro.kernels.delta_intersect import (
    delta_intersect_counts,
    delta_intersect_masks,
)
from repro.streaming import (
    DynamicCSR,
    EdgeBatch,
    StreamingCacheCoherence,
    StreamingLCCEngine,
    normalize_batch,
)


def _random_batch(rng, n, size, p_delete=0.3):
    e = rng.integers(0, n, size=(size, 2))
    op = np.where(rng.random(size) < p_delete, -1, 1).astype(np.int8)
    return EdgeBatch(u=e[:, 0], v=e[:, 1], op=op)


# ---------------------------------------------------------------------------
# DynamicCSR store
# ---------------------------------------------------------------------------
def test_dynamic_csr_matches_edge_set_reference():
    """Store vs a naive set-of-edges reference over random ops."""
    rng = np.random.default_rng(0)
    n = 40
    store = DynamicCSR.empty(n)
    ref = set()
    for _ in range(300):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u == v:
            continue
        lo, hi = min(u, v), max(u, v)
        if rng.random() < 0.6:
            if (lo, hi) not in ref:
                store.insert_edges(np.array([[lo, hi]]))
                ref.add((lo, hi))
        elif (lo, hi) in ref:
            store.delete_edges(np.array([[lo, hi]]))
            ref.discard((lo, hi))
        if rng.random() < 0.05:
            store.compact()
    for v in range(n):
        want = sorted(b for a, b in ref if a == v) + sorted(
            a for a, b in ref if b == v
        )
        assert store.row(v).tolist() == sorted(want)
        assert store.degree(v) == len(want)
    assert store.m == 2 * len(ref)
    csr = store.to_csr()
    assert np.array_equal(csr.degrees, store.degrees)


def test_dynamic_csr_compaction_invariant():
    rng = np.random.default_rng(1)
    base = powerlaw_graph(50, 4, seed=1)
    store = DynamicCSR.from_csr(base, compact_threshold=0.05)
    for _ in range(10):
        ins, dele, _ = normalize_batch(_random_batch(rng, 50, 30), store)
        rows_before = [store.row(v).copy() for v in range(store.n)]
        if dele.size:
            store.delete_edges(dele)
        if ins.size:
            store.insert_edges(ins)
        del rows_before
        snap = store.to_csr()
        compacted = store.maybe_compact()
        if compacted:
            assert not store._added and not store._removed
        for v in range(store.n):
            assert np.array_equal(store.row(v), snap.row(v))


def test_delta_accounting_cancels_on_churn():
    """Insert-then-delete the same edges must not accumulate phantom
    delta (which would trigger pointless compactions)."""
    store = DynamicCSR.empty(20)
    edges = np.array([[0, 1], [2, 3], [4, 5]], np.int64)
    store.insert_edges(edges)
    assert store.delta_edges == 6
    store.delete_edges(edges)
    assert store.delta_edges == 0
    assert not store.maybe_compact()
    # same for base edges: delete then re-insert cancels
    store.insert_edges(edges)
    store.compact()
    store.delete_edges(edges[:1])
    assert store.delta_edges == 2
    store.insert_edges(edges[:1])
    assert store.delta_edges == 0


def test_padded_rows_match_static_layout():
    base = powerlaw_graph(30, 4, seed=2)
    store = DynamicCSR.from_csr(base)
    from repro.core.csr import to_padded_rows

    w = base.max_degree
    want = to_padded_rows(base, w, sentinel=base.n)
    got = store.padded_rows(range(base.n), w, sentinel=base.n)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# delta-intersect kernel wrapper
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e,wa,wb", [(1, 4, 4), (7, 16, 8), (130, 12, 40)])
def test_delta_intersect_matches_numpy(e, wa, wb):
    rng = np.random.default_rng(3)
    sent = 512

    def rows(k, w):
        out = np.full((k, w), sent, np.int32)
        for i in range(k):
            vals = np.unique(rng.integers(0, sent, size=rng.integers(0, w + 1)))
            out[i, : vals.size] = vals
        return out

    a, b = rows(e, wa), rows(e, wb)
    cnt = delta_intersect_counts(a, b, sentinel=sent, interpret=True)
    mask = delta_intersect_masks(a, b, sentinel=sent)
    want = np.array(
        [np.intersect1d(a[i][a[i] < sent], b[i][b[i] < sent]).size
         for i in range(e)],
        np.int64,
    )
    assert np.array_equal(cnt, want)
    assert np.array_equal(mask.sum(1), want)
    # mask identifies exactly the common elements
    for i in range(e):
        got_ids = np.sort(a[i][mask[i]])
        want_ids = np.intersect1d(a[i][a[i] < sent], b[i][b[i] < sent])
        assert np.array_equal(got_ids, want_ids)


def test_delta_intersect_empty_batch():
    z = np.zeros((0, 8), np.int32)
    assert delta_intersect_counts(z, z, sentinel=16).shape == (0,)
    assert delta_intersect_masks(z, z, sentinel=16).shape == (0, 8)


# ---------------------------------------------------------------------------
# incremental engine == from-scratch recount (the core property)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_matches_recount_random_stream(seed):
    rng = np.random.default_rng(seed)
    n = 48
    eng = StreamingLCCEngine.empty(n, interpret=True)
    for _ in range(10):
        eng.apply_batch(_random_batch(rng, n, 36, p_delete=0.35))
        eng.verify()  # bit-exact T and LCC vs recount
    assert eng.triangle_count >= 0


def test_incremental_from_nonempty_seed_graph():
    rng = np.random.default_rng(7)
    base = powerlaw_graph(64, 6, seed=3)
    eng = StreamingLCCEngine(base, interpret=True)
    assert np.array_equal(eng.t, triangles_per_vertex(base))
    for _ in range(6):
        eng.apply_batch(_random_batch(rng, 64, 48, p_delete=0.4))
        eng.verify()


def test_duplicate_and_noop_edge_cases():
    n = 16
    eng = StreamingLCCEngine.empty(n, interpret=True)
    # duplicate inserts of the same edge in one batch -> one edge
    b = EdgeBatch(u=[1, 1, 2, 3], v=[2, 2, 1, 3], op=[1, 1, 1, 1])
    res = eng.apply_batch(b)
    assert res.n_inserted == 1 and res.n_noop == 3  # dup, reversed-dup, loop
    eng.verify()
    # delete nonexistent + insert existing are no-ops
    res = eng.apply_batch(EdgeBatch(u=[5, 1], v=[9, 2], op=[-1, 1]))
    assert res.n_inserted == 0 and res.n_deleted == 0 and res.n_noop == 2
    eng.verify()
    # insert+delete of the same edge in one batch: last op wins
    res = eng.apply_batch(EdgeBatch(u=[4, 4], v=[6, 6], op=[1, -1]))
    assert res.n_inserted == 0 and res.n_deleted == 0
    res = eng.apply_batch(EdgeBatch(u=[1, 1], v=[2, 2], op=[-1, 1]))
    assert res.n_inserted == 0 and res.n_deleted == 0  # present, net keep
    eng.verify()
    # empty batch
    res = eng.apply_batch(EdgeBatch(u=[], v=[], op=[]))
    assert res.d_triangles == 0
    eng.verify()


def test_delete_then_reinsert_restores_counts():
    base = powerlaw_graph(40, 5, seed=5)
    eng = StreamingLCCEngine(base, interpret=True, auto_compact=False)
    t0, lcc0 = eng.t.copy(), eng.lcc.copy()
    src, dst = base.edge_list()
    keep = src < dst
    edges = np.stack([src[keep], dst[keep]], 1)[:20].astype(np.int64)
    eng.apply_batch(EdgeBatch.deletes(edges))
    eng.verify()
    eng.apply_batch(EdgeBatch.inserts(edges))
    eng.verify()
    assert np.array_equal(eng.t, t0)
    assert np.array_equal(eng.lcc, lcc0)


def test_triangle_delta_known_case():
    eng = StreamingLCCEngine.empty(8, interpret=True)
    eng.apply_batch(EdgeBatch.inserts([[0, 1], [1, 2]]))
    assert eng.triangle_count == 0
    res = eng.apply_batch(EdgeBatch.inserts([[0, 2]]))  # closes the wedge
    assert res.d_triangles == 1 and eng.triangle_count == 1
    # one batch containing a full new triangle among fresh vertices
    res = eng.apply_batch(EdgeBatch.inserts([[4, 5], [5, 6], [4, 6]]))
    assert res.d_triangles == 1 and eng.triangle_count == 2
    res = eng.apply_batch(EdgeBatch.deletes([[5, 6]]))
    assert res.d_triangles == -1 and eng.triangle_count == 1
    eng.verify()


def test_rmat_stream_replay_with_compaction():
    eng = StreamingLCCEngine.empty(1 << 7, interpret=True,
                                   compact_threshold=0.1)
    for batch in rmat_stream(7, 4, batch_size=128, delete_frac=0.25, seed=4):
        eng.apply_batch(batch)
    assert eng.store.n_compactions > 0
    eng.verify()


def test_no_kernel_path_matches():
    """use_kernel=False (mask-only) must agree with the kernel path."""
    rng = np.random.default_rng(11)
    n = 32
    e1 = StreamingLCCEngine.empty(n, use_kernel=True, interpret=True)
    e2 = StreamingLCCEngine.empty(n, use_kernel=False)
    for _ in range(5):
        b = _random_batch(rng, n, 24)
        e1.apply_batch(b)
        e2.apply_batch(b)
    assert np.array_equal(e1.t, e2.t)
    assert np.array_equal(e1.lcc, e2.lcc)


# ---------------------------------------------------------------------------
# cache coherence
# ---------------------------------------------------------------------------
def test_clampi_invalidate():
    c = ClampiCache(1 << 12, 64)
    assert not c.get(7, 100)  # miss, cached
    assert c.get(7, 100)  # hit
    assert c.invalidate(7)
    assert not c.invalidate(7)  # already gone
    assert not c.get(7, 100)  # stale copy dropped -> miss again
    assert c.stats.invalidations == 1


def test_static_cache_refresh_rescores_on_drift():
    deg = np.array([10, 9, 8, 1, 1, 1], np.int64)
    cache = build_static_degree_cache(deg, 3)
    assert set(cache.vertex_ids) == {0, 1, 2}
    # vertex 5's degree surges past every resident
    deg2 = deg.copy()
    deg2[5] = 50
    ref = refresh_static_degree_cache(cache, deg2, np.array([5]))
    assert ref.rebuilt and 5 in set(ref.cache.vertex_ids)
    assert ref.evicted == 1 and ref.admitted == 1
    # a changed resident is stale even without ranking drift
    ref2 = refresh_static_degree_cache(ref.cache, deg2, np.array([0]))
    assert ref2.stale_rows == 1 and not ref2.rebuilt


def test_coherence_replay_counts():
    rng = np.random.default_rng(13)
    n = 64
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=4, cache_rows=8, clampi_bytes=1 << 12
    )
    eng = StreamingLCCEngine.empty(n, interpret=True, coherence=coh)
    for _ in range(6):
        eng.apply_batch(_random_batch(rng, n, 48, p_delete=0.2))
    rep = coh.report
    assert rep.remote_reads > 0
    assert rep.remote_reads + rep.local_reads == 2 * eng.n_updates
    assert 0.0 <= rep.hit_rate <= 1.0
    assert rep.invalidations <= coh.clampi.stats.misses  # only cached rows
    eng.verify()  # coherence layer must not perturb exactness


# ---------------------------------------------------------------------------
# sharded streaming over the runtime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [1, 4])
def test_sharded_engine_bit_exact(p):
    """Worklist sharding by owner rank must not change a single bit of
    T or LCC at any p (integer scatter-adds commute across shards)."""
    from repro.core.runtime import ShardedRuntime

    rng = np.random.default_rng(17)
    n = 64
    base = powerlaw_graph(n, 5, seed=17)
    ref = StreamingLCCEngine(base, interpret=True)  # unsharded reference
    eng = StreamingLCCEngine(
        base,
        interpret=True,
        runtime=ShardedRuntime(n=n, p=p, uncached=True),
    )
    for _ in range(6):
        b = _random_batch(rng, n, 40, p_delete=0.3)
        ref.apply_batch(b)
        eng.apply_batch(b)
        assert np.array_equal(eng.t, ref.t)
        assert np.array_equal(eng.lcc, ref.lcc)
        eng.verify()
    if p > 1:
        # the worklist really was split across ranks
        assert np.count_nonzero(eng.shard_pairs) > 1
    assert eng.shard_pairs.sum() == eng.delta_pairs_total


def test_engine_adopts_coherence_runtime():
    """Passing a StreamingCacheCoherence wires the engine onto the SAME
    runtime (one partition, one set of caches — no duplicate wiring)."""
    n = 48
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=4, cache_rows=8, clampi_bytes=1 << 12
    )
    eng = StreamingLCCEngine.empty(n, interpret=True, coherence=coh)
    assert eng.runtime is coh.runtime
    assert eng.runtime.store is eng.store  # bound on attach
    rng = np.random.default_rng(5)
    for _ in range(3):
        eng.apply_batch(_random_batch(rng, n, 32))
    eng.verify()
    assert eng.shard_pairs.sum() == eng.delta_pairs_total


# ---------------------------------------------------------------------------
# adversarial hub-targeted churn
# ---------------------------------------------------------------------------
def test_adversarial_churn_stresses_drift_rebuilds():
    """Hub-targeted deletes are the worst case for degree-scored
    residency: they must (a) keep the engine exact, (b) force top-C
    membership drift rebuilds, and (c) actually hit hubs (deleted
    endpoints skew far above the mean degree)."""
    from repro.graphs.rmat import rmat_adversarial_stream

    scale, ef = 8, 4
    n = 1 << scale
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=4, cache_rows=16,
        clampi_bytes=1 << 14, rebuild_fraction=0.05,
    )
    eng = StreamingLCCEngine.empty(n, interpret=True, coherence=coh)
    del_deg = []
    for batch in rmat_adversarial_stream(
        scale, ef, batch_size=256, delete_frac=0.3, seed=2
    ):
        dels = batch.op == -1
        if dels.any():
            deg = eng.store.degrees
            del_deg.append(float(np.mean(
                deg[np.concatenate([batch.u[dels], batch.v[dels]])]
            )))
        eng.apply_batch(batch)
    eng.verify()  # exactness survives the adversarial stream
    rep = coh.report
    assert rep.static_rebuilds > 0, "hub churn must force residency rebuilds"
    assert rep.static_stale_rows > 0  # resident rows were mutated in place
    mean_deg = float(eng.store.degrees.mean())
    assert np.mean(del_deg) > 2 * mean_deg, "deletes must target hubs"
