"""Cache-science observability: access-trace recording, Mattson
reuse-distance analytics, eviction audit, and offline policy replay.

The load-bearing invariant everywhere: replaying the recorded stream
under the *deployed* policy must reproduce the live ``CacheStats``
deltas bit-exactly, on both tiers, warm or cold, with or without
invalidations — otherwise every derived curve is fiction."""
import dataclasses
import json

import numpy as np
import pytest

from conftest import powerlaw_graph, random_graph

from repro.core.cache import (
    CacheStats,
    ClampiCache,
    merge_cache_stats,
)
from repro.core.runtime import ShardedRuntime
from repro.obs import cachescope
from repro.obs.validate import validate_cachescope
from repro.streaming import DynamicCSR


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    yield
    cachescope.disable_recording()


def _zipf_ids(n, k, seed=0, a=1.3):
    r = np.random.default_rng(seed)
    ids = r.zipf(a, size=k) - 1
    return np.minimum(ids, n - 1)


def _runtime(p=4, n=120, seed=0, **kw):
    csr = powerlaw_graph(n, 6, seed=seed)
    store = DynamicCSR.from_csr(csr)
    return ShardedRuntime(store, p, cache_bytes=1 << 12, **kw), store


def _drive(rt, store, seed=1, rounds=3, invalidate=True):
    r = np.random.default_rng(seed)
    for it in range(rounds):
        for rank in range(rt.p):
            ids = _zipf_ids(store.n, 150, seed=seed + 7 * it + rank)
            rt.fetch_rows(rank, ids)
        if invalidate:
            rt.invalidate(r.integers(0, store.n, size=10))


def _assert_host_reconciles(stream):
    live = stream.live_delta()
    rep = cachescope.replay_host(stream, policy="deployed")
    for k in cachescope.HOST_COMPARE:
        assert live[k] == rep[k], (
            f"{stream.label} r{stream.rank}: {k} live={live[k]} "
            f"replay={rep[k]}")


# ---------------------------------------------------------------------------
# recording: disabled path, exemption, event capture
# ---------------------------------------------------------------------------
def test_disabled_recording_records_nothing():
    assert cachescope.get_recorder() is None
    assert not cachescope.recording_enabled()
    c = ClampiCache(1 << 10, 16)
    c.get(1, 100)
    c.get(1, 100)
    c.invalidate(1)
    # nothing blows up and nothing is retained anywhere
    assert cachescope.get_recorder() is None


def test_replay_caches_are_exempt_from_recording():
    rec = cachescope.enable_recording()
    c = ClampiCache(1 << 10, 16)
    for k in (1, 2, 3, 1):
        c.get(k, 64)
    streams = rec.host_streams()
    assert len(streams) == 1
    # replaying while recording is still on must not register new streams
    cachescope.replay_host(streams[0], policy="deployed")
    assert len(rec.host_streams()) == 1


# ---------------------------------------------------------------------------
# the reconciliation property, host tier
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [1, 4])
@pytest.mark.parametrize("graph", ["powerlaw", "rmat_like"])
def test_host_replay_reconciles_bit_exactly(p, graph):
    if graph == "powerlaw":
        csr = powerlaw_graph(150, 6, seed=2)
    else:
        csr = random_graph(150, 8, seed=3)
    store = DynamicCSR.from_csr(csr)
    rt = ShardedRuntime(store, p, cache_bytes=1 << 12)
    rec = cachescope.enable_recording()
    _drive(rt, store, seed=p, invalidate=True)
    if p == 1:
        # single rank: every read is local, so the runtime never touches
        # its cache — drive the rank's ClampiCache directly instead
        r = np.random.default_rng(11)
        for k in _zipf_ids(store.n, 400, seed=12):
            rt.caches[0].get(int(k), int(8 * (1 + k % 9)),
                             score=float(k))
        for k in r.integers(0, store.n, size=15):
            rt.caches[0].invalidate(int(k))
    cachescope.disable_recording()
    streams = rec.host_streams()
    assert streams, "no host streams recorded"
    for s in streams:
        _assert_host_reconciles(s)


@pytest.mark.parametrize("policy", ["lru", "lru_positional", "degree",
                                    "ewma"])
def test_alternate_policies_replay_cleanly(policy):
    rt, store = _runtime(p=2)
    rec = cachescope.enable_recording()
    _drive(rt, store)
    cachescope.disable_recording()
    for s in rec.host_streams():
        rep = cachescope.replay_host(s, policy=policy)
        assert rep["gets"] == s.live_delta()["gets"]  # same access stream
        assert rep["hits"] + rep["misses"] == rep["gets"]


def test_warm_start_recording_reconciles():
    """Recording may begin mid-life: the preload snapshot restores the
    cache's entries/clock/free-list so the replay starts warm."""
    rt, store = _runtime(p=2)
    _drive(rt, store, seed=5, rounds=2)          # un-recorded prefix
    rec = cachescope.enable_recording()
    _drive(rt, store, seed=9, rounds=2)          # recorded suffix
    cachescope.disable_recording()
    streams = rec.host_streams()
    assert streams
    for s in streams:
        assert s.preload["entries"], "warm stream should carry a preload"
        _assert_host_reconciles(s)


def test_epoch_flush_events_replay():
    """Transparent-mode caches flush on close_epoch; the events must be
    recorded so replays cross epoch boundaries in lockstep."""
    rec = cachescope.enable_recording()
    c = ClampiCache(1 << 9, 16, mode="transparent")
    for k in (1, 2, 3, 1, 2):
        c.get(k, 64)
    c.close_epoch()
    for k in (1, 2, 4):
        c.get(k, 64)
    c.flush()
    c.get(1, 64)
    cachescope.disable_recording()
    (s,) = rec.host_streams()
    assert "c" in s.kinds and "f" in s.kinds
    _assert_host_reconciles(s)


# ---------------------------------------------------------------------------
# the reconciliation property, device tier
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [1, 4])
def test_device_replay_reconciles_bit_exactly(p):
    rt, store = _runtime(p=p)
    rec = cachescope.enable_recording()
    rt.enable_device_tier(8)
    _drive(rt, store, invalidate=True)
    cachescope.disable_recording()
    dstreams = rec.device_streams()
    assert dstreams, "no device streams recorded"
    for s in dstreams:
        live = s.live_delta()
        rep = cachescope.replay_device(s)
        for k in cachescope.DEVICE_COMPARE:
            assert live[k] == rep[k], f"{k}: {live[k]} != {rep[k]}"


# ---------------------------------------------------------------------------
# Mattson stack distances vs direct simulation
# ---------------------------------------------------------------------------
def _invalidation_free_stream(seed=0, n_keys=40, n_gets=600):
    rec = cachescope.enable_recording()
    c = ClampiCache(1 << 11, 64)
    r = np.random.default_rng(seed)
    keys = _zipf_ids(n_keys, n_gets, seed=seed)
    sizes = 16 + 8 * (np.arange(n_keys) % 7)
    for k in keys:
        c.get(int(k), int(sizes[k]))
    cachescope.disable_recording()
    (s,) = rec.host_streams()
    return s


def test_mattson_matches_direct_lru_simulation():
    s = _invalidation_free_stream()
    d = cachescope.reuse_distances(s)
    assert not d["had_invalidations"]
    lo = d["max_entry_bytes"]
    caps = [lo, 2 * lo, 4 * lo, 16 * lo, 1 << 20]
    assert len(caps) >= 3
    curve = cachescope.hit_curve(d["dist_bytes"], caps)
    for c, m_hits in zip(caps, curve):
        direct_hits, direct_misses = cachescope.simulate_lru_bytes(s, c)
        assert int(m_hits) == direct_hits, f"capacity {c}"
        assert direct_hits + direct_misses == d["n_gets"]


def test_mattson_curve_monotone_with_compulsory_floor():
    s = _invalidation_free_stream(seed=4)
    d = cachescope.reuse_distances(s)
    caps = [1 << i for i in range(4, 22)]
    curve = cachescope.hit_curve(d["dist_bytes"], caps)
    assert all(a <= b for a, b in zip(curve, curve[1:]))
    compulsory = int(np.count_nonzero(d["dist_bytes"] < 0))
    assert int(curve[-1]) == d["n_gets"] - compulsory


# ---------------------------------------------------------------------------
# Belady dominance
# ---------------------------------------------------------------------------
def test_belady_dominates_every_replayed_policy():
    rt, store = _runtime(p=2)
    rec = cachescope.enable_recording()
    _drive(rt, store, rounds=4)
    cachescope.disable_recording()
    for s in rec.host_streams():
        bel = cachescope.replay_belady(s)
        for policy in ("deployed", "lru", "lru_positional", "degree",
                       "ewma"):
            rep = cachescope.replay_host(s, policy=policy)
            assert bel["hits"] >= rep["hits"], (
                f"belady {bel['hits']} < {policy} {rep['hits']}")


# ---------------------------------------------------------------------------
# eviction-quality audit + bytes_evicted_live
# ---------------------------------------------------------------------------
def test_eviction_audit_sanity():
    rec = cachescope.enable_recording()
    c = ClampiCache(1 << 9, 8)  # tiny: forces evictions
    keys = _zipf_ids(60, 800, seed=6)
    for k in keys:
        c.get(int(k), 48, score=float(k % 5))
    cachescope.disable_recording()
    (s,) = rec.host_streams()
    audit = cachescope.eviction_audit(s, ks=(16, 128))
    assert audit["n_evictions"] == c.stats.evictions > 0
    assert 0.0 <= audit["reref_frac"] <= 1.0
    for k, frac in audit["premature_within_k"].items():
        assert 0.0 <= frac <= 1.0
    assert audit["bytes_evicted_live"] <= audit["bytes_evicted"]
    assert audit["bytes_evicted_live"] == c.stats.bytes_evicted_live


def test_bytes_evicted_live_counts_only_rereferenced_victims():
    c = ClampiCache(100, 8)
    assert c.get(1, 60) is False and c.get(2, 60) is False  # evicts 1
    assert c.stats.evictions == 1
    assert c.stats.bytes_evicted_live == 0  # not re-referenced yet
    c.get(1, 60)  # premature eviction materializes
    assert c.stats.bytes_evicted_live == 60
    c.get(2, 60)  # 2 was evicted by 1's return; re-referenced too
    assert c.stats.bytes_evicted_live == 120


def test_bytes_evicted_live_ignores_invalidated_victims():
    c = ClampiCache(100, 8)
    c.get(1, 60)
    c.get(2, 60)        # evicts 1
    c.invalidate(1)     # 1 changed upstream: refetch is correctness,
    c.get(1, 60)        # not an eviction mistake
    assert c.stats.bytes_evicted_live == 0


def test_bytes_evicted_live_reset_by_flush():
    c = ClampiCache(100, 8)
    c.get(1, 60)
    c.get(2, 60)
    c.flush()
    c.get(1, 60)
    assert c.stats.bytes_evicted_live == 0


def test_merge_cache_stats_includes_bytes_evicted_live():
    empty = merge_cache_stats([])
    assert empty.bytes_evicted_live == 0
    one = CacheStats(gets=3, bytes_evicted_live=7)
    assert merge_cache_stats([one]).bytes_evicted_live == 7
    mixed = [CacheStats(), CacheStats(bytes_evicted_live=5),
             CacheStats(bytes_evicted_live=0)]
    merged = merge_cache_stats(mixed)
    assert merged.bytes_evicted_live == 5
    # every field must aggregate, not just the ones we remembered
    for f in dataclasses.fields(CacheStats):
        assert getattr(merged, f.name) == sum(
            getattr(s, f.name) for s in mixed)


# ---------------------------------------------------------------------------
# analyze() report, sidecar, validator
# ---------------------------------------------------------------------------
def _recorded_report(tmp_path=None):
    rt, store = _runtime(p=2)
    rec = cachescope.enable_recording()
    rt.enable_device_tier(8)
    _drive(rt, store)
    cachescope.disable_recording()
    return cachescope.analyze(rec)


def test_analyze_summary_and_roundtrip(tmp_path):
    report = _recorded_report()
    assert report["summary"]["all_reconciled"]
    assert report["summary"]["belady_dominates"]
    assert (report["summary"]["n_host_streams"]
            + report["summary"]["n_device_streams"]
            == report["summary"]["n_streams"])
    path = tmp_path / "run.cachescope.json"
    cachescope.save_report(report, str(path))
    doc = cachescope.load_report(str(path))
    assert validate_cachescope(doc) == []
    assert cachescope.summarize(doc)  # human summary renders


def test_metrics_adapter_exports_cachescope_gauges():
    from repro.obs.metrics import MetricRegistry, record_cachescope
    from repro.obs.validate import validate_metrics

    report = _recorded_report()
    reg = MetricRegistry()
    record_cachescope(reg, report)
    snap = reg.to_dict()
    names = {g["name"] for g in snap["gauges"]}
    assert "cachescope_reconciled_all" in names
    assert "cachescope_reconciled" in names
    assert any(n.startswith("replay_hit_rate:") for n in names)
    assert validate_metrics(snap) == []


@pytest.mark.parametrize("corrupt", [
    "schema", "missing_stream_key", "misaligned", "tampered_live",
    "false_reconciled",
])
def test_validator_rejects_corrupt_sidecars(corrupt):
    doc = json.loads(json.dumps(_recorded_report()))  # deep plain copy
    host = next(s for s in doc["streams"] if s["tier"] == "host_cache")
    if corrupt == "schema":
        doc["schema"] = "repro.obs.cachescope/v0"
    elif corrupt == "missing_stream_key":
        del host["events"]
    elif corrupt == "misaligned":
        host["events"]["keys"] = host["events"]["keys"][:-1]
    elif corrupt == "tampered_live":
        host["live"]["hits"] = host["live"]["hits"] + 1
    elif corrupt == "false_reconciled":
        host["reconciled"] = False
    assert validate_cachescope(doc) != [], corrupt


def test_validator_accepts_doc_and_live_streams_alike():
    report = _recorded_report()
    assert validate_cachescope(json.loads(json.dumps(report))) == []
