"""Edge cases of the shared pow-2 width-bucketing helpers.

These are the primitives behind every padded kernel shape and every
width-bucketed collective, so their corner behavior (zero widths, exact
powers of two, degenerate bucket budgets) is pinned here explicitly.
"""
import numpy as np
import pytest

from repro.kernels.bucketing import (
    pack_rows,
    pow2_ceil,
    split_width_buckets,
    width_classes,
)


# --------------------------------------------------------------------------
# pow2_ceil / width_classes
# --------------------------------------------------------------------------
def test_pow2_ceil_zero_and_one():
    # width 0 (empty row) still pads to a legal 1-wide shape
    assert pow2_ceil(0) == 1
    assert pow2_ceil(1) == 1


@pytest.mark.parametrize("w", [1, 2, 4, 8, 256, 1 << 20])
def test_pow2_ceil_exact_power_is_identity(w):
    assert pow2_ceil(w) == w  # no gratuitous doubling at the boundary


@pytest.mark.parametrize("w, want", [(3, 4), (5, 8), (9, 16), (1025, 2048)])
def test_pow2_ceil_rounds_up(w, want):
    assert pow2_ceil(w) == want


def test_pow2_ceil_floor():
    assert pow2_ceil(3, floor=8) == 8   # floor dominates small x
    assert pow2_ceil(9, floor=8) == 16  # x dominates past the floor
    assert pow2_ceil(0, floor=6) == 8   # floor itself is still ceiled


def test_width_classes_matches_scalar():
    ws = [0, 1, 2, 3, 4, 5, 7, 8, 9, 1023, 1024]
    got = width_classes(ws)
    want = np.array([pow2_ceil(w) for w in ws], np.int64)
    assert np.array_equal(got, want)
    assert width_classes([]).size == 0


# --------------------------------------------------------------------------
# pack_rows
# --------------------------------------------------------------------------
def test_pack_rows_empty_and_all_empty_rows():
    assert pack_rows([], 4, -1).shape == (0, 4)
    out = pack_rows([np.zeros(0, np.int32)] * 3, 4, -1)
    assert out.shape == (3, 4) and (out == -1).all()


def test_pack_rows_ragged():
    rows = [np.array([5], np.int32), np.array([1, 2, 3], np.int32)]
    out = pack_rows(rows, 4, -1)
    assert np.array_equal(out[0], [5, -1, -1, -1])
    assert np.array_equal(out[1], [1, 2, 3, -1])


# --------------------------------------------------------------------------
# split_width_buckets
# --------------------------------------------------------------------------
def _cover(splits, n):
    """Every index appears in exactly one bucket."""
    seen = np.concatenate([idx for idx, _ in splits]) if splits else (
        np.zeros(0, np.int64)
    )
    assert np.array_equal(np.sort(seen), np.arange(n))


def test_split_empty():
    assert split_width_buckets([], 4) == []


def test_split_single_class_is_degenerate():
    ws = [5, 6, 7, 8]  # all pow2-class 8
    splits = split_width_buckets(ws, 4)
    assert len(splits) == 1
    idx, w = splits[0]
    assert w == 8 and np.array_equal(idx, np.arange(4))


def test_split_max_buckets_one_merges_everything():
    ws = [1, 2, 4, 8, 16, 300]
    splits = split_width_buckets(ws, 1)
    assert len(splits) == 1
    idx, w = splits[0]
    assert w == 512  # pow2 ceiling of the widest member
    _cover(splits, len(ws))


def test_split_respects_budget_and_covers():
    rng = np.random.default_rng(0)
    ws = rng.integers(0, 400, size=200)
    for cap in (1, 2, 3, 4):
        splits = split_width_buckets(ws, cap)
        assert 1 <= len(splits) <= cap
        _cover(splits, len(ws))
        # widths ascend, every member fits its bucket's padded width
        widths = [w for _, w in splits]
        assert widths == sorted(widths)
        for idx, w in splits:
            assert (np.maximum(ws[idx], 1) <= w).all()


def test_split_merges_smallest_class_into_next_larger():
    # classes: 2 (x3), 4 (x1, the smallest), 8 (x2) -> with budget 2 the
    # lone width-4 item merges upward into the 8 bucket, never downward
    ws = [2, 2, 2, 3, 8, 7]
    splits = split_width_buckets(ws, 2)
    assert [(sorted(i.tolist()), w) for i, w in splits] == [
        ([0, 1, 2], 2),
        ([3, 4, 5], 8),
    ]


def test_split_never_merges_top_class():
    # smallest-count class IS the top class; the rule must pick the
    # smallest among the rest (widths only ever grow to a neighbor's)
    ws = [1, 1, 2, 2, 4]  # counts: {1: 2, 2: 2, 4: 1}
    splits = split_width_buckets(ws, 2)
    widths = [w for _, w in splits]
    assert widths == [2, 4]  # 1-class merged into 2; top class intact
    _cover(splits, len(ws))
