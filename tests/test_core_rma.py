import numpy as np
import pytest

from repro.core.cache import build_static_degree_cache
from repro.core.rma import build_sharded_problem, simulate_rma_lcc
from repro.core.partition import partition_1d
from conftest import random_graph, powerlaw_graph


def resolve_rows(prob, k):
    """Host-side re-execution of the combined-index scheme for device k."""
    import numpy as np

    p, nr, _, s_max = prob.serve_idx.shape[0], prob.n_rounds, None, prob.s_max
    n_loc1 = prob.n_loc + 1
    w = prob.width
    out = np.zeros((prob.e_max,), np.int64)
    counts = np.full(prob.e_max, -1, np.int64)
    e_chunk = prob.e_max // nr
    for r in range(nr):
        # fetched rows for device k in round r: what each peer q serves to k
        fetched = np.full((prob.p, s_max, w), prob.sentinel, np.int32)
        for q in range(prob.p):
            idx = prob.serve_idx[q, r, k]
            fetched[q] = prob.rows_ext[q][idx]
        combined = np.concatenate(
            [prob.rows_ext[k], prob.cache_rows, fetched.reshape(-1, w)], 0
        )
        for e in range(r * e_chunk, (r + 1) * e_chunk):
            if not prob.edge_mask[k, e]:
                continue
            row_u = prob.rows_ext[k][prob.edge_u[k, e]]
            row_v = combined[prob.edge_vc[k, e]]
            a = row_u[row_u < prob.sentinel]
            b = row_v[row_v < prob.sentinel]
            counts[e] = len(np.intersect1d(a, b))
    return counts


@pytest.mark.parametrize("p,cache_rows,n_rounds", [
    (1, 0, 1), (4, 0, 2), (4, 16, 3), (8, 8, 4),
])
def test_schedule_resolves_correct_rows(p, cache_rows, n_rounds):
    """The static pull schedule must deliver exactly adj(v) for every edge."""
    csr = powerlaw_graph(96, 6, seed=4)
    cache = (
        build_static_degree_cache(csr.degrees, cache_rows)
        if cache_rows
        else None
    )
    prob = build_sharded_problem(csr, p, n_rounds=n_rounds, cache=cache)
    part = partition_1d(csr.n, p)
    from repro.core.triangles import triangles_per_vertex

    want_t = triangles_per_vertex(csr)
    for k in range(p):
        counts = resolve_rows(prob, k)
        s = np.zeros(prob.n_loc + 1, np.int64)
        np.add.at(s, prob.edge_u[k], np.where(prob.edge_mask[k], np.maximum(counts, 0), 0))
        lo, hi = part.lo(k), part.hi(k)
        got_t = s[: hi - lo] // 2
        assert np.array_equal(got_t, want_t[lo:hi]), f"device {k}"


def test_cache_reduces_comm_volume():
    csr = powerlaw_graph(128, 8, seed=1)
    p = 4
    prob0 = build_sharded_problem(csr, p, n_rounds=2)
    cache = build_static_degree_cache(csr.degrees, 24)
    prob1 = build_sharded_problem(csr, p, n_rounds=2, cache=cache)
    b0 = prob0.comm_bytes_per_round().sum()
    b1 = prob1.comm_bytes_per_round().sum()
    assert b1 < b0, "degree-cache must cut communication volume"


def test_simulate_rma_stats():
    csr = powerlaw_graph(200, 8, seed=2)
    p = 4
    st_nc = simulate_rma_lcc(csr, p)
    st_c = simulate_rma_lcc(
        csr, p, offsets_cache_bytes=800, adj_cache_bytes=4096
    )
    assert st_nc.remote_gets.sum() > 0
    # cache hits reduce modeled communication time
    assert st_c.comm_time.sum() < st_nc.comm_time.sum()
    # hit rate in a power-law graph with decent cache must be positive
    assert sum(s.hits for s in st_c.adj_stats) > 0
    # compulsory misses can't exceed total misses
    for s in st_c.adj_stats:
        assert s.compulsory_misses <= s.misses


def test_degree_score_beats_lru_on_powerlaw():
    """Fig. 8: degree-centrality victim selection beats LRU+positional."""
    csr = powerlaw_graph(400, 10, seed=3)
    p = 2
    kw = dict(adj_cache_bytes=2048, table_slots_adj=64)
    lru = simulate_rma_lcc(csr, p, use_degree_score=False, **kw)
    deg = simulate_rma_lcc(csr, p, use_degree_score=True, **kw)
    hits_lru = sum(s.hits for s in lru.adj_stats)
    hits_deg = sum(s.hits for s in deg.adj_stats)
    assert hits_deg >= hits_lru


def test_expected_remote_reads_formula():
    """Paper §III-B: E[reads of v] ~ deg^-(v) * (p-1)/p under random owners."""
    csr = powerlaw_graph(300, 8, seed=5)
    p = 4
    st = simulate_rma_lcc(csr, p)
    total_remote = st.remote_gets.sum()
    expect = csr.degrees.sum() * (p - 1) / p
    assert abs(total_remote - expect) / expect < 0.25
