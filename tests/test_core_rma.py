import numpy as np
import pytest

from repro.core.cache import build_static_degree_cache
from repro.core.csr import from_edges
from repro.core.rma import (
    ScheduleWidthOverflow,
    assert_problems_equal,
    build_sharded_problem,
    simulate_rma_lcc,
)
from repro.core.partition import partition_1d
from conftest import random_graph, powerlaw_graph


def resolve_rows(prob, k):
    """Host-side re-execution of the combined-index scheme for device k."""
    import numpy as np

    p, nr, _, s_max = prob.serve_idx.shape[0], prob.n_rounds, None, prob.s_max
    n_loc1 = prob.n_loc + 1
    w = prob.width
    out = np.zeros((prob.e_max,), np.int64)
    counts = np.full(prob.e_max, -1, np.int64)
    e_chunk = prob.e_max // nr
    for r in range(nr):
        # fetched rows for device k in round r: what each peer q serves to k
        fetched = np.full((prob.p, s_max, w), prob.sentinel, np.int32)
        for q in range(prob.p):
            idx = prob.serve_idx[q, r, k]
            fetched[q] = prob.rows_ext[q][idx]
        combined = np.concatenate(
            [prob.rows_ext[k], prob.cache_rows, fetched.reshape(-1, w)], 0
        )
        for e in range(r * e_chunk, (r + 1) * e_chunk):
            if not prob.edge_mask[k, e]:
                continue
            row_u = prob.rows_ext[k][prob.edge_u[k, e]]
            row_v = combined[prob.edge_vc[k, e]]
            a = row_u[row_u < prob.sentinel]
            b = row_v[row_v < prob.sentinel]
            counts[e] = len(np.intersect1d(a, b))
    return counts


@pytest.mark.parametrize("p,cache_rows,n_rounds", [
    (1, 0, 1), (4, 0, 2), (4, 16, 3), (8, 8, 4),
])
def test_schedule_resolves_correct_rows(p, cache_rows, n_rounds):
    """The static pull schedule must deliver exactly adj(v) for every edge."""
    csr = powerlaw_graph(96, 6, seed=4)
    cache = (
        build_static_degree_cache(csr.degrees, cache_rows)
        if cache_rows
        else None
    )
    prob = build_sharded_problem(csr, p, n_rounds=n_rounds, cache=cache)
    part = partition_1d(csr.n, p)
    from repro.core.triangles import triangles_per_vertex

    want_t = triangles_per_vertex(csr)
    for k in range(p):
        counts = resolve_rows(prob, k)
        s = np.zeros(prob.n_loc + 1, np.int64)
        np.add.at(s, prob.edge_u[k], np.where(prob.edge_mask[k], np.maximum(counts, 0), 0))
        lo, hi = part.lo(k), part.hi(k)
        got_t = s[: hi - lo] // 2
        assert np.array_equal(got_t, want_t[lo:hi]), f"device {k}"


def test_cache_reduces_comm_volume():
    csr = powerlaw_graph(128, 8, seed=1)
    p = 4
    prob0 = build_sharded_problem(csr, p, n_rounds=2)
    cache = build_static_degree_cache(csr.degrees, 24)
    prob1 = build_sharded_problem(csr, p, n_rounds=2, cache=cache)
    b0 = prob0.comm_bytes_per_round().sum()
    b1 = prob1.comm_bytes_per_round().sum()
    assert b1 < b0, "degree-cache must cut communication volume"


def test_simulate_rma_stats():
    csr = powerlaw_graph(200, 8, seed=2)
    p = 4
    st_nc = simulate_rma_lcc(csr, p)
    st_c = simulate_rma_lcc(
        csr, p, offsets_cache_bytes=800, adj_cache_bytes=4096
    )
    assert st_nc.remote_gets.sum() > 0
    # cache hits reduce modeled communication time
    assert st_c.comm_time.sum() < st_nc.comm_time.sum()
    # hit rate in a power-law graph with decent cache must be positive
    assert sum(s.hits for s in st_c.adj_stats) > 0
    # compulsory misses can't exceed total misses
    for s in st_c.adj_stats:
        assert s.compulsory_misses <= s.misses


def test_degree_score_beats_lru_on_powerlaw():
    """Fig. 8: degree-centrality victim selection beats LRU+positional."""
    csr = powerlaw_graph(400, 10, seed=3)
    p = 2
    kw = dict(adj_cache_bytes=2048, table_slots_adj=64)
    lru = simulate_rma_lcc(csr, p, use_degree_score=False, **kw)
    deg = simulate_rma_lcc(csr, p, use_degree_score=True, **kw)
    hits_lru = sum(s.hits for s in lru.adj_stats)
    hits_deg = sum(s.hits for s in deg.adj_stats)
    assert hits_deg >= hits_lru


def test_expected_remote_reads_formula():
    """Paper §III-B: E[reads of v] ~ deg^-(v) * (p-1)/p under random owners."""
    csr = powerlaw_graph(300, 8, seed=5)
    p = 4
    st = simulate_rma_lcc(csr, p)
    total_remote = st.remote_gets.sum()
    expect = csr.degrees.sum() * (p - 1) / p
    assert abs(total_remote - expect) / expect < 0.25


# ---------------------------------------------------------------------------
# incremental pull-schedule maintenance (apply_delta)
# ---------------------------------------------------------------------------
def _edge_set(csr):
    src, dst = csr.edge_list()
    keep = src < dst
    return set(map(tuple, np.stack([src[keep], dst[keep]], 1).tolist()))


def _random_effective_delta(rng, edges, n, n_ins, n_del):
    """(ins, dele) honoring the streaming contract: inserts absent,
    deletes present, canonical u < v."""
    ins = []
    while len(ins) < n_ins:
        a, b = (int(x) for x in rng.integers(0, n, 2))
        if a == b:
            continue
        e = (min(a, b), max(a, b))
        if e not in edges and e not in ins:
            ins.append(e)
    pool = sorted(edges)
    pick = rng.choice(len(pool), size=min(n_del, len(pool)), replace=False)
    dele = [pool[i] for i in pick]
    return np.array(ins, np.int64), np.array(dele, np.int64)


@pytest.mark.parametrize("seed,p,cache_rows,dedup", [
    (0, 1, 0, True), (1, 4, 0, True), (2, 4, 12, True),
    (3, 8, 8, True), (4, 3, 6, False),
])
def test_apply_delta_matches_scratch_build(seed, p, cache_rows, dedup):
    """Property: after ANY sequence of effective insert/delete batches,
    the patched problem is field-for-field bit-exact vs a from-scratch
    build of the mutated graph — serve lists, edge worklists, padded
    rows — and resolving the patched schedule yields the exact new
    per-vertex triangle counts."""
    rng = np.random.default_rng(seed)
    n = 90 + 12 * seed
    csr = powerlaw_graph(n, 5, seed=seed)
    cache = (
        build_static_degree_cache(csr.degrees, cache_rows)
        if cache_rows
        else None
    )
    width = csr.max_degree + 8  # headroom for inserts
    prob = build_sharded_problem(
        csr, p, n_rounds=3, cache=cache, width=width, dedup_rounds=dedup
    )
    edges = _edge_set(csr)
    for _ in range(3):
        ins, dele = _random_effective_delta(rng, edges, n, 10, 6)
        edges.difference_update(map(tuple, dele.tolist()))
        edges.update(map(tuple, ins.tolist()))
        prob.apply_delta(ins, dele)
        csr2 = from_edges(np.array(sorted(edges), np.int64), n)
        fresh = build_sharded_problem(
            csr2, p, n_rounds=3, cache=cache, width=width,
            dedup_rounds=dedup,
        )
        assert_problems_equal(prob, fresh)
    # the maintained schedule still resolves to exact triangle counts
    from repro.core.triangles import triangles_per_vertex

    csr2 = from_edges(np.array(sorted(edges), np.int64), n)
    want_t = triangles_per_vertex(csr2)
    part = partition_1d(n, p)
    for k in range(p):
        counts = resolve_rows(prob, k)
        s = np.zeros(prob.n_loc + 1, np.int64)
        np.add.at(s, prob.edge_u[k],
                  np.where(prob.edge_mask[k], np.maximum(counts, 0), 0))
        lo, hi = part.lo(k), part.hi(k)
        assert np.array_equal(s[: hi - lo] // 2, want_t[lo:hi])


def test_apply_delta_width_overflow_raises_before_mutating():
    csr = powerlaw_graph(60, 6, seed=9)
    prob = build_sharded_problem(csr, 4, n_rounds=2)  # width == max degree
    hub = int(np.argmax(csr.degrees))
    absent = next(
        (hub, v) if hub < v else (v, hub)
        for v in range(csr.n)
        if v != hub and v not in set(csr.row(hub).tolist())
    )
    snap = {f: getattr(prob, f).copy()
            for f in ("rows_ext", "degrees", "edge_u", "edge_vc",
                      "serve_idx")}
    with pytest.raises(ScheduleWidthOverflow):
        prob.apply_delta(np.array([absent], np.int64),
                         np.zeros((0, 2), np.int64))
    for f, v in snap.items():  # overflow must leave the problem untouched
        assert np.array_equal(getattr(prob, f), v), f


def test_apply_delta_empty_batch_is_noop():
    csr = powerlaw_graph(40, 4, seed=3)
    prob = build_sharded_problem(csr, 2, n_rounds=2)
    before = prob.edge_vc.copy()
    prob.apply_delta(np.zeros((0, 2), np.int64), np.zeros((0, 2), np.int64))
    assert np.array_equal(prob.edge_vc, before)


def test_apply_delta_invalid_batch_leaves_problem_untouched():
    """A contract-violating batch (double-applied delta) must raise and
    leave every field bit-identical — a failed patch is retryable."""
    csr = powerlaw_graph(50, 5, seed=11)
    prob = build_sharded_problem(csr, 4, n_rounds=2,
                                 width=csr.max_degree + 4)
    edges = _edge_set(csr)
    rng = np.random.default_rng(12)
    ins, dele = _random_effective_delta(rng, edges, csr.n, 6, 4)
    prob.apply_delta(ins, dele)
    snap = {f: getattr(prob, f).copy()
            for f in ("rows_ext", "degrees", "edge_u", "edge_vc",
                      "edge_mask", "serve_idx")}
    works_snap = [(u.copy(), v.copy()) for u, v in prob.works]
    with pytest.raises(ValueError):
        prob.apply_delta(ins, dele)  # inserts now present: breach
    with pytest.raises(ValueError):
        prob.apply_delta(np.zeros((0, 2), np.int64), dele)  # already gone
    for f, v in snap.items():
        assert np.array_equal(getattr(prob, f), v), f
    for (u0, v0), (u1, v1) in zip(works_snap, prob.works):
        assert np.array_equal(u0, u1) and np.array_equal(v0, v1)
    # and the problem is still maintainable afterwards
    edges.difference_update(map(tuple, dele.tolist()))
    edges.update(map(tuple, ins.tolist()))
    ins2, dele2 = _random_effective_delta(rng, edges, csr.n, 5, 3)
    edges.difference_update(map(tuple, dele2.tolist()))
    edges.update(map(tuple, ins2.tolist()))
    prob.apply_delta(ins2, dele2)
    fresh = build_sharded_problem(
        from_edges(np.array(sorted(edges), np.int64), csr.n), 4,
        n_rounds=2, width=prob.width,
    )
    assert_problems_equal(prob, fresh)


@pytest.mark.parametrize("seed,p", [(5, 1), (6, 4), (7, 8)])
def test_apply_delta_residency_drift_matches_scratch_build(seed, p):
    """Property: interleaving effective update batches with STATIC
    RESIDENCY DRIFT — each batch re-scores the top-C from the current
    degrees and hands the drifted set to ``apply_delta`` — keeps the
    patched problem field-for-field bit-exact vs a from-scratch build
    with that same residency, without ever rebuilding (the PR-3
    follow-up: drift alone must not force a full schedule rebuild)."""
    rng = np.random.default_rng(seed)
    n = 80 + 10 * seed
    csr = powerlaw_graph(n, 5, seed=seed)
    cache_rows = 10
    cache = build_static_degree_cache(csr.degrees, cache_rows)
    width = csr.max_degree + 10
    prob = build_sharded_problem(
        csr, p, n_rounds=3, cache=cache, width=width
    )
    edges = _edge_set(csr)
    degrees = csr.degrees.copy()
    for _ in range(3):
        ins, dele = _random_effective_delta(rng, edges, n, 12, 8)
        edges.difference_update(map(tuple, dele.tolist()))
        edges.update(map(tuple, ins.tolist()))
        for a, b in ins:
            degrees[a] += 1
            degrees[b] += 1
        for a, b in dele:
            degrees[a] -= 1
            degrees[b] -= 1
        drifted = build_static_degree_cache(degrees, cache_rows)
        prob.apply_delta(ins, dele, new_cache_ids=drifted.vertex_ids)
        csr2 = from_edges(np.array(sorted(edges), np.int64), n)
        assert np.array_equal(degrees, csr2.degrees)  # bookkeeping sane
        fresh = build_sharded_problem(
            csr2, p, n_rounds=3, cache=drifted, width=width
        )
        assert_problems_equal(prob, fresh)
    # a pure residency refresh (no edges) also patches in place
    flipped = build_static_degree_cache(-degrees.astype(np.float64) - 1,
                                        cache_rows)
    z = np.zeros((0, 2), np.int64)
    prob.apply_delta(z, z, new_cache_ids=flipped.vertex_ids)
    csr2 = from_edges(np.array(sorted(edges), np.int64), n)
    fresh = build_sharded_problem(
        csr2, p, n_rounds=3, cache=flipped, width=width
    )
    assert_problems_equal(prob, fresh)


def test_maintain_schedule_refreshes_residency_without_rebuild():
    """Runtime wiring: a drifted residency set flows through
    ``maintain_schedule(new_cache_ids=...)`` as an incremental patch
    (returns True, bumps the refresh counter, no rebuild)."""
    from repro.core.runtime import ShardedRuntime
    from repro.streaming import DynamicCSR

    csr = powerlaw_graph(70, 5, seed=21)
    store = DynamicCSR.from_csr(csr)
    rt = ShardedRuntime(store, 4)
    cache = build_static_degree_cache(csr.degrees, 8)
    rt.attach_problem(build_sharded_problem(
        csr, 4, cache=cache, width=csr.max_degree + 6
    ))
    z = np.zeros((0, 2), np.int64)
    # drift only: rotate the residency set
    new_ids = np.sort(
        np.concatenate([cache.vertex_ids[2:],
                        np.setdiff1d(np.arange(csr.n),
                                     cache.vertex_ids)[:2]])
    )
    assert rt.maintain_schedule(z, z, new_cache_ids=new_ids) is True
    assert rt.schedule_rebuilds == 0
    assert rt.schedule_residency_refreshes == 1
    assert np.array_equal(rt.problem.cache_ids, new_ids)
    # unchanged set does not count as a refresh
    assert rt.maintain_schedule(z, z, new_cache_ids=new_ids) is True
    assert rt.schedule_residency_refreshes == 1
