"""Serving subsystem correctness: every served query must be bit-exact
against a from-scratch recount of the same graph snapshot — including
after interleaved streaming update batches — and the cache-backed row
provider must uphold the freshness contract (zero stale cached rows)
exactly when coherence notifications are wired up, and observably break
it when they are not.
"""
import numpy as np
import pytest

from conftest import powerlaw_graph

from repro.core.triangles import lcc_scores, triangles_per_vertex
from repro.kernels.point_query import batched_pair_counts
from repro.serving import (
    CacheBackedRowProvider,
    DirectRowProvider,
    LiveQueryService,
    MicrobatchScheduler,
    Query,
    QueryEngine,
    QueryKind,
    make_queries,
    read_write_stream,
    sample_vertices,
)
from repro.streaming import DynamicCSR, EdgeBatch
from repro.streaming.coherence import StreamingCacheCoherence


def _check_results(results, snap, t_ref=None, lcc_ref=None):
    """Every point-query result == oracle on the snapshot, bit-exact."""
    if t_ref is None:
        t_ref = triangles_per_vertex(snap)
    if lcc_ref is None:
        lcc_ref = lcc_scores(snap, t_ref)
    for r in results:
        q = r.query
        if q.kind == QueryKind.TRIANGLES:
            assert r.value == t_ref[q.u]
        elif q.kind == QueryKind.LCC:
            assert r.value == lcc_ref[q.u]
        elif q.kind == QueryKind.COMMON_NEIGHBORS:
            want = np.intersect1d(snap.row(q.u), snap.row(q.v))
            assert r.value == want.size
            assert np.array_equal(r.ids, want)
        elif q.kind == QueryKind.TOP_K_LCC:
            order = np.lexsort((np.arange(snap.n), -lcc_ref))[: q.k]
            assert np.array_equal(r.ids, order)
            assert np.array_equal(r.values, lcc_ref[order])


# ---------------------------------------------------------------------------
# kernel wrapper
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_batched_pair_counts_matches_numpy(use_kernel):
    rng = np.random.default_rng(0)
    sent = 300
    rows = [
        np.unique(rng.integers(0, sent, size=rng.integers(0, w)))
        .astype(np.int32)
        for w in (1, 2, 3, 9, 40, 130, 7, 2, 65, 17)
    ]
    a = [rows[i] for i in rng.integers(0, len(rows), 25)]
    b = [rows[i] for i in rng.integers(0, len(rows), 25)]
    got = batched_pair_counts(
        a, b, sentinel=sent, use_kernel=use_kernel, interpret=True
    )
    want = np.array([np.intersect1d(x, y).size for x, y in zip(a, b)])
    assert np.array_equal(got, want)


def test_batched_pair_counts_empty():
    assert batched_pair_counts([], [], sentinel=8).shape == (0,)
    z = [np.zeros(0, np.int32)]
    assert batched_pair_counts(z, z, sentinel=8)[0] == 0


# ---------------------------------------------------------------------------
# point queries: bit-exact vs the batch oracle on a static graph
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cached", [False, True])
def test_point_queries_bit_exact_static(cached):
    csr = powerlaw_graph(90, 6, seed=1)
    store = DynamicCSR.from_csr(csr)
    provider = (
        CacheBackedRowProvider(store, p=4, capacity_bytes=1 << 16)
        if cached
        else DirectRowProvider(store, p=4)
    )
    eng = QueryEngine(store, provider, use_kernel=False)
    queries = (
        [Query.triangles(v) for v in range(csr.n)]
        + [Query.lcc(v) for v in range(csr.n)]
        + [Query.common_neighbors(u, v) for u, v in [(0, 1), (3, 17), (5, 5)]]
        + [Query.top_k_lcc(7)]
    )
    res = MicrobatchScheduler(eng, max_batch=16).run(queries)
    _check_results(res, csr)
    assert eng.n_queries == len(queries)
    if cached:
        assert provider.stats.cache_hits > 0  # reuse exists even here


def test_kernel_path_matches_host_path():
    csr = powerlaw_graph(60, 5, seed=2)
    store = DynamicCSR.from_csr(csr)
    queries = [Query.triangles(v) for v in range(0, 60, 3)]
    r_host = QueryEngine(store, use_kernel=False).execute_batch(queries)
    r_kern = QueryEngine(
        store, use_kernel=True, interpret=True
    ).execute_batch(queries)
    assert [r.value for r in r_host] == [r.value for r in r_kern]


def test_microbatch_windows_agree():
    """Scheduling policy must not change answers: window 1 == window 64."""
    csr = powerlaw_graph(70, 5, seed=3)
    store = DynamicCSR.from_csr(csr)
    qs = make_queries(csr.degrees, 80, kind="zipf", seed=4)
    outs = []
    for w in (1, 64):
        eng = QueryEngine(
            store, CacheBackedRowProvider(store, p=4), use_kernel=False
        )
        outs.append(MicrobatchScheduler(eng, max_batch=w).run(qs))
    for a, b in zip(*outs):
        assert a.query == b.query and a.value == b.value
        assert (a.ids is None) == (b.ids is None)
        if a.ids is not None:
            assert np.array_equal(a.ids, b.ids)
    # latency accounting populated
    assert all(r.latency_s > 0 for r in outs[0])


def test_top_k_recomputes_after_store_mutation():
    """Without an incremental lcc_source, top_k must not serve a cached
    pre-mutation ranking once the DynamicCSR changes."""
    csr = powerlaw_graph(40, 4, seed=20)
    store = DynamicCSR.from_csr(csr)
    eng = QueryEngine(store, use_kernel=False)
    r0 = eng.execute_batch([Query.top_k_lcc(5)])[0]
    _check_results([r0], store.to_csr())
    rng = np.random.default_rng(21)
    e = rng.integers(0, csr.n, size=(60, 2))
    e = e[e[:, 0] != e[:, 1]]
    lo, hi = np.minimum(e[:, 0], e[:, 1]), np.maximum(e[:, 0], e[:, 1])
    fresh = np.stack([lo, hi], 1)[~store.has_edges(lo, hi)]
    key = np.unique(fresh[:, 0] * csr.n + fresh[:, 1])
    store.insert_edges(np.stack([key // csr.n, key % csr.n], 1))
    r1 = eng.execute_batch([Query.top_k_lcc(5)])[0]
    _check_results([r1], store.to_csr())


def test_degree_zero_and_degree_one_vertices():
    csr = powerlaw_graph(30, 3, seed=5)
    store = DynamicCSR.empty(8)
    eng = QueryEngine(store, use_kernel=False)
    res = eng.execute_batch([Query.lcc(0), Query.triangles(1)])
    assert res[0].value == 0.0 and res[1].value == 0


# ---------------------------------------------------------------------------
# live service: updates interleaved with queries, freshness verified
# ---------------------------------------------------------------------------
def test_live_service_exact_under_updates():
    csr = powerlaw_graph(80, 5, seed=6)
    svc = LiveQueryService(csr, p=4, max_batch=32)
    rng = np.random.default_rng(7)
    for i in range(6):
        e = rng.integers(0, csr.n, size=(30, 2))
        op = np.where(rng.random(30) < 0.3, -1, 1).astype(np.int8)
        svc.apply_updates(EdgeBatch(u=e[:, 0], v=e[:, 1], op=op))
        res = svc.scheduler.run(
            make_queries(svc.store.degrees, 30, kind="zipf", seed=10 + i)
        )
        _check_results(res, svc.store.to_csr())
    svc.verify()  # streaming exactness + zero stale cached rows
    assert svc.provider.stats.invalidations > 0


def test_live_service_with_clampi_coherence_sim():
    """Full StreamingCacheCoherence attached: replay sim + provider
    invalidation must coexist and stay exact."""
    csr = powerlaw_graph(64, 4, seed=8)
    coh = StreamingCacheCoherence(
        csr.n, csr.degrees, p=4, cache_rows=8, clampi_bytes=1 << 12
    )
    svc = LiveQueryService(csr, p=4, coherence=coh, max_batch=16)
    rng = np.random.default_rng(9)
    for i in range(4):
        e = rng.integers(0, csr.n, size=(24, 2))
        svc.apply_updates(EdgeBatch.inserts(e))
        res = svc.scheduler.run(
            make_queries(svc.store.degrees, 20, kind="uniform", seed=20 + i)
        )
        _check_results(res, svc.store.to_csr())
    assert coh.report.remote_reads > 0  # replay sim ran
    svc.verify()


def test_read_write_stream_drives_service():
    csr = powerlaw_graph(64, 4, seed=10)
    svc = LiveQueryService(csr, p=4, max_batch=32)
    n_q = n_u = 0
    for ev in read_write_stream(
        lambda: svc.store.degrees, csr.n, 20, write_frac=0.4, seed=11
    ):
        if ev.is_update:
            svc.apply_updates(ev.update)
            n_u += 1
        else:
            res = svc.scheduler.run(ev.queries)
            n_q += len(res)
    assert n_q > 0 and n_u > 0
    _check_results(
        svc.scheduler.run(make_queries(svc.store.degrees, 20, seed=12)),
        svc.store.to_csr(),
    )
    svc.verify()


# ---------------------------------------------------------------------------
# the staleness contract, demonstrated from both sides
# ---------------------------------------------------------------------------
def test_stale_provider_diverges_without_coherence():
    """Without notify_batch, cached payloads go stale: the audit flags
    them and query answers diverge from the live graph — the failure
    mode the coherence hookup exists to prevent."""
    csr = powerlaw_graph(60, 6, seed=13)
    store = DynamicCSR.from_csr(csr)
    # rank chosen so vertex `hub` is remote -> cacheable
    hub = int(np.argmax(csr.degrees))
    p = 4
    provider = CacheBackedRowProvider(store, p=p, capacity_bytes=1 << 20)
    if int(provider.part.owner(hub)) == provider.rank:
        provider.rank = (provider.rank + 1) % p
    eng = QueryEngine(store, provider, use_kernel=False)
    before = eng.execute_batch([Query.triangles(hub)])[0].value
    assert provider.cache.contains(hub)

    # mutate the hub's row directly, bypassing any coherence hook
    absent = [v for v in range(csr.n)
              if v != hub and not store.has_edge(hub, v)][:3]
    store.insert_edges(np.array([[min(hub, v), max(hub, v)] for v in absent]))
    cached, stale = provider.audit_freshness()
    assert stale > 0, "audit must flag the stale cached hub row"
    stale_val = eng.execute_batch([Query.triangles(hub)])[0].value
    fresh_t = triangles_per_vertex(store.to_csr())
    # now deliver the (late) coherence notification: refetch heals it
    changed = np.unique(np.array([[hub, v] for v in absent]).ravel())
    provider.notify_batch(changed)
    assert provider.audit_freshness()[1] == 0
    healed = eng.execute_batch([Query.triangles(hub)])[0].value
    assert healed == fresh_t[hub]
    # the stale answer reflected the OLD snapshot (exactly), proving the
    # payload cache really serves payloads, not store passthroughs
    assert stale_val == before or stale_val != healed


def test_provider_payloads_survive_unrelated_updates():
    """Invalidations are per-vertex: rows untouched by a batch stay
    cached (hits), mutated rows refetch."""
    csr = powerlaw_graph(60, 5, seed=14)
    svc = LiveQueryService(csr, p=4, max_batch=16)
    hub = int(np.argmax(csr.degrees))
    if int(svc.provider.part.owner(hub)) == svc.provider.rank:
        svc.provider.rank = (svc.provider.rank + 1) % 4
    svc.query(Query.triangles(hub))
    assert svc.provider.cache.contains(hub)
    # update that does NOT touch the hub
    others = [v for v in range(csr.n) if v != hub]
    u, v = others[0], others[1]
    svc.apply_updates(EdgeBatch.inserts([[min(u, v), max(u, v)]]))
    assert svc.provider.cache.contains(hub), "unrelated update must not evict"
    svc.verify()


# ---------------------------------------------------------------------------
# workload generators
# ---------------------------------------------------------------------------
def test_workload_generators_deterministic_and_skewed():
    csr = powerlaw_graph(200, 6, seed=15)
    deg = csr.degrees
    rng = np.random.default_rng(0)
    zipf = sample_vertices(deg, 4000, rng, kind="zipf", exponent=1.0)
    rng2 = np.random.default_rng(0)
    uni = sample_vertices(deg, 4000, rng2, kind="uniform")
    # hub-skew: mean sampled degree under zipf strictly exceeds uniform
    assert deg[zipf].mean() > deg[uni].mean() * 1.5
    # determinism
    a = make_queries(deg, 50, kind="zipf", seed=3)
    b = make_queries(deg, 50, kind="zipf", seed=3)
    assert a == b
    kinds = {q.kind for q in make_queries(deg, 300, kind="zipf", seed=4)}
    assert kinds == {QueryKind.LCC, QueryKind.TRIANGLES,
                     QueryKind.COMMON_NEIGHBORS, QueryKind.TOP_K_LCC}
    with pytest.raises(ValueError):
        sample_vertices(deg, 5, rng, kind="nope")


# ---------------------------------------------------------------------------
# cross-rank serving over the shared runtime
# ---------------------------------------------------------------------------
def test_cross_rank_service_bit_exact_under_updates():
    """p provider/engine instances over one runtime: every query routed
    to its owner rank, answers bit-exact, freshness bound on all ranks."""
    csr = powerlaw_graph(96, 5, seed=21)
    svc = LiveQueryService(csr, p=4, cross_rank=True, max_batch=16)
    assert len(svc.providers) == 4
    rng = np.random.default_rng(22)
    for i in range(5):
        e = rng.integers(0, csr.n, size=(24, 2))
        op = np.where(rng.random(24) < 0.3, -1, 1).astype(np.int8)
        svc.apply_updates(EdgeBatch(u=e[:, 0], v=e[:, 1], op=op))
        res = svc.scheduler.run(
            make_queries(svc.store.degrees, 40, kind="zipf", seed=30 + i)
        )
        _check_results(res, svc.store.to_csr())
    svc.verify()  # exactness + zero stale rows on ANY rank
    # work actually spread across ranks, and rows crossed ranks
    active = [k for k, st in enumerate(svc.runtime.stats)
              if st.local_reads + st.remote_reads > 0]
    assert len(active) >= 2
    assert svc.runtime.cross_rank_rows_served() > 0
    # targeted coherence beat the broadcast fanout
    assert svc.runtime.invalidation_fanout_saved > 0


def test_cross_rank_routes_to_owner():
    from repro.core.runtime import ShardedRuntime
    from repro.serving import ShardedQueryEngine

    csr = powerlaw_graph(64, 4, seed=23)
    store = DynamicCSR.from_csr(csr)
    rt = ShardedRuntime(store, p=4)
    eng = ShardedQueryEngine(store, rt, use_kernel=False)
    for v in (0, 17, 40, 63):
        assert eng.route(Query.lcc(v)) == int(rt.part.owner(v))
    assert eng.route(Query.top_k_lcc(3)) == 0
    # endpoint reads of a routed query are LOCAL at the owner rank
    res = eng.execute_batch([Query.triangles(v) for v in range(64)])
    _check_results(res, csr)
    for k, st in enumerate(rt.stats):
        assert st.local_reads > 0  # each rank served its own block


def test_cross_rank_and_single_rank_answers_agree():
    csr = powerlaw_graph(80, 5, seed=24)
    qs = make_queries(csr.degrees, 60, kind="zipf", seed=25)
    outs = []
    for cross in (False, True):
        svc = LiveQueryService(csr, p=4, cross_rank=cross, max_batch=16)
        outs.append(svc.scheduler.run(qs))
    for a, b in zip(*outs):
        assert a.query == b.query and a.value == b.value


# ---------------------------------------------------------------------------
# deadline-aware batching (poll) alongside the FIFO drain (flush)
# ---------------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_scheduler_deadline_flush():
    csr = powerlaw_graph(40, 4, seed=26)
    store = DynamicCSR.from_csr(csr)
    eng = QueryEngine(store, use_kernel=False)
    clk = _FakeClock()
    sched = MicrobatchScheduler(eng, max_batch=8, max_wait=0.5, clock=clk)
    sched.submit(Query.triangles(3))
    assert sched.poll() == []  # deadline not reached: keep coalescing
    clk.t = 0.4
    sched.submit(Query.lcc(5))
    assert sched.poll() == []
    clk.t = 0.6  # oldest has now waited 0.6 >= 0.5
    res = sched.poll()
    assert [r.query.u for r in res] == [3, 5]
    assert sched.pending == 0 and sched.n_deadline_flushes == 1
    # latency measured from the injected clock, per query
    assert res[0].latency_s == pytest.approx(0.6)
    assert res[1].latency_s == pytest.approx(0.2)
    _check_results(res, csr)


def test_scheduler_full_window_and_priority_flush():
    csr = powerlaw_graph(40, 4, seed=27)
    store = DynamicCSR.from_csr(csr)
    eng = QueryEngine(store, use_kernel=False)
    clk = _FakeClock()
    sched = MicrobatchScheduler(eng, max_batch=4, max_wait=10.0, clock=clk)
    # full window dispatches immediately, leftover keeps waiting
    for v in range(5):
        sched.submit(Query.triangles(v))
    res = sched.poll()
    assert len(res) == 4 and sched.pending == 1
    # urgent query flushes the partial window ahead of the deadline,
    # batching the query that was already queued in front of it
    sched.submit(Query.lcc(7), urgent=True)
    res = sched.poll()
    assert [r.query.u for r in res] == [4, 7]
    assert sched.n_priority_flushes == 1
    assert sched.poll() == []  # drained
    # flush() still drains everything regardless of deadlines
    sched.submit(Query.triangles(9))
    assert len(sched.flush()) == 1


def test_scheduler_poll_matches_flush_answers():
    csr = powerlaw_graph(50, 4, seed=28)
    store = DynamicCSR.from_csr(csr)
    qs = make_queries(csr.degrees, 30, kind="zipf", seed=29)
    r_flush = MicrobatchScheduler(
        QueryEngine(store, use_kernel=False), max_batch=8
    ).run(qs)
    clk = _FakeClock()
    sched = MicrobatchScheduler(
        QueryEngine(store, use_kernel=False), max_batch=8, max_wait=0.1,
        clock=clk,
    )
    sched.submit_many(qs)
    clk.t = 1.0
    r_poll = sched.poll()
    for a, b in zip(r_flush, r_poll):
        assert a.query == b.query and a.value == b.value


def test_service_shares_coherence_runtime():
    """Passing a StreamingCacheCoherence must yield ONE runtime for
    replay and serving (no parallel partition/cache stacks), with
    serving reads hitting rows the replay already warmed."""
    csr = powerlaw_graph(64, 4, seed=31)
    coh = StreamingCacheCoherence(
        csr.n, csr.degrees, p=4, cache_rows=8, clampi_bytes=1 << 16
    )
    svc = LiveQueryService(csr, p=4, coherence=coh, max_batch=16)
    assert svc.runtime is coh.runtime
    assert svc.stream.runtime is coh.runtime
    rng = np.random.default_rng(32)
    for i in range(3):
        e = rng.integers(0, csr.n, size=(20, 2))
        svc.apply_updates(EdgeBatch.inserts(e[e[:, 0] != e[:, 1]]))
        res = svc.scheduler.run(
            make_queries(svc.store.degrees, 24, kind="zipf", seed=40 + i)
        )
        _check_results(res, svc.store.to_csr())
    svc.verify()


# ---------------------------------------------------------------------------
# admission control / load shedding
# ---------------------------------------------------------------------------
def test_scheduler_sheds_on_queue_depth():
    csr = powerlaw_graph(40, 4, seed=31)
    store = DynamicCSR.from_csr(csr)
    eng = QueryEngine(store, use_kernel=False)
    sched = MicrobatchScheduler(eng, max_batch=4, max_queue=6)
    accepted = [sched.submit(Query.triangles(v % 40)) for v in range(10)]
    assert accepted == [True] * 6 + [False] * 4  # reject-with-reason
    assert sched.pending == 6
    assert sched.n_shed_depth == 4
    assert sched.recorder.sheds == {"depth": 4}
    res = sched.flush()  # admitted queries still serve exactly
    assert len(res) == 6
    _check_results(res, csr)
    # the bound is on PENDING depth: a drained queue admits again
    assert sched.submit(Query.lcc(1)) is True
    # submit_many reports how many made it in
    assert sched.submit_many([Query.lcc(v) for v in range(10)]) == 5
    assert sched.n_shed_depth == 9
    summ = sched.latency_summary()
    assert summ.shed == 9
    assert summ.shed_rate == pytest.approx(9 / (6 + 9))


def test_scheduler_poll_sheds_stale_queries():
    csr = powerlaw_graph(40, 4, seed=32)
    store = DynamicCSR.from_csr(csr)
    eng = QueryEngine(store, use_kernel=False)
    clk = _FakeClock()
    sched = MicrobatchScheduler(
        eng, max_batch=8, max_wait=0.5, shed_wait=2.0, clock=clk
    )
    sched.submit(Query.triangles(3))  # will go stale
    clk.t = 1.9
    sched.submit(Query.lcc(5))  # still fresh at shed time
    clk.t = 2.5
    res = sched.poll()
    # the stale query was rejected-with-reason, the fresh one served
    # (its own 0.6s wait is past max_wait, so the window dispatched)
    assert [r.query.u for r in res] == [5]
    assert sched.n_shed_deadline == 1
    assert sched.recorder.sheds == {"deadline": 1}
    assert sched.latency_summary().shed == 1
    _check_results(res, csr)


def test_service_plumbs_admission_control():
    csr = powerlaw_graph(60, 5, seed=33)
    svc = LiveQueryService(csr, p=2, max_batch=8, max_queue=5)
    admitted = svc.submit_many(
        make_queries(svc.store.degrees, 12, kind="uniform", seed=34)
    )
    assert admitted == 5 and svc.scheduler.n_shed_depth == 7
    assert svc.submit(Query.lcc(1)) is False  # still at the bound
    res = svc.flush()
    assert len(res) == 5
    _check_results(res, svc.store.to_csr())
    svc.verify()
