"""ShardedRuntime contracts: ownership, rank-indexed transport, targeted
coherence fanout, freshness audit, and incremental schedule upkeep —
the substrate every consumer (epoch engine, streaming, serving) shares.
"""
import numpy as np

from conftest import powerlaw_graph

from repro.core.rma import build_sharded_problem
from repro.core.runtime import ShardedRuntime
from repro.streaming import DynamicCSR


def _runtime(n_vertices=80, p=4, seed=0, **kw):
    csr = powerlaw_graph(n_vertices, 5, seed=seed)
    store = DynamicCSR.from_csr(csr)
    return ShardedRuntime(store, p, **kw), store


# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------
def test_fetch_rows_local_free_remote_cached():
    rt, store = _runtime()
    lo, hi = rt.part.lo(1), rt.part.hi(1)
    local_v = lo  # owned by rank 1
    remote_v = 0  # owned by rank 0
    rows = rt.fetch_rows(1, [local_v, remote_v, remote_v])
    assert np.array_equal(rows[local_v], store.row(local_v))
    assert np.array_equal(rows[remote_v], store.row(remote_v))
    st = rt.stats[1]
    assert st.local_reads == 1
    assert st.remote_reads == 2
    assert st.cache_hits == 1  # second read of the remote row hit
    assert st.cache_misses == 1
    # the miss shipped one row owner(remote_v)=0 -> requester 1
    assert rt.serve_rows[0, 1] == 1
    # other ranks untouched
    assert rt.stats[0].remote_reads == 0


def test_serve_matrix_tracks_all_to_all():
    rt, store = _runtime(p=4)
    n = store.n
    for rank in range(4):
        rt.fetch_rows(rank, range(n))  # every rank reads every row once
    sr = rt.serve_rows
    assert np.array_equal(np.diag(sr), np.zeros(4, np.int64))
    block = rt.part
    for q in range(4):
        owned = block.hi(q) - block.lo(q)
        for k in range(4):
            if q != k:
                assert sr[q, k] == owned  # each row shipped exactly once


# ---------------------------------------------------------------------------
# targeted coherence fanout
# ---------------------------------------------------------------------------
def test_invalidation_fans_out_only_to_caching_ranks():
    rt, store = _runtime(p=4)
    v = 0  # owned by rank 0
    rt.fetch_rows(1, [v])  # only rank 1 caches it
    rt.fetch_rows(2, [rt.part.lo(2)])  # rank 2 reads a local row: no cache
    dropped = rt.invalidate([v])
    assert dropped == 1
    assert rt.stats[1].invalidations == 1
    assert all(rt.stats[k].invalidations == 0 for k in (0, 2, 3))
    # broadcast would have sent p messages for the one id; we sent 1
    assert rt.invalidations_sent == 1
    assert rt.invalidations_broadcast_equiv == 4
    assert rt.invalidation_fanout_saved == 3


def test_audit_flags_stale_then_invalidate_heals():
    rt, store = _runtime(p=4)
    hub = int(np.argmax(store.degrees))
    rank = (int(rt.part.owner(hub)) + 1) % 4  # a rank where hub is remote
    rt.fetch_rows(rank, [hub])
    assert rt.caches[rank].contains(hub)
    # mutate the hub's row behind the runtime's back
    absent = next(
        v for v in range(store.n)
        if v != hub and not store.has_edge(hub, v)
    )
    store.insert_edges(np.array([[min(hub, absent), max(hub, absent)]]))
    cached, stale = rt.audit_freshness()
    assert stale == 1
    rt.invalidate([hub, absent])
    assert rt.audit_freshness()[1] == 0
    rows = rt.fetch_rows(rank, [hub])  # refetch sees the fresh row
    assert np.array_equal(rows[hub], store.row(hub))


def test_uncached_runtime_is_always_fresh():
    rt, store = _runtime(p=2, uncached=True)
    rt.fetch_rows(1, [0, 0])
    assert rt.stats[1].cache_misses == 2  # every remote read pays
    assert rt.invalidate([0]) == 0
    assert rt.audit_freshness() == (0, 0)


# ---------------------------------------------------------------------------
# schedule upkeep
# ---------------------------------------------------------------------------
def test_maintain_schedule_incremental_then_overflow_rebuild():
    csr = powerlaw_graph(60, 4, seed=3)
    store = DynamicCSR.from_csr(csr)
    rt = ShardedRuntime(store, 4)
    rt.attach_problem(
        build_sharded_problem(csr, 4, width=csr.max_degree + 2)
    )
    hub = int(np.argmax(csr.degrees))
    absent = [v for v in range(csr.n)
              if v != hub and not store.has_edge(hub, v)]

    def edge(v):
        return [min(hub, v), max(hub, v)]

    z = np.zeros((0, 2), np.int64)
    ins = np.array([edge(absent[0])], np.int64)
    store.insert_edges(ins)
    assert rt.maintain_schedule(ins, z) is True  # fits: incremental
    assert rt.schedule_deltas == 1 and rt.schedule_rebuilds == 0
    ins = np.array([edge(absent[1]), edge(absent[2])], np.int64)
    store.insert_edges(ins)
    assert rt.maintain_schedule(ins, z) is False  # width overflow
    assert rt.schedule_rebuilds == 1
    assert rt.problem.width >= store.max_degree  # rebuilt with headroom
    # the rebuilt problem reflects the post-batch graph
    d_hub = int(store.degree(hub))
    k, lu = int(rt.part.owner(hub)), hub - rt.part.lo(int(rt.part.owner(hub)))
    assert rt.problem.degrees[k, lu] == d_hub


def test_replay_admitted_entries_serve_fresh_rows_on_shared_runtime():
    """StreamingCacheCoherence drives the same per-rank caches via
    get() without capturing payloads; a provider hit on such an entry
    must serve (and capture) the authoritative row, not crash."""
    from repro.streaming import EdgeBatch, StreamingCacheCoherence
    from repro.streaming.incremental import StreamingLCCEngine

    csr = powerlaw_graph(64, 5, seed=30)
    coh = StreamingCacheCoherence(
        csr.n, csr.degrees, p=4, cache_rows=4, clampi_bytes=1 << 16
    )
    eng = StreamingLCCEngine(csr, use_kernel=False, coherence=coh)
    rt = eng.runtime
    rng = np.random.default_rng(31)
    e = rng.integers(0, csr.n, size=(40, 2))
    eng.apply_batch(EdgeBatch.inserts(e[e[:, 0] != e[:, 1]]))
    # find a replay-admitted resident with no captured payload
    found = None
    for k, cache in enumerate(rt.caches):
        for key in cache.entries:
            if key not in rt._payloads[k]:
                found = (k, int(key))
                break
        if found:
            break
    assert found is not None, "replay should admit payload-less entries"
    k, v = found
    rows = rt.fetch_rows(k, [v])  # hit path: heal, don't KeyError
    assert np.array_equal(rows[v], eng.store.row(v))
    assert rt.stats[k].cache_hits >= 1
    assert rt.audit_rank(k)[1] == 0  # captured payload is fresh
