"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (see tests/requirements-
optional.txt); the module skips cleanly when it is not installed so the
tier-1 suite never dies at collection.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache import ClampiCache, build_static_degree_cache
from repro.core.csr import from_edges
from repro.core.intersect import (
    binary_search_scalar,
    eq3_ssi_faster,
    hybrid_scalar,
    ssi_scalar,
)
from repro.core.partition import partition_1d
from repro.core.triangles import global_triangle_count, triangles_per_vertex
from repro.models.recsys.embedding import bag_fixed, bag_ragged

edge_lists = st.lists(
    st.tuples(st.integers(0, 29), st.integers(0, 29)),
    min_size=0, max_size=120,
)


@given(edge_lists)
@settings(max_examples=40, deadline=None)
def test_triangle_count_permutation_invariant(edges):
    """TC is invariant under vertex relabeling."""
    n = 30
    e = np.array(edges, np.int64).reshape(-1, 2)
    g = from_edges(e, n)
    t1 = global_triangle_count(g)
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    e2 = perm[e] if e.size else e
    g2 = from_edges(e2, n)
    assert global_triangle_count(g2) == t1


@given(edge_lists, st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_partition_covers_all_vertices(edges, p):
    n = 30
    part = partition_1d(n, p)
    sizes = part.sizes()
    assert sizes.sum() == n
    owners = part.owner(np.arange(n))
    for v in range(n):
        assert part.lo(owners[v]) <= v < part.hi(owners[v])


@given(
    st.lists(st.integers(0, 500), max_size=60),
    st.lists(st.integers(0, 500), max_size=60),
)
@settings(max_examples=60, deadline=None)
def test_intersection_methods_equal(a, b):
    a = np.unique(np.array(a, np.int64))
    b = np.unique(np.array(b, np.int64))
    want = len(np.intersect1d(a, b))
    assert ssi_scalar(a, b) == want
    assert binary_search_scalar(a, b) == want
    assert hybrid_scalar(a, b) == want


@given(st.integers(0, 5000), st.integers(0, 5000))
@settings(max_examples=50, deadline=None)
def test_eq3_rule_total(la, lb):
    """Eq. 3 rule is a total boolean (never raises) and symmetric in the
    sense that it only depends on the (short, long) ordering."""
    r1 = eq3_ssi_faster(la, lb)
    r2 = eq3_ssi_faster(lb, la)
    assert isinstance(r1, (bool, np.bool_))
    assert r1 == r2


@given(
    st.lists(st.tuples(st.integers(0, 99), st.integers(1, 64)),
             min_size=1, max_size=200),
    st.integers(64, 2048),
)
@settings(max_examples=30, deadline=None)
def test_cache_invariants(accesses, capacity):
    """Cache never exceeds capacity; hits+misses == gets; compulsory
    misses <= unique keys; hit rate monotone-ish wrt capacity (weak form:
    a cache with 4x capacity has >= hits)."""
    c_small = ClampiCache(capacity, 1 << 20)
    c_big = ClampiCache(capacity * 4, 1 << 20)
    for key, size in accesses:
        c_small.get(key, size)
        c_big.get(key, size)
        assert c_small.used_bytes <= capacity
        assert c_big.used_bytes <= capacity * 4
    for c in (c_small, c_big):
        st_ = c.stats
        assert st_.hits + st_.misses == st_.gets
        assert st_.compulsory_misses <= len({k for k, _ in accesses})
    assert c_big.stats.hits >= c_small.stats.hits


@given(st.integers(0, 40), st.integers(1, 40))
@settings(max_examples=30, deadline=None)
def test_static_cache_capacity(n_request, n_vertices):
    deg = np.arange(n_vertices) % 7 + 1
    sc = build_static_degree_cache(deg, n_request)
    assert sc.capacity_rows == min(n_request, n_vertices)
    slots = sc.slot_of(np.arange(n_vertices))
    resident = slots >= 0
    assert resident.sum() == sc.capacity_rows


@given(
    st.integers(1, 30),  # vocab rows
    st.lists(st.lists(st.integers(0, 29), min_size=0, max_size=6),
             min_size=1, max_size=8),
)
@settings(max_examples=30, deadline=None)
def test_embedding_bag_ragged_equals_fixed(n_rows, bags):
    """bag_ragged == bag_fixed == one-hot matmul on the same bags."""
    bags = [[t % n_rows for t in bag] for bag in bags]  # ids in range
    rng = np.random.default_rng(0)
    d = 5
    table = jnp.asarray(rng.normal(size=(n_rows, d)).astype(np.float32))
    max_len = max((len(b) for b in bags), default=1) or 1
    ids_fx = np.zeros((len(bags), max_len), np.int32)
    mask = np.zeros((len(bags), max_len), bool)
    flat, offsets = [], []
    for i, bag in enumerate(bags):
        offsets.append(len(flat))
        flat.extend(bag)
        ids_fx[i, : len(bag)] = bag
        mask[i, : len(bag)] = True
    if not flat:
        flat = [0]  # searchsorted needs nonempty; bag 0 empty stays empty
    fx = bag_fixed(table, jnp.asarray(ids_fx), jnp.asarray(mask))
    rg = bag_ragged(table, jnp.asarray(np.array(flat, np.int32)),
                    jnp.asarray(np.array(offsets, np.int32)), len(bags))
    # one-hot oracle
    want = np.zeros((len(bags), d), np.float32)
    for i, bag in enumerate(bags):
        for t in bag:
            want[i] += np.asarray(table)[t]
    np.testing.assert_allclose(np.asarray(fx), want, rtol=1e-5, atol=1e-5)
    # ragged comparison: the flat=[0] placeholder for the all-empty case
    # maps ids to the wrong bag by construction, so only compare when
    # there is at least one real id.
    if sum(len(b) for b in bags) > 0 and all(len(b) for b in bags):
        np.testing.assert_allclose(np.asarray(rg), want, rtol=1e-5, atol=1e-5)
