"""End-to-end system behaviour: the full paper pipeline in one process.

graph generation -> preprocessing (§II-B) -> single-node reference ->
1-device compiled engine -> trace simulation with caching -> baseline
claims (cache saves communication; TriC barriers cost).
"""
import numpy as np
import networkx as nx

from repro.core.lcc import (
    lcc_simulated,
    lcc_single,
    prepare_graph,
    triangle_count,
)
from repro.core.async_engine import run_distributed_lcc
from repro.core.tric_baseline import simulate_tric
from repro.graphs.rmat import rmat_edges


def test_full_pipeline_end_to_end():
    # 1. data: R-MAT edges, paper parameters
    edges = rmat_edges(9, 8, seed=1)
    n = 1 << 9

    # 2. preprocessing: simple graph + degree<2 removal + random relabel
    csr, keep = prepare_graph(edges, n, relabel_seed=3)
    assert csr.n <= n and csr.m > 0
    assert np.all(csr.degrees >= 2)

    # 3. single-node reference vs networkx
    g = nx.Graph()
    g.add_nodes_from(range(csr.n))
    src, dst = csr.edge_list()
    g.add_edges_from(zip(src.tolist(), dst.tolist()))
    want_t = sum(nx.triangles(g).values()) // 3
    assert triangle_count(csr) == want_t
    lcc = lcc_single(csr)
    want_lcc = np.array([nx.clustering(g, v) for v in range(csr.n)])
    np.testing.assert_allclose(lcc, want_lcc, rtol=1e-10)

    # 4. compiled engine (1 device) agrees
    t_dist, lcc_dist = run_distributed_lcc(csr, 1, n_rounds=2,
                                           cache_rows=16, method="hybrid")
    np.testing.assert_allclose(lcc_dist, lcc, rtol=1e-5)

    # 5. RMA trace simulation: caching reduces modeled communication
    st_plain = lcc_simulated(csr, 4)
    st_cached = lcc_simulated(
        csr, 4, adj_cache_bytes=csr.csr_nbytes() // 2,
        offsets_cache_bytes=csr.n * 8, use_degree_score=True,
    )
    assert st_cached.comm_time.sum() < st_plain.comm_time.sum()

    # 6. TriC-style BSP baseline: barrier makespan ≥ any device's own time
    tric = simulate_tric(csr, 4)
    assert tric.makespan >= tric.comm_time.max() * 0.999
    assert tric.queries.sum() == st_plain.remote_gets.sum()
