"""Device-resident hot-row tier: kernel vs oracle, residency selection /
drift coherence, two-tier freshness, and the serving/streaming routes.
"""
import numpy as np
import pytest

from conftest import powerlaw_graph

from repro.core.runtime import ShardedRuntime
from repro.device import ResidencyManager
from repro.kernels.resident_intersect import resident_intersect_counts
from repro.serving import LiveQueryService, Query, QueryKind
from repro.serving.provider import DirectRowProvider
from repro.streaming import DynamicCSR, EdgeBatch
from repro.streaming.incremental import StreamingLCCEngine
from repro.streaming.updates import DELETE, INSERT


def _random_rows(rng, n_rows, width, id_space):
    out = np.full((n_rows, width), id_space, np.int32)
    for i in range(n_rows):
        k = int(rng.integers(0, width + 1))
        out[i, :k] = np.sort(rng.choice(id_space, size=k, replace=False))
    return out


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e,wb", [(1, 4), (7, 8), (64, 16), (130, 32)])
def test_resident_intersect_matches_oracle(e, wb):
    import jax.numpy as jnp

    from repro.kernels.ref import resident_intersect_ref

    rng = np.random.default_rng(e * 31 + wb)
    sent = 500
    res = _random_rows(rng, 12, 24, sent)
    rows = _random_rows(rng, e, wb, sent)
    sa = rng.integers(0, 12, e).astype(np.int32)
    sb = rng.integers(0, 12, e).astype(np.int32)
    got = resident_intersect_counts(res, sa, rows, sentinel=sent)
    want = np.asarray(
        resident_intersect_ref(
            jnp.asarray(res), jnp.asarray(sa), jnp.asarray(rows),
            sentinel=sent,
        ),
        np.int64,
    )
    assert np.array_equal(got, want)
    got2 = resident_intersect_counts(res, sa, slots_b=sb, sentinel=sent)
    want2 = np.asarray(
        resident_intersect_ref(
            jnp.asarray(res), jnp.asarray(sa), slots_b=jnp.asarray(sb),
            sentinel=sent,
        ),
        np.int64,
    )
    assert np.array_equal(got2, want2)


def test_resident_intersect_empty_batch():
    res = np.full((4, 8), 99, np.int32)
    out = resident_intersect_counts(
        res, np.zeros(0, np.int32), np.zeros((0, 4), np.int32), sentinel=99
    )
    assert out.shape == (0,)


# ---------------------------------------------------------------------------
# residency selection + drift coherence
# ---------------------------------------------------------------------------
def test_manager_selects_degree_scored_hot_set():
    csr = powerlaw_graph(120, 6, seed=4)
    store = DynamicCSR.from_csr(csr)
    dev = ResidencyManager(store, slots=16)
    assert dev.resident_rows == 16
    deg = store.degrees
    resident = np.flatnonzero(dev.slot_of(np.arange(csr.n)) >= 0)
    threshold = np.sort(deg[resident]).min()
    outsiders = np.setdiff1d(np.arange(csr.n), resident)
    # every outsider scores no better than the weakest resident
    assert deg[outsiders].max() <= threshold
    # resident rows are bit-exact store rows, padded with the sentinel
    for v in resident[:5]:
        s = int(dev.slot_of(np.array([v]))[0])
        row = store.row(int(v))
        assert np.array_equal(dev._host[s, : row.size], row)
        assert (dev._host[s, row.size:] == dev.sentinel).all()
    assert dev.audit() == (16, 0)


def test_manager_excludes_rows_wider_than_the_buffer():
    csr = powerlaw_graph(100, 6, seed=9)
    store = DynamicCSR.from_csr(csr)
    width = int(np.sort(store.degrees)[-3])  # two rows too wide to fit
    dev = ResidencyManager(store, slots=8, max_width=width)
    resident = np.flatnonzero(dev.slot_of(np.arange(csr.n)) >= 0)
    assert (store.degrees[resident] <= width).all()
    assert dev.audit()[1] == 0


def test_patch_evict_admit_and_epoch_bumps():
    csr = powerlaw_graph(80, 5, seed=1)
    store = DynamicCSR.from_csr(csr)
    dev = ResidencyManager(
        store, slots=6, max_width=int(store.max_degree) + 8
    )
    resident = np.flatnonzero(dev.slot_of(np.arange(csr.n)) >= 0)
    hub = int(resident[np.argmax(store.degrees[resident])])
    slots, epochs = dev.claim(np.array([hub]))
    dev.check(slots, epochs)  # fresh handle passes

    # small delta -> in-place patch (same slot, bumped epoch, fresh row)
    absent = next(
        v for v in range(store.n)
        if v != hub and not store.has_edge(hub, v)
        and dev.slot_of(np.array([v]))[0] < 0
        and store.degrees[v] + 1 < store.degrees[resident].min()
    )
    store.insert_edges(np.array([[min(hub, absent), max(hub, absent)]]))
    before = dev.stats.patches
    dev.notify_batch([hub, absent])
    assert dev.stats.patches == before + 1
    assert int(dev.slot_of(np.array([hub]))[0]) == int(slots[0])
    with pytest.raises(AssertionError):
        dev.check(slots, epochs)  # pre-mutation handle is now stale
    assert dev.audit()[1] == 0

    # drift: raise an outsider's degree above the weakest resident
    resident = np.flatnonzero(dev.slot_of(np.arange(csr.n)) >= 0)
    weakest = int(resident[np.argmin(store.degrees[resident])])
    outsider = next(
        v for v in range(store.n)
        if dev.slot_of(np.array([v]))[0] < 0 and store.degrees[v] > 0
    )
    target = int(store.degrees[weakest]) + 2
    adds = [
        v for v in range(store.n)
        if v != outsider and not store.has_edge(outsider, v)
    ][: target - int(store.degrees[outsider])]
    edges = np.array(
        [[min(outsider, v), max(outsider, v)] for v in adds], np.int64
    )
    store.insert_edges(edges)
    dev.notify_batch(np.unique(edges.ravel()).tolist())
    assert int(dev.slot_of(np.array([outsider]))[0]) >= 0, "admitted"
    assert dev.stats.admits >= 1 and dev.stats.evicts >= 1
    assert dev.audit()[1] == 0


# ---------------------------------------------------------------------------
# two-tier coherence property (satellite): after ANY insert/delete
# stream, device-tier reads are bit-identical to DirectRowProvider reads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [1, 4])
def test_two_tier_reads_match_direct_provider_after_any_stream(p):
    csr = powerlaw_graph(96, 5, seed=20 + p)
    rt = ShardedRuntime(None, p, n=csr.n, device_slots=12)
    eng = StreamingLCCEngine(csr, use_kernel=False, runtime=rt)
    direct = DirectRowProvider(eng.store, p=p)
    direct.runtime.bind_store(eng.store)
    rng = np.random.default_rng(100 + p)
    probe = np.arange(csr.n)
    for _ in range(5):
        ins = rng.integers(0, csr.n, size=(25, 2))
        src, dst = eng.store.to_csr().edge_list()
        keep = src < dst
        pool = np.stack([src[keep], dst[keep]], 1)
        pick = rng.choice(pool.shape[0], size=min(10, pool.shape[0]),
                          replace=False)
        u = np.concatenate([ins[:, 0], pool[pick][:, 0]])
        v = np.concatenate([ins[:, 1], pool[pick][:, 1]])
        op = np.concatenate([
            np.full(ins.shape[0], INSERT, np.int8),
            np.full(pick.size, DELETE, np.int8),
        ])
        eng.apply_batch(EdgeBatch(u=u, v=v, op=op))
        for rank in range(p):
            got = rt.fetch_rows(rank, probe)
            want = direct.runtime.fetch_rows(rank, probe)
            for w in probe:
                assert np.array_equal(got[int(w)], want[int(w)]), (
                    f"rank {rank} vertex {w} diverged from the direct read"
                )
        # no stale resident slot survives the batch's invalidate
        assert rt.device.audit()[1] == 0
        assert rt.audit_freshness()[1] == 0
    assert rt.device.stats.hits > 0, "the tier must actually serve reads"
    if p > 1:  # at p=1 every fetch_rows read is local (and free)
        agg = rt.aggregate_stats()
        assert agg.device_hits > 0 and agg.device_bytes_saved > 0


def test_fetch_rows_consults_device_before_host_cache():
    csr = powerlaw_graph(80, 6, seed=3)
    store = DynamicCSR.from_csr(csr)
    rt = ShardedRuntime(store, 4, device_slots=8)
    resident = np.flatnonzero(rt.device.slot_of(np.arange(csr.n)) >= 0)
    v = int(resident[0])
    rank = (int(rt.part.owner(v)) + 1) % 4  # remote at this rank
    rows = rt.fetch_rows(rank, [v, v])
    assert np.array_equal(rows[v], store.row(v))
    st = rt.stats[rank]
    assert st.device_hits == 2
    assert st.cache_hits == 0 and st.cache_misses == 0
    assert st.bytes_fetched == 0  # never reached the host cache/network
    assert not rt.caches[rank].contains(v)


# ---------------------------------------------------------------------------
# consumers: serving + streaming stay bit-exact with the tier on
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p,cross_rank", [(1, False), (4, False), (4, True)])
def test_serving_with_device_tier_bit_exact_under_updates(p, cross_rank):
    from repro.core.triangles import lcc_scores, triangles_per_vertex

    csr = powerlaw_graph(90, 6, seed=40 + p)
    svc = LiveQueryService(
        csr, p=p, cross_rank=cross_rank, device_slots=10, use_kernel=True
    )
    rng = np.random.default_rng(41 + p)
    for _ in range(3):
        qs = []
        for v in rng.integers(0, csr.n, 24):
            qs.append(
                Query.lcc(int(v)) if v % 2 else Query.triangles(int(v))
            )
        u, w = rng.integers(0, csr.n, 2)
        if u != w:
            qs.append(Query.common_neighbors(int(u), int(w)))
        results = svc.scheduler.run(qs)
        snap = svc.store.to_csr()
        t_ref = triangles_per_vertex(snap)
        lcc_ref = lcc_scores(snap, t_ref)
        for r in results:
            q = r.query
            if q.kind == QueryKind.TRIANGLES:
                assert r.value == t_ref[q.u]
            elif q.kind == QueryKind.LCC:
                assert r.value == lcc_ref[q.u]
            else:
                want = np.intersect1d(snap.row(q.u), snap.row(q.v))
                assert r.value == want.size and np.array_equal(r.ids, want)
        e = rng.integers(0, csr.n, size=(20, 2))
        svc.apply_updates(EdgeBatch.inserts(e[e[:, 0] != e[:, 1]]))
    svc.verify()  # streaming recount + zero stale rows on BOTH tiers
    assert svc.engine.n_pairs_resident > 0
    assert svc.runtime.device.stats.bytes_saved > 0


@pytest.mark.parametrize("p", [1, 4])
def test_streaming_oo_resident_kernel_bit_exact(p):
    csr = powerlaw_graph(96, 6, seed=60 + p)
    rt = ShardedRuntime(None, p, n=csr.n, device_slots=16)
    eng = StreamingLCCEngine(csr, use_kernel=True, runtime=rt)
    rng = np.random.default_rng(61 + p)
    for _ in range(4):
        ins = rng.integers(0, csr.n, size=(30, 2))
        src, dst = eng.store.to_csr().edge_list()
        keep = src < dst
        pool = np.stack([src[keep], dst[keep]], 1)
        pick = rng.choice(pool.shape[0], size=8, replace=False)
        u = np.concatenate([ins[:, 0], pool[pick][:, 0]])
        v = np.concatenate([ins[:, 1], pool[pick][:, 1]])
        op = np.concatenate([
            np.full(ins.shape[0], INSERT, np.int8),
            np.full(8, DELETE, np.int8),
        ])
        eng.apply_batch(EdgeBatch(u=u, v=v, op=op))
        eng.verify()  # checkpoints bit-exact vs recount
    assert eng.oo_resident_pairs > 0, "resident pairs must route on-device"
    assert rt.device.stats.bytes_saved > 0
    assert rt.device.audit()[1] == 0
