"""Distributed engine correctness.

In-process: p=1 (degenerate mesh). Multi-device: subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax pins device count
at first init, and the rest of the suite must see 1 device).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import powerlaw_graph, random_graph

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_engine_p1_matches_reference():
    from repro.core.async_engine import run_distributed_lcc
    from repro.core.triangles import lcc_scores, triangles_per_vertex

    csr = powerlaw_graph(80, 6, seed=0)
    t, lcc = run_distributed_lcc(csr, 1, n_rounds=2)
    assert np.array_equal(t, triangles_per_vertex(csr))
    np.testing.assert_allclose(lcc, lcc_scores(csr), rtol=1e-5)


def test_engine_p1_hybrid_matches():
    from repro.core.async_engine import run_distributed_lcc
    from repro.core.triangles import triangles_per_vertex

    csr = random_graph(64, 8, seed=1)
    t, _ = run_distributed_lcc(csr, 1, n_rounds=1, method="hybrid")
    assert np.array_equal(t, triangles_per_vertex(csr))


MULTIDEV_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json
import numpy as np
from repro.graphs.datasets import powerlaw_graph
from repro.core.async_engine import run_distributed_lcc
from repro.core.tric_baseline import tric_lcc_jnp
from repro.core.triangles import lcc_scores, triangles_per_vertex
from repro.core.partition import partition_1d

out = {}
csr = powerlaw_graph(160, 8, seed=0)
want_t = triangles_per_vertex(csr)
want_lcc = lcc_scores(csr)

for p in (2, 4, 8):
    for cache_rows in (0, 16):
        t, lcc = run_distributed_lcc(
            csr, p, n_rounds=3, cache_rows=cache_rows, method="bsearch"
        )
        out[f"p{p}_c{cache_rows}_t_ok"] = bool(np.array_equal(t, want_t))
        out[f"p{p}_c{cache_rows}_lcc_ok"] = bool(
            np.allclose(lcc, want_lcc, rtol=1e-5)
        )

# hybrid method on 4 devices
t, _ = run_distributed_lcc(csr, 4, n_rounds=2, cache_rows=8, method="hybrid")
out["hybrid_ok"] = bool(np.array_equal(t, want_t))

# TriC BSP baseline must also be exact
t2, lcc2 = tric_lcc_jnp(csr, 4)
part = partition_1d(csr.n, 4)
t2g = np.concatenate([t2[k, : part.hi(k) - part.lo(k)] for k in range(4)])
out["tric_ok"] = bool(np.array_equal(t2g, want_t))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def multidev_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_multidevice_exact(multidev_results):
    for k, v in multidev_results.items():
        assert v, f"{k} failed"
