"""Hub-replication gather (the paper's cache applied to GNN/recsys reads)."""
import numpy as np
import jax.numpy as jnp

from repro.distributed.hub_gather import hub_gather, split_hot_cold


def test_split_hot_cold_plan():
    scores = np.array([1.0, 100.0, 2.0, 50.0, 3.0])
    ids = np.array([0, 1, 1, 3, 4, 2])
    plan = split_hot_cold(ids, scores, capacity=2)
    assert set(plan.hot_ids.tolist()) == {1, 3}
    assert plan.is_hot.tolist() == [False, True, True, True, False, False]


def test_hub_gather_matches_plain_gather():
    rng = np.random.default_rng(0)
    n, d, k, c = 50, 8, 30, 10
    table = rng.normal(size=(n, d)).astype(np.float32)
    scores = rng.random(n)
    ids = rng.integers(0, n, k)
    plan = split_hot_cold(ids, scores, capacity=c)
    hot_table = table[plan.hot_ids]
    got = hub_gather(
        jnp.asarray(table), jnp.asarray(hot_table), jnp.asarray(ids),
        jnp.asarray(plan.is_hot), jnp.asarray(plan.hot_pos),
    )
    np.testing.assert_allclose(np.asarray(got), table[ids], rtol=1e-6)


def test_hot_rate_on_powerlaw_traffic():
    """Zipf traffic + popularity-scored cache -> high hit fraction with a
    small cache (the paper's Observation 3.1 for embedding rows)."""
    rng = np.random.default_rng(1)
    n = 10_000
    traffic = (rng.zipf(1.3, size=5000) - 1) % n
    counts = np.bincount(traffic, minlength=n)
    plan = split_hot_cold(traffic, counts.astype(float), capacity=n // 100)
    assert plan.is_hot.mean() > 0.5, "1% cache should absorb >50% of zipf"


def test_gat_hub_split_matches_plain():
    """GAT with hub-split edge streams == plain GAT (exact)."""
    import jax
    import jax.numpy as jnp
    from repro.models.gnn import gat

    rng = np.random.default_rng(2)
    n, e, c = 40, 150, 8
    cfg = gat.GATConfig(n_layers=2, d_hidden=4, n_heads=2, d_in=12,
                        n_classes=3)
    params = gat.init_params(cfg, jax.random.key(0))
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) < 0.9
    feat = rng.normal(size=(n, cfg.d_in)).astype(np.float32)
    plain = {
        "node_feat": jnp.asarray(feat), "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst), "edge_mask": jnp.asarray(mask),
        "node_mask": jnp.ones(n, bool),
    }
    y_plain = gat.apply(params, plain, cfg)
    # hub split: top-c by in-edge count, separate cold/hot streams
    deg = np.bincount(src, minlength=n)
    hub = np.sort(np.argsort(deg)[::-1][:c]).astype(np.int32)
    hubset = {int(v): i for i, v in enumerate(hub)}
    is_hot = np.array([int(s) in hubset for s in src])
    split = {
        "node_feat": jnp.asarray(feat),
        "edge_src_cold": jnp.asarray(src[~is_hot]),
        "edge_src_hub_pos": jnp.asarray(
            np.array([hubset[int(s)] for s in src[is_hot]], np.int32)),
        "hub_ids": jnp.asarray(hub),
        "edge_dst_cold": jnp.asarray(dst[~is_hot]),
        "edge_dst_hot": jnp.asarray(dst[is_hot]),
        "edge_mask_cold": jnp.asarray(mask[~is_hot]),
        "edge_mask_hot": jnp.asarray(mask[is_hot]),
        "node_mask": jnp.ones(n, bool),
    }
    y_split = gat.apply(params, split, cfg)
    np.testing.assert_allclose(np.asarray(y_split), np.asarray(y_plain),
                               rtol=1e-5, atol=1e-5)
