"""Traffic plane: arrivals, SLO scheduling, tenancy, live cache scores.

Covers the ISSUE's edge cases explicitly: an all-expired window (every
pending query past its class deadline at poll time), a mixed-class
urgent flush (EDF selection under a priority trigger), and a
quota-exhausted tenant (token bucket empty at the admission door) —
all under an injectable ``VirtualClock`` so no test sleeps.
"""
import dataclasses

import numpy as np
import pytest

from conftest import powerlaw_graph
from repro.core.cache import ClampiCache
from repro.serving import (
    LiveQueryService,
    MicrobatchScheduler,
    Query,
    QueryEngine,
    make_queries,
)
from repro.streaming import DynamicCSR
from repro.traffic import (
    ArrivalTrace,
    HybridClock,
    SLOPolicy,
    TenantQuotas,
    TenantSpec,
    TokenBucket,
    VirtualClock,
    WorkloadScorer,
    assign_tenants,
    burst_arrivals,
    diurnal_arrivals,
    make_arrivals,
    poisson_arrivals,
    run_open_loop,
)

MIX = (0.5, 0.3, 0.2, 0.0)


def _engine(n=40, seed=21):
    csr = powerlaw_graph(n, 4, seed=seed)
    store = DynamicCSR.from_csr(csr)
    return QueryEngine(store, use_kernel=False)


# ---------------------------------------------------------------------------
# arrival processes + clocks
# ---------------------------------------------------------------------------
def test_poisson_arrivals_deterministic_and_calibrated():
    a = poisson_arrivals(4000, 250.0, seed=3)
    b = poisson_arrivals(4000, 250.0, seed=3)
    assert np.array_equal(a.t, b.t)
    assert np.all(np.diff(a.t) >= 0)
    assert a.measured_qps == pytest.approx(250.0, rel=0.1)
    assert poisson_arrivals(100, 250.0, seed=4).t[1] != a.t[1]


def test_diurnal_and_burst_arrivals_sorted_and_reproducible():
    for mk in (diurnal_arrivals, burst_arrivals):
        a = mk(500, 100.0, seed=5)
        assert np.all(np.diff(a.t) >= 0)
        assert np.array_equal(a.t, mk(500, 100.0, seed=5).t)
    # burst process actually bursts: max instantaneous rate over a
    # window well above the offered average
    a = burst_arrivals(2000, 100.0, seed=6)
    gaps = np.diff(a.t)
    assert np.percentile(gaps, 10) < 0.2 / 100.0  # in-burst gaps tight


def test_arrival_trace_round_trip(tmp_path):
    a = poisson_arrivals(64, 50.0, seed=7)
    p = str(tmp_path / "arr.json")
    a.save(p)
    b = ArrivalTrace.load(p)
    assert np.array_equal(a.t, b.t) and b.process == a.process
    # trace: replays the file verbatim — n/rate are ignored
    c = make_arrivals(f"trace:{p}", 32, 999.0)
    assert np.array_equal(c.t, a.t)


def test_arrival_trace_rejects_unsorted():
    with pytest.raises(AssertionError):
        ArrivalTrace(t=np.asarray([0.2, 0.1]), process="x",
                     offered_qps=1.0)


def test_virtual_clock_monotone_and_hybrid_floor():
    c = VirtualClock()
    c.advance(0.5)
    c.advance_to(0.3)  # behind: no-op
    assert c() == pytest.approx(0.5)
    with pytest.raises(AssertionError):
        c.advance(-0.1)
    h = HybridClock(start=10.0)
    t0 = h()
    assert t0 >= 10.0
    h.advance_to(t0 - 5.0)  # past: no-op
    assert h() >= t0
    h.advance_to(t0 + 100.0)
    assert h() >= t0 + 100.0


# ---------------------------------------------------------------------------
# scheduler: SLO deadlines, EDF, shedding
# ---------------------------------------------------------------------------
def test_all_expired_window_sheds_everything():
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=8, clock=clk,
                                slo=SLOPolicy())
    sched.submit(Query.lcc(1))                 # deadline 0.100
    sched.submit(Query.common_neighbors(2, 3))  # deadline 0.050
    clk.advance(5.0)  # everything long expired
    assert sched.poll() == []
    assert sched.pending == 0 and sched.n_shed_slo == 2
    s = sched.latency_summary()
    assert s.shed_by_class == {"common_neighbors": 1, "lcc": 1}
    assert s.shed_rate_by_class["lcc"] == 1.0
    assert s.slo_hit_rate == 0.0  # nothing served, everything shed


def test_query_at_exact_deadline_rides_the_flush():
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=8, clock=clk,
                                slo=SLOPolicy())
    sched.submit(Query.lcc(1), at=0.0)
    clk.advance_to(sched.next_due_at())  # exactly deadline - headroom
    res = sched.poll()
    assert len(res) == 1 and sched.n_slo_flushes == 1
    assert sched.n_shed_slo == 0  # shed is strictly past deadline


def test_mixed_class_urgent_flush_uses_edf_selection():
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=4, clock=clk,
                                slo=SLOPolicy())
    sched.submit(Query.lcc(1))                  # deadline 0.100
    sched.submit(Query.lcc(2))                  # deadline 0.100
    sched.submit(Query.common_neighbors(3, 4), urgent=True)  # 0.050
    res = sched.poll()  # pending < max_batch: urgent is the trigger
    # all three fit the window, executed in submit order
    assert [r.query.u for r in res] == [1, 2, 3]
    assert sched.n_priority_flushes == 1
    assert sched.pending == 0
    s = sched.latency_summary()
    assert s.count == 3 and s.shed == 0
    assert s.slo_hit_rate == 1.0  # virtual time: served instantly


def test_edf_lets_tight_deadline_jump_fifo_queue():
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=2, clock=clk,
                                slo=SLOPolicy())
    sched.submit(Query.lcc(1), at=0.0)
    sched.submit(Query.lcc(2), at=0.0)
    sched.submit(Query.lcc(3), at=0.0)
    # late arrival, tighter class: deadline 0.051 beats every lcc's 0.100
    sched.submit(Query.common_neighbors(5, 6), at=0.001)
    clk.advance_to(0.051)
    res = sched.poll()
    assert [r.query.u for r in res[:2]] == [1, 5]  # cn jumped 2 and 3


def test_quota_exhausted_tenant_sheds_at_the_door():
    clk = VirtualClock()
    quotas = TenantQuotas([TenantSpec("a", rate_qps=1.0, burst=2.0)])
    sched = MicrobatchScheduler(_engine(), max_batch=64, clock=clk,
                                quotas=quotas)
    qa = dataclasses.replace(Query.lcc(1), tenant="a")
    assert sched.submit(qa) and sched.submit(qa)
    assert not sched.submit(qa)  # burst of 2 exhausted at t=0
    assert sched.n_shed_quota == 1 and sched.pending == 2
    # untagged traffic is never rate-limited
    assert sched.submit(Query.lcc(2))
    # bucket refills at 1 token/s under the virtual clock
    clk.advance(1.0)
    assert sched.submit(qa)
    assert quotas.rejected["a"] == 1 and quotas.admitted["a"] == 3
    assert sched.latency_summary().shed_by_class == {"lcc": 1}


def test_slo_violation_counted_when_served_late():
    clk = VirtualClock()
    # shed disabled would be ideal; instead serve late via urgent flush
    # after the deadline cannot happen (shed first). Use the recorder
    # contract directly through a deadline-stamped late completion:
    sched = MicrobatchScheduler(_engine(), max_batch=1, clock=clk,
                                slo=SLOPolicy())
    sched.submit(Query.lcc(1))  # max_batch=1: window full, dispatches
    res = sched.poll()
    assert len(res) == 1
    s = sched.latency_summary()
    # VirtualClock never advances during compute: served in 0s, no
    # violation, perfect attainment
    assert s.slo_violations == 0 and s.slo_hit_rate == 1.0
    sched.recorder.record(1.0, cls="lcc", deadline_s=0.1)  # late serve
    assert sched.latency_summary().slo_violations == 1


def test_next_due_at_tracks_earliest_slo_deadline():
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=8, clock=clk,
                                slo=SLOPolicy(headroom_s=0.01),
                                max_wait=1.0)
    assert sched.next_due_at() is None
    sched.submit(Query.lcc(1), at=0.0)
    assert sched.next_due_at() == pytest.approx(0.09)  # 0.1 - headroom
    sched.submit(Query.common_neighbors(2, 3), at=0.0)
    assert sched.next_due_at() == pytest.approx(0.04)


# ---------------------------------------------------------------------------
# tenancy: token bucket + cache shares
# ---------------------------------------------------------------------------
def test_token_bucket_refills_lazily():
    b = TokenBucket(rate=10.0, burst=4.0)
    assert all(b.try_take(0.0) for _ in range(4))
    assert not b.try_take(0.0)
    assert b.try_take(0.25)  # 2.5 tokens refilled
    assert b.level(0.25) == pytest.approx(1.5)
    assert b.level(100.0) == pytest.approx(4.0)  # capped at burst


def test_tenant_quotas_shares_normalized_and_uniform():
    q = TenantQuotas.uniform(4)
    assert sorted(q.tenants) == ["t0", "t1", "t2", "t3"]
    assert sum(q.cache_shares().values()) == pytest.approx(1.0)
    over = TenantQuotas([TenantSpec("a", cache_share=0.8),
                         TenantSpec("b", cache_share=0.8)])
    shares = over.cache_shares()
    assert sum(shares.values()) == pytest.approx(1.0)
    assert q.admit("unknown", 0.0)  # unknown tags pass, uncounted


def test_assign_tenants_deterministic_and_weighted():
    qs = [Query.lcc(i) for i in range(200)]
    a = assign_tenants(qs, ["x", "y"], rng=np.random.default_rng(3))
    b = assign_tenants(qs, ["x", "y"], rng=np.random.default_rng(3))
    assert [q.tenant for q in a] == [q.tenant for q in b]
    w = assign_tenants(qs, ["x", "y"], rng=np.random.default_rng(3),
                       weights={"x": 9.0, "y": 1.0})
    assert sum(q.tenant == "x" for q in w) > 150


def test_cache_tenant_shares_cap_and_accounting():
    c = ClampiCache(1000, 64)
    c.set_tenant_shares({"a": 0.5, "b": 0.5})
    for k in range(10):  # tenant a floods: 10 x 100B > 500B cap
        c.get(k, 100, score=float(k), tenant="a")
    tb = c.tenant_bytes()
    assert tb.get("a", 0) <= 500
    assert sum(tb.values()) == c.used_bytes
    # b's reservation is still available
    c.get(100, 100, score=0.5, tenant="b")
    assert c.tenant_bytes()["b"] == 100
    # a cannot evict b to grow: b's entry survives a's further flood
    for k in range(10, 20):
        c.get(k, 100, score=float(k), tenant="a")
    assert c.tenant_bytes()["b"] == 100
    assert sum(c.tenant_bytes().values()) == c.used_bytes


def test_cache_hit_keeps_first_fetcher_tag():
    c = ClampiCache(1000, 64)
    c.set_tenant_shares({"a": 0.5, "b": 0.5})
    c.get(1, 100, score=1.0, tenant="a")  # miss: a fetches, a owns
    assert c.get(1, 100, score=1.0, tenant="b")  # hit: still a's byte
    assert c.tenant_bytes() == {"a": 100}


def test_cache_shares_validation():
    c = ClampiCache(1000, 64)
    with pytest.raises(AssertionError):
        c.set_tenant_shares({"a": 0.7, "b": 0.7})
    with pytest.raises(AssertionError):
        c.set_tenant_shares({"a": 0.0})


# ---------------------------------------------------------------------------
# workload scorer
# ---------------------------------------------------------------------------
def test_scorer_matches_cachescope_formula():
    sc = WorkloadScorer(blend=1.0, decay=0.5)
    sc.observe(7)          # t=1: f = 1
    sc.observe(9)          # t=2
    sc.observe(7)          # t=3: f = 1 + 1 * 0.5**2 = 1.25
    assert sc.freq(7) == pytest.approx(1.25)
    assert sc.freq(9) == pytest.approx(1.0 * 0.5)  # decayed to t=3
    assert sc.freq(42) == 0.0


def test_scorer_blend_and_score_array_consistent():
    sc = WorkloadScorer(blend=0.7, decay=0.9)
    deg = np.asarray([10.0, 5.0, 0.0])
    sc.set_degree_scale(10.0)
    for _ in range(5):
        sc.observe(1)
    a = sc.score_array(deg)
    assert a.shape == (3,)
    for v in range(3):
        assert a[v] == pytest.approx(sc.cache_score(v, deg[v]))
    assert a[1] > sc.cache_score(0, 10.0) * 0  # hot low-degree row scores
    # blend < 1 keeps never-accessed rows positive (device-tier filter)
    assert sc.cache_score(0, 10.0) > 0.0


# ---------------------------------------------------------------------------
# open loop end to end
# ---------------------------------------------------------------------------
def _service(csr, **kw):
    return LiveQueryService(csr, p=4, cache_bytes=1 << 16, max_batch=16,
                            **kw)


def test_open_loop_bit_exact_vs_closed_loop():
    csr = powerlaw_graph(60, 4, seed=31)
    qs = make_queries(csr.degrees, 50, kind="zipf", mix=MIX, seed=32)
    closed = _service(csr).scheduler.run(qs)
    clk = VirtualClock()
    svc = _service(csr, clock=clk)
    rep = run_open_loop(svc.scheduler, qs,
                        poisson_arrivals(len(qs), 100.0, seed=33),
                        clock=clk)
    assert rep.n_served == len(qs)
    want = {}
    for r in closed:
        want[(r.query.kind, r.query.u, r.query.v, r.query.k)] = r.value
    for r in rep.results:
        q = r.query
        assert r.value == want[(q.kind, q.u, q.v, q.k)]


def test_open_loop_deterministic_under_virtual_clock():
    csr = powerlaw_graph(60, 4, seed=34)
    qs = make_queries(csr.degrees, 40, kind="zipf", mix=MIX, seed=35)
    arr = poisson_arrivals(len(qs), 200.0, seed=36)

    def once():
        clk = VirtualClock()
        svc = _service(csr, clock=clk, slo=SLOPolicy(headroom_s=0.005))
        rep = run_open_loop(svc.scheduler, qs, arr, clock=clk)
        s = rep.summary
        return (rep.n_served, s.p50_ms, s.p99_ms, s.shed_by_class)

    assert once() == once()


def test_open_loop_counts_queueing_delay_from_arrival_stamp():
    # submit(at=) backdates: a query whose submit call runs late still
    # measures latency from its schedule arrival
    clk = VirtualClock()
    sched = MicrobatchScheduler(_engine(), max_batch=1, clock=clk)
    clk.advance(2.0)  # the server is 2s behind schedule
    sched.submit(Query.lcc(1), at=0.5)
    res = sched.poll()
    assert res[0].latency_s == pytest.approx(1.5)


def test_service_tenant_accounting_sums_and_metrics_registry():
    csr = powerlaw_graph(80, 4, seed=37)
    quotas = TenantQuotas.uniform(2, rate_qps=1e6, burst=1e6)
    svc = _service(csr, quotas=quotas,
                   scorer=WorkloadScorer(blend=0.5))
    qs = assign_tenants(
        make_queries(csr.degrees, 60, kind="zipf", mix=MIX, seed=38),
        quotas.tenants, rng=np.random.default_rng(39))
    svc.scheduler.run(qs)
    for c in svc.runtime.caches:
        assert sum(c.tenant_bytes().values()) == c.used_bytes
    reg = svc.metrics_registry()
    assert reg.total("quota_admitted", tier="serving") == 60
    got = sum(v for (name, _, tier, _), v in reg.counters().items()
              if name.startswith("tenant_cache_bytes:")
              and tier == "host_cache")
    assert got == sum(c.used_bytes for c in svc.runtime.caches)
    # per-tenant transport attribution flattened out of ProviderStats
    assert reg.total("tenant_requests:t0", tier="host") > 0
