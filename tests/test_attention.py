import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import flash_attention_jnp


def dense_ref(q, k, v, *, scale, causal, window, softcap):
    b, s, kh, g, dh = q.shape
    t = k.shape[1]
    srs = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32) * scale,
                     k.astype(jnp.float32))
    if softcap > 0:
        srs = softcap * jnp.tanh(srs / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    srs = jnp.where(mask[None, None, None], srs, -1e30)
    w = jax.nn.softmax(srs, -1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0),
    (True, 64, 0.0),
    (True, 0, 50.0),
    (True, 32, 50.0),
    (False, 0, 0.0),
])
def test_flash_matches_dense(causal, window, softcap):
    rng = np.random.default_rng(0)
    b, s, kh, g, dh = 2, 256, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    scale = 1.0 / math.sqrt(dh)
    want = dense_ref(q, k, v, scale=scale, causal=causal, window=window,
                     softcap=softcap)
    got = flash_attention_jnp(
        q, k, v, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=64, block_k=64,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_shape_independence():
    rng = np.random.default_rng(1)
    b, s, kh, g, dh = 1, 128, 1, 4, 8
    q = jnp.asarray(rng.normal(size=(b, s, kh, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    outs = [
        flash_attention_jnp(q, k, v, scale=0.3, block_q=bq, block_k=bk)
        for bq, bk in [(16, 16), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)
