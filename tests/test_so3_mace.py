"""SO(3) machinery + MACE equivariance tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.gnn import so3
from repro.models.gnn.mace import MACEConfig, apply, init_params


def random_rotation(seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def test_cg_selection_rules():
    # outside the triangle inequality -> zero tensors would assert; check
    # known couplings exist and are normalized sensibly
    for (l1, l2, l3) in [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1),
                         (2, 2, 2), (2, 2, 0)]:
        c = so3.cg_real(l1, l2, l3)
        assert c.shape == (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1)
        assert np.abs(c).max() > 1e-3, (l1, l2, l3)


def test_cg_l1l1_l0_is_dot_product():
    """(v1 x v2)_{l=0} must be proportional to the dot product."""
    c = so3.cg_real(1, 1, 0)[:, :, 0]
    # proportional to identity in the real basis (numerical intertwiner:
    # precision floor ~1e-6 from the lstsq Wigner matrices)
    off = c - np.diag(np.diag(c))
    assert np.abs(off).max() < 1e-5
    d = np.diag(c)
    assert np.allclose(d, d[0], atol=1e-5) and abs(d[0]) > 0.1


def test_sph_harm_norm_invariance():
    """|Y_l(Rv)| == |Y_l(v)| for every l (rotation preserves the norm)."""
    rng = np.random.default_rng(1)
    v = rng.normal(size=(32, 3))
    rot = random_rotation(2)
    for l in range(4):
        y1 = np.asarray(so3.real_sph_harm(jnp.asarray(v), l)[l])
        y2 = np.asarray(so3.real_sph_harm(jnp.asarray(v @ rot.T), l)[l])
        np.testing.assert_allclose(
            np.linalg.norm(y1, axis=-1), np.linalg.norm(y2, axis=-1),
            rtol=1e-5,
        )


def test_sph_harm_wigner_consistency():
    """Y(Rv) == D(R) Y(v) with D recovered by least squares (pins that the
    SH components transform linearly under rotation — true equivariance)."""
    rot = random_rotation(3)
    rng = np.random.default_rng(4)
    v = rng.normal(size=(64, 3))
    for l in (1, 2):
        d = so3.wigner_d_real(l, rot)
        y = np.asarray(so3.real_sph_harm(jnp.asarray(v), l)[l])
        yr = np.asarray(so3.real_sph_harm(jnp.asarray(v @ rot.T), l)[l])
        np.testing.assert_allclose(yr, y @ d.T, atol=1e-5)
        # D must be orthogonal
        np.testing.assert_allclose(d @ d.T, np.eye(2 * l + 1), atol=1e-5)


def test_cg_coupling_rotation_invariant_norm():
    """||C(Y_l1(Rv1), Y_l2(Rv2))|| == ||C(Y_l1(v1), Y_l2(v2))||."""
    rng = np.random.default_rng(5)
    v1 = rng.normal(size=(16, 3))
    v2 = rng.normal(size=(16, 3))
    rot = random_rotation(6)
    for (l1, l2, l3) in [(1, 1, 2), (2, 1, 1), (2, 2, 2)]:
        c = so3.cg_real(l1, l2, l3)

        def coupled(a, b):
            ya = np.asarray(so3.real_sph_harm(jnp.asarray(a), l1)[l1])
            yb = np.asarray(so3.real_sph_harm(jnp.asarray(b), l2)[l2])
            return np.einsum("na,nb,abc->nc", ya, yb, c)

        f = coupled(v1, v2)
        fr = coupled(v1 @ rot.T, v2 @ rot.T)
        np.testing.assert_allclose(
            np.linalg.norm(f, axis=-1), np.linalg.norm(fr, axis=-1),
            rtol=1e-5,
        )


def mace_batch(rng, n=20, e=60):
    pos = rng.normal(size=(n, 3)).astype(np.float32) * 2.0
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    return {
        "node_feat": rng.integers(0, 4, n).astype(np.int32),
        "positions": jnp.asarray(pos),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.asarray(np.ones(e, bool)),
        "node_mask": jnp.asarray(np.ones(n, bool)),
    }


def test_mace_energy_rotation_invariant():
    cfg = MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    batch = mace_batch(rng)
    _, e1 = apply(params, batch, cfg)
    rot = jnp.asarray(random_rotation(8).astype(np.float32))
    batch2 = dict(batch, positions=batch["positions"] @ rot.T)
    _, e2 = apply(params, batch2, cfg)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)


def test_mace_energy_translation_invariant():
    cfg = MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(9)
    batch = mace_batch(rng)
    _, e1 = apply(params, batch, cfg)
    batch2 = dict(batch, positions=batch["positions"] + 5.0)
    _, e2 = apply(params, batch2, cfg)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)


def test_mace_forces_exist():
    """Energy is differentiable wrt positions (forces) and finite."""
    cfg = MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(10)
    batch = mace_batch(rng)

    def energy(pos):
        return apply(params, dict(batch, positions=pos), cfg)[1]

    f = jax.grad(energy)(batch["positions"])
    assert np.all(np.isfinite(np.asarray(f)))
    assert np.abs(np.asarray(f)).max() > 0
