"""Hub-aware partitioning: contract, fragments, migration, bit-exactness.

The contract every consumer shares (docs/partitioning.md): blocks tile
[0, n) contiguously, owner() inverts lo()/hi(), sizes() sums to n.
Hub splitting and online migration must change WHERE rows live and HOW
hub rows ship — never WHAT a query or checkpoint computes.
"""
import numpy as np
import pytest

from conftest import powerlaw_graph

from repro.core.partition import (
    HubPartition,
    Partition1D,
    balanced_cuts,
    default_hub_threshold,
    local_block,
    partition_1d,
    partition_hub,
)
from repro.core.repartition import Rebalancer, plan_repartition
from repro.core.runtime import ShardedRuntime
from repro.streaming.incremental import StreamingLCCEngine
from repro.streaming.updates import EdgeBatch


def _contract(part, n, p):
    """The shared owner/lo/hi/sizes/block invariants."""
    assert part.sizes().sum() == n
    assert part.lo(0) == 0 and part.hi(p - 1) == n
    for k in range(p):
        lo, hi = part.lo(k), part.hi(k)
        assert 0 <= lo <= hi <= n
        assert hi - lo == part.sizes()[k] <= part.block
        if k + 1 < p:
            assert hi == part.lo(k + 1)  # contiguous, no gaps
        if hi > lo:
            assert np.all(part.owner(np.arange(lo, hi)) == k)
    if n:
        v = np.arange(n)
        owners = part.owner(v)
        assert owners.min() >= 0 and owners.max() < p


@pytest.mark.parametrize("n,p", [(0, 4), (1, 4), (7, 3), (256, 4),
                                 (3, 8), (5, 16)])
def test_contract_both_families(n, p):
    """Both families honor the contract — including p > n, where the
    trailing ranks own empty blocks."""
    _contract(partition_1d(n, p), n, p)
    rng = np.random.default_rng(0)
    deg = rng.integers(0, 50, size=n)
    _contract(partition_hub(deg, p), n, p)


def test_hub_threshold_boundary():
    """Degree == threshold is a hub; threshold - 1 is not."""
    deg = np.array([1, 9, 10, 11, 2, 10], np.int64)
    part = partition_hub(deg, 2, threshold=10)
    assert part.threshold == 10
    assert np.array_equal(part.hubs, [2, 3, 5])
    assert bool(part.is_hub(2)) and bool(part.is_hub(3))
    assert not bool(part.is_hub(1))  # deg 9 < threshold
    assert np.array_equal(part.is_hub([0, 2, 3, 4]),
                          [False, True, True, False])


def test_single_dominant_hub():
    """One vertex holding most of the edges: its weight is clipped at
    the threshold (fragmentation spreads the rest), so the remaining
    ranks still receive non-degenerate blocks, and its fragments
    reassemble exactly."""
    n, p = 64, 4
    deg = np.ones(n, np.int64)
    deg[17] = 10_000
    # default threshold: contract holds even when the hub outweighs
    # whole blocks (cuts cannot split a vertex — blocks may be empty)
    _contract(partition_hub(deg, p), n, p)
    # explicit clip: the hub's above-threshold cost is fragmented away,
    # so every rank still receives a non-degenerate block
    part = partition_hub(deg, p, threshold=10)
    assert np.array_equal(part.hubs, [17])
    assert (part.sizes() > 0).all()
    row = np.arange(10_000, dtype=np.int32)
    frags = [part.fragment(row, k) for k in range(p)]
    assert np.array_equal(np.concatenate(frags), row)
    assert np.array_equal(part.fragment_sizes(row.size),
                          [f.size for f in frags])
    # round-robin routing spreads hub work off the owner
    assert part.route(17) == 0 % p
    assert part.route(0) == int(part.owner(0))


def test_fragment_reduction_additive():
    """|A ∩ B| == sum_k |A ∩ frag_k(B)| for sorted rows (fragments are
    disjoint)."""
    rng = np.random.default_rng(3)
    part = partition_hub(np.full(8, 100), 4, threshold=1)
    a = np.unique(rng.integers(0, 500, 120)).astype(np.int32)
    b = np.unique(rng.integers(0, 500, 300)).astype(np.int32)
    whole = np.intersect1d(a, b).size
    split = sum(
        np.intersect1d(a, part.fragment(b, k)).size for k in range(4)
    )
    assert whole == split


def test_balanced_cuts_weighted():
    w = np.array([10, 1, 1, 1, 1, 1, 1, 10], np.float64)
    cuts = balanced_cuts(w, 2)
    assert cuts[0] == 0 and cuts[-1] == 8
    assert np.all(np.diff(cuts) >= 0)
    # the heavy endpoints split the middle near-evenly
    left = w[: cuts[1]].sum()
    assert abs(left - w.sum() / 2) <= 10


def test_default_threshold():
    assert default_hub_threshold(np.zeros(10, np.int64)) == 2
    assert default_hub_threshold(np.array([], np.int64)) == 2
    assert default_hub_threshold(np.full(10, 10)) == 40


def test_refresh_hubs_tracks_drift():
    part = partition_hub(np.zeros(16, np.int64), 4)
    assert not part.has_hubs
    deg = np.ones(16, np.int64)
    deg[3] = 50
    assert part.refresh_hubs(deg) == 1
    assert np.array_equal(part.hubs, [3])
    assert part.threshold == default_hub_threshold(deg)
    assert part.refresh_hubs(deg, threshold=1000) == 0


def test_local_block_any_contiguous_partition():
    g = powerlaw_graph(128, 8, seed=0)
    part = partition_hub(g.degrees, 4)
    for k in range(4):
        blk = local_block(g, part, k)
        for v in range(blk.lo, blk.hi):
            assert np.array_equal(blk.row(v), g.row(v))


def test_plan_repartition_bounded_and_monotone():
    rng = np.random.default_rng(1)
    deg = rng.zipf(1.6, 512).clip(max=400).astype(np.int64)
    part = HubPartition(n=512, p=4,
                        cuts=np.array([0, 128, 256, 384, 512]),
                        hubs=np.zeros(0, np.int64),
                        threshold=default_hub_threshold(deg))
    plan = plan_repartition(part, deg, max_moves=10)
    if plan is not None:
        assert np.all(np.abs(plan.new_cuts - plan.old_cuts) <= 10)
        assert np.all(np.diff(plan.new_cuts) >= 0)
        assert plan.new_cuts[0] == 0 and plan.new_cuts[-1] == 512
        # moved ids are exactly the ids whose owner changes
        before = part.owner(plan.moved).copy()
        part.cuts[:] = plan.new_cuts
        after = part.owner(plan.moved)
        assert np.all(before != after)
    # converges: repeated planning reaches the balanced target
    for _ in range(200):
        p2 = plan_repartition(part, deg, max_moves=10)
        if p2 is None:
            break
        part.cuts[:] = p2.new_cuts
    assert plan_repartition(part, deg, max_moves=10) is None


def _random_batch(rng, n, size, p_delete=0.3):
    e = rng.integers(0, n, size=(size, 2))
    op = np.where(rng.random(size) < p_delete, -1, 1).astype(np.int8)
    return EdgeBatch(u=e[:, 0], v=e[:, 1], op=op)


@pytest.mark.parametrize("p", [1, 4, 8])
def test_hub_partition_streaming_bit_exact(p):
    """Streaming checkpoints under a hub partition match the unsharded
    reference bit-exactly at p in {1, 4, 8}."""
    n = 96
    rng = np.random.default_rng(7)
    base = powerlaw_graph(n, 6, seed=2)
    part = partition_hub(base.degrees, p)
    ref = StreamingLCCEngine(base, interpret=True)
    eng = StreamingLCCEngine(
        base, interpret=True,
        runtime=ShardedRuntime(n=n, p=p, uncached=True, partition=part),
    )
    for _ in range(5):
        b = _random_batch(rng, n, 40)
        ref.apply_batch(b)
        eng.apply_batch(b)
        assert eng.triangle_count == ref.triangle_count
        assert np.array_equal(eng.lcc, ref.lcc)
    eng.verify()


@pytest.mark.parametrize("p", [4, 8])
def test_migration_mid_stream_bit_exact(p):
    """migrate() between batches (in-place cuts + invalidation fanout)
    leaves every subsequent checkpoint bit-exact."""
    n = 96
    rng = np.random.default_rng(11)
    base = powerlaw_graph(n, 6, seed=2)
    part = partition_hub(base.degrees, p)
    eng = StreamingLCCEngine(
        base, interpret=True,
        runtime=ShardedRuntime(n=n, p=p, cache_bytes=1 << 16,
                               partition=part),
    )
    rt = eng.runtime
    for i in range(6):
        eng.apply_batch(_random_batch(rng, n, 40))
        eng.verify()
        if i == 2:
            plan = plan_repartition(part, eng.store.degrees, max_moves=8)
            if plan is not None:
                moved = rt.migrate(plan.new_cuts)
                assert moved == plan.n_moved
                assert rt.migrations == 1
    # caches stayed coherent across the ownership change
    cached, stale = rt.audit_freshness()
    assert stale == 0


def test_rebalancer_triggers_and_cools_down():
    n, p = 128, 4
    deg = np.ones(n, np.int64)
    deg[:8] = 60  # rank 0 is overloaded under equal cuts
    part = HubPartition(n=n, p=p, cuts=np.array([0, 32, 64, 96, 128]),
                        hubs=np.zeros(0, np.int64), threshold=100)
    rt = ShardedRuntime(n=n, p=p, uncached=True, partition=part)
    loads = np.zeros(p)
    reb = Rebalancer(rt, trigger=1.5, max_moves=16, cooldown=2,
                     reads=lambda: loads)
    assert reb.maybe_rebalance(deg) is None  # balanced window: no-op
    loads[0] += 1000  # skewed window
    plan = reb.maybe_rebalance(deg)
    assert plan is not None and reb.migrations == 1
    assert part.has_hubs  # refresh picked up the heavy rows
    loads[0] += 1000
    assert reb.maybe_rebalance(deg) is None  # cooling down
    assert reb.rows_moved == plan.n_moved
