"""Serve a small LM: batched prefill + token-by-token decode with the
ring-buffer KV cache (local+global alternating config, like gemma2).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import transformer as tfm
from repro.train import train_loop as tl


def main():
    cfg = get_arch("gemma2-27b").smoke_config()
    params = tfm.init_params(cfg, jax.random.key(0))
    batch, prompt_len, gen_len = 4, 24, 16
    max_len = prompt_len + gen_len

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, prompt_len)).astype(np.int32)
    )

    prefill = jax.jit(tl.make_lm_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(tl.make_lm_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(gen_len):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, tok, jnp.int32(prompt_len + t), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, 1)
    print(f"batch={batch} prompt={prompt_len} generated={gen_len}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({batch * prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode / gen_len * 1e3:.1f} ms/token "
          f"({batch * gen_len / t_decode:.0f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(batch, 2)):
        print(" ", gen[b][:12], "...")
    assert gen.shape == (batch, gen_len)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)


if __name__ == "__main__":
    main()
