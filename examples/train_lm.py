"""Train a small LM end to end with the full substrate: data pipeline,
AdamW, microbatching, async checkpointing, restart, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py               # ~12M params
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

Demonstrates fault tolerance: trains, kills itself at --kill-at, then a
second invocation resumes from the checkpoint and the loss curve
continues seamlessly.
"""
import argparse
import os
import shutil

import jax
import numpy as np

from repro.data.tokens import TokenStream
from repro.distributed.fault_tolerance import StragglerMonitor, TrainRunner
from repro.models import transformer as tfm
from repro.train import train_loop as tl
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw, cosine_schedule

SIZES = {
    "12m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_head=64,
                d_ff=1024, vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 d_head=64, d_ff=3072, vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="12m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    cfg = tfm.TransformerConfig(name=f"lm-{args.size}", remat=False,
                                dtype=jax.numpy.float32, **SIZES[args.size])
    print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.1f}M params")

    opt = adamw(lr=cosine_schedule(3e-4, 20, args.steps), weight_decay=0.01)
    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    start_step = 0
    params = tfm.init_params(cfg, jax.random.key(0))
    opt_state = opt.init(params)
    if args.resume and ckpt.latest_step() is not None:
        tmpl = {"params": params, "opt_state": opt_state}
        state, meta = ckpt.restore(tmpl)
        params, opt_state = state["params"], state["opt_state"]
        start_step = meta["next_step"]
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(tl.make_lm_train_step(cfg, opt, n_microbatches=2))
    runner = TrainRunner(
        step_fn=step_fn,
        data_fn=stream.batch_at,
        ckpt=ckpt,
        ckpt_every=20,
        monitor=StragglerMonitor(),
    )
    params, opt_state, log = runner.run(
        params, opt_state, start_step=start_step,
        n_steps=args.steps - start_step,
        meta={"arch": cfg.name}, async_ckpt=True,
    )
    losses = [m["loss"] for m in log]
    print(f"steps {start_step}..{args.steps}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    k = max(len(losses) // 5, 1)
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    print("loss improved; straggler flags:", len(runner.monitor.flagged))


if __name__ == "__main__":
    main()
