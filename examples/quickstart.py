"""Quickstart: triangle counting + LCC with RMA caching in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.csr import from_edges
from repro.core.lcc import lcc_single, lcc_simulated, triangle_count
from repro.graphs.rmat import rmat_graph


def main():
    # a toy graph (Fig. 1 of the paper)
    edges = np.array([
        [0, 1], [0, 2], [1, 2], [1, 4], [2, 4], [3, 4], [3, 5], [4, 5],
    ])
    g = from_edges(edges, 6, undirected=True)
    print("toy graph:", g.n, "vertices,", g.m // 2, "undirected edges")
    print("triangles:", triangle_count(g))
    print("LCC:", np.round(lcc_single(g), 3))

    # the paper's workload: power-law graph, distributed with RMA caching
    g = rmat_graph(12, 16, seed=0)
    print(f"\nR-MAT S12 EF16: n={g.n} m={g.m}")
    print("total triangles:", triangle_count(g))

    # simulate the distributed RMA access stream on 8 nodes,
    # with and without the CLaMPI-style cache (degree scores)
    st0 = lcc_simulated(g, 8)
    st1 = lcc_simulated(
        g, 8,
        offsets_cache_bytes=g.n,  # ~1 offset-pair per 8 vertices
        adj_cache_bytes=g.csr_nbytes() // 4,
        use_degree_score=True,
    )
    print(f"\n8-node RMA simulation:")
    print(f"  remote reads:        {st0.remote_gets.sum():,}")
    print(f"  comm time (no cache): {st0.makespan * 1e3:.1f} ms (modeled)")
    print(f"  comm time (cached):   {st1.makespan * 1e3:.1f} ms (modeled)")
    hits = sum(s.hits for s in st1.adj_stats)
    gets = sum(s.gets for s in st1.adj_stats)
    print(f"  C_adj hit rate:       {hits / gets:.1%}")
    print(f"  saved:                "
          f"{1 - st1.makespan / st0.makespan:.1%} of communication time")


if __name__ == "__main__":
    main()
