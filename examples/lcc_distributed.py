"""End-to-end driver (the paper's kind): distributed asynchronous LCC
over 8 devices with RMA-style pull gathers + degree-score caching,
verified exact against the single-node reference and timed against the
TriC-style BSP baseline.

    PYTHONPATH=src python examples/lcc_distributed.py [--scale 12] [--p 8]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np

from repro.core.async_engine import lcc_pipelined
from repro.core.cache import build_static_degree_cache
from repro.core.rma import build_sharded_problem
from repro.core.tric_baseline import tric_problem
from repro.core.triangles import lcc_scores, triangles_per_vertex
from repro.core.partition import partition_1d
from repro.graphs.rmat import rmat_graph


def bench(prob, label, n_iters=3):
    t, lcc = lcc_pipelined(prob)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(n_iters):
        t, lcc = lcc_pipelined(prob)
    dt = (time.perf_counter() - t0) / n_iters
    print(f"  {label:28s} {dt * 1e3:8.1f} ms/iter")
    return t, lcc, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--cache-rows", type=int, default=256)
    args = ap.parse_args()

    g = rmat_graph(args.scale, args.edge_factor, seed=0)
    print(f"graph: R-MAT S{args.scale} EF{args.edge_factor} "
          f"(n={g.n}, m={g.m}), p={args.p}")

    want_t = triangles_per_vertex(g)
    want_lcc = lcc_scores(g)
    part = partition_1d(g.n, args.p)

    def check(t, lcc, label):
        tg = np.concatenate(
            [t[k, : part.hi(k) - part.lo(k)] for k in range(args.p)]
        )
        lg = np.concatenate(
            [lcc[k, : part.hi(k) - part.lo(k)] for k in range(args.p)]
        )
        ok = np.array_equal(tg, want_t) and np.allclose(lg, want_lcc,
                                                        rtol=1e-5)
        print(f"  {label:28s} exact: {'YES' if ok else 'NO'}")
        assert ok

    print("\nengines (compiled shard_map, 8 host devices):")
    p_async = build_sharded_problem(g, args.p, n_rounds=4)
    t, lcc, dt_async = bench(p_async, "async (pipelined)")
    check(t, lcc, "async (pipelined)")

    cache = build_static_degree_cache(g.degrees, args.cache_rows)
    p_cached = build_sharded_problem(g, args.p, n_rounds=4, cache=cache)
    t, lcc, dt_cached = bench(p_cached, "async + degree cache")
    check(t, lcc, "async + degree cache")

    p_tric = tric_problem(g, args.p)
    t, lcc, dt_tric = bench(p_tric, "TriC-style BSP baseline")
    check(t, lcc, "TriC-style BSP baseline")

    b_async = p_async.comm_bytes_per_round().sum()
    b_cached = p_cached.comm_bytes_per_round().sum()
    b_tric = p_tric.comm_bytes_per_round().sum()
    print(f"\ncommunication volume (bytes, all devices):")
    print(f"  async:        {b_async:,}")
    print(f"  async+cache:  {b_cached:,} "
          f"({1 - b_cached / b_async:.1%} saved by caching)")
    print(f"  TriC BSP:     {b_tric:,} "
          f"({b_tric / b_async:.2f}x the async volume — no dedup)")


if __name__ == "__main__":
    main()
