"""DIN recsys end to end: train on the synthetic CTR stream (zipf item
popularity — the paper's power-law reuse structure), then serve and run
candidate retrieval.

    PYTHONPATH=src python examples/din_ctr.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.recsys import CTRStream
from repro.models.recsys import din
from repro.train import train_loop as tl
from repro.train.optimizer import adamw


def main():
    cfg = get_arch("din").smoke_config()
    params = din.init_params(cfg, jax.random.key(0))
    opt = adamw(lr=2e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    stream = CTRStream(cfg.n_items, cfg.n_cats, batch=256,
                       seq_len=cfg.seq_len, d_profile=cfg.d_profile, seed=0)
    step = jax.jit(tl.make_recsys_train_step(din.apply, cfg, opt))

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    print(f"train BCE: {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "DIN did not learn"

    # serving
    serve = jax.jit(tl.make_recsys_serve_step(din.apply, cfg))
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(999).items()}
    probs = np.asarray(serve(params, batch))
    # AUC-ish check: positives should score higher on average
    lab = np.asarray(batch["label"])
    print(f"serve: mean p(click|pos)={probs[lab > 0].mean():.3f} "
          f"p(click|neg)={probs[lab == 0].mean():.3f}")

    # retrieval: one user vs 4096 candidates
    rng = np.random.default_rng(1)
    rb = {
        "hist_items": batch["hist_items"][:1],
        "hist_cats": batch["hist_cats"][:1],
        "hist_mask": batch["hist_mask"][:1],
        "user_profile": batch["user_profile"][:1],
        "cand_items": jnp.asarray(
            rng.integers(0, cfg.n_items, 4096).astype(np.int32)),
        "cand_cats": jnp.asarray(
            rng.integers(0, cfg.n_cats, 4096).astype(np.int32)),
    }
    retr = jax.jit(tl.make_retrieval_step(din.retrieval_score, cfg, top_k=10))
    vals, idx = retr(params, rb)
    print("retrieval top-10 candidate ids:", np.asarray(idx))


if __name__ == "__main__":
    main()
