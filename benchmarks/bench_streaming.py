"""Streaming updates: incremental maintenance vs from-scratch recount.

Replays an R-MAT insert/delete stream through ``StreamingLCCEngine`` and
reports, per batch size:

- updates/sec of the incremental path (cached: coherence replay enabled
  with the static degree cache + CLaMPI simulator; uncached: engine only),
- the delta-stream cache hit rate and invalidation/rebuild counts, and
- the measured speedup over recomputing ``triangles_per_vertex`` from
  scratch at every batch boundary (the quantity the subsystem exists to
  beat — deltas proportional to the batch, not the graph).

Expected: updates/sec grows with batch size (batch amortizes padding and
kernel launches); incremental wins once the graph dwarfs the batch; hit
rate stays high because the delta stream is as degree-skewed as the
static access stream (paper Obs. 3.1/3.2).

Note: replays run with ``use_kernel=False`` (the vectorized host
membership path). The Pallas kernel path targets TPU; off-TPU it falls
back to interpret mode, whose per-call emulation overhead would swamp
every timing here. Cross-path agreement is asserted in
``tests/test_streaming.py::test_no_kernel_path_matches``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.triangles import triangles_per_vertex
from repro.graphs.rmat import rmat_stream
from repro.streaming import StreamingCacheCoherence, StreamingLCCEngine


def _replay(scale, edge_factor, batch_size, *, cached, delete_frac=0.15):
    n = 1 << scale
    coh = (
        StreamingCacheCoherence(
            n, np.zeros(n, np.int64), p=4, cache_rows=max(64, n // 8),
            clampi_bytes=1 << 20,
        )
        if cached
        else None
    )
    eng = StreamingLCCEngine.empty(n, coherence=coh, use_kernel=False)
    wall = 0.0
    for batch in rmat_stream(scale, edge_factor, batch_size=batch_size,
                             delete_frac=delete_frac, seed=0):
        t0 = time.perf_counter()
        eng.apply_batch(batch)
        wall += time.perf_counter() - t0
    row = {
        "batch_size": batch_size,
        "cached": cached,
        "effective_updates": eng.n_updates,
        "updates_per_sec": eng.n_updates / max(wall, 1e-9),
        "wall_s": round(wall, 3),
        "compactions": eng.store.n_compactions,
        "triangles": eng.triangle_count,
    }
    if coh is not None:
        rep = coh.report
        row.update(
            hit_rate=rep.hit_rate,
            invalidations=rep.invalidations,
            static_rebuilds=rep.static_rebuilds,
            modeled_comm_ms=coh.total_comm_time * 1e3,
        )
    return row, eng


def _naive_insert_directed(added, removed, u, v):
    """Reference per-edge mutation (the pre-vectorization DynamicCSR hot
    path): one np.insert per directed edge."""
    rem = removed.get(u)
    if rem is not None and rem.size and v in rem:
        removed[u] = rem[rem != v]
    else:
        add = added.get(u)
        if add is None:
            added[u] = np.array([v], np.int64)
        else:
            added[u] = np.insert(add, int(np.searchsorted(add, v)), v)


def bench_store_mutation(scale=11, edge_factor=4, batch_size=4096, seed=0):
    """Vectorized group-by-vertex DynamicCSR mutations vs the naive
    per-edge np.insert reference, on identical insert batches."""
    from repro.streaming import DynamicCSR

    n = 1 << scale
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(batch_size * 8, 2))
    e = e[e[:, 0] != e[:, 1]]
    lo, hi = np.minimum(e[:, 0], e[:, 1]), np.maximum(e[:, 0], e[:, 1])
    key = np.unique(lo * n + hi)
    pairs = np.stack([key // n, key % n], 1)

    store = DynamicCSR.empty(n)
    t0 = time.perf_counter()
    for i in range(0, pairs.shape[0], batch_size):
        store.insert_edges(pairs[i : i + batch_size])
    t_vec = time.perf_counter() - t0

    added, removed = {}, {}
    t0 = time.perf_counter()
    for u, v in pairs:
        _naive_insert_directed(added, removed, int(u), int(v))
        _naive_insert_directed(added, removed, int(v), int(u))
    t_naive = time.perf_counter() - t0

    # both paths must build identical delta buffers
    assert len(added) == len(store._added)
    for u, arr in store._added.items():
        assert np.array_equal(arr, added[u])
    ups = int(pairs.shape[0])
    return {
        "edges": ups,
        "vectorized_upd_per_sec": round(ups / max(t_vec, 1e-9)),
        "naive_upd_per_sec": round(ups / max(t_naive, 1e-9)),
        "speedup": round(t_naive / max(t_vec, 1e-9), 1),
    }


def run(quick: bool = True):
    scale = 9 if quick else 12
    edge_factor = 8
    batch_sizes = (64, 256, 1024) if quick else (256, 1024, 4096, 16384)
    out = {"scale": scale, "edge_factor": edge_factor, "rows": [],
           "paper_ref": "streaming extension (Tangwongsan et al.)"}
    out["store_mutation"] = bench_store_mutation(
        scale=10 if quick else 12, batch_size=1024 if quick else 4096
    )
    out["store_vectorized_speedup"] = out["store_mutation"]["speedup"]
    for bs in batch_sizes:
        for cached in (False, True):
            row, _ = _replay(scale, edge_factor, bs, cached=cached)
            out["rows"].append(row)

    # incremental-vs-recount: a small update batch against the fully built
    # graph — delta work scales with the batch, recount with the graph.
    _, eng = _replay(scale, edge_factor, batch_sizes[-1], cached=False)
    n = 1 << scale
    rng = np.random.default_rng(99)
    from repro.streaming import EdgeBatch

    batch_wall = float("inf")
    for _ in range(3):  # min over fresh batches (absorbs recompiles)
        e = rng.integers(0, n, size=(batch_sizes[0], 2))
        t0 = time.perf_counter()
        eng.apply_batch(EdgeBatch.inserts(e))
        batch_wall = min(batch_wall, time.perf_counter() - t0)
    t0 = time.perf_counter()
    triangles_per_vertex(eng.store.to_csr())
    recount = time.perf_counter() - t0
    out["small_batch_size"] = batch_sizes[0]
    out["small_batch_wall_s"] = round(batch_wall, 4)
    out["full_recount_wall_s"] = round(recount, 4)
    out["incremental_speedup_vs_recount"] = round(
        recount / max(batch_wall, 1e-9), 1
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
