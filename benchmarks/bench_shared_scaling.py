"""Fig. 6 + Table III context: shared-memory parallel intersection.

The paper scales OpenMP threads 1->16 on a Xeon (2.7x best). This
container has ONE core, so thread scaling cannot be measured; we instead
measure the axis that stands in for intra-node parallelism on TPU: the
vectorized (VPU-style) batch intersection vs the scalar merge loop, and
its sensitivity to edge-block size (the BlockSpec analogue — too-small
parallel regions lose, exactly the paper's cut-off observation §III-C).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import intersect as it
from repro.core.csr import to_padded_rows
from repro.graphs.rmat import rmat_graph


def run(quick: bool = True):
    g = rmat_graph(11 if quick else 14, 8, seed=0)
    src, dst = g.edge_list()
    n_e = min(len(src), 8192)
    src, dst = src[:n_e], dst[:n_e]
    w = min(g.max_degree, 128)
    rows = to_padded_rows(g, w)
    rows_a = jnp.asarray(rows[src])
    rows_b = jnp.asarray(rows[dst])

    # scalar baseline (paper's 1-thread case)
    t0 = time.perf_counter()
    tot_scalar = 0
    for i in range(min(n_e, 1000)):
        a, b = g.row(src[i]), g.row(dst[i])
        tot_scalar += it.ssi_scalar(a, b)
    scalar_eps = min(n_e, 1000) / (time.perf_counter() - t0) / 1e6

    # vectorized, sweeping block size
    fn = jax.jit(lambda a, b: it.count_bsearch_jnp(a, b, g.n))
    results = []
    for blk in (64, 256, 1024, 4096, 8192):
        if blk > n_e:
            continue
        nb = n_e // blk
        fn(rows_a[:blk], rows_b[:blk]).block_until_ready()  # warm
        t0 = time.perf_counter()
        c = []
        for j in range(nb):
            c.append(fn(rows_a[j * blk:(j + 1) * blk],
                        rows_b[j * blk:(j + 1) * blk]))
        jax.block_until_ready(c)
        dt = time.perf_counter() - t0
        results.append({
            "block": blk,
            "edges_per_us": (nb * blk) / dt / 1e6,
            "speedup_vs_scalar": (nb * blk) / dt / 1e6 / scalar_eps,
        })
    return {
        "scalar_edges_per_us": scalar_eps,
        "vectorized": results,
        "note": "1-core container: block-size axis stands in for the "
                "paper's OpenMP thread axis; small blocks lose to dispatch "
                "overhead exactly like the paper's too-small parallel "
                "regions (§III-C cut-off).",
        "paper_ref": "Fig. 6",
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
