"""Traffic plane: open-loop load, SLO shedding, tenancy, live scores.

Four experiments over one R-MAT graph:

1. **Latency vs offered load** (open-loop Poisson arrivals). Closed-loop
   benchmarks measure service time; an open-loop generator measures
   *queueing*: arrivals are stamped on a schedule that never waits for
   completions, so when offered load crosses the service capacity the
   backlog — and therefore p99 — must grow. Three offered rates
   (sub-saturated, near-capacity, saturated vs the measured closed-loop
   capacity); the gate is the queueing-theory shape: p99 at saturation
   strictly above p99 at low load.

2. **Workload-driven cache scores on a hub-drift trace.** The paper's
   degree score assumes popularity tracks degree (Obs. 3.1). This trace
   breaks the assumption: query popularity is Zipf over a *random
   permutation* of vertices (popularity ⟂ degree) and the permutation
   rotates mid-trace (drift). The live frequency-EWMA blend
   (``WorkloadScorer``) must beat the pure-degree score on host-cache
   hit rate, and a pure-frequency (blend=1) run must reconcile
   **bit-exactly** with cachescope's offline ``ewma`` policy replay of
   the same recorded trace — the live scorer and the offline replayer
   implement one formula.

3. **Tenant isolation.** Tenant A floods the cache with a uniform scan
   working set; tenant B re-reads a small hot set. Without cache
   shares, A's flood evicts B; with 50/50 byte shares and quota-aware
   eviction, B's hit rate must not degrade. Accounting gate: per-tenant
   resident bytes sum exactly to ``used_bytes`` on every rank cache,
   and A's resident bytes never exceed its share cap.

4. **Open-loop vs closed-loop bit-exactness.** The arrival process
   changes *when* queries enter the scheduler, never *what* they
   compute: the same query multiset served both ways must produce
   identical answers (and identical EDF-free result counts).
"""
from __future__ import annotations

import time

import numpy as np

from repro.graphs.rmat import rmat_graph
from repro.serving import LiveQueryService, Query, make_queries
from repro.traffic import (
    HybridClock,
    SLOPolicy,
    TenantQuotas,
    TenantSpec,
    VirtualClock,
    WorkloadScorer,
    assign_tenants,
    poisson_arrivals,
    run_open_loop,
)

MIX = (0.5, 0.3, 0.2, 0.0)  # lcc / triangles / common_neighbors, no top-k


# ---------------------------------------------------------------------------
# 1. latency vs offered load
# ---------------------------------------------------------------------------
def _closed_loop_capacity(csr, queries, *, cache_kib):
    svc = LiveQueryService(csr, p=4, cache_bytes=cache_kib << 10,
                           max_batch=64)
    t0 = time.perf_counter()
    svc.scheduler.run(queries)
    wall = time.perf_counter() - t0
    return len(queries) / max(wall, 1e-9)


def _offered_load_curve(csr, queries, *, cache_kib, load_fracs):
    capacity = _closed_loop_capacity(csr, queries, cache_kib=cache_kib)
    rows = []
    for frac in load_fracs:
        rate = frac * capacity
        clock = HybridClock()
        svc = LiveQueryService(
            csr, p=4, cache_bytes=cache_kib << 10, max_batch=64,
            max_wait=0.005, clock=clock,
        )
        arrivals = poisson_arrivals(len(queries), rate, seed=11)
        rep = run_open_loop(svc.scheduler, queries, arrivals, clock=clock)
        lat = rep.summary
        rows.append({
            "offered_frac_of_capacity": round(frac, 3),
            "offered_qps": round(rep.offered_qps, 1),
            "achieved_qps": round(rep.achieved_qps, 1),
            "served": rep.n_served,
            "p50_ms": round(lat.p50_ms, 3),
            "p99_ms": round(lat.p99_ms, 3),
        })
    return capacity, rows


# ---------------------------------------------------------------------------
# 2. hub-drift trace: live EWMA blend vs static degree score
# ---------------------------------------------------------------------------
def _hub_drift_queries(n, n_queries, *, seed, zipf_s=1.1, phases=2):
    """Pair queries whose popularity is Zipf over a random vertex
    permutation — decoupled from degree — with the permutation rotated
    every phase (the hot set drifts). Pure-degree scoring protects
    high-degree rows that this workload never re-reads; a frequency
    score follows the drift."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n + 1) ** zipf_s
    w /= w.sum()
    out = []
    per = n_queries // phases
    for _ in range(phases):
        perm = rng.permutation(n)
        ranks = rng.choice(n, size=(per, 2), p=w)
        for u, v in ranks:
            uu, vv = int(perm[u]), int(perm[v])
            if uu == vv:
                vv = int(perm[(v + 1) % n])
            out.append(Query.common_neighbors(uu, vv))
    return out


def _hit_rate(csr, queries, *, cache_bytes, scorer=None):
    svc = LiveQueryService(csr, p=4, cache_bytes=cache_bytes,
                           max_batch=64, scorer=scorer)
    svc.scheduler.run(queries)
    st = svc.provider.stats
    return st.hit_rate, svc


def _ewma_vs_degree(csr, *, n_queries, cache_bytes, seed):
    qs = _hub_drift_queries(csr.n, n_queries, seed=seed)
    deg_hr, _ = _hit_rate(csr, qs, cache_bytes=cache_bytes)
    ewma_hr, _ = _hit_rate(
        csr, qs, cache_bytes=cache_bytes,
        scorer=WorkloadScorer(blend=0.9, decay=0.98),
    )

    # Validation: a pure-frequency live run (blend=1 ⇒ score is a
    # positive linear rescale of the replayer's raw EWMA, f < f_cap
    # always) recorded through cachescope must reconcile bit-exactly
    # with the offline "ewma" policy replay of its own trace.
    from repro.obs import cachescope as obs_cachescope

    rec = obs_cachescope.enable_recording()
    try:
        live_hr, svc = _hit_rate(
            csr, qs, cache_bytes=cache_bytes,
            scorer=WorkloadScorer(blend=1.0, decay=0.98),
        )
    finally:
        obs_cachescope.disable_recording()
    report = obs_cachescope.analyze(rec, policies=("deployed", "ewma"))
    stream0 = next(s for s in report["streams"]
                   if s["tier"] == "host_cache" and s["rank"] == 0)
    replay_hr = stream0["replay"]["ewma"]["hit_rate"]
    st0 = svc.runtime.stats[0]
    live0_hr = st0.cache_hits / max(st0.cache_hits + st0.cache_misses, 1)
    return {
        "degree_hit_rate": round(deg_hr, 4),
        "ewma_hit_rate": round(ewma_hr, 4),
        "ewma_hit_rate_gain": round(ewma_hr - deg_hr, 4),
        "ewma_beats_degree_hit_rate": bool(ewma_hr > deg_hr),
        "pure_freq_live_hit_rate": round(live0_hr, 6),
        "pure_freq_replay_hit_rate": round(replay_hr, 6),
        "ewma_matches_offline_replay": bool(
            abs(live0_hr - replay_hr) < 1e-12
        ),
        "n_queries": len(qs),
    }


# ---------------------------------------------------------------------------
# 3. tenant isolation
# ---------------------------------------------------------------------------
def _tenant_queries(csr, *, n_queries, seed, hot_set=24, flood_ratio=3):
    """Interleave tenant A's uniform flood with tenant B's re-reads of
    a small fixed hot set (the cacheable customer)."""
    rng = np.random.default_rng(seed)
    hot = rng.choice(csr.n, size=hot_set, replace=False)
    out = []
    for i in range(n_queries):
        if i % (flood_ratio + 1) < flood_ratio:  # tenant A: flood
            u, v = rng.integers(0, csr.n, size=2)
            q = Query.common_neighbors(int(u), int(v if v != u else (u + 1) % csr.n))
            out.append((q, "A"))
        else:  # tenant B: hot-set re-reads
            u, v = rng.choice(hot, size=2, replace=False)
            out.append((Query.common_neighbors(int(u), int(v)), "B"))
    return out


def _run_tenants(csr, tagged, *, cache_bytes, shares):
    import dataclasses as _dc

    specs = [
        TenantSpec("A", rate_qps=1e9, burst=1e9,
                   cache_share=0.5 if shares else 0.0),
        TenantSpec("B", rate_qps=1e9, burst=1e9,
                   cache_share=0.5 if shares else 0.0),
    ]
    quotas = TenantQuotas(specs)
    svc = LiveQueryService(csr, p=4, cache_bytes=cache_bytes,
                           max_batch=64, quotas=quotas)
    qs = [_dc.replace(q, tenant=t) for q, t in tagged]
    svc.scheduler.run(qs)
    st = svc.runtime.stats[0]
    # per-tenant hit rates out of the tenant request/byte ledgers need a
    # per-tenant probe: rerun B's hot set through the cache read path and
    # count hits directly instead — simpler and exact: use the per-class
    # latency? No: measure via a second pass of B-only queries with stats
    # deltas.
    hits0, miss0 = st.cache_hits, st.cache_misses
    b_qs = [q for q in qs if q.tenant == "B"]
    svc.scheduler.run(b_qs)
    st = svc.runtime.stats[0]
    b_hits = st.cache_hits - hits0
    b_gets = b_hits + (st.cache_misses - miss0)
    caches = svc.runtime.caches
    tb_sum_exact = all(
        sum(c.tenant_bytes().values()) == c.used_bytes for c in caches
    )
    a_within_cap = all(
        c.tenant_bytes().get("A", 0) <= int(0.5 * c.capacity) or not shares
        for c in caches
    )
    return {
        "b_probe_hit_rate": round(b_hits / max(b_gets, 1), 4),
        "accounting_exact": bool(tb_sum_exact),
        "a_within_share_cap": bool(a_within_cap),
        "tenant_bytes_rank0": {
            t or "_": b for t, b in sorted(caches[0].tenant_bytes().items())
        },
    }


def _tenant_isolation(csr, *, n_queries, cache_bytes, seed):
    tagged = _tenant_queries(csr, n_queries=n_queries, seed=seed)
    free = _run_tenants(csr, tagged, cache_bytes=cache_bytes, shares=False)
    iso = _run_tenants(csr, tagged, cache_bytes=cache_bytes, shares=True)
    return {
        "b_hit_rate_no_shares": free["b_probe_hit_rate"],
        "b_hit_rate_with_shares": iso["b_probe_hit_rate"],
        "tenant_bytes_rank0": iso["tenant_bytes_rank0"],
        "tenant_isolation_holds": bool(
            iso["b_probe_hit_rate"] >= free["b_probe_hit_rate"]
            and iso["a_within_share_cap"]
        ),
        "tenant_accounting_exact": bool(
            free["accounting_exact"] and iso["accounting_exact"]
        ),
    }


# ---------------------------------------------------------------------------
# 4. open-loop vs closed-loop bit-exactness
# ---------------------------------------------------------------------------
def _open_vs_closed(csr, queries, *, cache_bytes):
    svc_c = LiveQueryService(csr, p=4, cache_bytes=cache_bytes,
                             max_batch=64)
    closed = svc_c.scheduler.run(queries)

    clock = VirtualClock()
    svc_o = LiveQueryService(csr, p=4, cache_bytes=cache_bytes,
                             max_batch=64, clock=clock)
    arrivals = poisson_arrivals(len(queries), 500.0, seed=3)
    rep = run_open_loop(svc_o.scheduler, queries, arrivals, clock=clock)

    def _key(q):
        return (q.kind, q.u, q.v, q.k)

    want = {}
    for r in closed:
        want.setdefault(_key(r.query), set()).add(
            (r.value, None if r.ids is None else tuple(map(int, r.ids)))
        )
    exact = rep.n_served == len(closed) == len(queries) and all(
        (r.value, None if r.ids is None else tuple(map(int, r.ids)))
        in want[_key(r.query)]
        for r in rep.results
    )
    return {
        "n_closed": len(closed),
        "n_open": rep.n_served,
        "open_loop_bit_exact": bool(exact),
    }


# ---------------------------------------------------------------------------
def run(quick: bool = True):
    scale = 9 if quick else 11
    edge_factor = 8
    n_queries = 600 if quick else 2000
    cache_kib = 4 if quick else 16
    csr = rmat_graph(scale, edge_factor, seed=0)
    out = {
        "scale": scale,
        "edge_factor": edge_factor,
        "n_queries": n_queries,
        "paper_ref": ("production traffic plane over the §III-B2 serving "
                      "stack: open-loop load, SLOs, tenancy, live scores"),
    }

    # 1. latency vs offered load (>=3 offered rates)
    qs = make_queries(csr.degrees, n_queries, kind="zipf", mix=MIX, seed=1)
    # the sub-saturated anchor sits well under capacity: the open-loop
    # harness adds per-arrival host overhead on top of engine service
    # time, so mid fractions already queue (which the curve shows).
    capacity, rows = _offered_load_curve(
        csr, qs, cache_kib=cache_kib, load_fracs=(0.1, 0.6, 2.5)
    )
    out["closed_loop_capacity_qps"] = round(capacity, 1)
    out["offered_load_rows"] = rows
    out["p99_rises_under_saturation"] = bool(
        rows[-1]["p99_ms"] > rows[0]["p99_ms"]
    )

    # 2. hub-drift: live EWMA blend vs degree + offline-replay identity
    out["hub_drift"] = _ewma_vs_degree(
        csr, n_queries=2 * n_queries, cache_bytes=cache_kib << 10, seed=5
    )
    out["ewma_beats_degree_hit_rate"] = \
        out["hub_drift"]["ewma_beats_degree_hit_rate"]
    out["ewma_matches_offline_replay"] = \
        out["hub_drift"]["ewma_matches_offline_replay"]
    out["ewma_hit_rate_gain"] = out["hub_drift"]["ewma_hit_rate_gain"]

    # 3. tenant isolation + exact cache-share accounting
    out["tenants"] = _tenant_isolation(
        csr, n_queries=n_queries, cache_bytes=cache_kib << 10, seed=7
    )
    out["tenant_isolation_holds"] = out["tenants"]["tenant_isolation_holds"]
    out["tenant_accounting_exact"] = \
        out["tenants"]["tenant_accounting_exact"]

    # 4. open-loop vs closed-loop answers
    out["open_vs_closed"] = _open_vs_closed(
        csr, qs, cache_bytes=cache_kib << 10
    )
    out["open_loop_bit_exact"] = out["open_vs_closed"]["open_loop_bit_exact"]

    # one SLO+tenant open-loop run folded into the suite metrics snapshot
    from repro.obs.metrics import record_cachescope  # noqa: F401  (import check)

    clock = HybridClock()
    quotas = TenantQuotas.uniform(3, rate_qps=0.5 * capacity / 3)
    svc = LiveQueryService(
        csr, p=4, cache_bytes=cache_kib << 10, max_batch=64,
        slo=SLOPolicy(headroom_s=0.005), quotas=quotas, clock=clock,
        scorer=WorkloadScorer(),
    )
    tagged = assign_tenants(qs, quotas.tenants,
                            rng=np.random.default_rng(9))
    arrivals = poisson_arrivals(len(tagged), capacity, seed=13)
    rep = run_open_loop(svc.scheduler, tagged, arrivals, clock=clock)
    lat = rep.summary
    out["slo_run"] = {
        "offered_qps": round(rep.offered_qps, 1),
        "served": rep.n_served,
        "slo_hit_rate": round(lat.slo_hit_rate, 4),
        "shed_rate_by_class": lat.shed_rate_by_class,
        "quota_shed": svc.scheduler.n_shed_quota,
    }
    out["_metrics_snapshot"] = svc.metrics_registry().to_dict()
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
