"""Incremental pull-schedule maintenance vs from-scratch rebuild.

``rma.build_sharded_problem`` compiles the static pull schedule with a
host-side pass over every edge (worklists, per-round request dedup,
serve lists, combined indices) — the preprocessing cost Tom & Karypis
(arXiv:1907.09575) flag as the part that must be amortized. After a
stream batch touches a 1% sliver of the graph, rebuilding that schedule
from scratch repeats all of it; ``ShardedLCCProblem.apply_delta``
instead patches the touched rows/worklists and recompiles the schedule
with the vectorized group-op compiler.

Measures, per update batch (1% of edges, mixed insert/delete) at
R-MAT scale 12:

- ``t_incremental`` — ``apply_delta`` on the live problem,
- ``t_scratch``     — ``DynamicCSR.to_csr()`` + ``build_sharded_problem``
                      on the post-batch snapshot (what an epoch restart
                      would pay),

and asserts the two problems are bit-exact before timing is trusted.
Acceptance target: incremental >= 5x faster host preprocessing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.rma import assert_problems_equal, build_sharded_problem
from repro.graphs.rmat import rmat_graph
from repro.streaming.store import DynamicCSR
from repro.streaming.updates import EdgeBatch, INSERT, DELETE, normalize_batch


def _delta_batch(store, n, size, rng):
    """Random effective batch: ~half inserts (absent), ~half deletes
    (present), normalized against the live store."""
    e = rng.integers(0, n, size=(size, 2))
    op = np.where(rng.random(size) < 0.5, DELETE, INSERT).astype(np.int8)
    return normalize_batch(EdgeBatch(u=e[:, 0], v=e[:, 1], op=op), store)


def run(quick: bool = True) -> dict:
    scale, ef, p = 12, 8, 4
    n_batches = 3 if quick else 6
    n = 1 << scale
    csr = rmat_graph(scale, ef, seed=0)
    store = DynamicCSR.from_csr(csr)
    width = csr.max_degree + 64  # headroom: deltas must not overflow
    prob = build_sharded_problem(csr, p, n_rounds=4, width=width)
    # 1% of undirected edges per batch (requested ops; effective ~ that)
    batch_ops = max(1, csr.m // 2 // 100)
    rng = np.random.default_rng(1)

    rows = []
    t_inc_all, t_scr_all = [], []
    for i in range(n_batches):
        ins, dele, _ = _delta_batch(store, n, batch_ops, rng)
        t0 = time.perf_counter()
        prob.apply_delta(ins, dele)
        t_inc = time.perf_counter() - t0
        if dele.shape[0]:
            store.delete_edges(dele)
        if ins.shape[0]:
            store.insert_edges(ins)
        t0 = time.perf_counter()
        snap = store.to_csr()
        fresh = build_sharded_problem(snap, p, n_rounds=4, width=width)
        t_scratch = time.perf_counter() - t0
        assert_problems_equal(prob, fresh)  # bit-exact before timing counts
        t_inc_all.append(t_inc)
        t_scr_all.append(t_scratch)
        rows.append({
            "batch": i,
            "ops": int(ins.shape[0] + dele.shape[0]),
            "t_incremental_ms": round(t_inc * 1e3, 2),
            "t_scratch_ms": round(t_scratch * 1e3, 2),
            "speedup": round(t_scratch / max(t_inc, 1e-9), 1),
        })
    med_inc = float(np.median(t_inc_all))
    med_scr = float(np.median(t_scr_all))
    return {
        "graph": f"rmat S{scale} EF{ef}",
        "p": p,
        "delta_frac": 0.01,
        "rows": rows,
        "median_incremental_ms": round(med_inc * 1e3, 2),
        "median_scratch_ms": round(med_scr * 1e3, 2),
        "schedule_incremental_speedup": round(med_scr / max(med_inc, 1e-9), 1),
        "bit_exact": True,  # assert_problems_equal passed every batch
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
