"""Table III: intersection-method throughput (edges/us).

Hybrid vs SSI vs binary search over the per-edge frontier pairs of R-MAT
and power-law graphs. CPU stand-in for the paper's 16-thread Xeon run:
the vectorized numpy methods play the role of the SIMD/parallel inner
loop; the hybrid applies the paper's Eq. 3 rule per edge.

Expected qualitative result (paper Table III): hybrid >= SSI > bsearch on
scale-free graphs, with the gap growing with edge factor.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import intersect as it
from repro.core.csr import CSRGraph
from repro.graphs.datasets import powerlaw_graph
from repro.graphs.rmat import rmat_graph


def edge_pairs(csr: CSRGraph, max_edges: int, seed: int = 0):
    src, dst = csr.edge_list()
    if src.size > max_edges:
        idx = np.random.default_rng(seed).choice(src.size, max_edges,
                                                 replace=False)
        src, dst = src[idx], dst[idx]
    return src, dst


def run_method(csr, src, dst, method: str):
    """Faithful SCALAR algorithms (the paper's Alg. 1/2 + Eq. 3 hybrid) —
    the Table III comparison is about scalar CPU loops, where SSI's
    linear merge beats bsearch on balanced lists and loses on skewed
    ones. Returns edges/us."""
    rows = [csr.row(v) for v in range(csr.n)]
    t0 = time.perf_counter()
    total = 0
    for u, v in zip(src, dst):
        a, b = rows[u], rows[v]
        if len(a) > len(b):
            a, b = b, a
        if method == "ssi":
            total += it.ssi_scalar(a, b)
        elif method == "bsearch":
            total += it.binary_search_scalar(a, b)
        else:  # hybrid: Eq. 3
            if it.eq3_ssi_faster(len(a), len(b)):
                total += it.ssi_scalar(a, b)
            else:
                total += it.binary_search_scalar(a, b)
    dt = time.perf_counter() - t0
    return len(src) / (dt * 1e6), total


def run(quick: bool = True):
    graphs = {
        "R-MAT S12 EF8": rmat_graph(12, 8, seed=0),
        "R-MAT S12 EF16": rmat_graph(12, 16, seed=0),
        "R-MAT S12 EF32": rmat_graph(12, 32, seed=0),
        "LiveJournal (stand-in)": powerlaw_graph(4096, 16, seed=1),
        "Orkut (stand-in)": powerlaw_graph(3000, 32, seed=2),
    }
    max_edges = 2500 if quick else 50000
    rows = []
    for name, g in graphs.items():
        src, dst = edge_pairs(g, max_edges)
        res = {}
        counts = set()
        for m in ("hybrid", "ssi", "bsearch"):
            eps, total = run_method(g, src, dst, m)
            res[m] = round(eps, 4)
            counts.add(total)
        assert len(counts) == 1, "methods disagree on triangle counts!"
        # timing noise guard: hybrid counts as best within 10%
        rows.append({"graph": name, **res,
                     "hybrid_best": res["hybrid"] >= 0.9 * max(res["ssi"],
                                                               res["bsearch"])})
    return {"table": rows, "unit": "edges/us (scalar loops)",
            "paper_ref": "Table III"}


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
