"""CI benchmark regression gate.

    python benchmarks/ci_compare.py <baseline.json> <current.json> \
        [--threshold 0.25]

Compares each registered benchmark's key metric against the committed
baseline (``results/benchmarks.json``) and exits non-zero if any
regresses by more than ``--threshold`` (default 25%). Only the metrics
named in ``METRICS`` gate — raw wall-clock numbers are too noisy on
shared CI runners, so the gate sticks to ratios and rates that are
stable across machines (speedups, hit rates, reduction fractions).

Booleans in ``BOOLEANS`` must simply stay true (e.g. the SPMD
measured-vs-modeled traffic agreement).

A metric missing from the *baseline* is skipped with a note (new
benchmark, not yet in the committed baseline — refresh it per
benchmarks/README.md). A metric missing from the *current* run fails:
the benchmark broke.
"""
from __future__ import annotations

import argparse
import json
import sys

# metric path -> direction ("higher" means bigger is better)
METRICS = {
    "streaming_updates.incremental_speedup_vs_recount": "higher",
    "streaming_updates.store_vectorized_speedup": "higher",
    "serving_queries.microbatch_speedup_zipf": "higher",
    "serving_queries.cache_comm_reduction_zipf": "higher",
    "serving_queries.hit_rate_zipf": "higher",
    "schedule_rebuild.schedule_incremental_speedup": "higher",
    "device_tier.serving_materialization_reduction": "higher",
    "device_tier.streaming_materialization_reduction": "higher",
    "device_tier.device_hit_rate_zipf": "higher",
    "cache_size_fig7.max_comm_reduction_adj_only": "higher",
    "cache_size_fig7.mattson_speedup": "higher",
    "traffic_plane.ewma_hit_rate_gain": "higher",
}

# metric path -> must be truthy in the current run
BOOLEANS = [
    "spmd_scaling.model_agreement_all",
    "spmd_scaling.upload_savings_positive",
    "spmd_scaling.wire_padding_reduced",
    "schedule_rebuild.bit_exact",
    "serving_queries.trace_overhead_ok",
    "serving_queries.cache_trace_overhead_ok",
    "scores_fig8.replay_reconciled",
    "cache_size_fig7.mattson_matches_direct",
    "traffic_plane.p99_rises_under_saturation",
    "traffic_plane.ewma_beats_degree_hit_rate",
    "traffic_plane.ewma_matches_offline_replay",
    "traffic_plane.tenant_isolation_holds",
    "traffic_plane.tenant_accounting_exact",
    "traffic_plane.open_loop_bit_exact",
    "partition_hub.bit_exact_all",
    "partition_hub.imbalance_reduced",
    "partition_hub.skew_reduced",
]


def get(tree: dict, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="maximum tolerated fractional regression")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)

    failures = []
    for path, direction in METRICS.items():
        b = get(base, path)
        c = get(cur, path)
        if b is None:
            print(f"SKIP {path}: not in baseline (refresh the baseline "
                  "to start gating it)")
            continue
        if c is None:
            failures.append(f"{path}: present in baseline ({b}) but "
                            "missing from the current run")
            print(f"FAIL {path}: missing from current run")
            continue
        b, c = float(b), float(c)
        if direction == "higher":
            # regression = how far current fell below baseline
            reg = (b - c) / abs(b) if b else 0.0
        else:
            reg = (c - b) / abs(b) if b else 0.0
        status = "FAIL" if reg > args.threshold else "ok"
        print(f"{status:4s} {path}: baseline {b:.4g} -> current {c:.4g} "
              f"({-reg:+.1%} vs baseline, threshold -{args.threshold:.0%})")
        if reg > args.threshold:
            failures.append(
                f"{path}: {b:.4g} -> {c:.4g} ({reg:.1%} regression)"
            )

    for path in BOOLEANS:
        c = get(cur, path)
        if c is None:
            # unlike METRICS, booleans don't need a baseline: absence
            # means the benchmark that produces the invariant broke.
            failures.append(f"{path}: missing from the current run "
                            "(the benchmark producing it failed)")
            print(f"FAIL {path}: missing from current run")
            continue
        ok = bool(c)
        print(f"{'ok  ' if ok else 'FAIL'} {path}: {c}")
        if not ok:
            failures.append(f"{path}: expected true, got {c}")

    if failures:
        print(f"\n{len(failures)} benchmark regression(s):")
        for f_ in failures:
            print("  - " + f_)
        return 1
    print("\nno benchmark regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
