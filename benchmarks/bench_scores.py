"""Fig. 8: application-defined (degree-centrality) scores vs CLaMPI's
default LRU+positional victim selection.

C_adj fixed to 25% of the non-local partition (forces evictions, as in
the paper); reports average modeled time per remote vertex read.
Expected: degree scores improve 14.4%-35.6% on R-MAT (paper numbers).

One live run per graph, the rest offline: the deployed degree-scored run
is recorded with ``repro.obs.cachescope`` and every other policy row
(``lru_positional``, ``ewma``, clairvoyant ``belady``) is an offline
replay of that trace — the access stream is policy-independent, so the
replayed ``lru_positional`` stats are identical to what a second full
run would produce, at a fraction of the cost.  ``replay_reconciled``
gates the whole construction: the deployed-policy replay must reproduce
the live ``CacheStats`` deltas bit-exactly on every rank.
"""
from __future__ import annotations

import numpy as np

from repro.core.rma import simulate_rma_lcc
from repro.graphs.rmat import rmat_graph
from repro.graphs.datasets import powerlaw_graph
from repro.obs import cachescope


def _replay_row(streams, policy, other_comm, reads):
    reps = [cachescope.replay_host(s, policy=policy) for s in streams]
    comm = other_comm + sum(r["comm_time"] for r in reps)
    return {
        "avg_time_per_read_us": 1e6 * comm / max(reads, 1),
        "hit_rate": float(np.mean([r["hit_rate"] for r in reps])),
        "evictions": int(sum(r["evictions"] for r in reps)),
        "replayed": True,
    }


def run(quick: bool = True):
    scale = 12 if quick else 16
    graphs = {
        f"R-MAT S{scale} EF16": rmat_graph(scale, 16, seed=0),
        "powerlaw": powerlaw_graph(4096 if quick else 65536, 16, seed=3),
    }
    out = {"rows": [], "paper_ref": "Fig. 8"}
    reconciled_all = True
    for name, g in graphs.items():
        p = 2
        cache_bytes = int(g.csr_nbytes() * (1 - 1 / p) * 0.25)
        # one live run: the deployed degree-scored policy, recorded
        rec = cachescope.enable_recording()
        st = simulate_rma_lcc(
            g, p, adj_cache_bytes=cache_bytes, use_degree_score=True,
            table_slots_adj=max(64, g.n // 4),
        )
        cachescope.disable_recording()
        streams = [s for s in rec.host_streams() if s.label == "adj"]
        reads = st.remote_gets.sum()
        adj_comm = sum(s.comm_time for s in st.adj_stats)
        other_comm = st.comm_time.sum() - adj_comm

        # the reconciliation invariant: deployed replay == live deltas
        for s in streams:
            live = s.live_delta()
            rep = cachescope.replay_host(s, policy="deployed")
            if any(live[k] != rep[k] for k in cachescope.HOST_COMPARE):
                reconciled_all = False

        rows = {
            "degree": {
                "avg_time_per_read_us":
                    1e6 * st.comm_time.sum() / max(reads, 1),
                "hit_rate":
                    float(np.mean([s.hit_rate for s in st.adj_stats])),
                "evictions": int(sum(s.evictions for s in st.adj_stats)),
            },
            "lru_positional": _replay_row(
                streams, "lru_positional", other_comm, reads),
            "ewma": _replay_row(streams, "ewma", other_comm, reads),
        }
        bel = [cachescope.replay_belady(s) for s in streams]
        rows["belady"] = {  # clairvoyant bound: counts only, no comm model
            "hit_rate": float(np.mean([b["hit_rate"] for b in bel])),
            "evictions": int(sum(b["evictions"] for b in bel)),
            "replayed": True,
        }
        impr = 1 - (rows["degree"]["avg_time_per_read_us"]
                    / rows["lru_positional"]["avg_time_per_read_us"])
        out["rows"].append({"graph": name, **rows,
                            "degree_score_improvement": round(impr, 4)})
    out["replay_reconciled"] = reconciled_all
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
