"""Fig. 8: application-defined (degree-centrality) scores vs CLaMPI's
default LRU+positional victim selection.

C_adj fixed to 25% of the non-local partition (forces evictions, as in
the paper); reports average modeled time per remote vertex read.
Expected: degree scores improve 14.4%-35.6% on R-MAT (paper numbers).
"""
from __future__ import annotations

import numpy as np

from repro.core.rma import simulate_rma_lcc
from repro.graphs.rmat import rmat_graph
from repro.graphs.datasets import powerlaw_graph


def run(quick: bool = True):
    scale = 12 if quick else 16
    graphs = {
        f"R-MAT S{scale} EF16": rmat_graph(scale, 16, seed=0),
        "powerlaw": powerlaw_graph(4096 if quick else 65536, 16, seed=3),
    }
    out = {"rows": [], "paper_ref": "Fig. 8"}
    for name, g in graphs.items():
        p = 2
        cache_bytes = int(g.csr_nbytes() * (1 - 1 / p) * 0.25)
        rows = {}
        for label, use_deg in (("lru_positional", False), ("degree", True)):
            st = simulate_rma_lcc(
                g, p, adj_cache_bytes=cache_bytes, use_degree_score=use_deg,
                table_slots_adj=max(64, g.n // 4),
            )
            reads = st.remote_gets.sum()
            rows[label] = {
                "avg_time_per_read_us": 1e6 * st.comm_time.sum() / max(reads, 1),
                "hit_rate": float(np.mean([s.hit_rate for s in st.adj_stats])),
                "evictions": int(sum(s.evictions for s in st.adj_stats)),
            }
        impr = 1 - (rows["degree"]["avg_time_per_read_us"]
                    / rows["lru_positional"]["avg_time_per_read_us"])
        out["rows"].append({"graph": name, **rows,
                            "degree_score_improvement": round(impr, 4)})
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
