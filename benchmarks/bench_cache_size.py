"""Fig. 7: cache behaviour as a function of cache size.

Two ways to the same curve:

1. **Mattson (one run)** — record the per-rank access streams of ONE
   full-capacity ``simulate_rma_lcc`` run with cachescope, then derive
   the entire hit-rate/miss-rate/comm-time-vs-capacity curve from the
   byte-weighted reuse distances (``repro.obs.cachescope``): an access
   hits an ideal LRU cache of B bytes iff its reuse distance is <= B.
   The adj and offsets windows are separate streams (separate caches in
   the simulator), so both sweeps fall out of the same recorded run.
   These are the headline ``adj_sweep`` / ``offsets_sweep`` rows.

2. **Direct (N runs)** — the legacy sweep: one full ``simulate_rma_lcc``
   per cache size with a real ``ClampiCache`` (hash-table slots,
   first-fit fragmentation, positional eviction). Kept as
   ``adj_sweep_direct`` / ``offsets_sweep_direct`` for the model-gap
   cross-check and to measure ``mattson_speedup`` honestly.

Consistency gates:
- ``mattson_matches_direct``: the Mattson curve equals a direct
  ideal-LRU simulation of the same trace bit-exactly at >= 3 spot
  capacities (the traces are invalidation-free, so the stack model is
  exact).
- ``max_missrate_delta_vs_direct``: how far ideal LRU is from the real
  ClampiCache sweep (table-slot limits + fragmentation) — a model gap,
  reported not gated.

Expected: power-law miss curve for C_adj (small caches already save ~30%
of comm), linear for C_offsets; most of the byte volume is carried by
C_adj (paper: 51.6% comm-time cut with C_adj alone).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cache import NetworkModel
from repro.core.rma import simulate_rma_lcc
from repro.graphs.rmat import rmat_graph
from repro.obs import cachescope

OFFSET_ENTRY_BYTES = 8


def _mattson_rows(streams, capacities, frac_key, fracs, other_const_comm,
                  t0, net):
    """Fig.7-style rows at each capacity from recorded per-rank streams.

    For capacity c, per rank: hits/misses from the reuse-distance curve,
    modeled comm = hits*hit_cost + misses*alpha + missed_bytes*beta +
    admitted*insert_cost, plus the constant comm of the *other* window
    (uncached in that sweep, same convention as the direct sweep).
    """
    dists = [cachescope.reuse_distances(s) for s in streams]
    rows = []
    for frac, cap in zip(fracs, capacities):
        gets = hits = misses = comp = 0
        comm = other_const_comm
        for d in dists:
            db, sz = d["dist_bytes"], d["sizes"]
            hit = (db >= 0) & (db <= cap)
            n_hit = int(np.count_nonzero(hit))
            n_get = int(d["n_gets"])
            missed = ~hit
            missed_bytes = int(sz[missed].sum())
            admitted = int(np.count_nonzero(missed & (sz <= cap)))
            gets += n_get
            hits += n_hit
            misses += n_get - n_hit
            comp += int(np.count_nonzero(db < 0))
            comm += (n_hit * net.hit_cost
                     + (n_get - n_hit) * net.alpha
                     + missed_bytes * net.beta
                     + admitted * net.insert_cost)
        rows.append({
            frac_key: frac,
            "miss_rate": misses / max(gets, 1),
            "hit_rate": hits / max(gets, 1),
            "compulsory_floor": comp / max(gets, 1),
            "comm_time_frac": comm / t0,
        })
    return rows


def _spot_check(streams, n_checks=3):
    """Mattson vs direct ideal-LRU simulation of the recorded trace,
    bit-exact at >= n_checks capacities per stream."""
    checks = []
    for s in streams:
        d = cachescope.reuse_distances(s)
        if d["n_gets"] == 0:
            continue
        lo = max(d["max_entry_bytes"], 1)
        caps = sorted({lo, 4 * lo, 16 * lo})[:max(n_checks, 3)]
        for c in caps:
            m_hits = int(cachescope.hit_curve(d["dist_bytes"], [c])[0])
            dir_hits, _ = cachescope.simulate_lru_bytes(s, c)
            checks.append({
                "capacity_bytes": int(c),
                "mattson_hits": m_hits,
                "direct_hits": int(dir_hits),
                "match": m_hits == dir_hits,
            })
    return checks


def run(quick: bool = True):
    scale = 12 if quick else 16
    g = rmat_graph(scale, 16, seed=0)
    p = 2
    net = NetworkModel()
    base = simulate_rma_lcc(g, p)
    t0 = base.comm_time.sum()
    out = {"baseline_comm_time": t0, "paper_ref": "Fig. 7"}
    csr_bytes = g.csr_nbytes()
    adj_fracs = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0)
    off_fracs = (0.05, 0.1, 0.25, 0.5, 1.0, 2.0)

    # ---- one recorded full-capacity run -> both sweeps via Mattson ----
    t_rec = time.perf_counter()
    rec = cachescope.enable_recording()
    simulate_rma_lcc(
        g, p,
        adj_cache_bytes=csr_bytes,
        offsets_cache_bytes=int(g.n * 2.0 * OFFSET_ENTRY_BYTES),
    )
    cachescope.disable_recording()
    streams = rec.host_streams()
    adj_streams = [s for s in streams if s.label == "adj"]
    off_streams = [s for s in streams if s.label == "offsets"]
    # the access stream is capacity/policy-independent, so per-rank get
    # counts give the uncached constant of the window the sweep disables
    adj_const = sum(
        net.remote(sz) for s in adj_streams
        for k, sz in zip(s.kinds, s.sizes) if k == "g"
    )
    off_const = sum(
        net.remote(sz) for s in off_streams
        for k, sz in zip(s.kinds, s.sizes) if k == "g"
    )
    out["adj_sweep"] = _mattson_rows(
        adj_streams, [int(csr_bytes * f) for f in adj_fracs],
        "cache_frac_of_csr", adj_fracs, off_const, t0, net)
    out["offsets_sweep"] = _mattson_rows(
        off_streams,
        [int(g.n * f * OFFSET_ENTRY_BYTES) for f in off_fracs],
        "cache_entries_per_vertex", off_fracs, adj_const, t0, net)
    checks = _spot_check(adj_streams) + _spot_check(off_streams)
    out["mattson_spot_checks"] = checks
    out["mattson_matches_direct"] = (
        len(checks) >= 3 and all(c["match"] for c in checks))
    mattson_s = time.perf_counter() - t_rec

    # ---- legacy direct sweep (model-gap cross-check + speedup ref) ----
    t_dir = time.perf_counter()
    direct_adj = []
    for frac in adj_fracs:
        st = simulate_rma_lcc(g, p, adj_cache_bytes=int(csr_bytes * frac))
        misses = sum(s.misses for s in st.adj_stats)
        gets = sum(s.gets for s in st.adj_stats)
        comp = sum(s.compulsory_misses for s in st.adj_stats)
        direct_adj.append({
            "cache_frac_of_csr": frac,
            "miss_rate": misses / max(gets, 1),
            "compulsory_floor": comp / max(gets, 1),
            "comm_time_frac": st.comm_time.sum() / t0,
        })
    direct_off = []
    for frac in off_fracs:
        st = simulate_rma_lcc(
            g, p, offsets_cache_bytes=int(g.n * frac * OFFSET_ENTRY_BYTES))
        misses = sum(s.misses for s in st.offsets_stats)
        gets = sum(s.gets for s in st.offsets_stats)
        comp = sum(s.compulsory_misses for s in st.offsets_stats)
        direct_off.append({
            "cache_entries_per_vertex": frac,
            "miss_rate": misses / max(gets, 1),
            "compulsory_floor": comp / max(gets, 1),
            "comm_time_frac": st.comm_time.sum() / t0,
        })
    direct_s = time.perf_counter() - t_dir
    out["adj_sweep_direct"] = direct_adj
    out["offsets_sweep_direct"] = direct_off
    out["max_missrate_delta_vs_direct"] = max(
        abs(a["miss_rate"] - b["miss_rate"])
        for sweep in (("adj_sweep", "adj_sweep_direct"),
                      ("offsets_sweep", "offsets_sweep_direct"))
        for a, b in zip(out[sweep[0]], out[sweep[1]])
    )
    out["mattson_seconds"] = mattson_s
    out["direct_sweep_seconds"] = direct_s
    out["mattson_speedup"] = direct_s / max(mattson_s, 1e-9)

    best_adj = min(s["comm_time_frac"] for s in out["adj_sweep"])
    out["max_comm_reduction_adj_only"] = 1.0 - best_adj
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
