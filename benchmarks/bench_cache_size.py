"""Fig. 7: cache behaviour as a function of cache size.

Sweeps the memory allocated to C_offsets and C_adj independently (caching
enabled on one window at a time, like the paper) on an R-MAT graph split
over 2 nodes, reporting miss rate and modeled communication time, plus
the compulsory-miss floor (the grey region of the figure).

Expected: power-law miss curve for C_adj (small caches already save ~30%
of comm), linear for C_offsets; most of the byte volume is carried by
C_adj (paper: 51.6% comm-time cut with C_adj alone).
"""
from __future__ import annotations

import numpy as np

from repro.core.rma import simulate_rma_lcc
from repro.graphs.rmat import rmat_graph


def run(quick: bool = True):
    scale = 12 if quick else 16
    g = rmat_graph(scale, 16, seed=0)
    p = 2
    base = simulate_rma_lcc(g, p)
    t0 = base.comm_time.sum()
    out = {"baseline_comm_time": t0, "adj_sweep": [], "offsets_sweep": [],
           "paper_ref": "Fig. 7"}
    csr_bytes = g.csr_nbytes()
    for frac in (0.01, 0.05, 0.1, 0.25, 0.5, 1.0):
        size = int(csr_bytes * frac)
        st = simulate_rma_lcc(g, p, adj_cache_bytes=size)
        misses = sum(s.misses for s in st.adj_stats)
        gets = sum(s.gets for s in st.adj_stats)
        comp = sum(s.compulsory_misses for s in st.adj_stats)
        out["adj_sweep"].append({
            "cache_frac_of_csr": frac,
            "miss_rate": misses / max(gets, 1),
            "compulsory_floor": comp / max(gets, 1),
            "comm_time_frac": st.comm_time.sum() / t0,
        })
    for frac in (0.05, 0.1, 0.25, 0.5, 1.0, 2.0):
        size = int(g.n * frac * 8)
        st = simulate_rma_lcc(g, p, offsets_cache_bytes=size)
        misses = sum(s.misses for s in st.offsets_stats)
        gets = sum(s.gets for s in st.offsets_stats)
        comp = sum(s.compulsory_misses for s in st.offsets_stats)
        out["offsets_sweep"].append({
            "cache_entries_per_vertex": frac,
            "miss_rate": misses / max(gets, 1),
            "compulsory_floor": comp / max(gets, 1),
            "comm_time_frac": st.comm_time.sum() / t0,
        })
    best_adj = min(s["comm_time_frac"] for s in out["adj_sweep"])
    out["max_comm_reduction_adj_only"] = 1.0 - best_adj
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
