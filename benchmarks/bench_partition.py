"""Hub-aware partitioning vs the paper's 1D blocks (ROADMAP item 2).

Three CI-gated claims on a scale-free graph under a zipf query mix:

1. **bit_exact_all** — swapping ``Partition1D`` for ``partition_hub``
   changes WHERE rows live and HOW hub rows ship (per-rank fragments,
   reduced additively), never WHAT a query answers: every query result
   is identical across {1d, hub} x {loop, spmd} x p in {1, 4, 8}, and
   the per-rank freshness audit passes everywhere.
2. **imbalance_reduced** — balance-aware cuts + round-robin hub routing
   pull the per-rank read load (the ``load_imbalance`` gauge) below the
   1D baseline.
3. **skew_reduced** — fragmenting hub rows across all ranks flattens
   the serve matrix (the ``serve_matrix_skew`` gauge): a hot hub's
   serve traffic spreads over p ranks instead of hammering its owner.

The SPMD rows double as a model-fidelity check: the executor asserts
measured == modeled traffic per microbatch, so a hub-fragment
mischarge would abort the run rather than skew a number.

Runs in a subprocess with 8 forced host devices (jax pins the device
count at first init), like ``bench_spmd_scaling``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURE_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json, sys, time
import numpy as np

quick = bool(int(sys.argv[1]))

from repro.core.partition import partition_hub
from repro.graphs.datasets import powerlaw_graph
from repro.serving import LiveQueryService
from repro.serving.workload import make_queries

n = 2048 if quick else 8192
csr = powerlaw_graph(n, 16 if quick else 24, seed=0)
queries = make_queries(
    csr.degrees, 384 if quick else 2048, kind="zipf", seed=1
)


def fingerprint(results):
    out = []
    for r in results:
        ids = getattr(r, "ids", None)
        out.append([float(r.value),
                    None if ids is None else [int(x) for x in ids]])
    return out


def run_one(p, mode, execution):
    part = partition_hub(csr.degrees, p) if mode == "hub" else None
    svc = LiveQueryService(csr, p=p, cross_rank=True, execution=execution,
                           partition=part, max_batch=64)
    t0 = time.perf_counter()
    results = svc.scheduler.run(queries)
    wall = time.perf_counter() - t0
    svc.verify()  # bit-exact vs recount + zero stale cached rows
    reg = svc.metrics_registry()
    return {
        "p": p, "partition": mode, "execution": execution,
        "wall_s": round(wall, 4),
        "load_imbalance": round(
            reg.get_gauge("load_imbalance", tier="host"), 4),
        "serve_matrix_skew": round(
            reg.get_gauge("serve_matrix_skew", tier="wire"), 4),
        "rows_served": int(svc.runtime.cross_rank_rows_served()),
    }, fingerprint(results)


rows, fps = [], []
for p in (1, 4, 8):
    for mode, execution in (("1d", "loop"), ("hub", "loop"),
                            ("hub", "spmd")):
        row, fp = run_one(p, mode, execution)
        rows.append(row)
        fps.append(fp)
print(json.dumps({
    "rows": rows,
    "bit_exact_all": all(fp == fps[0] for fp in fps[1:]),
}))
"""


def _mean(rows, mode, key):
    vals = [r[key] for r in rows if r["partition"] == mode and r["p"] > 1
            and r["execution"] == "loop"]
    return sum(vals) / max(len(vals), 1)


def run(quick: bool = True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", MEASURE_SCRIPT, str(int(quick))],
        capture_output=True,
        text=True,
        env=env,
        timeout=3600,
    )
    if r.returncode != 0:
        return {"error": r.stderr[-2000:]}
    res = json.loads(r.stdout.strip().splitlines()[-1])
    rows = res["rows"]
    imb_1d = _mean(rows, "1d", "load_imbalance")
    imb_hub = _mean(rows, "hub", "load_imbalance")
    skew_1d = _mean(rows, "1d", "serve_matrix_skew")
    skew_hub = _mean(rows, "hub", "serve_matrix_skew")
    return {
        "rows": rows,
        # CI-gated booleans (deterministic — counters, not wall clocks)
        "bit_exact_all": bool(res["bit_exact_all"]),
        "load_imbalance_1d": round(imb_1d, 4),
        "load_imbalance_hub": round(imb_hub, 4),
        "imbalance_reduced": bool(imb_hub < imb_1d),
        "serve_skew_1d": round(skew_1d, 4),
        "serve_skew_hub": round(skew_hub, 4),
        "skew_reduced": bool(skew_hub < skew_1d),
        "paper_ref": "ROADMAP item 2 — past the paper's §III-A 1D "
                     "blocks (hub splitting per Sanders & Uhl "
                     "arXiv:2302.11443)",
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
