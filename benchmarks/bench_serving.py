"""Online query serving: microbatching + cache-backed provider science.

Two experiments over an R-MAT graph:

1. **Microbatch scaling** (Zipf/hub-skewed workload, cache-backed
   provider): throughput and p50/p99 latency vs the scheduler's batch
   window. window=1 is one-query-at-a-time serving; larger windows share
   row fetches, dedup pair intersections batch-wide, and amortize the
   vectorized/kernel dispatch. Expected: ≥5x throughput at the largest
   window.

2. **Provider comparison** (uniform vs Zipf, fixed window): the
   degree-scored ``CacheBackedRowProvider`` vs the uncached
   ``DirectRowProvider`` on identical workloads — hit rate, remote bytes
   moved, and modeled remote-read time (NetworkModel, paper §IV-D1).
   Expected: on Zipf the cache converts hub reuse into a large modeled
   communication cut (paper Obs. 3.1/3.2: degree predicts reuse); on
   uniform the gain is smaller (the paper's low-reuse control).

Timings use the host intersection path (see bench_streaming.py: the
Pallas kernel targets TPU; interpret-mode emulation would swamp every
number here).
"""
from __future__ import annotations

import time

import numpy as np

from repro.graphs.rmat import rmat_graph
from repro.serving import (
    CacheBackedRowProvider,
    DirectRowProvider,
    MicrobatchScheduler,
    QueryEngine,
    make_queries,
)
from repro.streaming import DynamicCSR

MIX = (0.5, 0.3, 0.2, 0.0)  # lcc / triangles / common_neighbors, no top-k


def _serve(csr, store, queries, *, window, cached, p=4, cache_bytes=1 << 20):
    provider = (
        CacheBackedRowProvider(store, p=p, capacity_bytes=cache_bytes)
        if cached
        else DirectRowProvider(store, p=p)
    )
    engine = QueryEngine(store, provider, use_kernel=False)
    sched = MicrobatchScheduler(engine, max_batch=window)
    t0 = time.perf_counter()
    sched.run(queries)
    wall = time.perf_counter() - t0
    lat = sched.latency_summary()
    st = provider.stats
    return {
        "window": window,
        "cached": cached,
        "qps": round(len(queries) / max(wall, 1e-9), 1),
        "wall_s": round(wall, 4),
        "p50_ms": round(lat.p50_ms, 3),
        "p99_ms": round(lat.p99_ms, 3),
        "hit_rate": round(st.hit_rate, 4),
        "remote_reads": st.remote_reads,
        "remote_bytes": st.bytes_fetched,
        "modeled_comm_ms": round(st.modeled_comm_s * 1e3, 4),
        "pairs_raw": engine.n_pairs_raw,
        "pairs_deduped": engine.n_pairs_total,
    }


def _trace_overhead(csr, store, queries, *, window, reps):
    """Cost of the observability hooks.

    Two numbers, different stability classes:

    - ``trace_disabled_overhead_frac`` — the estimate that gates: the
      microbenched cost of one disabled ``span()`` call (a module-global
      None check returning a shared null object) times the spans one
      serve emits, over the serve wall. Deterministic enough for CI.
    - ``trace_enabled_overhead_frac`` — median enabled vs disabled
      wall delta. Informational only; wall noise on shared runners
      swamps single-digit percents.
    """
    from repro.obs import trace as obs_trace

    walls_off = sorted(
        _serve(csr, store, queries, window=window, cached=True)["wall_s"]
        for _ in range(reps)
    )
    tracer = obs_trace.enable_tracing()
    try:
        walls_on = sorted(
            _serve(csr, store, queries, window=window, cached=True)["wall_s"]
            for _ in range(reps)
        )
    finally:
        obs_trace.disable_tracing()
    spans_per_run = len(tracer) / reps

    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs_trace.span("fetch_rows", rank=0, cat="bench", n=1):
            pass
    disabled_span_ns = (time.perf_counter() - t0) / n * 1e9

    wall_off = walls_off[reps // 2]
    wall_on = walls_on[reps // 2]
    disabled_frac = (disabled_span_ns * 1e-9 * spans_per_run
                     / max(wall_off, 1e-9))
    return {
        "wall_disabled_s": round(wall_off, 4),
        "wall_enabled_s": round(wall_on, 4),
        "trace_enabled_overhead_frac": round(
            wall_on / max(wall_off, 1e-9) - 1.0, 4),
        "disabled_span_ns": round(disabled_span_ns, 1),
        "n_spans_enabled": round(spans_per_run, 1),
        "trace_disabled_overhead_frac": round(disabled_frac, 6),
        "trace_overhead_ok": bool(disabled_frac < 0.03),
    }


def _cache_trace_overhead(csr, store, queries, *, window, reps):
    """Cost of the cachescope recorder hooks (same construction as
    ``_trace_overhead``): when recording is off, every ``ClampiCache.get``
    pays one module-global load plus two ``is not None`` checks. Gate =
    microbenched disabled-hook cost x hooks one serve would fire (the
    event count of one recorded serve), over the disabled serve wall."""
    from repro.obs import cachescope as obs_cachescope

    walls_off = sorted(
        _serve(csr, store, queries, window=window, cached=True)["wall_s"]
        for _ in range(reps)
    )
    rec = obs_cachescope.enable_recording()
    try:
        t0 = time.perf_counter()
        _serve(csr, store, queries, window=window, cached=True)
        wall_rec = time.perf_counter() - t0
    finally:
        obs_cachescope.disable_recording()
    events_per_run = rec.n_events()

    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        r = obs_cachescope._recorder
        if r is not None:
            pass
        if r is not None:
            pass
    disabled_hook_ns = (time.perf_counter() - t0) / n * 1e9

    wall_off = walls_off[reps // 2]
    disabled_frac = (disabled_hook_ns * 1e-9 * events_per_run
                     / max(wall_off, 1e-9))
    return {
        "wall_recorded_s": round(wall_rec, 4),
        "cache_trace_enabled_overhead_frac": round(
            wall_rec / max(wall_off, 1e-9) - 1.0, 4),
        "disabled_cachehook_ns": round(disabled_hook_ns, 1),
        "n_cache_events": events_per_run,
        "cache_trace_disabled_overhead_frac": round(disabled_frac, 6),
        "cache_trace_overhead_ok": bool(disabled_frac < 0.03),
    }


def run(quick: bool = True):
    scale = 9 if quick else 11
    edge_factor = 8
    n_queries = 600 if quick else 2000
    windows = (1, 16, 256)
    csr = rmat_graph(scale, edge_factor, seed=0)
    store = DynamicCSR.from_csr(csr)
    out = {
        "scale": scale,
        "edge_factor": edge_factor,
        "n_queries": n_queries,
        "paper_ref": "serving extension of §III-B2 degree-scored caching",
        "microbatch_rows": [],
        "provider_rows": [],
    }

    # 1. microbatch scaling (Zipf, cached provider)
    qs_zipf = make_queries(csr.degrees, n_queries, kind="zipf", mix=MIX, seed=1)
    for w in windows:
        out["microbatch_rows"].append(
            _serve(csr, store, qs_zipf, window=w, cached=True)
        )
    rows = out["microbatch_rows"]
    out["microbatch_speedup_zipf"] = round(
        rows[-1]["qps"] / max(rows[0]["qps"], 1e-9), 2
    )

    # 2. cached vs uncached provider, fixed window, both workloads
    w = windows[-1]
    for kind in ("uniform", "zipf"):
        qs = make_queries(csr.degrees, n_queries, kind=kind, mix=MIX, seed=2)
        direct = _serve(csr, store, qs, window=w, cached=False)
        cached = _serve(csr, store, qs, window=w, cached=True)
        direct["workload"] = cached["workload"] = kind
        out["provider_rows"] += [direct, cached]
        red = 1.0 - cached["modeled_comm_ms"] / max(
            direct["modeled_comm_ms"], 1e-9
        )
        out[f"cache_comm_reduction_{kind}"] = round(red, 4)
        out[f"hit_rate_{kind}"] = cached["hit_rate"]

    # 3. observability: tracer overhead gate + one traced run folded
    # into the suite metrics snapshot (run.py writes it next to --out)
    out.update(_trace_overhead(csr, store, qs_zipf, window=windows[-1],
                               reps=3 if quick else 5))
    out.update(_cache_trace_overhead(csr, store, qs_zipf,
                                     window=windows[-1],
                                     reps=3 if quick else 5))
    from repro.obs import trace as obs_trace
    from repro.obs.metrics import (
        MetricRegistry,
        fold_trace,
        record_latency,
        record_provider_stats,
    )

    provider = CacheBackedRowProvider(store, p=4, capacity_bytes=1 << 20)
    engine = QueryEngine(store, provider, use_kernel=False)
    sched = MicrobatchScheduler(engine, max_batch=windows[-1])
    tracer = obs_trace.enable_tracing()
    try:
        sched.run(qs_zipf)
    finally:
        obs_trace.disable_tracing()
    reg = MetricRegistry()
    record_provider_stats(reg, provider.stats, rank=0)
    record_latency(reg, sched.recorder)
    fold_trace(reg, tracer)
    out["_metrics_snapshot"] = reg.to_dict()
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
