"""Assemble EXPERIMENTS.md from results/ artifacts (reproducible report).

Every input is optional: a missing template, missing roofline dry-run
records, or a missing benchmarks.json degrade to an inline note instead
of crashing, so the report can be regenerated at any point in the
repo's life. The per-phase time/bytes tables come from the labeled
metrics snapshot ``benchmarks/run.py`` writes next to ``--out``
(``results/benchmarks.metrics.json``) — see docs/observability.md for
the span/metric taxonomy behind them.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_roofline import analyze_record, markdown_table, run as roofline_run  # noqa: E402

DEFAULT_TMPL = """\
# Experiments

Auto-assembled by `python benchmarks/make_experiments_md.py` from
`results/` artifacts. Regenerate after `python -m benchmarks.run`.

## Roofline (dry-run cells)

{{ROOFLINE_TABLE}}

```json
{{ROOFLINE_SUMMARY}}
```

## Optimization deltas

{{OPT_TABLE}}

## Intersection methods (Table III)

```json
{{TABLE3}}
```

## Cache-size sweep (Fig. 7)

```json
{{FIG7}}
```

## Score policies (Fig. 8)

```json
{{FIG8}}
```

## Strong scaling, modeled (Figs. 9/10)

```json
{{FIG9}}
```

## Strong scaling, measured on 8 host devices

```json
{{FIG9M}}
```

## Degree/reuse correlation (Figs. 1/4/5)

```json
{{REUSE}}
```

## Shared-memory scaling (Fig. 6)

```json
{{FIG6}}
```

## Per-phase time/bytes (observability snapshot)

Folded from `--trace` spans via `repro.obs.metrics.fold_trace`; the
phase taxonomy is documented in docs/observability.md.

{{PHASE_TABLES}}
"""


def load(path):
    with open(path) as f:
        return json.load(f)


def opt_delta_table(cells, opt_dirs):
    """baseline vs best-optimized comparison rows."""
    lines = [
        "| cell | term | baseline | best opt | x | winning iteration |",
        "|---|---|---|---|---|---|",
    ]
    for tag, label in cells:
        base_path = f"results/dryrun/{tag}.json"
        if not os.path.exists(base_path):
            continue
        b = analyze_record(load(base_path))
        best = None
        best_dir = None
        for d in opt_dirs:
            p = f"results/{d}/{tag}.json"
            if not os.path.exists(p):
                continue
            rec = load(p)
            if not rec.get("ok"):
                continue
            o = analyze_record(rec)
            if best is None or o["roofline_bound_s"] < best["roofline_bound_s"]:
                best, best_dir = o, d
        if best is None:
            continue
        x = b["roofline_bound_s"] / max(best["roofline_bound_s"], 1e-12)
        lines.append(
            f"| {tag} | bound | {b['roofline_bound_s']:.3f}s "
            f"| {best['roofline_bound_s']:.3f}s | **{x:.1f}x** | {best_dir} ({label}) |"
        )
    if len(lines) == 2:
        return "(no dry-run optimization records under results/)"
    return "\n".join(lines)


def phase_tables(path="results/benchmarks.metrics.json"):
    """Per-suite markdown tables of per-phase wall time / calls / bytes,
    read from the ``phase_time_s``/``phase_calls``/``phase_bytes``
    counters of each suite's metrics snapshot."""
    if not os.path.exists(path):
        return ("(no metrics snapshot — `python -m benchmarks.run` writes "
                "results/benchmarks.metrics.json)")
    blocks = []
    for suite, snap in sorted(load(path).items()):
        rows = {}
        for c in snap.get("counters", []):
            if c["name"] in ("phase_time_s", "phase_calls", "phase_bytes"):
                d = rows.setdefault(c["phase"], {})
                d[c["name"]] = d.get(c["name"], 0.0) + c["value"]
        if not rows:
            continue
        lines = [
            f"**{suite}**", "",
            "| phase | calls | time (ms) | bytes |",
            "|---|---|---|---|",
        ]
        for ph in sorted(rows, key=lambda p: -rows[p].get("phase_time_s", 0)):
            d = rows[ph]
            lines.append(
                f"| `{ph}` | {d.get('phase_calls', 0):.0f} "
                f"| {d.get('phase_time_s', 0.0) * 1e3:.2f} "
                f"| {d.get('phase_bytes', 0):,.0f} |"
            )
        blocks.append("\n".join(lines))
    return "\n\n".join(blocks) or "(snapshot has no per-phase counters)"


def main():
    try:
        roof = roofline_run("results/dryrun")
        roofline_md = markdown_table(roof)
        roofline_summary = json.dumps(roof["summary"], indent=1)
    except Exception as e:  # noqa: BLE001 — report survives missing artifacts
        roofline_md = roofline_summary = f"(no roofline dry-run records: {e})"
    bench = load("results/benchmarks.json") if os.path.exists(
        "results/benchmarks.json") else {}

    cells = [
        ("phi3_5-moe-42b-a6_6b__train_4k__multi", "local-EP MoE + flash"),
        ("phi3_5-moe-42b-a6_6b__train_4k__single", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__train_4k__multi", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__train_4k__single", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__prefill_32k__multi", "local-EP MoE"),
        ("gemma2-27b__train_4k__multi", "flash + n_micro=8"),
        ("qwen2_5-14b__train_4k__multi", "flash + n_micro=8"),
        ("gat-cora__ogb_products__multi", "hub-split + node-sharded agg"),
        ("gat-cora__ogb_products__single", "hub-split + node-sharded agg"),
        ("stablelm-1_6b__train_4k__multi", "flash + n_micro=8"),
    ]
    opt_dirs = ["dryrun_opt", "dryrun_opt2", "dryrun_opt3", "dryrun_opt4",
                "dryrun_opt5", "dryrun_opt6", "dryrun_opt7"]

    if os.path.exists("EXPERIMENTS.tmpl.md"):
        with open("EXPERIMENTS.tmpl.md") as f:
            tmpl = f.read()
    else:
        tmpl = DEFAULT_TMPL

    out = tmpl.replace("{{ROOFLINE_TABLE}}", roofline_md)
    out = out.replace("{{ROOFLINE_SUMMARY}}", roofline_summary)
    out = out.replace("{{OPT_TABLE}}", opt_delta_table(cells, opt_dirs))

    # benchmark extracts
    def get(path, default="(run `python -m benchmarks.run`)"):
        cur = bench
        for k in path.split("."):
            if not isinstance(cur, dict) or k not in cur:
                return default
            cur = cur[k]
        return json.dumps(cur, indent=1, default=str)

    out = out.replace("{{TABLE3}}", get("intersection_tableIII.table"))
    out = out.replace("{{FIG7}}", get("cache_size_fig7"))
    out = out.replace("{{FIG8}}", get("scores_fig8.rows"))
    out = out.replace("{{FIG9}}", get("strong_scaling_fig9_10.modeled"))
    out = out.replace("{{FIG9M}}", get("strong_scaling_fig9_10.measured_8hostdev"))
    out = out.replace("{{REUSE}}", get("reuse_fig1_4_5.rows"))
    out = out.replace("{{FIG6}}", get("shared_scaling_fig6"))
    out = out.replace("{{PHASE_TABLES}}", phase_tables())

    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
