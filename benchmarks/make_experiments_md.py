"""Assemble EXPERIMENTS.md from results/ artifacts (reproducible report)."""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_roofline import analyze_record, markdown_table, run as roofline_run  # noqa: E402


def load(path):
    with open(path) as f:
        return json.load(f)


def opt_delta_table(cells, opt_dirs):
    """baseline vs best-optimized comparison rows."""
    lines = [
        "| cell | term | baseline | best opt | x | winning iteration |",
        "|---|---|---|---|---|---|",
    ]
    for tag, label in cells:
        b = analyze_record(load(f"results/dryrun/{tag}.json"))
        best = None
        best_dir = None
        for d in opt_dirs:
            p = f"results/{d}/{tag}.json"
            if not os.path.exists(p):
                continue
            rec = load(p)
            if not rec.get("ok"):
                continue
            o = analyze_record(rec)
            if best is None or o["roofline_bound_s"] < best["roofline_bound_s"]:
                best, best_dir = o, d
        if best is None:
            continue
        x = b["roofline_bound_s"] / max(best["roofline_bound_s"], 1e-12)
        lines.append(
            f"| {tag} | bound | {b['roofline_bound_s']:.3f}s "
            f"| {best['roofline_bound_s']:.3f}s | **{x:.1f}x** | {best_dir} ({label}) |"
        )
    return "\n".join(lines)


def main():
    roof = roofline_run("results/dryrun")
    bench = load("results/benchmarks.json") if os.path.exists(
        "results/benchmarks.json") else {}

    cells = [
        ("phi3_5-moe-42b-a6_6b__train_4k__multi", "local-EP MoE + flash"),
        ("phi3_5-moe-42b-a6_6b__train_4k__single", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__train_4k__multi", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__train_4k__single", "local-EP MoE + flash"),
        ("moonshot-v1-16b-a3b__prefill_32k__multi", "local-EP MoE"),
        ("gemma2-27b__train_4k__multi", "flash + n_micro=8"),
        ("qwen2_5-14b__train_4k__multi", "flash + n_micro=8"),
        ("gat-cora__ogb_products__multi", "hub-split + node-sharded agg"),
        ("gat-cora__ogb_products__single", "hub-split + node-sharded agg"),
        ("stablelm-1_6b__train_4k__multi", "flash + n_micro=8"),
    ]
    opt_dirs = ["dryrun_opt", "dryrun_opt2", "dryrun_opt3", "dryrun_opt4",
                "dryrun_opt5", "dryrun_opt6", "dryrun_opt7"]

    with open("EXPERIMENTS.tmpl.md") as f:
        tmpl = f.read()

    out = tmpl.replace("{{ROOFLINE_TABLE}}", markdown_table(roof))
    out = out.replace("{{ROOFLINE_SUMMARY}}",
                      json.dumps(roof["summary"], indent=1))
    out = out.replace("{{OPT_TABLE}}", opt_delta_table(cells, opt_dirs))

    # benchmark extracts
    def get(path, default="(run `python -m benchmarks.run`)"):
        cur = bench
        for k in path.split("."):
            if not isinstance(cur, dict) or k not in cur:
                return default
            cur = cur[k]
        return json.dumps(cur, indent=1, default=str)

    out = out.replace("{{TABLE3}}", get("intersection_tableIII.table"))
    out = out.replace("{{FIG7}}", get("cache_size_fig7"))
    out = out.replace("{{FIG8}}", get("scores_fig8.rows"))
    out = out.replace("{{FIG9}}", get("strong_scaling_fig9_10.modeled"))
    out = out.replace("{{FIG9M}}", get("strong_scaling_fig9_10.measured_8hostdev"))
    out = out.replace("{{REUSE}}", get("reuse_fig1_4_5.rows"))
    out = out.replace("{{FIG6}}", get("shared_scaling_fig6"))

    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
