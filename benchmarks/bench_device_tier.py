"""Device-resident hot-row tier: host-materialization savings science.

The host ``ClampiCache`` removes repeated *remote fetches*; the device
tier removes the next cost down the hierarchy — re-materializing (merge
+ pack) and re-uploading the same hub rows per kernel call. Two
experiments, both with answers/checkpoints verified bit-exact against a
from-scratch recount at p ∈ {1, 4}:

1. **Zipf serving** (hub-skewed point queries + interleaved update
   batches): uncached vs host-cache-only vs host+device over identical
   event streams. The comparison metric is the engine's
   ``host_pack_bytes`` (row bytes merged+packed host-side per kernel
   call) — the device tier routes resident pairs through the
   ``resident_intersect`` gather, so those bytes never materialize —
   plus a hot-set capacity sweep (hit rate + bytes saved vs slots).

2. **Streaming oo intersections**: the incremental engine's old∩old
   row pairs with and without the tier. Resident hub rows are served
   from the persistent mirror instead of per-batch ``DynamicCSR.row``
   merges; ``oo_host_bytes`` counts what still had to be built.

Counting paths use the host intersection fallback (cf.
bench_streaming.py: the Pallas kernels target TPU; interpret-mode
emulation would swamp the byte ledgers being measured — which are
identical on either path).
"""
from __future__ import annotations

import numpy as np

from repro.core.triangles import lcc_scores, triangles_per_vertex
from repro.graphs.rmat import rmat_graph, rmat_stream
from repro.serving import LiveQueryService, QueryKind, read_write_stream
from repro.streaming import StreamingLCCEngine
from repro.core.runtime import ShardedRuntime


def _serve_config(csr, *, p, uncached, device_slots, n_events, seed):
    svc = LiveQueryService(
        csr,
        p=p,
        device_slots=device_slots,
        uncached=uncached,
        max_batch=64,
        use_kernel=False,
    )
    n = csr.n
    served = 0
    results_tail = []
    snap = csr
    for ev in read_write_stream(
        lambda: svc.store.degrees,
        n,
        n_events=n_events,
        write_frac=0.2,
        queries_per_event=64,
        updates_per_event=32,
        kind="zipf",
        seed=seed,
    ):
        if ev.is_update:
            svc.apply_updates(ev.update)
            continue
        results_tail = svc.scheduler.run(ev.queries)
        snap = svc.store.to_csr()  # the snapshot those answers saw
        served += len(results_tail)
    # bit-exact check on the final microbatch vs a recount of ITS
    # snapshot (later update events must not enter the oracle)
    t_ref = triangles_per_vertex(snap)
    lcc_ref = lcc_scores(snap, t_ref)
    for r in results_tail:
        q = r.query
        if q.kind == QueryKind.TRIANGLES:
            assert r.value == t_ref[q.u]
        elif q.kind == QueryKind.LCC:
            assert r.value == lcc_ref[q.u]
    svc.verify()  # recount + zero stale rows on both tiers
    dev = svc.runtime.device
    st = svc.runtime.aggregate_stats()
    return {
        "p": p,
        "config": (
            "uncached" if uncached
            else f"host+device[{device_slots}]" if device_slots
            else "host-only"
        ),
        "served": served,
        "host_pack_bytes": svc.engine.host_pack_bytes,
        "pairs_resident": svc.engine.n_pairs_resident,
        "pairs_total": svc.engine.n_pairs_total,
        "remote_bytes_fetched": st.bytes_fetched,
        "device_hit_rate": round(dev.stats.hit_rate, 4) if dev else 0.0,
        "device_bytes_saved": dev.stats.bytes_saved if dev else 0,
        "device_upload_bytes": dev.stats.upload_bytes if dev else 0,
        "verified": True,
    }


def _stream_config(scale, edge_factor, *, p, device_slots, batches, seed):
    n = 1 << scale
    rt = ShardedRuntime(None, p, n=n)
    eng = StreamingLCCEngine.empty(n, use_kernel=False, runtime=rt)
    if device_slots:
        rt.enable_device_tier(device_slots, 256)
    total = edge_factor << scale
    for batch in rmat_stream(
        scale, edge_factor, batch_size=-(-total // batches),
        delete_frac=0.15, seed=seed,
    ):
        eng.apply_batch(batch)
        eng.verify()  # every checkpoint bit-exact vs recount
    dev = rt.device
    return {
        "p": p,
        "config": f"device[{device_slots}]" if device_slots else "host-only",
        "updates": eng.n_updates,
        "oo_pairs": eng.delta_pairs_total,
        "oo_host_rows": eng.oo_host_rows,
        "oo_host_bytes": eng.oo_host_bytes,
        "device_hit_rate": round(dev.stats.hit_rate, 4) if dev else 0.0,
        "device_bytes_saved": dev.stats.bytes_saved if dev else 0,
        "verified": True,
    }


def run(quick: bool = True):
    scale = 9 if quick else 11
    edge_factor = 8
    n_events = 12 if quick else 40
    csr = rmat_graph(scale, edge_factor, seed=0)
    out = {
        "scale": scale,
        "edge_factor": edge_factor,
        "paper_ref": "device-tier extension of §III-B2 degree-scored "
                     "caching (reuse argument one level down)",
        "serving_rows": [],
        "capacity_rows": [],
        "streaming_rows": [],
    }

    # 1. serving: uncached / host-only / host+device at p in {1, 4}
    slots = 256 if quick else 512
    for p in (1, 4):
        for cfg in ({"uncached": True, "device_slots": 0},
                    {"uncached": False, "device_slots": 0},
                    {"uncached": False, "device_slots": slots}):
            out["serving_rows"].append(_serve_config(
                csr, p=p, n_events=n_events, seed=3, **cfg
            ))
    by = {(r["p"], r["config"]): r for r in out["serving_rows"]}
    host = by[(4, "host-only")]["host_pack_bytes"]
    dev = by[(4, f"host+device[{slots}]")]["host_pack_bytes"]
    out["serving_materialization_reduction"] = round(1.0 - dev / host, 4)
    out["device_hit_rate_zipf"] = by[
        (4, f"host+device[{slots}]")
    ]["device_hit_rate"]

    # 2. capacity sweep: hit rate + bytes saved vs hot-set slots (p=4)
    for c in (32, 128, slots):
        r = _serve_config(
            csr, p=4, uncached=False, device_slots=c,
            n_events=n_events, seed=3,
        )
        out["capacity_rows"].append({
            "slots": c,
            "device_hit_rate": r["device_hit_rate"],
            "device_bytes_saved": r["device_bytes_saved"],
            "host_pack_bytes": r["host_pack_bytes"],
        })

    # 3. streaming oo with/without the tier at p in {1, 4}. The hot set
    #    is a fraction of the vertex set, so the number measures hub
    #    skew, not trivially-complete residency.
    s_scale = scale - 1
    s_slots = (1 << s_scale) // 4
    batches = 6 if quick else 12
    for p in (1, 4):
        for c in (0, s_slots):
            out["streaming_rows"].append(_stream_config(
                s_scale, edge_factor, p=p, device_slots=c,
                batches=batches, seed=5,
            ))
    sb = {(r["p"], r["config"]): r for r in out["streaming_rows"]}
    host_b = sb[(4, "host-only")]["oo_host_bytes"]
    dev_b = sb[(4, f"device[{s_slots}]")]["oo_host_bytes"]
    out["streaming_materialization_reduction"] = round(
        1.0 - dev_b / max(host_b, 1), 4
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
