"""Figs. 1(right), 4, 5: data-reuse characterization.

- reuse histogram: how many remote reads repeat y times (Fig. 1 right)
- contribution of the top-10% highest-degree vertices to remote reads
  (Fig. 4: power-law graphs concentrate; uniform graphs don't)
- C_adj entry size vs reuse correlation (Fig. 5 / Observation 3.1)
"""
from __future__ import annotations

import numpy as np

from repro.core.partition import partition_1d
from repro.core.rma import _edge_worklist
from repro.graphs.datasets import powerlaw_graph, uniform_graph
from repro.graphs.rmat import rmat_graph


def analyze(csr, p: int):
    part = partition_1d(csr.n, p)
    deg = csr.degrees
    all_remote = []
    for k in range(p):
        _, v_g = _edge_worklist(csr, part, k)
        owners = part.owner(v_g)
        all_remote.append(v_g[owners != k])
    remote = np.concatenate(all_remote)
    ids, counts = np.unique(remote, return_counts=True)
    hist_y, hist_c = np.unique(counts, return_counts=True)
    order = np.argsort(deg)[::-1]
    top10 = set(order[: max(csr.n // 10, 1)].tolist())
    top_mask = np.isin(remote, list(top10))
    # Observation 3.1: entry size (== degree) correlates with reuse
    corr = float(np.corrcoef(deg[ids], counts)[0, 1]) if ids.size > 2 else 0.0
    return {
        "total_remote_reads": int(remote.size),
        "unique_remote_vertices": int(ids.size),
        "top10pct_share_of_reads": float(top_mask.mean()),
        "size_reuse_correlation": corr,
        "reuse_histogram_head": [
            {"repeats": int(y), "n_reads": int(c)}
            for y, c in list(zip(hist_y, hist_c))[:10]
        ],
    }


def run(quick: bool = True):
    n = 4096 if quick else 65536
    graphs = {
        "facebook_circles (stand-in)": powerlaw_graph(n, 20, seed=0),
        "R-MAT S12 EF16": rmat_graph(12, 16, seed=0),
        "uniform": uniform_graph(n, 16, seed=1),
    }
    out = {"rows": [], "paper_ref": "Figs. 1/4/5"}
    for name, g in graphs.items():
        a = analyze(g, 8)
        a["graph"] = name
        out["rows"].append(a)
    # the paper's headline: power-law >> uniform in top-10% concentration
    pl = [r for r in out["rows"] if "uniform" not in r["graph"]]
    un = [r for r in out["rows"] if "uniform" in r["graph"]]
    out["powerlaw_concentrates"] = all(
        p_["top10pct_share_of_reads"] > u["top10pct_share_of_reads"]
        for p_ in pl for u in un
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
