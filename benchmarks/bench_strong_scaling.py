"""Figs. 9/10: distributed strong scaling — async vs async+cache vs TriC.

Two layers of evidence (this container has one physical CPU):
1. **Modeled makespans** via the paper's t(s)=alpha+s*beta network model:
   per-device communication times for the async engine (max over devices,
   no barriers; overlap absorbs compute) vs the TriC BSP simulator
   (sum over supersteps of the max — barriers bill the stragglers).
   Scales p = 4..64 as in Fig. 9.
2. **Measured wall time** of the real compiled shard_map engine vs the
   one-shot BSP baseline on 8 host devices (subprocess), p = 2/4/8.

Expected: ~linear async scaling on scale-free graphs (paper: 14x from
4->64 on LiveJournal1), cache cuts total time (up to 73% large-scale),
TriC slower by 10-100x on scale-free inputs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from repro.core.cache import build_static_degree_cache
from repro.core.rma import simulate_rma_lcc
from repro.core.tric_baseline import simulate_tric
from repro.graphs.datasets import powerlaw_graph, uniform_graph
from repro.graphs.rmat import rmat_graph

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


ALPHA = 2.0e-6  # one-sided get latency (Cray Aries class, paper §III-B)
BETA = 1.0e-10  # s/byte
# double buffering (paper §III-A) hides one of the two gets per edge
# (the w_offsets get overlaps the previous edge's w_adj fetch), so the
# effective per-get latency averages ~alpha/2:
ALPHA_EFF = ALPHA / 2
# TriC's two-sided query/response pays MPI matching + copies per query
# (paper §II-E) and cannot cache/dedup:
ALPHA_2S = 1.5e-6
T_EDGE = 2.0e-6  # intersection compute per edge (~0.5 edges/us, Table III)


def _async_time(st):
    """Async RMA model: compute overlaps communication, NO barriers — the
    makespan is the slowest device's max(comm, compute). Returns
    (makespan, comm_makespan) — the paper's cache figures (Fig. 7/8, the
    73%/47% reductions) are comm-time reductions, visible in the total
    only in the comm-dominated regime (large graphs / many nodes)."""
    comm = st.post_cache_gets * ALPHA_EFF + st.remote_bytes * BETA
    compute = st.compute_edges * T_EDGE
    return (float(np.maximum(comm, compute).max()) + ALPHA,
            float(comm.max()) + ALPHA)


def _tric_time(st, p, supersteps=8):
    """TriC: blocking query/response supersteps with a barrier each; no
    caching/dedup (one query per remote edge); the barrier bills everyone
    for max(comm) + max(compute) per superstep — no overlap across it."""
    comm_step = ((p - 1) * ALPHA + st.remote_gets * ALPHA_2S
                 + st.remote_bytes_raw * BETA) / supersteps
    compute_step = st.compute_edges * T_EDGE / supersteps
    return supersteps * (float(comm_step.max()) + float(compute_step.max()))


def modeled(quick: bool = True):
    # quick sizes: small enough for the pure-python CLaMPI trace sim; note
    # that p=64 over a 4-8k-vertex graph IS the paper's over-partitioning
    # regime (§IV-D2), so quick-mode speedups saturate below the paper's
    # 14x — run with --full for paper-scale graphs.
    scale = 12 if quick else 16
    n_small = 8192 if quick else 100000
    graphs = {
        f"R-MAT S{scale} EF16": rmat_graph(scale, 16, seed=0),
        "LiveJournal1 (stand-in)": powerlaw_graph(n_small, 28, seed=1),
        "uniform": uniform_graph(n_small, 16, seed=2),
    }
    out = []
    for name, g in graphs.items():
        rows = []
        for p in (4, 8, 16, 32, 64):
            nc = simulate_rma_lcc(g, p)
            cache_bytes = max(int(16 * 2**30 / p), 1) if not quick else \
                int(g.csr_nbytes() * 0.5)
            c = simulate_rma_lcc(g, p, adj_cache_bytes=cache_bytes,
                                 offsets_cache_bytes=int(0.8 * g.n),
                                 use_degree_score=True)
            t_async, comm_async = _async_time(nc)
            t_cached, comm_cached = _async_time(c)
            t_tric = _tric_time(nc, p)
            rows.append({
                "p": p,
                "async_s": t_async,
                "async_cached_s": t_cached,
                "tric_s": t_tric,
                "cache_gain_total": 1 - t_cached / max(t_async, 1e-12),
                "cache_gain_comm": 1 - comm_cached / max(comm_async, 1e-12),
                "vs_tric": t_tric / max(t_async, 1e-12),
            })
        base = rows[0]["async_s"]
        for r in rows:
            r["speedup_vs_p4"] = base / max(r["async_s"], 1e-12)
        out.append({"graph": name, "rows": rows})
    return out


def hub_partition_rows(quick: bool = True):
    """Hub-aware cuts vs equal 1D blocks on the modeled epoch engine
    (ROADMAP item 2; the serving-side fragment/skew evidence is in
    ``bench_partition``): per p, the balance of remote gets across
    ranks and the async makespans under both partitions. Compute stays
    identical — only ownership boundaries move — so the interesting
    columns are the get-imbalance and the comm-bound makespan."""
    from repro.core.partition import partition_hub

    g = powerlaw_graph(8192 if quick else 100000, 28, seed=1)
    rows = []
    for p in (4, 8, 16, 32):
        st_1d = simulate_rma_lcc(g, p)
        st_hub = simulate_rma_lcc(g, p, part=partition_hub(g.degrees, p))
        t_1d, _ = _async_time(st_1d)
        t_hub, _ = _async_time(st_hub)
        imb = lambda st: float(  # noqa: E731
            st.post_cache_gets.max() / max(st.post_cache_gets.mean(), 1e-9)
        )
        rows.append({
            "p": p,
            "async_1d_s": t_1d,
            "async_hub_s": t_hub,
            "get_imbalance_1d": round(imb(st_1d), 4),
            "get_imbalance_hub": round(imb(st_hub), 4),
            "makespan_gain": round(1 - t_hub / max(t_1d, 1e-12), 4),
        })
    return rows


MEASURE_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json, time
import numpy as np
from repro.graphs.rmat import rmat_graph
from repro.core.rma import build_sharded_problem
from repro.core.cache import build_static_degree_cache
from repro.core.async_engine import lcc_pipelined
from repro.core.tric_baseline import tric_problem

g = rmat_graph(11, 8, seed=0)
out = []
for p in (2, 4, 8):
    row = {"p": p}
    for label, kw in (
        ("async", dict(n_rounds=4)),
        ("async_cached", dict(n_rounds=4,
                              cache=build_static_degree_cache(g.degrees, 256))),
    ):
        prob = build_sharded_problem(g, p, **kw)
        t, lcc = lcc_pipelined(prob)  # compile+warm
        t0 = time.perf_counter()
        for _ in range(3):
            t, lcc = lcc_pipelined(prob)
        row[label] = (time.perf_counter() - t0) / 3
    prob = tric_problem(g, p)
    t, lcc = lcc_pipelined(prob)
    t0 = time.perf_counter()
    for _ in range(3):
        t, lcc = lcc_pipelined(prob)
    row["tric_bsp"] = (time.perf_counter() - t0) / 3
    out.append(row)
print(json.dumps(out))
"""


def measured():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MEASURE_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        return {"error": r.stderr[-1000:]}
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(quick: bool = True):
    return {
        "modeled": modeled(quick),
        "hub_partition": hub_partition_rows(quick),
        "measured_8hostdev": measured(),
        "paper_ref": "Figs. 9/10",
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
