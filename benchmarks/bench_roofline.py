"""Roofline table: three terms per (arch x shape x mesh) from the dry-run.

  compute    = dot_flops_per_device / PEAK_FLOPS_BF16
  memory     = hbm_bytes_per_device / HBM_BW
  collective = weighted_collective_bytes_per_device / ICI_BW

All inputs are PER-DEVICE quantities from the partitioned module (the
dry-run compiles the SPMD program, so shapes in the HLO are local), which
makes the terms directly per-chip times. dot_flops is the loop-corrected
census (cost_analysis does not multiply while bodies — see hlo_census).
MODEL_FLOPS = 6*N_active*D tokens (LM train; x1/3 for inference fwd-only)
compares 'useful' model math against compiled math.
"""
from __future__ import annotations

import glob
import json
import os

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import HW  # noqa: E402


def model_flops_per_device(rec: dict) -> float:
    """6*N_active*D for train, 2*N_active*D for single forward (per chip)."""
    n_act = rec.get("active_params") or rec.get("params") or 0
    kind = rec.get("kind", "")
    chips = 1
    for d in rec.get("mesh_shape", [1]):
        chips *= d
    if kind == "lm_train":
        toks = rec.get("tokens_per_step", 0)
        return 6.0 * n_act * toks / chips
    if kind == "lm_prefill":
        # batch*seq forward tokens
        return 0.0  # filled by caller when shapes known
    return 0.0


def analyze_record(rec: dict) -> dict:
    cost = rec.get("cost", {})
    col = rec.get("collectives", {})
    flops = col.get("dot_flops", 0.0) or cost.get("flops", 0.0)
    hbm = cost.get("bytes_accessed", 0.0)
    cbytes = col.get("weighted_bytes", 0.0)
    t_c = flops / HW.PEAK_FLOPS_BF16
    t_m = hbm / HW.HBM_BW
    t_x = cbytes / HW.ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    total = max(t_c, t_m, t_x)
    mf = model_flops_per_device(rec)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "ok": rec.get("ok", False),
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": dom.replace("_s", ""),
        "roofline_bound_s": round(total, 6),
        "hlo_dot_flops_per_dev": flops,
    }
    if mf > 0:
        out["model_flops_per_dev"] = mf
        out["useful_fraction"] = round(mf / max(flops, 1.0), 4)
        # MFU-at-roofline-bound: useful flops / (time * peak)
        out["roofline_mfu"] = round(
            mf / (max(total, 1e-12) * HW.PEAK_FLOPS_BF16), 4
        )
    mem = rec.get("memory", {})
    per_dev = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0) + \
        mem.get("output_bytes", 0)
    out["hbm_bytes_per_dev"] = per_dev
    out["fits_hbm"] = per_dev <= HW.HBM_BYTES
    return out


def run(dryrun_dir: str = "results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "ok": False,
                         "error": (rec.get("error") or "")[:120]})
            continue
        rows.append(analyze_record(rec))
    ok = [r for r in rows if r.get("ok")]
    summary = {
        "n_cells": len(rows),
        "n_ok": len(ok),
        "bottleneck_histogram": {},
    }
    for r in ok:
        b = r["bottleneck"]
        summary["bottleneck_histogram"][b] = (
            summary["bottleneck_histogram"].get(b, 0) + 1
        )
    return {"rows": rows, "summary": summary}


def markdown_table(result: dict) -> str:
    lines = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) "
        "| bottleneck | useful frac | fits HBM |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in result["rows"]:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - "
                f"| FAILED | - | - |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | **{r['bottleneck']}** "
            f"| {r.get('useful_fraction', '-')} "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    res = run()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(res, f, indent=1)
    print(markdown_table(res))
    print("\nsummary:", json.dumps(res["summary"]))
