"""Benchmark runner: one benchmark per paper table/figure + roofline.

``python -m benchmarks.run [--full] [--only <name>] [--out <path>]``
Writes results/benchmarks.json (or ``--out``) and prints a readable
summary. CI runs quick mode with ``--out results/BENCH_ci.json`` and
gates regressions via ``benchmarks/ci_compare.py`` (see
benchmarks/README.md for how to refresh the committed baseline).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger graphs (slower, closer to paper scales)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/benchmarks.json",
                    help="output JSON path (CI writes BENCH_ci.json so "
                         "the committed baseline is never clobbered)")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (
        bench_cache_size,
        bench_device_tier,
        bench_intersection,
        bench_partition,
        bench_reuse,
        bench_roofline,
        bench_schedule_rebuild,
        bench_scores,
        bench_serving,
        bench_shared_scaling,
        bench_spmd_scaling,
        bench_streaming,
        bench_strong_scaling,
        bench_traffic,
    )

    suites = {
        "intersection_tableIII": lambda: bench_intersection.run(quick),
        "shared_scaling_fig6": lambda: bench_shared_scaling.run(quick),
        "cache_size_fig7": lambda: bench_cache_size.run(quick),
        "scores_fig8": lambda: bench_scores.run(quick),
        "reuse_fig1_4_5": lambda: bench_reuse.run(quick),
        "strong_scaling_fig9_10": lambda: bench_strong_scaling.run(quick),
        "streaming_updates": lambda: bench_streaming.run(quick),
        "serving_queries": lambda: bench_serving.run(quick),
        "device_tier": lambda: bench_device_tier.run(quick),
        "schedule_rebuild": lambda: bench_schedule_rebuild.run(quick),
        "spmd_scaling": lambda: bench_spmd_scaling.run(quick),
        "partition_hub": lambda: bench_partition.run(quick),
        "traffic_plane": lambda: bench_traffic.run(quick),
        "roofline": lambda: bench_roofline.run(),
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if args.only in k}

    results = {}
    for name, fn in suites.items():
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            results[name] = fn()
            results[name]["_seconds"] = round(time.time() - t0, 1)
            print(json.dumps(results[name], indent=1, default=str)[:4000])
        except Exception as e:  # noqa: BLE001
            import traceback

            results[name] = {"error": str(e),
                             "traceback": traceback.format_exc()[-2000:]}
            print(f"FAILED: {e}")
        print(flush=True)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    # suites may attach a labeled metrics snapshot (repro.obs) under
    # "_metrics_snapshot"; split those into a sidecar so the results
    # JSON stays diff-reviewable and the report can tabulate per-phase
    # time/bytes from one place.
    snapshots = {
        name: r.pop("_metrics_snapshot")
        for name, r in results.items()
        if isinstance(r, dict) and "_metrics_snapshot" in r
    }
    if snapshots:
        mpath = os.path.splitext(args.out)[0] + ".metrics.json"
        with open(mpath, "w") as f:
            json.dump(snapshots, f, indent=1)
        print(f"wrote {mpath}")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"wrote {args.out}")

    checklist(results)
    return 0


def checklist(results):
    """Headline assertions mirroring the paper's claims."""
    print("\n=== paper-claim checklist ===")
    checks = []
    t3 = results.get("intersection_tableIII", {}).get("table", [])
    if t3:
        checks.append(("hybrid best or tied on every graph (Table III)",
                       all(r["hybrid_best"] for r in t3)))
    f7 = results.get("cache_size_fig7", {})
    if "max_comm_reduction_adj_only" in f7:
        checks.append((f"C_adj alone cuts comm time by "
                       f"{f7['max_comm_reduction_adj_only']:.0%} (paper: ~52%)",
                       f7["max_comm_reduction_adj_only"] > 0.3))
    if "mattson_speedup" in f7:
        checks.append((
            f"cachescope: Fig. 7 curves from ONE recorded trace "
            f"(Mattson), {f7['mattson_speedup']:.1f}x faster than the "
            f"per-size sweep, bit-exact at "
            f"{len(f7.get('mattson_spot_checks', []))} spot capacities",
            f7["mattson_matches_direct"] and f7["mattson_speedup"] > 1.0,
        ))
    f8 = results.get("scores_fig8", {}).get("rows", [])
    if f8:
        checks.append(("degree scores beat LRU on every graph (Fig. 8)",
                       all(r["degree_score_improvement"] > 0 for r in f8)))
        checks.append((
            "cachescope: Fig. 8 policy rows replayed offline from one "
            "recorded run; deployed replay reconciles bit-exactly",
            results["scores_fig8"].get("replay_reconciled", False),
        ))
        checks.append((
            "cachescope: clairvoyant Belady dominates every replayed "
            "policy (Fig. 8)",
            all(r["belady"]["hit_rate"]
                >= max(r["degree"]["hit_rate"],
                       r["lru_positional"]["hit_rate"],
                       r["ewma"]["hit_rate"])
                for r in f8),
        ))
    f9 = results.get("strong_scaling_fig9_10", {}).get("modeled", [])
    for g in f9:
        last = g["rows"][-1]
        uniform = "uniform" in g["graph"]
        if uniform:
            # the paper's control: flat degree distribution => little
            # reuse => caching must NOT help much (Fig. 4)
            ok = (last["speedup_vs_p4"] > 2 and last["vs_tric"] > 1.0
                  and last["cache_gain_comm"] < 0.2)
            note = "(control: low gain EXPECTED)"
        else:
            ok = (last["speedup_vs_p4"] > 2 and last["vs_tric"] > 1.0
                  and last["cache_gain_comm"] > 0.2)
            note = ""
        checks.append((
            f"{g['graph']}: async {last['speedup_vs_p4']:.1f}x 4->64 nodes; "
            f"{last['vs_tric']:.1f}x vs TriC; cache cuts "
            f"{last['cache_gain_comm']:.0%} of comm {note}",
            ok,
        ))
    fs = results.get("streaming_updates", {})
    if "incremental_speedup_vs_recount" in fs:
        checks.append((
            f"streaming: incremental maintenance "
            f"{fs['incremental_speedup_vs_recount']}x faster than "
            f"per-batch recount",
            fs["incremental_speedup_vs_recount"] > 1.0,
        ))
    if "store_vectorized_speedup" in fs:
        checks.append((
            f"streaming: vectorized DynamicCSR mutations "
            f"{fs['store_vectorized_speedup']}x vs per-edge np.insert",
            fs["store_vectorized_speedup"] > 1.0,
        ))
    sr = results.get("schedule_rebuild", {})
    if "schedule_incremental_speedup" in sr:
        checks.append((
            f"schedule: incremental apply_delta "
            f"{sr['schedule_incremental_speedup']}x faster than "
            f"from-scratch rebuild at 1% deltas (target >= 5x, bit-exact)",
            sr["schedule_incremental_speedup"] >= 5.0 and sr["bit_exact"],
        ))
    dt = results.get("device_tier", {})
    if "serving_materialization_reduction" in dt:
        checks.append((
            f"device tier: cuts serving host-row materialization "
            f"{dt['serving_materialization_reduction']:.0%} on Zipf "
            f"(device hit rate {dt['device_hit_rate_zipf']:.0%}), "
            f"answers bit-exact at p in {{1,4}}",
            dt["serving_materialization_reduction"] > 0
            and dt["device_hit_rate_zipf"] > 0.2,
        ))
    if "streaming_materialization_reduction" in dt:
        checks.append((
            f"device tier: cuts streaming oo materialization "
            f"{dt['streaming_materialization_reduction']:.0%} with a "
            f"quarter-size hot set, checkpoints bit-exact at p in {{1,4}}",
            dt["streaming_materialization_reduction"] > 0.3,
        ))
    sv = results.get("serving_queries", {})
    if "microbatch_speedup_zipf" in sv:
        checks.append((
            f"serving: microbatching {sv['microbatch_speedup_zipf']}x vs "
            f"one-query-at-a-time on Zipf (target >= 5x)",
            sv["microbatch_speedup_zipf"] >= 5.0,
        ))
        checks.append((
            f"serving: degree-scored cache cuts modeled remote time "
            f"{sv['cache_comm_reduction_zipf']:.0%} on Zipf "
            f"(hit rate {sv['hit_rate_zipf']:.0%})",
            sv["cache_comm_reduction_zipf"] > 0.2
            and sv["hit_rate_zipf"] > 0.2,
        ))
    if "trace_overhead_ok" in sv:
        checks.append((
            f"observability: disabled-tracer hook overhead "
            f"{sv['trace_disabled_overhead_frac']:.2%} of serve wall "
            f"({sv['disabled_span_ns']:.0f} ns/span x "
            f"{sv['n_spans_enabled']:.0f} spans; target < 3%)",
            sv["trace_overhead_ok"],
        ))
    if "cache_trace_overhead_ok" in sv:
        checks.append((
            f"observability: disabled cachescope hook overhead "
            f"{sv['cache_trace_disabled_overhead_frac']:.2%} of serve "
            f"wall ({sv['disabled_cachehook_ns']:.0f} ns/get x "
            f"{sv['n_cache_events']} events; target < 3%)",
            sv["cache_trace_overhead_ok"],
        ))
    sp = results.get("spmd_scaling", {})
    if "model_agreement_all" in sp:
        checks.append((
            "SPMD execution: measured all_to_all traffic == modeled "
            "serve matrix on every run (rows and payload bytes)",
            sp["model_agreement_all"],
        ))
    ph = results.get("partition_hub", {})
    if "bit_exact_all" in ph:
        checks.append((
            "partition: hub splitting bit-exact vs 1D across "
            "{loop, spmd} x p in {1,4,8}",
            ph["bit_exact_all"],
        ))
        checks.append((
            f"partition: hub cuts + fragments reduce load imbalance "
            f"({ph['load_imbalance_1d']:.2f}x -> "
            f"{ph['load_imbalance_hub']:.2f}x) and serve-matrix skew "
            f"({ph['serve_skew_1d']:.2f}x -> {ph['serve_skew_hub']:.2f}x) "
            f"on the scale-free graph",
            ph["imbalance_reduced"] and ph["skew_reduced"],
        ))
    tp = results.get("traffic_plane", {})
    if "p99_rises_under_saturation" in tp:
        lo, hi = tp["offered_load_rows"][0], tp["offered_load_rows"][-1]
        checks.append((
            f"traffic: open-loop p99 grows with offered load "
            f"({lo['p99_ms']:.0f} ms @ {lo['offered_frac_of_capacity']}x "
            f"-> {hi['p99_ms']:.0f} ms @ "
            f"{hi['offered_frac_of_capacity']}x capacity)",
            tp["p99_rises_under_saturation"],
        ))
        checks.append((
            f"traffic: live EWMA blend beats pure degree by "
            f"{tp['ewma_hit_rate_gain']:+.1%} hit rate on the "
            f"hub-drift trace; live pure-frequency run reconciles "
            f"bit-exactly with cachescope's offline ewma replay",
            tp["ewma_beats_degree_hit_rate"]
            and tp["ewma_matches_offline_replay"],
        ))
        checks.append((
            f"traffic: 50/50 cache shares protect tenant B's hit rate "
            f"({tp['tenants']['b_hit_rate_no_shares']:.0%} -> "
            f"{tp['tenants']['b_hit_rate_with_shares']:.0%} under "
            f"tenant A's flood); per-tenant bytes sum exactly to "
            f"used_bytes",
            tp["tenant_isolation_holds"] and tp["tenant_accounting_exact"],
        ))
        checks.append((
            "traffic: open-loop arrivals change when queries run, "
            "never what they answer (bit-exact vs closed loop)",
            tp["open_loop_bit_exact"],
        ))
    for msg, ok in checks:
        print(("PASS " if ok else "FAIL ") + msg)


if __name__ == "__main__":
    sys.exit(main())
