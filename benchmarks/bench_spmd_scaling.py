"""SPMD vs loop execution of the sharded runtime's rank views.

Two questions, per p:

1. **Wall-clock** — what does running the p rank views as one
   ``shard_map`` over a p-device mesh cost/buy vs the sequential
   in-process loop? (On the CPU host-device mesh the SPMD path pays
   dispatch + padding overhead — the harness exists so the same code
   measures honestly on a real TPU mesh; the numbers here are the CPU
   floor, not the paper's scaling claim.)
2. **Model fidelity** — does the *measured* all_to_all traffic agree
   with the modeled ``serve_rows`` matrix? The executor asserts
   row-for-row equality on every microbatch; this benchmark reports the
   aggregate measured-vs-modeled rows/bytes and the padded wire bytes
   (the overhead the model does not charge).

Runs in a subprocess with 8 forced host devices, like
``bench_strong_scaling`` (jax pins the device count at first init).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURE_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json, sys, time
import numpy as np

quick = bool(int(sys.argv[1]))
scale = 8 if quick else 10
n_events = 6 if quick else 24
ps = (1, 4) if quick else (1, 4, 8)

from repro.graphs.rmat import rmat_graph, rmat_stream
from repro.serving import LiveQueryService
from repro.serving.workload import read_write_stream
from repro.streaming import StreamingCacheCoherence, StreamingLCCEngine


def serve_wall(execution, p):
    csr = rmat_graph(scale, 8, seed=0)
    svc = LiveQueryService(csr, p=p, cross_rank=True, execution=execution)
    events = list(read_write_stream(
        lambda: svc.store.degrees, csr.n, n_events=n_events,
        write_frac=0.0, queries_per_event=64, kind="zipf", seed=0,
    ))
    # warm-up: one window (compile cost excluded from the steady rate)
    svc.scheduler.run(events[0].queries)
    t0 = time.perf_counter()
    served = 0
    for ev in events[1:]:
        served += len(svc.scheduler.run(ev.queries))
    wall = time.perf_counter() - t0
    row = {"p": p, "execution": execution, "served": served,
           "wall_s": round(wall, 4),
           "qps": round(served / max(wall, 1e-9), 1)}
    if execution == "spmd":
        led = svc.engine.spmd.ledger
        modeled_rows = int(svc.runtime.serve_rows.sum())
        modeled_bytes = int(sum(s.bytes_fetched for s in svc.runtime.stats))
        row.update(
            measured_rows=led.total_rows,
            modeled_rows=modeled_rows,
            measured_payload_bytes=led.bytes_payload,
            modeled_bytes=modeled_bytes,
            wire_bytes=led.bytes_on_wire,
            collectives=led.n_collectives,
            device_wall_s=round(led.device_wall_s, 4),
            model_agreement=bool(
                led.total_rows == modeled_rows
                and led.bytes_payload == modeled_bytes
            ),
        )
    return row


def stream_wall(execution, p):
    n = 1 << scale
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=p, cache_rows=128
    )
    eng = StreamingLCCEngine.empty(n, coherence=coh, execution=execution)
    batches = list(rmat_stream(
        scale, 8, batch_size=(1 << scale), delete_frac=0.15, seed=0,
    ))
    eng.apply_batch(batches[0])  # warm-up / compile
    t0 = time.perf_counter()
    ops = 0
    for b in batches[1:]:
        r = eng.apply_batch(b)
        ops += r.n_inserted + r.n_deleted
    wall = time.perf_counter() - t0
    eng.verify()
    row = {"p": p, "execution": execution, "updates": ops,
           "wall_s": round(wall, 4),
           "upd_per_s": round(ops / max(wall, 1e-9), 1)}
    if execution == "spmd":
        led = eng.spmd.ledger
        row.update(
            measured_rows=led.total_rows,
            measured_payload_bytes=led.bytes_payload,
            wire_bytes=led.bytes_on_wire,
            collectives=led.n_collectives,
            device_wall_s=round(led.device_wall_s, 4),
        )
    return row


out = {"serving": [], "streaming": []}
for p in ps:
    for execution in ("loop", "spmd"):
        out["serving"].append(serve_wall(execution, p))
        out["streaming"].append(stream_wall(execution, p))
print(json.dumps(out))
"""


def run(quick: bool = True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", MEASURE_SCRIPT, str(int(quick))],
        capture_output=True,
        text=True,
        env=env,
        timeout=3600,
    )
    if r.returncode != 0:
        return {"error": r.stderr[-2000:]}
    res = json.loads(r.stdout.strip().splitlines()[-1])
    agree = [
        row["model_agreement"]
        for row in res["serving"]
        if "model_agreement" in row
    ]
    return {
        "serving": res["serving"],
        "streaming": res["streaming"],
        "model_agreement_all": bool(agree and all(agree)),
        "paper_ref": "measured RMA-get traffic vs the §IV cost model; "
                     "loop-vs-SPMD execution of the rank views",
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
