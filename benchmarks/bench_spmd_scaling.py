"""SPMD vs loop execution of the sharded runtime's rank views.

Three questions, per p:

1. **Wall-clock** — what does running the p rank views as one
   ``shard_map`` over a p-device mesh cost/buy vs the sequential
   in-process loop, and what does the pipelined (double-buffered)
   variant buy on top? (On the CPU host-device mesh the SPMD path pays
   dispatch + padding overhead — the harness exists so the same code
   measures honestly on a real TPU mesh; the numbers here are the CPU
   floor, not the paper's scaling claim.)
2. **Model fidelity** — does the *measured* all_to_all traffic agree
   with the modeled ``serve_rows`` matrix? The executor asserts
   row-for-row equality on every microbatch; this benchmark reports the
   aggregate measured-vs-modeled rows/bytes and the padded wire bytes
   (the overhead the model does not charge).
3. **Async-plane savings** — how many upload bytes does the resident
   rank-sharded device buffer save vs re-packing every unit
   (``upload_bytes_saved``), and how much wire padding do the
   width-bucketed collectives recover vs the single-width baseline
   (``wire_padding_saved``)? Both are deterministic byte counters, so
   CI gates on them as booleans (``upload_savings_positive``,
   ``wire_padding_reduced``) rather than on noisy wall clocks.

Runs in a subprocess with 8 forced host devices, like
``bench_strong_scaling`` (jax pins the device count at first init).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURE_SCRIPT = r"""
from repro.distributed.spmd_runtime import ensure_host_devices
ensure_host_devices(8)  # preserves external XLA_FLAGS; must precede jax init
import json, sys, time
import numpy as np

quick = bool(int(sys.argv[1]))
scale = 8 if quick else 10
n_events = 6 if quick else 24
ps = (4, 8) if quick else (1, 4, 8)

from repro.graphs.rmat import rmat_graph, rmat_stream
from repro.serving import LiveQueryService
from repro.serving.workload import read_write_stream
from repro.streaming import StreamingCacheCoherence, StreamingLCCEngine


def _mode(execution, pipeline):
    return execution + ("+pipeline" if pipeline else "")


def _ledger_fields(led):
    return dict(
        measured_rows=led.total_rows,
        measured_payload_bytes=led.bytes_payload,
        wire_bytes=led.bytes_on_wire,
        wire_bytes_single=led.bytes_on_wire_single,
        wire_padding_saved=led.wire_padding_saved,
        bytes_uploaded=led.bytes_uploaded,
        upload_bytes_saved=led.upload_bytes_saved,
        patches=led.n_patches,
        collectives=led.n_collectives,
        device_wall_s=round(led.device_wall_s, 4),
        overlap_wait_s=round(led.overlap_wait_s, 4),
    )


def serve_wall(execution, p, pipeline):
    csr = rmat_graph(scale, 8, seed=0)
    svc = LiveQueryService(csr, p=p, cross_rank=True, execution=execution,
                           pipeline=pipeline)
    events = list(read_write_stream(
        lambda: svc.store.degrees, csr.n, n_events=n_events,
        write_frac=0.0, queries_per_event=64, kind="zipf", seed=0,
    ))
    # warm-up: one window (compile cost excluded from the steady rate)
    svc.scheduler.run(events[0].queries)
    t0 = time.perf_counter()
    served = 0
    for ev in events[1:]:
        served += len(svc.scheduler.run(ev.queries))
    wall = time.perf_counter() - t0
    row = {"p": p, "execution": _mode(execution, pipeline),
           "served": served, "wall_s": round(wall, 4),
           "qps": round(served / max(wall, 1e-9), 1)}
    if execution == "spmd":
        led = svc.engine.spmd.ledger
        modeled_rows = int(svc.runtime.serve_rows.sum())
        modeled_bytes = int(sum(s.bytes_fetched for s in svc.runtime.stats))
        row.update(_ledger_fields(led))
        row.update(
            modeled_rows=modeled_rows,
            modeled_bytes=modeled_bytes,
            model_agreement=bool(
                led.total_rows == modeled_rows
                and led.bytes_payload == modeled_bytes
            ),
        )
    return row


def stream_wall(execution, p, pipeline):
    n = 1 << scale
    coh = StreamingCacheCoherence(
        n, np.zeros(n, np.int64), p=p, cache_rows=128
    )
    eng = StreamingLCCEngine.empty(n, coherence=coh, execution=execution,
                                   pipeline=pipeline)
    batches = list(rmat_stream(
        scale, 8, batch_size=(1 << scale), delete_frac=0.15, seed=0,
    ))
    eng.apply_batch(batches[0])  # warm-up / compile
    t0 = time.perf_counter()
    ops = 0
    for b in batches[1:]:
        r = eng.apply_batch(b)
        ops += r.n_inserted + r.n_deleted
    wall = time.perf_counter() - t0
    eng.verify()
    row = {"p": p, "execution": _mode(execution, pipeline),
           "updates": ops, "wall_s": round(wall, 4),
           "upd_per_s": round(ops / max(wall, 1e-9), 1)}
    if execution == "spmd":
        row.update(_ledger_fields(eng.spmd.ledger))
    return row


MODES = (("loop", False), ("spmd", False), ("spmd", True))
out = {"serving": [], "streaming": []}
for p in ps:
    for execution, pipeline in MODES:
        out["serving"].append(serve_wall(execution, p, pipeline))
        out["streaming"].append(stream_wall(execution, p, pipeline))
print(json.dumps(out))
"""


def _spmd(rows):
    return [r for r in rows if r["execution"].startswith("spmd")]


def _speedups(rows, key="wall_s"):
    """Per-p wall of the best SPMD variant over the loop baseline
    (> 1.0 means SPMD beat the loop)."""
    out = {}
    ps = sorted({r["p"] for r in rows})
    for p in ps:
        loop = [r for r in rows if r["p"] == p and r["execution"] == "loop"]
        spmd = [r for r in rows if r["p"] == p
                and r["execution"].startswith("spmd")]
        if loop and spmd:
            best = min(r[key] for r in spmd)
            out[str(p)] = round(loop[0][key] / max(best, 1e-9), 3)
    return out


def run(quick: bool = True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", MEASURE_SCRIPT, str(int(quick))],
        capture_output=True,
        text=True,
        env=env,
        timeout=3600,
    )
    if r.returncode != 0:
        return {"error": r.stderr[-2000:]}
    res = json.loads(r.stdout.strip().splitlines()[-1])
    agree = [
        row["model_agreement"]
        for row in res["serving"]
        if "model_agreement" in row
    ]
    spmd_rows = _spmd(res["serving"]) + _spmd(res["streaming"])
    upload_saved = sum(r["upload_bytes_saved"] for r in spmd_rows)
    wire = sum(r["wire_bytes"] for r in spmd_rows)
    wire_single = sum(r["wire_bytes_single"] for r in spmd_rows)
    serving_speedup = _speedups(res["serving"])
    streaming_speedup = _speedups(res["streaming"])
    return {
        "serving": res["serving"],
        "streaming": res["streaming"],
        "model_agreement_all": bool(agree and all(agree)),
        # deterministic async-plane byte savings (CI-gated booleans)
        "upload_bytes_saved_total": upload_saved,
        "upload_savings_positive": bool(upload_saved > 0),
        "wire_bytes_total": wire,
        "wire_bytes_single_total": wire_single,
        "wire_padding_reduced": bool(wire < wire_single),
        # wall-clock context (informational — CPU floor, not gated)
        "serving_spmd_speedup": serving_speedup,
        "streaming_spmd_speedup": streaming_speedup,
        "spmd_beats_loop_any": bool(
            any(v > 1.0 for v in serving_speedup.values())
            or any(v > 1.0 for v in streaming_speedup.values())
        ),
        "paper_ref": "measured RMA-get traffic vs the §IV cost model; "
                     "loop vs SPMD vs pipelined-SPMD execution of the "
                     "rank views",
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
