"""Step factories: one train/serve step per architecture family.

Every factory returns a pure function suitable for ``jax.jit`` with
explicit in/out shardings — the same functions are used by the smoke
tests (1 device), the end-to-end examples, and the 512-device dry-run.

LM training uses gradient accumulation over microbatches via ``lax.scan``
(keeps peak activation memory to one microbatch) with remat inside the
layer scan; GNN/recsys steps are single-shot.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import transformer as tfm
from ..models.common import cross_entropy_loss
from .optimizer import adamw

__all__ = [
    "make_lm_train_step",
    "make_lm_prefill_step",
    "make_lm_decode_step",
    "make_gnn_train_step",
    "make_recsys_train_step",
    "make_recsys_serve_step",
    "make_retrieval_step",
    "tree_add",
    "tree_scale",
]


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_f32(t):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)


# --------------------------------------------------------------------------
# LM
# --------------------------------------------------------------------------
def make_lm_train_step(
    cfg: tfm.TransformerConfig,
    opt: adamw,
    rules: tfm.AxisRules = tfm.AxisRules(),
    *,
    n_microbatches: int = 1,
    accum_dtype=jnp.float32,
):
    """``accum_dtype=bf16`` halves gradient-accumulator memory AND the
    gradient all-reduce bytes (§Perf iteration 7); the optimizer update
    still runs its moments in f32."""

    def loss_of(params, tokens, labels):
        return tfm.loss_fn(params, tokens, labels, cfg, rules)

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if n_microbatches > 1:
            b = tokens.shape[0]
            mb = b // n_microbatches
            tk = tokens.reshape(n_microbatches, mb, -1)
            lb = labels.reshape(n_microbatches, mb, -1)

            def acc_body(carry, xs):
                g_acc, l_acc = carry
                t_i, l_i = xs
                l, g = jax.value_and_grad(loss_of)(params, t_i, l_i)
                g = jax.tree.map(lambda a, x: a + x.astype(accum_dtype),
                                 g_acc, g)
                return (g, l_acc + l), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, accum_dtype), params)
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_body, (zeros, 0.0), (tk, lb)
            )
            grads = tree_scale(g_sum, 1.0 / n_microbatches)
            loss = l_sum / n_microbatches
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, tokens, labels)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


def make_lm_prefill_step(cfg, rules=tfm.AxisRules(), *, max_len: int):
    def step(params, tokens):
        return tfm.forward_prefill(params, tokens, cfg, rules, max_len=max_len)

    return step


def make_lm_decode_step(cfg, rules=tfm.AxisRules()):
    def step(params, token, pos, cache):
        return tfm.forward_decode(params, token, pos, cache, cfg, rules)

    return step


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------
def _gnn_loss(apply_fn, cfg, params, batch):
    out = apply_fn(params, batch, cfg)
    if isinstance(out, tuple):  # MACE: (node_e, graph_e) — energy regression
        _, energy = out
        target = batch["labels"].astype(jnp.float32)
        return jnp.mean(jnp.square(energy.astype(jnp.float32) - target))
    labels = batch["labels"]
    if labels.dtype in (jnp.int32, jnp.int64):  # classification
        logits = out
        if "graph_ids" in batch and labels.shape[0] != logits.shape[0]:
            # graph-level labels over node-level logits: mean-pool readout
            from ..models.gnn.common import segment_mean

            logits = segment_mean(logits, batch["graph_ids"], labels.shape[0])
        if "label_mask" in batch:
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            ll = jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
            msk = batch["label_mask"].astype(jnp.float32)
            return -(ll * msk).sum() / jnp.maximum(msk.sum(), 1.0)
        return cross_entropy_loss(logits, labels)
    return jnp.mean(jnp.square(out[..., 0].astype(jnp.float32)
                               - labels.astype(jnp.float32)))


def make_gnn_train_step(apply_fn: Callable, cfg, opt: adamw):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            functools.partial(_gnn_loss, apply_fn, cfg)
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


# --------------------------------------------------------------------------
# recsys (DIN)
# --------------------------------------------------------------------------
def _bce(logits, labels):
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def make_recsys_train_step(apply_fn, cfg, opt: adamw):
    def step(params, opt_state, batch):
        def loss_of(p):
            return _bce(apply_fn(p, batch, cfg), batch["label"])

        loss, grads = jax.value_and_grad(loss_of)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


def make_recsys_serve_step(apply_fn, cfg):
    def step(params, batch):
        return jax.nn.sigmoid(apply_fn(params, batch, cfg))

    return step


def make_retrieval_step(score_fn, cfg, *, top_k: int = 100):
    def step(params, batch):
        scores = score_fn(params, batch, cfg)
        vals, idx = jax.lax.top_k(scores, top_k)
        return vals, idx

    return step
