from . import optimizer, train_loop, checkpoint  # noqa: F401
