"""Checkpointing: save/restore pytrees with resharding-on-restore.

Design goals (1000+ node deployments):
- **portable**: leaves are written as one ``.npz`` (path-keyed) plus a
  msgpack manifest (step, config fingerprint, mesh shape, data-stream
  state) — no pickle.
- **restart-safe**: writes go to a temp dir + atomic rename; the manager
  keeps the last K checkpoints and a ``latest`` pointer.
- **elastic**: ``restore`` takes target shardings — arrays are loaded on
  host and ``device_put`` against the *new* mesh, so a job can restart on
  a different device count (tested by round-tripping across mesh shapes).
- **async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread, overlapping I/O with the next
  training steps (the classic async-checkpoint trick).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import msgpack
import numpy as np

__all__ = ["CheckpointManager", "flatten_tree", "unflatten_tree"]


def flatten_tree(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def unflatten_tree(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()

    # ------------- write -------------
    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{time.time_ns()}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
        final = os.path.join(self.dir, f"step_{step:010d}")
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "latest"), "w") as f:
                f.write(os.path.basename(final))
            self._gc()
        return final

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def save(self, step: int, tree, *, meta: Optional[dict] = None) -> str:
        flat = flatten_tree(jax.tree.map(np.asarray, tree))
        m = dict(meta or {})
        m.update(step=step, time=time.time())
        return self._write(step, flat, m)

    def save_async(self, step: int, tree, *, meta: Optional[dict] = None) -> Future:
        # snapshot device arrays to host NOW; write later
        flat = flatten_tree(jax.tree.map(np.asarray, tree))
        m = dict(meta or {})
        m.update(step=step, time=time.time())
        return self._pool.submit(self._write, step, flat, m)

    # ------------- read -------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        return int(name.split("_")[1])

    def restore(
        self,
        template,
        *,
        step: Optional[int] = None,
        shardings=None,
    ):
        """Load into the structure of ``template``; if ``shardings`` given
        (a pytree of NamedSharding / None), device_put against them —
        this is the elastic-restart path (mesh may differ from save time)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = unflatten_tree(template, flat)
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree,
                shardings,
                is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)),
            )
        return tree, meta
