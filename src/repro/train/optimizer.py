"""AdamW on pytrees (no optax in this container) + ZeRO-1-style sharding.

``adamw()`` returns an (init, update) pair operating on arbitrary pytrees
with global-norm gradient clipping and decoupled weight decay. Moments are
f32 regardless of param dtype (bf16-safe).

``zero1_specs`` extends the parameter PartitionSpecs so optimizer moments
are additionally sharded along the 'data' axis (the first dimension not
already sharded whose size divides the data-axis extent) — the ZeRO-1
trick: optimizer state is partitioned across data-parallel replicas, and
GSPMD inserts the reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["adamw", "AdamWState", "cosine_schedule", "zero1_specs",
           "global_norm"]


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class adamw:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
        return AdamWState(
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState]:
        count = state.count + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = self.lr(count) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** count), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** count), nu)

        def upd(p, m, v):
            u = m / (jnp.sqrt(v) + self.eps) + self.weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
        return new_params, AdamWState(mu=mu, nu=nu, count=count)


def _shard_moment_spec(spec: P, shape, data_axes, mesh_shape) -> P:
    """Add 'data' sharding to the first unsharded, divisible dim."""
    if not data_axes:
        return spec
    extent = 1
    for a in data_axes:
        extent *= mesh_shape.get(a, 1)
    parts = list(spec) if spec is not None else [None] * len(shape)
    while len(parts) < len(shape):
        parts.append(None)
    for i, (p_, s_) in enumerate(zip(parts, shape)):
        if p_ is None and s_ % extent == 0 and s_ >= extent:
            parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*parts)
    return spec


def zero1_specs(param_specs, param_shapes, data_axes: Tuple[str, ...],
                mesh_shape: dict):
    """Specs for AdamW moments: params' specs + data-axis sharding (ZeRO-1)."""
    mom = jax.tree.map(
        lambda sp, sh: _shard_moment_spec(sp, sh.shape, data_axes, mesh_shape),
        param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
    return AdamWState(mu=mom, nu=mom, count=P())
