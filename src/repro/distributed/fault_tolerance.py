"""Fault-tolerance substrate: restartable training, straggler detection,
elastic re-meshing.

On a 1000+ node fleet the failure model is: (a) a pod/host dies -> the job
restarts from the last checkpoint, possibly on fewer/more hosts;
(b) a host is slow (thermals, network) -> detect and surface so the
scheduler can swap it; (c) transient step failures -> bounded retry.

Components:
- ``TrainRunner``: step loop with periodic async checkpoints, bounded
  retry on step exceptions, deterministic data resume (stream state in the
  manifest), wall-clock budget.
- ``StragglerMonitor``: per-step timing stats; flags steps/devices slower
  than ``threshold x`` the running median (on real TPU fleets per-host
  step times come from the profiler; here the hook takes any timing map).
- ``elastic_restore``: checkpoint -> new mesh/shardings (device count may
  differ from save time; arrays are host-staged and re-device_put).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..train.checkpoint import CheckpointManager

__all__ = ["StragglerMonitor", "TrainRunner", "elastic_restore"]


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: deque[float] = deque(maxlen=window)
        self.flagged: list[dict] = []

    def record(self, step: int, dt: float,
               per_device: Optional[Dict[str, float]] = None):
        med = float(np.median(self.times)) if self.times else dt
        self.times.append(dt)
        if len(self.times) >= 8 and dt > self.threshold * med:
            self.flagged.append({"step": step, "dt": dt, "median": med})
        if per_device:
            slow = {
                d: t
                for d, t in per_device.items()
                if t > self.threshold * float(np.median(list(per_device.values())))
            }
            if slow:
                self.flagged.append({"step": step, "devices": slow})

    @property
    def straggler_suspected(self) -> bool:
        return len(self.flagged) > 0


def elastic_restore(ckpt: CheckpointManager, template, shardings, *, step=None):
    """Restore onto a (possibly different) mesh: the manifest's saved mesh
    shape is advisory; arrays re-shard via device_put on load."""
    return ckpt.restore(template, step=step, shardings=shardings)


@dataclasses.dataclass
class TrainRunner:
    step_fn: Callable  # (params, opt_state, batch) -> (params, opt_state, metrics)
    data_fn: Callable[[int], Any]  # step -> batch
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 100
    max_retries: int = 2
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)

    def run(self, params, opt_state, *, start_step: int, n_steps: int,
            meta: Optional[dict] = None, async_ckpt: bool = True):
        metrics_log = []
        pending = None
        for step in range(start_step, start_step + n_steps):
            batch = self.data_fn(step)
            t0 = time.perf_counter()
            for attempt in range(self.max_retries + 1):
                try:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch
                    )
                    break
                except Exception:
                    if attempt == self.max_retries:
                        raise
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.record(step, dt)
            metrics_log.append(
                {"step": step, "dt": dt,
                 "loss": float(np.asarray(metrics["loss"]))}
            )
            if self.ckpt and (step + 1) % self.ckpt_every == 0:
                m = dict(meta or {})
                m["next_step"] = step + 1
                state = {"params": params, "opt_state": opt_state}
                if async_ckpt:
                    if pending is not None:
                        pending.result()  # backpressure: one in flight
                    pending = self.ckpt.save_async(step + 1, state, meta=m)
                else:
                    self.ckpt.save(step + 1, state, meta=m)
        if pending is not None:
            pending.result()
        return params, opt_state, metrics_log
