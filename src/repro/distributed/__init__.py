from . import sharding, hub_gather, fault_tolerance  # noqa: F401
