from . import sharding, hub_gather, fault_tolerance, spmd_runtime  # noqa: F401
