"""Device-parallel SPMD execution of the sharded runtime's rank views.

Since PR 3 the ``ShardedRuntime`` models p ranks — per-rank caches, a
rank-indexed ``fetch_rows`` transport, an all-to-all ``serve_rows``
matrix — but the rank views *execute* as a sequential Python loop over p
in-process engines. This module runs them as real SPMD compute over a
JAX device mesh, the way the static epoch ``async_engine`` already does:

- **Rank-sharded state** — each rank's working set for one execution
  unit (a serving microbatch, a streaming delta shard) is packed into a
  rank-sharded padded row buffer ``[p, H+1, W]``: rows the rank holds
  (its own shard's rows, cache-hit payloads, device-tier mirror rows)
  plus the rows it *serves* to other ranks this unit.
- **Collective transport** — the control plane (``fetch_rows`` cache
  admission, stats, the modeled ``serve_rows`` matrix) stays host-side
  and untouched; its recorded ``"miss"`` events become a serve list
  ``serve_idx[p, p, S]``, and inside ``shard_map`` one
  ``jax.lax.all_to_all`` ships exactly those rows owner -> requester.
  The measured collective traffic (``CollectiveLedger``) therefore
  reconciles *by construction* against the modeled matrix — the
  executor asserts row-for-row equality, and the padded-vs-payload gap
  is reported as wire overhead.
- **On-device intersect** — every rank gathers its pair worklist from
  the combined [held | fetched] buffer and counts |row_a ∩ row_b| inside
  the mapped function: the Pallas ``intersect_count`` kernel when
  ``use_kernel`` (the same kernel ``delta_intersect``/``point_query``
  dispatch to), else the vectorized ``count_bsearch_jnp`` path. Counts
  are exact integers either way, so SPMD execution is bit-exact against
  the loop-mode engines — the property tests compare them
  field-for-field.

Consumers: ``serving.engine.ShardedQueryEngine(execution="spmd")`` and
``streaming.incremental.StreamingLCCEngine(execution="spmd")``; drivers
``launch/query_serve.py --spmd`` and ``launch/stream_run.py --spmd``.
Multi-device CPU runs force host devices via ``ensure_host_devices``
(``--xla_force_host_platform_device_count``), preserving any
user-provided ``XLA_FLAGS``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.compat import shard_map
from ..core.intersect import count_bsearch_jnp
from ..kernels.bucketing import pow2_ceil
from ..kernels.intersect_count import intersect_count
from ..obs import trace as obs_trace

__all__ = [
    "CollectiveLedger",
    "ShardWork",
    "SpmdIntersectExecutor",
    "ensure_host_devices",
]

ID_BYTES = 4
_DEVCOUNT_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int, *, strict: bool = True) -> int:
    """Make at least ``n`` JAX devices available, forcing host-platform
    devices when none exist yet.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    — *preserving* any flags already set by the user or CI, and never
    overriding an existing device-count directive (jax pins the device
    count at first backend init, so an explicit external value must
    win). Returns the device count actually available; with ``strict``
    raises if it is still smaller than ``n`` (e.g. jax was already
    initialized single-device before this call, or an external
    directive pinned a smaller count). This is the one home of the
    flag-preserving logic — drivers, benchmarks, and subprocess test
    scripts call it instead of hand-editing ``XLA_FLAGS``."""
    n = int(n)
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVCOUNT_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_DEVCOUNT_FLAG}={n}".strip()
    have = len(jax.devices())  # first call initializes with the flags
    if strict and have < n:
        raise RuntimeError(
            f"need {n} devices for SPMD execution but only {have} are "
            f"available; set XLA_FLAGS={_DEVCOUNT_FLAG}={n} before the "
            "first jax use (jax locks the device count at first init)"
        )
    return have


@dataclasses.dataclass
class ShardWork:
    """One rank's slice of an execution unit.

    ``rows_held`` maps vertex id -> sorted 1-D row for every row that is
    rank-resident this unit (local shard rows, cache-hit payloads,
    device-tier mirror rows) — content is whatever the loop-mode engine
    would have read, so staleness semantics carry over unchanged.
    ``fetched_ids`` are the remote misses (in fetch order): their content
    is *not* taken from this rank — it ships from the owner's buffer
    through the collective. Every id referenced by ``pair_a``/``pair_b``
    must be in exactly one of the two."""

    rank: int
    pair_a: np.ndarray  # int64 [E] vertex ids
    pair_b: np.ndarray  # int64 [E]
    rows_held: Dict[int, np.ndarray]
    fetched_ids: Sequence[int] = ()


@dataclasses.dataclass
class CollectiveLedger:
    """Measured collective traffic of SPMD execution units.

    ``rows_shipped[owner, requester]`` counts rows that travelled
    through ``all_to_all`` — the measured analogue of the runtime's
    modeled ``serve_rows`` matrix (the executor asserts they agree
    delta-for-delta). ``bytes_payload`` is the true row payload moved
    (sum of shipped row widths, the quantity the ``NetworkModel``
    charges); ``bytes_on_wire`` is what the padded collective actually
    moved between devices (excludes the self-chunk), so
    ``bytes_on_wire - bytes_payload`` is padding overhead."""

    p: int
    rows_shipped: np.ndarray  # [p, p] int64, owner -> requester
    bytes_payload: int = 0
    bytes_on_wire: int = 0
    n_collectives: int = 0
    n_pairs: int = 0
    device_wall_s: float = 0.0

    @staticmethod
    def zero(p: int) -> "CollectiveLedger":
        return CollectiveLedger(p=p, rows_shipped=np.zeros((p, p), np.int64))

    def add(self, other: "CollectiveLedger") -> None:
        assert other.p == self.p
        self.rows_shipped += other.rows_shipped
        self.bytes_payload += other.bytes_payload
        self.bytes_on_wire += other.bytes_on_wire
        self.n_collectives += other.n_collectives
        self.n_pairs += other.n_pairs
        self.device_wall_s += other.device_wall_s

    @property
    def total_rows(self) -> int:
        return int(self.rows_shipped.sum())

    def to_dict(self) -> dict:
        return {
            "p": self.p,
            "rows_shipped": int(self.rows_shipped.sum()),
            "bytes_payload": int(self.bytes_payload),
            "bytes_on_wire": int(self.bytes_on_wire),
            "n_collectives": int(self.n_collectives),
            "n_pairs": int(self.n_pairs),
            "device_wall_s": self.device_wall_s,
        }


def _body(
    rows,  # [1, H+1+V, W] this rank's packed row buffer (pad row last)
    serve_idx,  # [1, p, S] local indices of rows shipped to each rank
    a_idx,  # [1, E] combined-buffer index of each pair's A row
    b_idx,  # [1, E]
    mask,  # [1, E] real-pair mask
    *,
    axis: str,
    p: int,
    s_max: int,
    w: int,
    sentinel: int,
    use_kernel: bool,
    block_e: int,
    interpret: bool,
):
    # shard_map keeps the sharded leading axis at local size 1 — squeeze.
    rows = rows[0]
    serve_idx = serve_idx[0]
    a_idx = a_idx[0]
    b_idx = b_idx[0]
    mask = mask[0]
    # serve phase: gather this rank's serve lists and run ONE all-to-all
    # — the dynamic analogue of the static engine's per-round fetch.
    to_send = rows[serve_idx]  # [p, S, W]
    got = jax.lax.all_to_all(
        to_send, axis, split_axis=0, concat_axis=0, tiled=False
    )
    fetched = got.reshape(p * s_max, w)
    combined = jnp.concatenate([rows, fetched], 0)
    ra = combined[a_idx]
    rb = combined[b_idx]
    if use_kernel:
        cnt = intersect_count(
            ra, rb, sentinel=sentinel, block_e=block_e, interpret=interpret
        )
    else:
        cnt = count_bsearch_jnp(ra, rb, sentinel)
    return jnp.where(mask, cnt, 0).astype(jnp.int32)[None]


class SpmdIntersectExecutor:
    """Runs per-rank pair-intersection worklists as one ``shard_map``
    over a 1-D ``("rank",)`` mesh of ``p`` devices.

    One ``run()`` call is one execution unit: pack every rank's held
    rows and serve lists into rank-sharded arrays, ship the remote
    misses with a single ``all_to_all``, intersect every pair on its
    executing rank's device, and return per-rank counts plus the
    measured ``CollectiveLedger``."""

    def __init__(
        self,
        part,
        n: int,
        *,
        p: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        use_kernel: Optional[bool] = None,
        block_e: int = 128,
        interpret: Optional[bool] = None,
        axis: str = "rank",
    ):
        self.part = part
        self.n = int(n)
        self.p = int(p if p is not None else part.p)
        self.axis = axis
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = bool(use_kernel)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.block_e = int(block_e)
        if mesh is None:
            devs = jax.devices()
            if len(devs) < self.p:
                raise RuntimeError(
                    f"SPMD execution at p={self.p} needs {self.p} devices "
                    f"but only {len(devs)} exist — call "
                    f"ensure_host_devices({self.p}) (or set XLA_FLAGS="
                    f"{_DEVCOUNT_FLAG}={self.p}) before the first jax use"
                )
            mesh = Mesh(np.array(devs[: self.p]), (axis,))
        self.mesh = mesh
        self.ledger = CollectiveLedger.zero(self.p)
        self._fn_cache: dict = {}

    # ---------------- compiled-function cache ----------------
    def _fn(self, h1v: int, s_max: int, w: int, e_pad: int, be: int):
        key = (h1v, s_max, w, e_pad, be)
        fn = self._fn_cache.get(key)
        if fn is None:
            body = functools.partial(
                _body,
                axis=self.axis,
                p=self.p,
                s_max=s_max,
                w=w,
                sentinel=self.n,
                use_kernel=self.use_kernel,
                block_e=be,
                interpret=self.interpret,
            )
            sh = P(self.axis)
            fn = jax.jit(
                shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(sh, sh, sh, sh, sh),
                    out_specs=sh,
                    check_vma=False,
                )
            )
            self._fn_cache[key] = fn
        return fn

    # ---------------- one execution unit ----------------
    def run(self, shards: List[ShardWork], store):
        """Execute one unit. ``store`` provides ``row(v)`` for the rows
        each owner serves (its authoritative shard content). Returns
        ``(counts, ledger)``: per-rank int64 count arrays in worklist
        order and this unit's measured collective ledger (also folded
        into the cumulative ``self.ledger``)."""
        p = self.p
        assert len(shards) == p and all(
            s.rank == k for k, s in enumerate(shards)
        ), "need one ShardWork per rank, in rank order"
        unit = CollectiveLedger.zero(p)
        n_pairs = sum(s.pair_a.size for s in shards)
        n_fetched = sum(len(s.fetched_ids) for s in shards)
        if n_pairs == 0 and n_fetched == 0:
            return [np.zeros(0, np.int64) for _ in range(p)], unit

        # spans: host-side packing vs. the device collective, as two
        # sibling phases (manual open/close keeps the hot path unindented)
        _pack = obs_trace.span("spmd_pack", cat="spmd", n_pairs=n_pairs,
                               n_fetched=n_fetched)
        _pack.__enter__()

        # serve lists: ship[k][j] = rows owner k sends requester j, in
        # requester fetch order (mirrors the serve_rows accounting).
        ship: List[List[List[int]]] = [[[] for _ in range(p)] for _ in range(p)]
        fetch_pos: List[Dict[int, int]] = [{} for _ in range(p)]
        for j, sh in enumerate(shards):
            for v in sh.fetched_ids:
                v = int(v)
                assert v not in sh.rows_held, (
                    f"id {v} both held and fetched at rank {j}"
                )
                k = int(self.part.owner(v))
                assert k != j, f"rank {j} fetching its own row {v}"
                if v in fetch_pos[j]:
                    continue  # one shipment per (owner, requester, id)
                fetch_pos[j][v] = (k, len(ship[k][j]))
                ship[k][j].append(v)

        # serve content: an owner ships its authoritative store rows —
        # reuse a held copy when the owner also holds the row this unit.
        serve_rows_content: List[Dict[int, np.ndarray]] = [
            {} for _ in range(p)
        ]
        w_max = 1
        for k in range(p):
            for j in range(p):
                for v in ship[k][j]:
                    if v not in serve_rows_content[k]:
                        held = shards[k].rows_held.get(v)
                        row = held if held is not None else np.asarray(
                            store.row(v)
                        )
                        serve_rows_content[k][v] = row
                        w_max = max(w_max, row.size)
                    unit.rows_shipped[k, j] += 1
                    unit.bytes_payload += (
                        serve_rows_content[k][v].size * ID_BYTES
                    )
        for sh in shards:
            for row in sh.rows_held.values():
                w_max = max(w_max, row.size)
        w = pow2_ceil(w_max, 1)

        # rank buffers: [held | serve-extras | pad]; uniform H+1+V slots.
        local_idx: List[Dict[int, int]] = [{} for _ in range(p)]
        buf_rows: List[List[np.ndarray]] = [[] for _ in range(p)]
        for k, sh in enumerate(shards):
            for v, row in sh.rows_held.items():
                local_idx[k][int(v)] = len(buf_rows[k])
                buf_rows[k].append(np.asarray(row))
            for v, row in serve_rows_content[k].items():
                if v not in local_idx[k]:
                    local_idx[k][v] = len(buf_rows[k])
                    buf_rows[k].append(row)
        # every device-array dimension is pow2-bucketed (like the width)
        # so the jit cache actually hits across microbatches — otherwise
        # h/s take arbitrary per-unit values and every unit recompiles.
        h_max = max(len(r) for r in buf_rows)
        h_buf = pow2_ceil(h_max + 1, 8)  # >= 1 slack row for the pad
        pad_idx = h_buf - 1  # the (last) all-sentinel row
        s_max = max(
            (len(ship[k][j]) for k in range(p) for j in range(p)),
            default=0,
        )
        s_max = pow2_ceil(s_max, 4)

        sentinel = self.n
        rows_arr = np.full((p, h_buf, w), sentinel, np.int32)
        for k in range(p):
            for i, row in enumerate(buf_rows[k]):
                rows_arr[k, i, : row.size] = row
        serve_idx = np.full((p, p, s_max), pad_idx, np.int32)
        for k in range(p):
            for j in range(p):
                for s, v in enumerate(ship[k][j]):
                    serve_idx[k, j, s] = local_idx[k][v]

        # pair worklists -> combined-buffer indices
        fetch_base = h_buf
        e_max = max((s.pair_a.size for s in shards), default=0)
        be = min(self.block_e, pow2_ceil(max(e_max, 1), 8))
        e_pad = -(-max(e_max, 1) // be) * be
        a_idx = np.full((p, e_pad), pad_idx, np.int32)
        b_idx = np.full((p, e_pad), pad_idx, np.int32)
        mask = np.zeros((p, e_pad), bool)

        def resolve(j: int, v: int) -> int:
            idx = local_idx[j].get(v)
            if idx is not None:
                return idx
            k, s = fetch_pos[j][v]
            return fetch_base + k * s_max + s

        for j, sh in enumerate(shards):
            e = sh.pair_a.size
            if not e:
                continue
            a_idx[j, :e] = [resolve(j, int(v)) for v in sh.pair_a]
            b_idx[j, :e] = [resolve(j, int(v)) for v in sh.pair_b]
            mask[j, :e] = True

        fn = self._fn(h_buf, s_max, w, e_pad, be)
        _pack.__exit__(None, None, None)
        # padded wire bytes, self-chunk excluded (it never leaves the
        # device) — the padding overhead the model does not charge.
        wire_bytes = p * (p - 1) * s_max * w * ID_BYTES
        with obs_trace.span(
            "all_to_all", cat="spmd", pairs=n_pairs,
            payload_bytes=int(unit.bytes_payload), wire_bytes=wire_bytes,
        ):
            t0 = time.perf_counter()
            out = fn(rows_arr, serve_idx, a_idx, b_idx, mask)
            out = np.asarray(jax.block_until_ready(out), np.int64)
            unit.device_wall_s += time.perf_counter() - t0

        unit.n_collectives += 1
        unit.n_pairs += n_pairs
        unit.bytes_on_wire += wire_bytes
        self.ledger.add(unit)
        counts = [out[j, : shards[j].pair_a.size] for j in range(p)]
        return counts, unit
