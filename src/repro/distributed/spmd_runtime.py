"""Device-parallel SPMD execution of the sharded runtime's rank views.

Since PR 3 the ``ShardedRuntime`` models p ranks — per-rank caches, a
rank-indexed ``fetch_rows`` transport, an all-to-all ``serve_rows``
matrix — but the rank views *execute* as a sequential Python loop over p
in-process engines. This module runs them as real SPMD compute over a
JAX device mesh, the way the static epoch ``async_engine`` already does
— and, since PR 8, it does so *asynchronously*:

- **Resident rank-sharded state** — the padded row buffer ``[p, H, W]``
  persists on device across execution units. Each unit only *patches*
  the rows that are new or drifted (the idiom ``ResidencyManager`` uses
  for the device tier): reused rows cost zero H2D traffic and are
  reported as ``upload_bytes_saved``. Freshness is an invalidation
  contract — the runtime's coherence fanout (and the streaming engine's
  mid-batch delete notification) drop mutated ids from the buffer, so a
  mapped id always matches ``store.row(v)`` at pack time.
- **Width-bucketed collective transport** — the control plane
  (``fetch_rows`` cache admission, stats, the modeled ``serve_rows``
  matrix) stays host-side and untouched; its recorded ``"miss"`` events
  become serve lists, bucketed onto a fixed geometric ladder of pow-2
  width rungs (``_PAIR_WIDTH_LADDER``) with windowed high-water
  capacities, so skewed batches stop shipping max-width padding *and*
  the compiled collective keeps a canonical shape across units. One
  ``jax.lax.all_to_all`` per rung moves exactly those rows owner ->
  requester; the measured ``CollectiveLedger`` reconciles
  *by construction* against the modeled matrix, and the recovered
  padding shows up as ``bytes_on_wire`` vs ``bytes_on_wire_single``
  (what the old single-width scheme would have moved).
- **Hub-fragment fan-out** — under a hub-aware partition
  (``core.partition.HubPartition``) a fetched split-hub row does not
  ship whole from its owner: every rank serves its *fragment* (slot
  keyed ``n + 1 + v`` so fragment and full-row residency never
  collide), the requester's own fragment stays local, and each pair
  touching the row expands into sub-pairs whose counts are summed by
  an additive scatter — the deterministic fragment reduction.
  Fragments are disjoint contiguous slices of the sorted row, so the
  reduction is exact and the measured ledger still reconciles
  row-for-row against the runtime's fragment-charged serve matrix.
- **Double-buffered units** — ``dispatch()`` packs, patches, and
  launches a unit without blocking; ``PendingUnit.wait()`` is the only
  reconciliation barrier (``jax.block_until_ready``). Callers overlap
  the pack + collective of unit k+1 with the in-flight intersect of
  unit k; because the ledger is computed host-side at dispatch, the
  measured-vs-modeled assertion still holds row-for-row before the
  device work ever completes. ``run()`` is dispatch + wait, the
  unpipelined shape consumers used before.
- **On-device intersect** — every rank gathers its pair worklist from
  the combined [resident | fetched] buffer; pairs are bucketed by their
  pow-2 width class and counted per bucket with the Pallas
  ``intersect_count`` kernel when ``use_kernel`` (the same kernel
  ``delta_intersect``/``point_query`` dispatch to), else the vectorized
  ``count_bsearch_jnp`` path. Counts are exact integers either way, so
  SPMD execution — pipelined or not — is bit-exact against the
  loop-mode engines; the property tests compare them field-for-field.

Consumers: ``serving.engine.ShardedQueryEngine(execution="spmd")`` and
``streaming.incremental.StreamingLCCEngine(execution="spmd")``; drivers
``launch/query_serve.py --spmd [--pipeline]`` and
``launch/stream_run.py --spmd [--pipeline]``. Multi-device CPU runs
force host devices via ``ensure_host_devices``
(``--xla_force_host_platform_device_count``), preserving any
user-provided ``XLA_FLAGS``. See docs/spmd.md for the resident-buffer
patch protocol and where the reconciliation barriers sit.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.compat import shard_map
from ..core.intersect import count_bsearch_jnp
from ..kernels.bucketing import pow2_ceil
from ..kernels.intersect_count import intersect_count
from ..obs import trace as obs_trace

__all__ = [
    "CollectiveLedger",
    "PendingUnit",
    "ShardWork",
    "SpmdIntersectExecutor",
    "ensure_host_devices",
]

ID_BYTES = 4
_DEVCOUNT_FLAG = "--xla_force_host_platform_device_count"
# bounded bucket counts — serve buckets each cost one all_to_all
# launch (adaptive smallest-merge split, exact wire accounting); pair
# buckets one kernel call each, on the fixed geometric width ladder
# below (clipped to the buffer width) so the compiled intersect shapes
# stay canonical across units.
_PAIR_WIDTH_LADDER = (16, 64, 256, 1 << 30)
# Windowed high-water capacities: per-rung counts follow the max need
# over the last _CAP_WINDOW units, so capacities (and the compiled
# programs keyed on them) stay put through per-unit jitter, grow
# immediately on demand, and decay once a peak ages out of the window.
_CAP_WINDOW = 16


def ensure_host_devices(n: int, *, strict: bool = True) -> int:
    """Make at least ``n`` JAX devices available, forcing host-platform
    devices when none exist yet.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    — *preserving* any flags already set by the user or CI, and never
    overriding an existing device-count directive (jax pins the device
    count at first backend init, so an explicit external value must
    win). An existing directive's *value* is parsed and compared
    against ``n``: a smaller pinned count fails here, immediately and
    by name, instead of surfacing later as a confusing generic device
    shortage. Returns the device count actually available; with
    ``strict`` raises if it is still smaller than ``n`` (e.g. jax was
    already initialized single-device before this call). This is the
    one home of the flag-preserving logic — drivers, benchmarks, and
    subprocess test scripts call it instead of hand-editing
    ``XLA_FLAGS``."""
    n = int(n)
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(re.escape(_DEVCOUNT_FLAG) + r"\s*=\s*(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = f"{flags} {_DEVCOUNT_FLAG}={n}".strip()
    have = len(jax.devices())  # first call initializes with the flags
    if strict and have < n:
        if m is not None and int(m.group(1)) < n:
            raise RuntimeError(
                f"XLA_FLAGS already pins {_DEVCOUNT_FLAG}={m.group(1)}, "
                f"smaller than the {n} devices SPMD execution needs — "
                f"raise it to at least {n} (or unset it and let "
                "ensure_host_devices set the count)"
            )
        raise RuntimeError(
            f"need {n} devices for SPMD execution but only {have} are "
            f"available; set XLA_FLAGS={_DEVCOUNT_FLAG}={n} before the "
            "first jax use (jax locks the device count at first init)"
        )
    return have


@dataclasses.dataclass
class ShardWork:
    """One rank's slice of an execution unit.

    ``rows_held`` maps vertex id -> sorted 1-D row for every row that is
    rank-resident this unit (local shard rows, cache-hit payloads,
    device-tier mirror rows) — content is whatever the loop-mode engine
    would have read, so staleness semantics carry over unchanged.
    ``fetched_ids`` are the remote misses (in fetch order): their content
    is *not* taken from this rank — it ships from the owner's buffer
    through the collective. Every id referenced by ``pair_a``/``pair_b``
    must be in exactly one of the two."""

    rank: int
    pair_a: np.ndarray  # int64 [E] vertex ids
    pair_b: np.ndarray  # int64 [E]
    rows_held: Dict[int, np.ndarray]
    fetched_ids: Sequence[int] = ()


@dataclasses.dataclass
class CollectiveLedger:
    """Measured collective + upload traffic of SPMD execution units.

    ``rows_shipped[owner, requester]`` counts rows that travelled
    through ``all_to_all`` — the measured analogue of the runtime's
    modeled ``serve_rows`` matrix (the executor asserts they agree
    delta-for-delta). ``bytes_payload`` is the true row payload moved
    (sum of shipped row widths, the quantity the ``NetworkModel``
    charges); ``bytes_on_wire`` is what the width-bucketed collectives
    actually moved between devices (excludes the self-chunk), and
    ``bytes_on_wire_single`` is what the pre-bucketing single-max-width
    collective *would* have moved — their difference is the recovered
    padding. ``bytes_uploaded`` / ``upload_bytes_saved`` split each
    unit's working set into rows that had to be H2D-patched into the
    resident buffer vs rows already resident from earlier units (a full
    re-pack would upload the sum of both). Wall-clock fields:
    ``device_wall_s`` is dispatch-to-ready per unit; ``overlap_wait_s``
    is the part actually spent blocked in ``wait()`` — under pipelining
    the gap between them is compute the overlap hid."""

    p: int
    rows_shipped: np.ndarray  # [p, p] int64, owner -> requester
    bytes_payload: int = 0
    bytes_on_wire: int = 0
    bytes_on_wire_single: int = 0
    bytes_uploaded: int = 0
    upload_bytes_saved: int = 0
    n_patches: int = 0
    n_collectives: int = 0
    n_pairs: int = 0
    device_wall_s: float = 0.0
    overlap_wait_s: float = 0.0

    @staticmethod
    def zero(p: int) -> "CollectiveLedger":
        return CollectiveLedger(p=p, rows_shipped=np.zeros((p, p), np.int64))

    def add(self, other: "CollectiveLedger") -> None:
        assert other.p == self.p
        self.rows_shipped += other.rows_shipped
        self.bytes_payload += other.bytes_payload
        self.bytes_on_wire += other.bytes_on_wire
        self.bytes_on_wire_single += other.bytes_on_wire_single
        self.bytes_uploaded += other.bytes_uploaded
        self.upload_bytes_saved += other.upload_bytes_saved
        self.n_patches += other.n_patches
        self.n_collectives += other.n_collectives
        self.n_pairs += other.n_pairs
        self.device_wall_s += other.device_wall_s
        self.overlap_wait_s += other.overlap_wait_s

    @property
    def total_rows(self) -> int:
        return int(self.rows_shipped.sum())

    @property
    def wire_padding_saved(self) -> int:
        """Wire bytes the width-bucketed collectives did NOT move
        compared to the single-max-width baseline."""
        return int(self.bytes_on_wire_single - self.bytes_on_wire)

    def to_dict(self) -> dict:
        return {
            "p": self.p,
            "rows_shipped": int(self.rows_shipped.sum()),
            "bytes_payload": int(self.bytes_payload),
            "bytes_on_wire": int(self.bytes_on_wire),
            "bytes_on_wire_single": int(self.bytes_on_wire_single),
            "wire_padding_saved": self.wire_padding_saved,
            "bytes_uploaded": int(self.bytes_uploaded),
            "upload_bytes_saved": int(self.upload_bytes_saved),
            "n_patches": int(self.n_patches),
            "n_collectives": int(self.n_collectives),
            "n_pairs": int(self.n_pairs),
            "device_wall_s": self.device_wall_s,
            "overlap_wait_s": self.overlap_wait_s,
        }


class _ResidentShardBuffer:
    """The persistent rank-sharded row buffer ``[p, H, W]``.

    Slot ``H-1`` of every rank is a permanent all-sentinel pad row; data
    slots hold one adjacency row each, keyed by vertex id per rank. The
    numpy ``mirror`` is authoritative; ``device`` is its sharded twin
    (``NamedSharding`` over the executor's mesh) updated by in-place
    ``.at[].set`` patches — the same epoch/patch idiom as the device
    tier's ``ResidencyManager``, minus the scoring (admission here is
    "whatever this unit needs", eviction is LRU among slots the current
    unit does not reference).

    Freshness contract: a mapped id's mirror content equals
    ``store.row(v)`` as of the last unit that wrote it. Callers MUST
    route every store mutation through ``invalidate`` before the next
    dispatch (the engines register on the runtime's coherence fanout,
    and the streaming engine notifies deletions mid-batch); ``audit``
    verifies the contract against an authoritative store."""

    def __init__(self, p: int, sentinel: int, mesh: Mesh, axis: str):
        self.p = int(p)
        self.sentinel = int(sentinel)
        self.mesh = mesh
        self.axis = axis
        self.h = 0  # slots per rank, incl. the trailing pad row
        self.w = 0
        self.mirror: Optional[np.ndarray] = None  # [p, h, w] int32
        self.device = None  # jnp twin, sharded P(axis)
        self.slot_of: List[Dict[int, int]] = [dict() for _ in range(p)]
        self.slot_ids: Optional[np.ndarray] = None  # [p, h] int64, -1 free
        self.widths: Optional[np.ndarray] = None  # [p, h] int32
        self.last_used: Optional[np.ndarray] = None  # [p, h] int64
        self.tick = 0

    @property
    def pad_slot(self) -> int:
        return self.h - 1

    # ---------------- capacity ----------------
    def _grow(self, h_new: int, w_new: int, unit: "CollectiveLedger") -> None:
        """Reallocate to (h_new, w_new), keeping mapped rows (slot
        indices are preserved — only the pad slot moves). A grow is a
        full re-upload, charged to ``bytes_uploaded`` at true payload
        widths."""
        p = self.p
        mirror = np.full((p, h_new, w_new), self.sentinel, np.int32)
        slot_ids = np.full((p, h_new), -1, np.int64)
        widths = np.zeros((p, h_new), np.int32)
        last_used = np.zeros((p, h_new), np.int64)
        if self.mirror is not None:
            keep = self.h - 1  # old data slots (old pad row is empty)
            mirror[:, :keep, : self.w] = self.mirror[:, :keep, :]
            slot_ids[:, :keep] = self.slot_ids[:, :keep]
            widths[:, :keep] = self.widths[:, :keep]
            last_used[:, :keep] = self.last_used[:, :keep]
            unit.bytes_uploaded += int(self.widths[:, :keep].sum()) * ID_BYTES
        self.mirror, self.slot_ids = mirror, slot_ids
        self.widths, self.last_used = widths, last_used
        self.h, self.w = h_new, w_new
        self._upload_full()

    def _upload_full(self) -> None:
        self.device = jax.device_put(
            jnp.asarray(self.mirror),
            NamedSharding(self.mesh, P(self.axis)),
        )

    def _alloc(self, k: int, protected: set) -> int:
        """A data slot for rank k: first free slot, else LRU-evict a
        slot the current unit does not reference. Capacity is grown
        ahead of assignment, so an evictable slot always exists."""
        ids = self.slot_ids[k, : self.h - 1]
        free = np.flatnonzero(ids < 0)
        if free.size:
            return int(free[0])
        lu = self.last_used[k, : self.h - 1].astype(np.int64, copy=True)
        if protected:
            lu[list(protected)] = np.iinfo(np.int64).max
        s = int(np.argmin(lu))
        assert s not in protected, "no evictable resident slot"
        old = int(self.slot_ids[k, s])
        del self.slot_of[k][old]
        return s

    # ---------------- per-unit patching ----------------
    def ensure(
        self,
        needed: List[Dict[int, np.ndarray]],
        unit: "CollectiveLedger",
    ) -> None:
        """Make every (rank, id) in ``needed`` resident: reuse mapped
        rows (``upload_bytes_saved``), patch the rest in one device
        scatter (``bytes_uploaded`` / ``n_patches``, span
        ``spmd_patch``)."""
        self.tick += 1
        p = self.p
        w_need = max((r.size for d in needed for r in d.values()), default=1)
        h_need = max((len(d) for d in needed), default=0) + 1
        grew = False
        if w_need > self.w or h_need > self.h:
            grew = True
            self._grow(
                max(self.h, pow2_ceil(h_need, 8)),
                max(self.w, pow2_ceil(w_need, 8)),
                unit,
            )
        patches: List[Tuple[int, int, np.ndarray]] = []
        for k in range(p):
            # reused slots are protected from this unit's evictions
            protected = {
                s
                for v, row in needed[k].items()
                if (s := self.slot_of[k].get(v)) is not None
                and self.widths[k, s] == row.size
            }
            for v, row in needed[k].items():
                s = self.slot_of[k].get(v)
                if s is not None and self.widths[k, s] == row.size:
                    # fresh by the invalidation contract — zero H2D.
                    # (a grow already charged this row to the full
                    # re-upload, so it is not "saved" this unit)
                    if not grew:
                        unit.upload_bytes_saved += row.size * ID_BYTES
                    self.last_used[k, s] = self.tick
                    continue
                if s is None:
                    s = self._alloc(k, protected)
                    self.slot_of[k][v] = s
                    self.slot_ids[k, s] = v
                protected.add(s)
                self.widths[k, s] = row.size
                self.last_used[k, s] = self.tick
                self.mirror[k, s, :] = self.sentinel
                self.mirror[k, s, : row.size] = row
                patches.append((k, s, row))
                unit.bytes_uploaded += row.size * ID_BYTES
                unit.n_patches += 1
        self._patch_device(patches, grew)

    def _patch_device(self, patches, grew: bool) -> None:
        if not patches:
            return
        with obs_trace.span(
            "spmd_patch", cat="spmd", n_patches=len(patches),
            patch_bytes=sum(r.size for _, _, r in patches) * ID_BYTES,
            rebuild=grew,
        ):
            if grew:
                # the grow already uploaded the full mirror; fold the
                # new rows into one more full upload (they were written
                # to the mirror above)
                self._upload_full()
                return
            # pad the scatter to a pow-2 row count so its compiled
            # shape space stays logarithmic; filler rows rewrite the
            # permanent pad slot with the sentinel it already holds
            m = pow2_ceil(len(patches))
            ks = np.zeros(m, np.int32)
            ss = np.full(m, self.pad_slot, np.int32)
            vals = np.full((m, self.w), self.sentinel, np.int32)
            for i, (k, s, row) in enumerate(patches):
                ks[i], ss[i] = k, s
                vals[i, : row.size] = row
            self.device = self.device.at[ks, ss].set(jnp.asarray(vals))

    # ---------------- coherence ----------------
    def invalidate(self, changed_ids=None) -> None:
        """Drop mutated ids from every rank's map (``None`` = drop
        everything, e.g. on a store swap). Slot contents become
        unreferenced garbage; no device traffic."""
        if self.mirror is None:
            return
        if changed_ids is None:
            for k in range(self.p):
                self.slot_of[k].clear()
            self.slot_ids[:, :] = -1
            self.widths[:, :] = 0
            return
        for v in np.unique(np.asarray(changed_ids, np.int64).ravel()):
            v = int(v)
            for k in range(self.p):
                s = self.slot_of[k].pop(v, None)
                if s is not None:
                    self.slot_ids[k, s] = -1
                    self.widths[k, s] = 0

    def audit(self, store, expect=None) -> int:
        """Number of mapped rows whose mirror content differs from the
        authoritative store — 0 under the invalidation contract.
        ``expect(k, key)`` (optional) maps a buffer key to its expected
        content; the default is ``store.row(key)`` (the executor passes
        a resolver that understands hub-fragment keys)."""
        bad = 0
        for k in range(self.p):
            for v, s in self.slot_of[k].items():
                row = (
                    expect(k, v)
                    if expect is not None
                    else np.asarray(store.row(v))
                )
                ok = self.widths[k, s] == row.size and np.array_equal(
                    self.mirror[k, s, : row.size], row
                )
                bad += 0 if ok else 1
        return bad


def _body_serve(
    rows,  # [1, H, W] this rank's resident row buffer (pad row last)
    serve_idx,  # [1, p, S_tot] resident slots shipped per requester
    *,
    axis: str,
    p: int,
    w: int,
    serve_cfg: Tuple[Tuple[int, int], ...],  # (s_b, w_b) per bucket
    f_pad: int,  # high-water fetched-block capacity (pow-2)
    sentinel: int,
):
    """Serve phase: one ``all_to_all`` per width rung — each ships its
    rung's rows at the rung width instead of the global max width.
    ``serve_cfg`` holds windowed high-water capacities, so the program
    recompiles only when a capacity moves, and ``bytes_on_wire`` is
    charged from these exact shapes. The received rows are padded into
    a fixed-capacity ``[1, f_pad, w]`` block so the downstream
    intersect program's input shape is stable across units."""
    # shard_map keeps the sharded leading axis at local size 1 — squeeze.
    rows = rows[0]
    serve_idx = serve_idx[0]
    parts = []
    off = 0
    for s_b, w_b in serve_cfg:
        idx = serve_idx[:, off : off + s_b]  # [p, s_b]
        to_send = rows[idx][:, :, :w_b]  # [p, s_b, w_b]
        got = jax.lax.all_to_all(
            to_send, axis, split_axis=0, concat_axis=0, tiled=False
        )
        fetched = got.reshape(p * s_b, w_b)
        if w_b < w:
            fetched = jnp.pad(
                fetched, ((0, 0), (0, w - w_b)), constant_values=sentinel
            )
        parts.append(fetched)
        off += s_b
    n_rows = sum(fp.shape[0] for fp in parts)
    parts.append(
        jnp.full((f_pad - n_rows, w), sentinel, rows.dtype)
    )
    return jnp.concatenate(parts, 0)[None]


def _body_pairs(
    rows,  # [1, H, W] this rank's resident row buffer (pad row last)
    fetched,  # [1, f_pad, W] the serve program's padded output block
    a_idx,  # [1, E_tot] combined-buffer index of each pair's A row
    b_idx,  # [1, E_tot]
    mask,  # [1, E_tot] real-pair mask
    *,
    p: int,
    w: int,
    pair_cfg: Tuple[Tuple[int, int, int], ...],  # (e_b, w_p, block_e)
    sentinel: int,
    use_kernel: bool,
    interpret: bool,
):
    """Intersect phase: one kernel call per pair width bucket, each
    comparing only w_p columns instead of the global max width. Shapes
    here are canonical (fixed bucket widths, high-water sizes), so this
    — the expensive program to compile — recompiles only when a
    high-water mark grows, not per unit."""
    rows = rows[0]
    fetched = fetched[0]
    a_idx = a_idx[0]
    b_idx = b_idx[0]
    mask = mask[0]
    combined = jnp.concatenate([rows, fetched], 0)
    outs = []
    off = 0
    for e_b, w_p, block_e in pair_cfg:
        ra = combined[a_idx[off : off + e_b]][:, :w_p]
        rb = combined[b_idx[off : off + e_b]][:, :w_p]
        if use_kernel:
            cnt = intersect_count(
                ra, rb, sentinel=sentinel, block_e=block_e,
                interpret=interpret,
            )
        else:
            cnt = count_bsearch_jnp(ra, rb, sentinel)
        outs.append(
            jnp.where(mask[off : off + e_b], cnt, 0).astype(jnp.int32)
        )
        off += e_b
    out = (
        jnp.concatenate(outs) if outs else jnp.zeros((0,), jnp.int32)
    )
    return out[None]


@dataclasses.dataclass
class PendingUnit:
    """An in-flight execution unit: the host-side ledger is final at
    dispatch (pack, patch, and ship accounting are synchronous), the
    device counts are not. ``wait()`` is the reconciliation barrier —
    the only ``block_until_ready`` in the SPMD path — and returns
    ``(counts, unit)`` exactly like the old blocking ``run()``."""

    executor: "SpmdIntersectExecutor"
    out: object  # device array, or None for the empty unit
    scatter: Optional[List[List[Tuple[np.ndarray, int]]]]
    pair_sizes: List[int]
    unit: CollectiveLedger
    t_dispatch: float
    _done: Optional[tuple] = None

    def wait(self):
        if self._done is not None:
            return self._done
        if self.out is None:  # empty unit — nothing was dispatched
            counts = [np.zeros(sz, np.int64) for sz in self.pair_sizes]
            self._done = (counts, self.unit)
            return self._done
        with obs_trace.span(
            "spmd_overlap_wait", cat="spmd", pairs=int(self.unit.n_pairs)
        ):
            t0 = time.perf_counter()
            arr = np.asarray(jax.block_until_ready(self.out), np.int64)
            t1 = time.perf_counter()
        waited = t1 - t0
        wall = t1 - self.t_dispatch
        self.unit.overlap_wait_s += waited
        self.unit.device_wall_s += wall
        led = self.executor.ledger
        led.overlap_wait_s += waited
        led.device_wall_s += wall
        counts = [np.zeros(sz, np.int64) for sz in self.pair_sizes]
        for j in range(self.executor.p):
            for positions, off in self.scatter[j]:
                # additive scatter: a pair against a split hub row
                # expands into one sub-pair per fragment, all mapped to
                # the same worklist position — fragments partition the
                # row, so summing the sub-counts IS the deterministic
                # fragment reduction (and reduces to plain assignment
                # when every position is unique, the non-hub case).
                np.add.at(
                    counts[j], positions,
                    arr[j, off : off + positions.size],
                )
        self._done = (counts, self.unit)
        return self._done


class SpmdIntersectExecutor:
    """Runs per-rank pair-intersection worklists as one ``shard_map``
    over a 1-D ``("rank",)`` mesh of ``p`` devices.

    One ``dispatch()`` call launches one execution unit: patch the
    persistent resident buffer with this unit's working-set drift, ship
    the remote misses with width-bucketed ``all_to_all`` collectives,
    and count every pair on its executing rank's device. The returned
    ``PendingUnit`` carries the complete measured ``CollectiveLedger``
    immediately; ``wait()`` blocks for the per-rank counts. ``run()``
    is the unpipelined dispatch+wait convenience."""

    def __init__(
        self,
        part,
        n: int,
        *,
        p: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        use_kernel: Optional[bool] = None,
        block_e: int = 128,
        interpret: Optional[bool] = None,
        axis: str = "rank",
        runtime=None,
    ):
        self.part = part
        self.n = int(n)
        self.p = int(p if p is not None else part.p)
        self.axis = axis
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = bool(use_kernel)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.block_e = int(block_e)
        if mesh is None:
            devs = jax.devices()
            if len(devs) < self.p:
                raise RuntimeError(
                    f"SPMD execution at p={self.p} needs {self.p} devices "
                    f"but only {len(devs)} exist — call "
                    f"ensure_host_devices({self.p}) (or set XLA_FLAGS="
                    f"{_DEVCOUNT_FLAG}={self.p}) before the first jax use"
                )
            mesh = Mesh(np.array(devs[: self.p]), (axis,))
        self.mesh = mesh
        self.ledger = CollectiveLedger.zero(self.p)
        self._buf = _ResidentShardBuffer(self.p, self.n, self.mesh, axis)
        self._fn_cache: dict = {}
        # windowed high-water capacities (keyed by rung width) that keep
        # both programs' shapes canonical across units — see _CAP_WINDOW
        self._f_hw = 1  # fetched-block capacity, pow-2, grow-only
        self._serve_s_seen: Dict[int, object] = {}  # rung w -> need deque
        self._pair_e_seen: Dict[int, object] = {}  # rung w -> need deque
        if runtime is not None:
            runtime.add_invalidation_listener(self.invalidate)

    # ---------------- coherence ----------------
    def invalidate(self, changed_ids=None) -> None:
        """Drop mutated ids from the resident buffer (``None`` = all).
        Wired to the runtime's coherence fanout by the engines; the
        streaming engine additionally notifies deletions mid-batch.
        Hub fragments live under synthetic keys ``n + 1 + v`` (see
        ``dispatch``), so a mutated row drops both its full-row and its
        fragment residency."""
        self._buf.invalidate(changed_ids)
        if changed_ids is not None:
            arr = np.unique(np.asarray(changed_ids, np.int64).ravel())
            if arr.size:
                self._buf.invalidate(arr + self.n + 1)

    def audit_resident(self, store) -> int:
        """Stale resident rows vs the authoritative store (0 expected).
        Fragment keys audit against the fragment of the current store
        row they are defined to mirror."""
        frag_base = self.n + 1
        part = self.part

        def expect(k: int, key: int) -> np.ndarray:
            if key >= frag_base:
                return part.fragment(
                    np.asarray(store.row(key - frag_base)), k
                )
            return np.asarray(store.row(key))

        return self._buf.audit(store, expect=expect)

    # ---------------- compiled-function caches ----------------
    # Two programs, split on purpose: the serve program re-shapes when
    # the wire capacities move, the expensive intersect program when the
    # pair capacities do — both follow windowed high-water marks, so in
    # steady state neither recompiles and dispatch is pure execution.
    def _fn_serve(self, h, w, serve_cfg, f_pad):
        key = ("serve", h, w, serve_cfg, f_pad)
        fn = self._fn_cache.get(key)
        if fn is None:
            body = functools.partial(
                _body_serve,
                axis=self.axis,
                p=self.p,
                w=w,
                serve_cfg=serve_cfg,
                f_pad=f_pad,
                sentinel=self.n,
            )
            sh = P(self.axis)
            fn = jax.jit(
                shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(sh, sh),
                    out_specs=sh,
                    check_vma=False,
                )
            )
            self._fn_cache[key] = fn
        return fn

    def _fn_pairs(self, h, f_pad, w, pair_cfg):
        key = ("pairs", h, f_pad, w, pair_cfg)
        fn = self._fn_cache.get(key)
        if fn is None:
            body = functools.partial(
                _body_pairs,
                p=self.p,
                w=w,
                pair_cfg=pair_cfg,
                sentinel=self.n,
                use_kernel=self.use_kernel,
                interpret=self.interpret,
            )
            sh = P(self.axis)
            fn = jax.jit(
                shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(sh, sh, sh, sh, sh),
                    out_specs=sh,
                    check_vma=False,
                )
            )
            self._fn_cache[key] = fn
        return fn

    def _empty_fetched(self, f_pad: int, w: int):
        """Cached all-sentinel fetch block for units with no serve
        traffic: the intersect program still takes its canonical
        ``[p, f_pad, w]`` fetch input, but nothing goes on the wire."""
        key = ("fetched0", f_pad, w)
        blk = self._fn_cache.get(key)
        if blk is None:
            blk = jax.device_put(
                jnp.full((self.p, f_pad, w), self.n, jnp.int32),
                NamedSharding(self.mesh, P(self.axis)),
            )
            self._fn_cache[key] = blk
        return blk

    def _pair_widths(self, w: int) -> List[int]:
        """Fixed geometric pow-2 pair-bucket widths for buffer width
        ``w`` (the ladder clipped to ``w``, so at most
        ``len(_PAIR_WIDTH_LADDER)`` buckets, last always ``w``). Fixed
        boundaries trade a bounded amount of compare padding (<4x
        within a bucket) for a canonical compiled shape set — the
        adaptive smallest-merge split would re-shape (and recompile)
        the intersect program nearly every unit."""
        return sorted({min(w, c) for c in _PAIR_WIDTH_LADDER})

    def _cap(self, seen: Dict[int, object], rung_w: int, need: int,
             lo: int) -> int:
        """Windowed pow-2 capacity for one rung: the pow-2 ceiling of
        the max need over the last ``_CAP_WINDOW`` units. Stable under
        per-unit jitter (no recompile), grows immediately when a unit
        needs more, and decays once an old peak leaves the window — so
        a converging workload stops paying (wire bytes and pad compute)
        for its warm-up spike."""
        dq = seen.get(rung_w)
        if dq is None:
            dq = seen[rung_w] = collections.deque(maxlen=_CAP_WINDOW)
        dq.append(int(need))
        return pow2_ceil(max(dq), lo)

    # ---------------- one execution unit ----------------
    def dispatch(self, shards: List[ShardWork], store) -> PendingUnit:
        """Pack, patch, and launch one unit without blocking. ``store``
        provides ``row(v)`` for the rows each owner serves (its
        authoritative shard content). The returned ``PendingUnit``'s
        ledger is complete immediately (and already folded into the
        cumulative ``self.ledger``, wall-clock fields excepted) — the
        measured-vs-modeled reconciliation can run before ``wait()``."""
        p = self.p
        assert len(shards) == p and all(
            s.rank == k for k, s in enumerate(shards)
        ), "need one ShardWork per rank, in rank order"
        unit = CollectiveLedger.zero(p)
        pair_sizes = [s.pair_a.size for s in shards]
        n_pairs = sum(pair_sizes)
        n_fetched = sum(len(s.fetched_ids) for s in shards)
        if n_pairs == 0 and n_fetched == 0:
            return PendingUnit(self, None, None, pair_sizes, unit, 0.0)

        # spans: host-side packing vs. the device collective, as two
        # sibling phases (manual open/close keeps the hot path unindented)
        _pack = obs_trace.span("spmd_pack", cat="spmd", n_pairs=n_pairs,
                               n_fetched=n_fetched)
        _pack.__enter__()

        # serve lists: ship[k][j] = buffer keys rank k sends requester
        # j, in requester fetch order (mirrors serve_rows accounting).
        # Keys are vertex ids for whole rows; a *split hub* row ships
        # as per-rank fragments under synthetic keys ``frag_base + v``
        # (frag_base = n + 1, so full-row and fragment residency never
        # collide): every rank with a nonempty fragment serves it, the
        # requester's own fragment stays rank-resident and free —
        # exactly the charges ``ShardedRuntime._charge_remote_miss``
        # models, so the reconciliation stays row-for-row.
        part = self.part
        hub_split = bool(getattr(part, "has_hubs", False))
        frag_base = self.n + 1
        ship: List[List[List[int]]] = [
            [[] for _ in range(p)] for _ in range(p)
        ]
        requested: List[set] = [set() for _ in range(p)]
        # full content of every fetched hub row (fragments slice it)
        hub_full: Dict[int, np.ndarray] = {}
        # requester -> fetched hub ids (their own-fragment residency)
        hub_fetched: List[List[int]] = [[] for _ in range(p)]
        for j, sh in enumerate(shards):
            for v in sh.fetched_ids:
                v = int(v)
                assert v not in sh.rows_held, (
                    f"id {v} both held and fetched at rank {j}"
                )
                k = int(part.owner(v))
                assert k != j, f"rank {j} fetching its own row {v}"
                if v in requested[j]:
                    continue  # one shipment per (owner, requester, id)
                requested[j].add(v)
                if hub_split and bool(part.is_hub(v)):
                    row = hub_full.get(v)
                    if row is None:
                        held = shards[k].rows_held.get(v)
                        row = np.asarray(
                            held if held is not None else store.row(v)
                        )
                        hub_full[v] = row
                    hub_fetched[j].append(v)
                    for q in range(p):
                        if q == j:
                            continue
                        if part.fragment(row, q).size == 0:
                            continue
                        ship[q][j].append(frag_base + v)
                else:
                    ship[k][j].append(v)

        # serve content: whole rows come from the serving rank's held
        # copy (else the authoritative store); fragment keys slice the
        # full hub row — every rank can serve its fragment because the
        # fragment IS rank q's share of the split row.
        serve_rows_content: List[Dict[int, np.ndarray]] = [
            {} for _ in range(p)
        ]
        for k in range(p):
            for j in range(p):
                for key in ship[k][j]:
                    if key not in serve_rows_content[k]:
                        if key >= frag_base:
                            row = part.fragment(
                                hub_full[key - frag_base], k
                            )
                        else:
                            held = shards[k].rows_held.get(key)
                            row = held if held is not None else np.asarray(
                                store.row(key)
                            )
                        serve_rows_content[k][key] = row
                    unit.rows_shipped[k, j] += 1
                    unit.bytes_payload += (
                        serve_rows_content[k][key].size * ID_BYTES
                    )

        # resident working set: held rows, the rows/fragments served
        # from this rank's buffer, and each requester's own fragment of
        # every hub row it fetched (local, never on the wire) —
        # already-resident entries cost zero H2D.
        needed: List[Dict[int, np.ndarray]] = []
        for k, sh in enumerate(shards):
            d = {int(v): np.asarray(row) for v, row in sh.rows_held.items()}
            for key, row in serve_rows_content[k].items():
                d.setdefault(key, row)
            for v in hub_fetched[k]:
                own = part.fragment(hub_full[v], k)
                if own.size:
                    d.setdefault(frag_base + v, own)
            needed.append(d)
        self._buf.ensure(needed, unit)
        h, w = self._buf.h, self._buf.w
        pad_slot = self._buf.pad_slot

        # per-unit max width (held + served), for the single-width
        # wire baseline the old non-bucketed collective would have paid
        w_unit = max((r.size for d in needed for r in d.values()), default=1)

        # ---- serve rungs: one all_to_all per ladder width class ----
        # Canonical shapes here too: the fixed geometric width ladder
        # (same as the pair buckets) and windowed per-rung count
        # capacities. Adaptive per-unit buckets shipped slightly fewer
        # wire bytes but re-shaped (and recompiled) the serve program
        # nearly every unit — on the measured profile that compile churn
        # was the entire SPMD-vs-loop gap. ``bytes_on_wire`` still
        # reports the actual shipped shapes, so the padding accounting
        # stays honest; the windowed decay keeps the capacities tracking
        # the workload instead of its historical peak.
        widths = self._pair_widths(w)
        serve_lists: List[Dict[Tuple[int, int], List[int]]] = [
            {} for _ in widths
        ]
        widths_arr = np.asarray(widths, np.int64)
        has_serve = False
        for k in range(p):
            for j in range(p):
                for key in ship[k][j]:
                    has_serve = True
                    rung = int(np.searchsorted(
                        widths_arr, max(serve_rows_content[k][key].size, 1),
                        side="left",
                    ))
                    serve_lists[rung].setdefault((k, j), []).append(key)
        serve_cfg: List[Tuple[int, int]] = []
        serve_segs: List[np.ndarray] = []
        # fetch_refs[j][key] -> every (combined-buffer index, width)
        # that arrived for ``key`` at requester j. Whole rows have one
        # ref; a split hub row has one ref per serving rank (its
        # fragments), all under the same ``frag_base + v`` key.
        fetch_refs: List[Dict[int, List[Tuple[int, int]]]] = [
            {} for _ in range(p)
        ]
        fetch_base = h
        wire_bytes = 0
        for rung, w_b in enumerate(widths):
            lists = serve_lists[rung]
            need = max((len(vs) for vs in lists.values()), default=0)
            s_b = self._cap(self._serve_s_seen, w_b, need, 1)
            # a unit with no serve traffic at all skips the collective
            # entirely (wire bytes 0, cached sentinel fetch block below)
            if not has_serve:
                continue
            seg = np.full((p, p, s_b), pad_slot, np.int32)
            for (k, j), keys in lists.items():
                for pos, key in enumerate(keys):
                    seg[k, j, pos] = self._buf.slot_of[k][key]
                    fetch_refs[j].setdefault(key, []).append((
                        fetch_base + k * s_b + pos,
                        serve_rows_content[k][key].size,
                    ))
            serve_cfg.append((s_b, w_b))
            serve_segs.append(seg)
            fetch_base += p * s_b
            wire_bytes += p * (p - 1) * s_b * w_b * ID_BYTES
        serve_idx = (
            np.concatenate(serve_segs, axis=2)
            if has_serve
            else np.zeros((p, p, 0), np.int32)
        )
        # single-width baseline: one collective padded to the max ship
        # count and the unit's max row width (the pre-bucketing scheme)
        s_single = pow2_ceil(
            max((len(ship[k][j]) for k in range(p) for j in range(p)),
                default=0),
            4,
        )
        # the baseline skips empty units too — it gets the same
        # no-traffic shortcut, so the comparison is padding-vs-padding
        single_bytes = (
            p * (p - 1) * s_single * pow2_ceil(w_unit, 1) * ID_BYTES
            if has_serve
            else 0
        )

        # ---- pair worklists, bucketed by pow-2 pair width ----
        # A pair references each side through its *refs*: the combined-
        # buffer indices (with true widths) covering that row as read by
        # rank j. Whole rows — held, served-from-own-buffer, or fetched
        # — have exactly one ref; a fetched split-hub row has one ref
        # per nonempty fragment (own fragment resident, the rest in the
        # fetch block). The pair expands into the cross product of its
        # sides' refs; fragments partition the row, so the sub-counts
        # sum to the whole-row intersection (the additive scatter in
        # ``PendingUnit.wait`` performs that reduction). Everything
        # reduces to one sub-pair per pair when no hub is split.
        def refs(j: int, v: int) -> List[Tuple[int, int]]:
            row = needed[j].get(v)
            if row is not None:
                return [(self._buf.slot_of[j][v], row.size)]
            out: List[Tuple[int, int]] = []
            own = needed[j].get(frag_base + v)
            if own is not None:
                out.append((self._buf.slot_of[j][frag_base + v],
                            own.size))
            out.extend(fetch_refs[j].get(frag_base + v, ()))
            out.extend(fetch_refs[j].get(v, ()))
            return out

        sub_rank: List[int] = []
        sub_pos: List[int] = []
        sub_a: List[int] = []
        sub_b: List[int] = []
        sub_w: List[int] = []
        for j, sh in enumerate(shards):
            for i in range(sh.pair_a.size):
                for ia, wa in refs(j, int(sh.pair_a[i])):
                    for ib, wb in refs(j, int(sh.pair_b[i])):
                        sub_rank.append(j)
                        sub_pos.append(i)
                        sub_a.append(ia)
                        sub_b.append(ib)
                        sub_w.append(max(wa, wb, 1))
        sub_rank = np.asarray(sub_rank, np.int64)
        sub_pos = np.asarray(sub_pos, np.int64)
        sub_a_arr = np.asarray(sub_a, np.int64)
        sub_b_arr = np.asarray(sub_b, np.int64)

        # the fetched block is padded to a grow-only pow-2 capacity so
        # the intersect program's input shape is unit-independent
        f_exact = fetch_base - h
        self._f_hw = max(self._f_hw, pow2_ceil(max(f_exact, 1)))
        f_pad = self._f_hw

        widths = self._pair_widths(w)
        sub_w_arr = np.maximum(np.asarray(sub_w, np.int64), 1)
        pair_slot = np.searchsorted(
            np.asarray(widths, np.int64), sub_w_arr, side="left"
        )
        pair_cfg: List[Tuple[int, int, int]] = []
        a_segs: List[np.ndarray] = []
        b_segs: List[np.ndarray] = []
        m_segs: List[np.ndarray] = []
        scatter: List[List[Tuple[np.ndarray, int]]] = [[] for _ in range(p)]
        seg_off = 0
        for slot, w_p in enumerate(widths):
            indices = np.flatnonzero(pair_slot == slot)
            e_max = (
                int(np.max(np.bincount(sub_rank[indices], minlength=p)))
                if indices.size
                else 0
            )
            # windowed per-rung capacity: the slot re-shapes (and the
            # intersect program recompiles) only when its windowed
            # high-water mark moves, never because this unit jitters
            e_pad = self._cap(self._pair_e_seen, w_p, e_max, 8)
            be = min(self.block_e, e_pad)
            a_seg = np.full((p, e_pad), pad_slot, np.int32)
            b_seg = np.full((p, e_pad), pad_slot, np.int32)
            m_seg = np.zeros((p, e_pad), bool)
            if indices.size:
                with obs_trace.span(
                    "intersect_kernel", cat="spmd", bucket_w=w_p,
                    pairs=int(indices.size),
                ):
                    for j in range(p):
                        sel = indices[sub_rank[indices] == j]
                        if not sel.size:
                            continue
                        a_seg[j, : sel.size] = sub_a_arr[sel]
                        b_seg[j, : sel.size] = sub_b_arr[sel]
                        m_seg[j, : sel.size] = True
                        scatter[j].append((sub_pos[sel], seg_off))
            pair_cfg.append((e_pad, w_p, be))
            a_segs.append(a_seg)
            b_segs.append(b_seg)
            m_segs.append(m_seg)
            seg_off += e_pad
        a_idx = np.concatenate(a_segs, axis=1)
        b_idx = np.concatenate(b_segs, axis=1)
        mask = np.concatenate(m_segs, axis=1)

        fn_s = (
            self._fn_serve(h, w, tuple(serve_cfg), f_pad)
            if has_serve
            else None
        )
        fn_p = self._fn_pairs(h, f_pad, w, tuple(pair_cfg))
        _pack.__exit__(None, None, None)

        unit.n_collectives += 1 if has_serve else 0
        unit.n_pairs += n_pairs
        unit.bytes_on_wire += wire_bytes
        unit.bytes_on_wire_single += single_bytes
        t0 = time.perf_counter()
        # async launch — the span covers dispatch only; the device time
        # surfaces in spmd_overlap_wait at the reconciliation barrier.
        with obs_trace.span(
            "all_to_all", cat="spmd", pairs=n_pairs,
            payload_bytes=int(unit.bytes_payload), wire_bytes=wire_bytes,
            buckets=len(serve_cfg),
        ):
            fetched = (
                fn_s(self._buf.device, serve_idx)
                if has_serve
                else self._empty_fetched(f_pad, w)
            )
            out = fn_p(self._buf.device, fetched, a_idx, b_idx, mask)
        self.ledger.add(unit)  # wall-clock fields accrue at wait()
        return PendingUnit(self, out, scatter, pair_sizes, unit, t0)

    def run(self, shards: List[ShardWork], store):
        """Execute one unit synchronously (dispatch + wait). Returns
        ``(counts, ledger)``: per-rank int64 count arrays in worklist
        order and this unit's measured collective ledger (also folded
        into the cumulative ``self.ledger``)."""
        return self.dispatch(shards, store).wait()
