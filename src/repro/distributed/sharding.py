"""Per-family sharding rules against the production mesh axes.

The production mesh is ('data', 'model') single-pod or
('pod', 'data', 'model') multi-pod (launch/mesh.py). Rules:

- LM      : batch -> (pod, data); heads/d_ff/vocab/experts -> model
- GNN     : nodes 1D-partitioned (the paper's scheme) + edges sharded over
            the flattened (pod, data, model) axis; features unsharded
- recsys  : batch -> (pod, data); embedding-table rows -> model
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.transformer import AxisRules

__all__ = ["rules_for_mesh", "lm_rules", "gnn_specs", "recsys_specs",
           "named", "flat_axes"]


def flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def lm_rules(mesh: Mesh) -> AxisRules:
    names = mesh.axis_names
    data = tuple(a for a in names if a in ("pod", "data"))
    model = tuple(a for a in names if a == "model")
    return AxisRules(data=data, model=model)


def rules_for_mesh(mesh: Mesh) -> AxisRules:
    return lm_rules(mesh)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def gnn_specs(mesh: Mesh) -> dict:
    """Input specs for a GNN batch dict: edges over everything, nodes over
    the data axes (1D partition), small tensors replicated."""
    all_ax = flat_axes(mesh)
    data = tuple(a for a in all_ax if a in ("pod", "data"))
    return {
        "node_feat": P(data, None),
        "positions": P(data, None),
        "node_mask": P(data),
        "edge_src": P(all_ax),
        "edge_dst": P(all_ax),
        "edge_mask": P(all_ax),
        "edge_src_cold": P(all_ax),
        "edge_src_hub_pos": P(all_ax),
        "edge_dst_cold": P(all_ax),
        "edge_dst_hot": P(all_ax),
        "edge_mask_cold": P(all_ax),
        "edge_mask_hot": P(all_ax),
        "hub_ids": P(),  # replicated hub id table (the degree-score cache)
        "graph_ids": P(data),
        "labels": P(),
        "label_mask": P(),
    }


def recsys_specs(mesh: Mesh) -> dict:
    all_ax = flat_axes(mesh)
    data = tuple(a for a in all_ax if a in ("pod", "data"))
    b = P(data)
    b2 = P(data, None)
    return {
        "hist_items": b2,
        "hist_cats": b2,
        "hist_mask": b2,
        "target_item": b,
        "target_cat": b,
        "user_profile": b2,
        "label": b,
        "cand_items": P(all_ax),
        "cand_cats": P(all_ax),
    }
