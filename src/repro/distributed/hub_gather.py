"""Hub-replication gather: the paper's degree-score cache applied beyond
LCC — to distributed GNN feature reads and recsys hot-row lookups.

Idea (paper §III-B, Observations 3.1/3.2): access frequency of a row is
power-law in its degree/popularity, so replicating the top-C hottest rows
on every device removes the bulk of cross-shard traffic; the remaining
cold rows go through the ordinary sharded gather (XLA lowers it to
all-gather / a2a). The split is *static* (degree/popularity is known
offline), so the compiled program contains two plain gathers and a select
— no data-dependent shapes.

``split_hot_cold`` is the host-side planner; ``hub_gather`` the device op.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["HotColdPlan", "split_hot_cold", "hub_gather"]


class HotColdPlan(NamedTuple):
    hot_ids: np.ndarray  # [C] sorted global ids replicated on all devices
    # per-index remap (precomputed on host for a static id stream):
    is_hot: np.ndarray  # [N_idx] bool
    hot_pos: np.ndarray  # [N_idx] slot into the hot table (junk if cold)


def split_hot_cold(ids: np.ndarray, scores: np.ndarray, capacity: int) -> HotColdPlan:
    """Pick the top-``capacity`` rows by score (degree / popularity) and
    classify a static id stream against them."""
    n_rows = scores.shape[0]
    c = min(capacity, n_rows)
    hot = np.sort(np.argpartition(scores, n_rows - c)[n_rows - c:]) if c > 0 \
        else np.zeros((0,), np.int64)
    pos = np.searchsorted(hot, ids)
    pos = np.minimum(pos, max(c - 1, 0))
    is_hot = c > 0 and hot.size > 0
    hit = hot[pos] == ids if hot.size else np.zeros(ids.shape, bool)
    return HotColdPlan(hot_ids=hot.astype(np.int64),
                       is_hot=hit,
                       hot_pos=pos.astype(np.int32))


def hub_gather(
    table: jnp.ndarray,      # [N, D] sharded over rows
    hot_table: jnp.ndarray,  # [C, D] replicated
    ids: jnp.ndarray,        # [K] int32 row ids
    is_hot: jnp.ndarray,     # [K] bool   (static plan, device-resident)
    hot_pos: jnp.ndarray,    # [K] int32
) -> jnp.ndarray:
    """rows[i] = hot_table[hot_pos[i]] if is_hot[i] else table[ids[i]].

    The cold gather is pointed at row 0 for hot ids (cheap, avoids the
    cross-shard traffic for them under GSPMD's gather partitioning).
    """
    cold_ids = jnp.where(is_hot, 0, ids)
    cold = jnp.take(table, cold_ids, axis=0)
    hot = jnp.take(hot_table, hot_pos, axis=0)
    return jnp.where(is_hot[:, None], hot, cold)
