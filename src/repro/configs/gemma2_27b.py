"""gemma2-27b [dense] — arXiv:2408.00118.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, alternating
local(window 4096)/global attention, attn logit softcap 50, final logit
softcap 30, zero-centered RMSNorm with post-norms, tied embeddings,
query scale (d_model/n_heads)^-1/2 = 144^-1/2.

Alternating local layers make long_500k runnable (local layers cache only
the window; global-layer KV shards over the mesh).
"""
from ..models.transformer import TransformerConfig

ARCH_ID = "gemma2-27b"
FAMILY = "lm"
SKIP_SHAPES = ()


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=36864,
        vocab=256000,
        sliding_window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        zero_centered_norm=True,
        tie_embeddings=True,
        query_scale=(4608 / 32) ** -0.5,
        rope_theta=10000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=256,
        vocab=512,
        sliding_window=16,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        zero_centered_norm=True,
        tie_embeddings=True,
        query_scale=(64 / 4) ** -0.5,
        remat=False,
    )
