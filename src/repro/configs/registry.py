"""Architecture registry: --arch <id> resolution for launchers/tests."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from . import (
    din,
    gat_cora,
    gemma2_27b,
    gin_tu,
    mace,
    moonshot_v1_16b_a3b,
    paper_lcc,
    phi35_moe_42b_a6_6b,
    pna,
    qwen25_14b,
    stablelm_1_6b,
)
from .shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES

__all__ = ["ArchEntry", "ARCHS", "get_arch", "list_archs", "shape_table",
           "cells"]

_MODULES = [
    moonshot_v1_16b_a3b,
    phi35_moe_42b_a6_6b,
    stablelm_1_6b,
    gemma2_27b,
    qwen25_14b,
    mace,
    pna,
    gin_tu,
    gat_cora,
    din,
    paper_lcc,
]


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str
    config: Callable[[], Any]
    smoke_config: Callable[[], Any]
    skip_shapes: Tuple[str, ...]

    @property
    def shapes(self) -> Dict[str, Any]:
        return shape_table(self.family)


def shape_table(family: str):
    return {
        "lm": LM_SHAPES,
        "gnn": GNN_SHAPES,
        "recsys": RECSYS_SHAPES,
        "graph-analytics": {},
    }[family]


ARCHS: Dict[str, ArchEntry] = {
    m.ARCH_ID: ArchEntry(
        arch_id=m.ARCH_ID,
        family=m.FAMILY,
        config=m.config,
        smoke_config=m.smoke_config,
        skip_shapes=tuple(m.SKIP_SHAPES),
    )
    for m in _MODULES
}


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs(assigned_only: bool = False):
    out = sorted(ARCHS)
    if assigned_only:
        out = [a for a in out if a != "paper-lcc"]
    return out


def cells(include_skipped: bool = False):
    """All (arch_id, shape_id) baseline cells (36 runnable + 4 skips)."""
    out = []
    for aid in list_archs(assigned_only=True):
        e = ARCHS[aid]
        for sid in e.shapes:
            if sid in e.skip_shapes and not include_skipped:
                continue
            out.append((aid, sid))
    return out
