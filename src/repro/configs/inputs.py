"""Input construction for every (arch x shape) cell.

Two modes sharing one shape computation:
- ``input_specs(arch_id, shape_id)``: jax.ShapeDtypeStruct stand-ins for
  the FULL assigned shapes (dry-run: lower + compile, no allocation).
- ``make_smoke_batch(arch_id, rng)``: small concrete numpy batches with
  identical structure for the CPU smoke tests.

Per the assignment, modality frontends are stubs: MACE gets synthetic 3D
positions; GNN features/labels are synthetic with the assigned dims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .registry import ArchEntry, get_arch
from .shapes import GNNShape, LMShape, RecsysShape

__all__ = ["cell_shapes", "input_specs", "make_smoke_batch", "step_kind"]

F32, I32, BOOL = jnp.float32, jnp.int32, jnp.bool_


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# --------------------------------------------------------------------------
# shape computation (dict of name -> (shape, dtype)), shared by both modes
# --------------------------------------------------------------------------
def _sampled_sizes(batch_nodes: int, fanout: Tuple[int, ...]) -> Tuple[int, int]:
    n_max, e_max, frontier = batch_nodes, 0, batch_nodes
    for f in fanout:
        e_max += frontier * f
        frontier *= f
        n_max += frontier
    return n_max, e_max


def _gnn_class_count(shape_id: str) -> int:
    return {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
            "molecule": 2}[shape_id]


def gnn_feat_dim(arch_cfg, shape: GNNShape) -> int:
    if shape.d_feat is not None:
        return int(shape.d_feat)
    if shape.kind == "sampled":
        return 602  # Reddit features
    return getattr(arch_cfg, "d_in", 16)


def cell_shapes(arch: ArchEntry, cfg, shape) -> Dict[str, Tuple[tuple, Any]]:
    """name -> (shape tuple, dtype) for the step's batch inputs."""
    if arch.family == "lm":
        s: LMShape = shape
        if s.kind == "train":
            return {
                "tokens": ((s.global_batch, s.seq_len), I32),
                "labels": ((s.global_batch, s.seq_len), I32),
            }
        if s.kind == "prefill":
            return {"tokens": ((s.global_batch, s.seq_len), I32)}
        # decode: one new token; KV cache built separately (see dryrun)
        return {"token": ((s.global_batch,), I32)}
    if arch.family == "gnn":
        g: GNNShape = shape
        if g.kind == "sampled":
            n, e = _sampled_sizes(g.batch_nodes, g.fanout)
            n_out = g.batch_nodes
        elif g.kind == "batched":
            n = g.batch_graphs * g.nodes_per_graph
            e = g.batch_graphs * g.edges_per_graph
            n_out = g.batch_graphs
        else:
            n, e = g.n_nodes, g.n_edges
            n_out = n
        d = gnn_feat_dim(cfg, g)
        out: Dict[str, Tuple[tuple, Any]] = {
            "edge_src": ((e,), I32),
            "edge_dst": ((e,), I32),
            "edge_mask": ((e,), BOOL),
            "node_mask": ((n,), BOOL),
        }
        if cfg.__class__.__name__ == "MACEConfig":
            out["node_feat"] = ((n,), I32)  # species ids
            out["positions"] = ((n, 3), F32)
            if g.kind in ("batched",):
                out["graph_ids"] = ((n,), I32)
                out["labels"] = ((n_out,), F32)
            else:
                out["graph_ids"] = ((n,), I32)
                out["labels"] = ((1,), F32)
        else:
            out["node_feat"] = ((n, d), F32)
            if g.kind == "batched":
                out["graph_ids"] = ((n,), I32)
                out["labels"] = ((n_out,), I32)
            elif g.kind == "sampled":
                out["labels"] = ((n,), I32)
                out["label_mask"] = ((n,), BOOL)
            else:
                out["labels"] = ((n,), I32)
                out["label_mask"] = ((n,), BOOL)
        return out
    if arch.family == "recsys":
        r: RecsysShape = shape
        if r.kind == "retrieval":
            return {
                "hist_items": ((1, cfg.seq_len), I32),
                "hist_cats": ((1, cfg.seq_len), I32),
                "hist_mask": ((1, cfg.seq_len), BOOL),
                "user_profile": ((1, cfg.d_profile), F32),
                "cand_items": ((r.n_candidates,), I32),
                "cand_cats": ((r.n_candidates,), I32),
            }
        b = r.batch
        out = {
            "hist_items": ((b, cfg.seq_len), I32),
            "hist_cats": ((b, cfg.seq_len), I32),
            "hist_mask": ((b, cfg.seq_len), BOOL),
            "target_item": ((b,), I32),
            "target_cat": ((b,), I32),
            "user_profile": ((b, cfg.d_profile), F32),
        }
        if r.kind == "train":
            out["label"] = ((b,), F32)
        return out
    raise ValueError(arch.family)


def step_kind(arch: ArchEntry, shape) -> str:
    if arch.family == "lm":
        return {"train": "lm_train", "prefill": "lm_prefill",
                "decode": "lm_decode"}[shape.kind]
    if arch.family == "gnn":
        return "gnn_train"
    if arch.family == "recsys":
        return {"train": "recsys_train", "serve": "recsys_serve",
                "retrieval": "retrieval"}[shape.kind]
    raise ValueError(arch.family)


def input_specs(arch_id: str, shape_id: str):
    """ShapeDtypeStruct batch for the FULL cell (dry-run)."""
    arch = get_arch(arch_id)
    cfg = arch.config()
    shape = arch.shapes[shape_id]
    shapes = cell_shapes(arch, cfg, shape)
    # replace feature dim in GNN configs that adapt to the shape
    cfg = _adapt_cfg(arch, cfg, shape_id, shape)
    return (
        cfg,
        shape,
        {k: _sds(s, dt) for k, (s, dt) in shapes.items()},
    )


def _adapt_cfg(arch: ArchEntry, cfg, shape_id: str, shape):
    if arch.family != "gnn":
        return cfg
    kw = {}
    if cfg.__class__.__name__ != "MACEConfig":
        kw["d_in"] = gnn_feat_dim(cfg, shape)
        if hasattr(cfg, "n_classes") and cfg.__class__.__name__ != "PNAConfig":
            kw["n_classes"] = _gnn_class_count(shape_id)
    return dataclasses.replace(cfg, **kw) if kw else cfg


# --------------------------------------------------------------------------
# concrete smoke batches (reduced sizes, same structure)
# --------------------------------------------------------------------------
SMOKE_LM = dict(seq_len=32, global_batch=4)
SMOKE_GNN = dict(n=48, e=192, n_graphs=4, nodes_per_graph=6, edges_per_graph=10)
SMOKE_RECSYS = dict(batch=8, n_candidates=64)


def make_smoke_batch(arch_id: str, kind: str, rng: np.random.Generator):
    """(cfg, batch dict of numpy arrays) for a reduced cell of ``kind``."""
    arch = get_arch(arch_id)
    cfg = arch.smoke_config()
    if arch.family == "lm":
        b, s = SMOKE_LM["global_batch"], SMOKE_LM["seq_len"]
        toks = rng.integers(0, cfg.vocab, size=(b, s + 1)).astype(np.int32)
        if kind == "lm_train":
            return cfg, {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if kind == "lm_prefill":
            return cfg, {"tokens": toks[:, :-1]}
        return cfg, {"token": toks[:, 0]}
    if arch.family == "gnn":
        n, e = SMOKE_GNN["n"], SMOKE_GNN["e"]
        src = rng.integers(0, n, size=e).astype(np.int32)
        dst = rng.integers(0, n, size=e).astype(np.int32)
        batch: Dict[str, Any] = {
            "edge_src": src,
            "edge_dst": dst,
            "edge_mask": (rng.random(e) < 0.9),
            "node_mask": np.ones(n, bool),
        }
        if cfg.__class__.__name__ == "MACEConfig":
            batch["node_feat"] = rng.integers(0, cfg.n_species, size=n).astype(
                np.int32
            )
            batch["positions"] = rng.normal(size=(n, 3)).astype(np.float32)
            batch["graph_ids"] = (np.arange(n) * SMOKE_GNN["n_graphs"] // n).astype(np.int32)
            batch["labels"] = rng.normal(size=SMOKE_GNN["n_graphs"]).astype(
                np.float32
            )
            return cfg, batch
        batch["node_feat"] = rng.normal(size=(n, cfg.d_in)).astype(np.float32)
        if cfg.__class__.__name__ == "GINConfig":
            batch["graph_ids"] = (np.arange(n) * SMOKE_GNN["n_graphs"] // n).astype(np.int32)
            batch["labels"] = rng.integers(
                0, cfg.n_classes, SMOKE_GNN["n_graphs"]
            ).astype(np.int32)
        elif cfg.__class__.__name__ == "PNAConfig":
            batch["graph_ids"] = (np.arange(n) * SMOKE_GNN["n_graphs"] // n).astype(np.int32)
            batch["labels"] = rng.normal(size=SMOKE_GNN["n_graphs"]).astype(
                np.float32
            )
        else:  # GAT: node classification
            batch["labels"] = rng.integers(0, cfg.n_classes, n).astype(np.int32)
            batch["label_mask"] = np.ones(n, bool)
        return cfg, batch
    if arch.family == "recsys":
        from ..data.recsys import CTRStream

        b = SMOKE_RECSYS["batch"]
        stream = CTRStream(cfg.n_items, cfg.n_cats, b, seq_len=cfg.seq_len,
                           d_profile=cfg.d_profile, seed=0)
        batch = stream.batch_at(0)
        if kind == "retrieval":
            nc = SMOKE_RECSYS["n_candidates"]
            batch = {
                "hist_items": batch["hist_items"][:1],
                "hist_cats": batch["hist_cats"][:1],
                "hist_mask": batch["hist_mask"][:1],
                "user_profile": batch["user_profile"][:1],
                "cand_items": rng.integers(0, cfg.n_items, nc).astype(np.int32),
                "cand_cats": rng.integers(0, cfg.n_cats, nc).astype(np.int32),
            }
        return cfg, batch
    raise ValueError(arch.family)
