"""paper-lcc — the paper's own workload as a selectable config.

Distributed LCC over an R-MAT/power-law graph with the async RMA-style
engine + degree-score cache. Not one of the 10 assigned architectures —
included so the launcher exposes the paper technique end to end
(`--arch paper-lcc`), and the dry-run can lower the shard_map engine on
the production mesh.
"""
import dataclasses

ARCH_ID = "paper-lcc"
FAMILY = "graph-analytics"
SKIP_SHAPES = ()


@dataclasses.dataclass(frozen=True)
class LCCRunConfig:
    name: str = ARCH_ID
    n_vertices: int = 1 << 20
    avg_degree: int = 16
    row_width: int = 512  # padded adjacency width on device
    n_rounds: int = 8
    cache_rows: int = 4096
    method: str = "hybrid"


def config() -> LCCRunConfig:
    return LCCRunConfig()


def smoke_config() -> LCCRunConfig:
    return LCCRunConfig(
        name=ARCH_ID + "-smoke", n_vertices=256, avg_degree=8,
        row_width=64, n_rounds=2, cache_rows=16,
    )
