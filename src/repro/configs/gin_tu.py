"""gin-tu [gnn] — arXiv:1810.00826.

n_layers=5, d_hidden=64, sum aggregator, learnable eps (GIN-eps).
"""
from ..models.gnn.gin import GINConfig

ARCH_ID = "gin-tu"
FAMILY = "gnn"
SKIP_SHAPES = ()


def config() -> GINConfig:
    return GINConfig(name=ARCH_ID, n_layers=5, d_hidden=64)


def smoke_config() -> GINConfig:
    return GINConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16, d_in=4)
