"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
Full attention -> long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "lm"
SKIP_SHAPES = ("long_500k",)


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=6400,
        vocab=32064,
        moe_experts=16,
        moe_top_k=2,
        rope_theta=10000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=256,
        moe_experts=4,
        moe_top_k=2,
        remat=False,
    )
