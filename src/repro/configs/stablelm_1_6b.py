"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b.

24L d_model=2048 32H (GQA kv=32 => MHA) d_ff=5632 vocab=100352.
Full attention -> long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig

ARCH_ID = "stablelm-1.6b"
FAMILY = "lm"
SKIP_SHAPES = ("long_500k",)


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=5632,
        vocab=100352,
        rope_theta=10000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=160,
        vocab=512,
        remat=False,
    )
