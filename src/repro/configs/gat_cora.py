"""gat-cora [gnn] — arXiv:1710.10903.

n_layers=2, d_hidden=8, n_heads=8, attention aggregator (Cora: 1433 input
features, 7 classes).
"""
from ..models.gnn.gat import GATConfig

ARCH_ID = "gat-cora"
FAMILY = "gnn"
SKIP_SHAPES = ()


def config() -> GATConfig:
    return GATConfig(name=ARCH_ID, n_layers=2, d_hidden=8, n_heads=8,
                     d_in=1433, n_classes=7)


def smoke_config() -> GATConfig:
    return GATConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=4,
                     n_heads=2, d_in=16, n_classes=3)
