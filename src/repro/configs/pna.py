"""pna [gnn] — arXiv:2004.05718.

n_layers=4, d_hidden=75, aggregators mean-max-min-std,
scalers identity-amplification-attenuation.
"""
from ..models.gnn.pna import PNAConfig

ARCH_ID = "pna"
FAMILY = "gnn"
SKIP_SHAPES = ()


def config() -> PNAConfig:
    return PNAConfig(name=ARCH_ID, n_layers=4, d_hidden=75)


def smoke_config() -> PNAConfig:
    return PNAConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=12, d_in=8)
