from .registry import get_arch, list_archs, ARCHS  # noqa: F401
