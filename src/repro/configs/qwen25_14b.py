"""qwen2.5-14b [dense] — hf:Qwen/Qwen2.5-14B family.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias.
Full attention -> long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig

ARCH_ID = "qwen2.5-14b"
FAMILY = "lm"
SKIP_SHAPES = ("long_500k",)


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=13824,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1000000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=192,
        vocab=512,
        qkv_bias=True,
        remat=False,
    )
