"""Canonical assigned input shapes per architecture family (the 40 cells).

LM shapes are (seq_len x global_batch); decode_*/long_* lower serve_step
(one token + KV cache), not train_step. long_500k requires sub-quadratic
attention: only gemma2-27b (alternating local/global) runs it — the pure
full-attention archs record a documented skip (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["LMShape", "GNNShape", "RecsysShape", "LM_SHAPES", "GNN_SHAPES",
           "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


LM_SHAPES = {
    "train_4k": LMShape("train_4k", 4096, 256, "train"),
    "prefill_32k": LMShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": LMShape("decode_32k", 32768, 128, "decode"),
    "long_500k": LMShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: Optional[int]
    kind: str  # 'full' | 'sampled' | 'batched'
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    batch_graphs: int = 0
    nodes_per_graph: int = 0
    edges_per_graph: int = 0


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", 2708, 10556, 1433, "full"),
    "minibatch_lg": GNNShape(
        "minibatch_lg", 232_965, 114_615_892, None, "sampled",
        batch_nodes=1024, fanout=(15, 10),
    ),
    "ogb_products": GNNShape("ogb_products", 2_449_029, 61_859_140, 100, "full"),
    "molecule": GNNShape(
        "molecule", 30, 64, None, "batched",
        batch_graphs=128, nodes_per_graph=30, edges_per_graph=64,
    ),
}


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    batch: int
    kind: str  # 'train' | 'serve' | 'retrieval'
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", 65_536, "train"),
    "serve_p99": RecsysShape("serve_p99", 512, "serve"),
    "serve_bulk": RecsysShape("serve_bulk", 262_144, "serve"),
    "retrieval_cand": RecsysShape("retrieval_cand", 1, "retrieval",
                                  n_candidates=1_000_000),
}
