"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048 16H (GQA kv=16 => MHA-width KV) d_ff=1408 vocab=163840,
MoE 64 experts top-6. Full attention -> long_500k is a documented skip.
"""
from ..models.transformer import TransformerConfig

ARCH_ID = "moonshot-v1-16b-a3b"
FAMILY = "lm"
SKIP_SHAPES = ("long_500k",)  # pure full attention


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab=163840,
        moe_experts=64,
        moe_top_k=6,
        rope_theta=50000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=96,
        vocab=512,
        moe_experts=8,
        moe_top_k=2,
        remat=False,
    )
