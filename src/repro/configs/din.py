"""din [recsys] — arXiv:1706.06978.

embed_dim=18, seq_len=100, attention MLP 80-40, output MLP 200-80,
target-attention interaction. Tables: 1e8 items / 1e6 categories
(taxonomy §RecSys: 10^6-10^9 rows), rows sharded over 'model'.
"""
from ..models.recsys.din import DINConfig

ARCH_ID = "din"
FAMILY = "recsys"
SKIP_SHAPES = ()


def config() -> DINConfig:
    return DINConfig(
        name=ARCH_ID,
        n_items=100_000_000,
        n_cats=1_000_000,
        embed_dim=18,
        seq_len=100,
        attn_hidden=(80, 40),
        mlp_hidden=(200, 80),
    )


def smoke_config() -> DINConfig:
    return DINConfig(
        name=ARCH_ID + "-smoke",
        n_items=1000,
        n_cats=50,
        embed_dim=8,
        seq_len=12,
        attn_hidden=(16, 8),
        mlp_hidden=(24, 12),
    )
