"""mace [gnn] — arXiv:2206.07697.

n_layers=2, d_hidden=128 channels, l_max=2, correlation_order=3, n_rbf=8,
E(3)-equivariant ACE product basis. Needs 3D positions: non-molecular
shapes get synthetic coordinates from input_specs (modality stub per the
assignment).
"""
from ..models.gnn.mace import MACEConfig

ARCH_ID = "mace"
FAMILY = "gnn"
SKIP_SHAPES = ()


def config() -> MACEConfig:
    return MACEConfig(
        name=ARCH_ID, n_layers=2, channels=128, l_max=2, correlation=3,
        n_rbf=8, n_species=16,
    )


def smoke_config() -> MACEConfig:
    return MACEConfig(
        name=ARCH_ID + "-smoke", n_layers=2, channels=8, l_max=2,
        correlation=3, n_rbf=4, n_species=4,
    )
