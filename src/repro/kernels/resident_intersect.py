"""Pallas TPU kernel: intersect query rows against device-resident slots.

The device tier (``repro.device.ResidencyManager``) keeps the
degree-scored hot adjacency rows persistently resident in a padded
``[slots, max_width]`` device buffer. The host-side intersection path
would gather those rows back to host, re-pack and re-upload them per
kernel call — exactly the per-epoch refetch cost the paper's CLaMPI
cache removes one level up. This kernel removes it on-device: the
resident operand never leaves the device.

The gather is fused into the schedule via **scalar prefetch**
(``PrefetchScalarGridSpec``): the per-pair slot indices are prefetched
to SMEM before the kernel body runs, and each input's ``index_map``
uses them to DMA the *resident row of that pair's slot* straight from
the residency buffer into VMEM — one program per pair, block
``[1, W]`` vs ``[1, WB]``, the same all-pairs VPU compare (chunked over
LANES) as ``intersect_count``. Two layouts:

- ``rows_b`` given   — resident slot vs a packed (uploaded) query row;
- ``slots_b`` given  — both sides resident: two gathers, zero upload.

Shapes are bounded by the shared power-of-2 bucketing
(``kernels.bucketing``): the pair count pads to the next power of two
(phantom pairs hit slot 0 with an all-sentinel query row, contributing
0), and callers bucket ragged query widths before calling in.

Rows follow the repo-wide invariant: sorted ascending, deduplicated,
ids < sentinel (padding never matches). The pure-jnp oracle is
``kernels.ref.resident_intersect_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bucketing import pow2_ceil

__all__ = ["resident_intersect", "resident_intersect_counts"]

LANES = 128


def _kernel(*refs, sentinel: int, wb: int):
    # trailing refs are (a_ref [1, W], b_ref [1, WB], out_ref [1]); any
    # leading refs are the prefetched slot arrays (unused in the body —
    # they drive the index_maps).
    a_ref, b_ref, out_ref = refs[-3], refs[-2], refs[-1]
    a = a_ref[0]  # [W]
    valid_a = a < sentinel
    acc = jnp.zeros((), jnp.int32)
    for lo in range(0, wb, LANES):
        hi = min(lo + LANES, wb)
        b = b_ref[0, lo:hi]  # [chunk]
        eq = a[:, None] == b[None, :]
        eq = jnp.logical_and(eq, valid_a[:, None])
        acc = acc + eq.sum().astype(jnp.int32)
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("sentinel", "interpret"))
def _vs_rows(slots_a, residency, rows_b, *, sentinel: int, interpret: bool):
    e = slots_a.shape[0]
    _, w = residency.shape
    _, wb = rows_b.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, sa: (sa[i], 0)),
            pl.BlockSpec((1, wb), lambda i, sa: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, sa: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, sentinel=sentinel, wb=wb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(slots_a, residency, rows_b)


@functools.partial(jax.jit, static_argnames=("sentinel", "interpret"))
def _vs_slots(slots_a, slots_b, residency, *, sentinel: int, interpret: bool):
    e = slots_a.shape[0]
    _, w = residency.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, sa, sb: (sa[i], 0)),
            pl.BlockSpec((1, w), lambda i, sa, sb: (sb[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, sa, sb: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, sentinel=sentinel, wb=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(slots_a, slots_b, residency, residency)


def resident_intersect(
    residency: jnp.ndarray,  # [S, W] int32 resident rows, sentinel-padded
    slots_a: jnp.ndarray,  # [E] int32 slot per pair
    rows_b: Optional[jnp.ndarray] = None,  # [E, WB] packed query rows
    *,
    slots_b: Optional[jnp.ndarray] = None,  # [E] both-resident variant
    sentinel: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """``|residency[slots_a[e]] ∩ B[e]|`` per pair (int32 [E]).

    ``B`` is ``rows_b[e]`` (one uploaded side) or
    ``residency[slots_b[e]]`` (fully resident). E must match the padded
    grid exactly — use ``resident_intersect_counts`` for ragged batches.
    """
    assert (rows_b is None) != (slots_b is None), "pass rows_b XOR slots_b"
    if slots_b is not None:
        return _vs_slots(
            slots_a, slots_b, residency, sentinel=sentinel,
            interpret=interpret,
        )
    return _vs_rows(
        slots_a, residency, rows_b, sentinel=sentinel, interpret=interpret
    )


def resident_intersect_counts(
    residency,  # [S, W] int32 (jnp: stays on device; np is uploaded once)
    slots_a: np.ndarray,  # [E] slot indices (all >= 0)
    rows_b: Optional[np.ndarray] = None,  # [E, WB] int32 sorted, padded
    *,
    slots_b: Optional[np.ndarray] = None,
    sentinel: int,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """Ragged-friendly wrapper: any E >= 0, returns int64 [E].

    Pads the pair batch to the next power of two (phantom pairs reuse
    slot 0 and are sliced off the result) so the number of compiled
    grid shapes stays logarithmic in the batch size.
    """
    assert (rows_b is None) != (slots_b is None), "pass rows_b XOR slots_b"
    slots_a = np.ascontiguousarray(slots_a, np.int32)
    e = slots_a.shape[0]
    if e == 0:
        return np.zeros((0,), np.int64)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    res = (
        residency
        if isinstance(residency, jnp.ndarray)
        else jnp.asarray(np.ascontiguousarray(residency, np.int32))
    )
    e_pad = pow2_ceil(e, 8)
    sa = np.zeros(e_pad, np.int32)
    sa[:e] = slots_a
    if slots_b is not None:
        slots_b = np.ascontiguousarray(slots_b, np.int32)
        assert slots_b.shape[0] == e
        sb = np.zeros(e_pad, np.int32)
        sb[:e] = slots_b
        cnt = _vs_slots(
            jnp.asarray(sa), jnp.asarray(sb), res,
            sentinel=sentinel, interpret=interpret,
        )
    else:
        rows_b = np.ascontiguousarray(rows_b, np.int32)
        assert rows_b.shape[0] == e
        rb = np.full((e_pad, rows_b.shape[1]), sentinel, np.int32)
        rb[:e] = rows_b
        cnt = _vs_rows(
            jnp.asarray(sa), res, jnp.asarray(rb),
            sentinel=sentinel, interpret=interpret,
        )
    return np.asarray(cnt[:e], np.int64)
