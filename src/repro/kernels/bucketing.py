"""Shared power-of-2 width-bucketing helpers for the ragged-row kernels.

Every batched intersection wrapper faces the same ragged-input problem:
row widths (and pair counts) are data-dependent, but a compiled kernel
wants a small, bounded set of padded shapes. The repo-wide answer is
power-of-2 ceilings — padding waste is bounded by 2x per dimension while
the number of distinct compiled variants stays logarithmic. This module
is the single home of that logic; ``point_query`` (pair widths),
``delta_intersect`` (edge-block clamp), and ``resident_intersect``
(query-side widths + grid padding) all bucket through it instead of
each keeping a private copy.
"""
from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple

import numpy as np

__all__ = [
    "pow2_ceil",
    "width_classes",
    "pack_rows",
    "iter_width_buckets",
    "split_width_buckets",
]


def pow2_ceil(x: int, floor: int = 1) -> int:
    """Smallest power of two >= max(x, floor) (scalar)."""
    x = max(int(x), int(floor))
    return 1 << int(np.ceil(np.log2(x)))


def width_classes(widths: Sequence[int]) -> np.ndarray:
    """Power-of-2 ceiling per width, min 1 (vectorized)."""
    w = np.maximum(np.asarray(widths, np.int64), 1)
    exp = np.ceil(np.log2(w)).astype(np.int64)
    return (np.int64(1) << exp).astype(np.int64)


def pack_rows(
    rows: Sequence[np.ndarray], width: int, sentinel: int
) -> np.ndarray:
    """Scatter ragged rows into a padded [E, width] matrix (vectorized)."""
    out = np.full((len(rows), width), sentinel, np.int32)
    if not rows:
        return out
    lens = np.fromiter((r.size for r in rows), np.int64, len(rows))
    total = int(lens.sum())
    if total == 0:
        return out
    flat = np.concatenate(rows)
    ei = np.repeat(np.arange(len(rows), dtype=np.int64), lens)
    starts = np.repeat(np.cumsum(lens) - lens, lens)
    out[ei, np.arange(total, dtype=np.int64) - starts] = flat
    return out


def iter_width_buckets(
    widths_a: Sequence[int], widths_b: Sequence[int]
) -> Iterator[Tuple[np.ndarray, int, int]]:
    """Group pair indices by their (pow2(|a|), pow2(|b|)) width class.

    Yields ``(indices, wa, wb)`` per distinct padded shape — the bucketed
    batches the pair-intersection wrappers run one kernel call each on.
    """
    wa_cls = width_classes(widths_a)
    wb_cls = width_classes(widths_b)
    key = wa_cls << 32 | wb_cls
    for k in np.unique(key):
        yield np.flatnonzero(key == k), int(k >> 32), int(k & 0xFFFFFFFF)


def split_width_buckets(
    widths: Sequence[int], max_buckets: int = 4
) -> List[Tuple[np.ndarray, int]]:
    """Partition items into at most ``max_buckets`` width groups.

    Each group's padded width is the pow-2 ceiling of its widest member,
    so per-item padding waste stays < 2x *within* a bucket while the
    number of padded shapes (and therefore compiled variants /
    collective launches) stays bounded. When more than ``max_buckets``
    pow-2 classes occur, the class with the fewest members is merged
    into the next-larger class (repeatedly) — a deterministic rule that
    sacrifices the least total padding. Returns ``[(indices, width)]``
    sorted by width ascending; empty input yields ``[]``; a single
    width class yields the degenerate one-bucket split.
    """
    assert max_buckets >= 1
    widths = np.asarray(widths, np.int64)
    if widths.size == 0:
        return []
    cls = width_classes(widths)
    uniq = [int(c) for c in np.unique(cls)]
    while len(uniq) > max_buckets:
        counts = [int(np.count_nonzero(cls == c)) for c in uniq]
        # never merge the top class upward — it has no larger neighbor
        i = int(np.argmin(counts[:-1]))
        cls[cls == uniq[i]] = uniq[i + 1]
        uniq.pop(i)
    return [(np.flatnonzero(cls == c), c) for c in uniq]
