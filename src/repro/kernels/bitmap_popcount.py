"""Pallas TPU kernel: bitmap AND + popcount intersection counting.

The dense-community regime of the hybrid (paper §III-C adapted): rows are
pre-packed into uint32 bitmap words over a vertex window; the kernel ANDs
the word streams and popcounts — O(n/32) vector int ops per edge
regardless of degree skew.

  grid: (E / BLOCK_E,)
  in:   words_a [BLOCK_E, W] u32, words_b [BLOCK_E, W] u32  (VMEM)
  out:  counts [BLOCK_E] i32

Popcount is the classic SWAR bit-slice (no dependence on a popcount
intrinsic — add/shift/and only, all VPU-native).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitmap_intersect_count"]


def _popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount on uint32 lanes."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(wa_ref, wb_ref, counts_ref):
    both = jnp.bitwise_and(wa_ref[...], wb_ref[...])  # [BE, W] u32
    counts_ref[...] = _popcount_u32(both).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def bitmap_intersect_count(
    words_a: jnp.ndarray,  # [E, W] uint32
    words_b: jnp.ndarray,  # [E, W] uint32
    *,
    block_e: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    e, w = words_a.shape
    assert e % block_e == 0, (e, block_e)
    return pl.pallas_call(
        _kernel,
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, w), lambda i: (i, 0)),
            pl.BlockSpec((block_e, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(words_a, words_b)
