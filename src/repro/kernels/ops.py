"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against ref.py in interpret mode).
On real TPU backends pass ``interpret=False`` (or rely on the default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bitmap_popcount import bitmap_intersect_count as _bitmap
from .embedding_bag import embedding_bag as _bag
from .flash_attention import flash_attention as _flash
from .intersect_count import intersect_count as _intersect
from .segment_sum_sorted import segment_sum_sorted as _segsum

__all__ = [
    "default_interpret",
    "intersect_count",
    "bitmap_intersect_count",
    "embedding_bag",
    "segment_sum_sorted",
    "flash_attention_gqa",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def intersect_count(rows_a, rows_b, *, sentinel, block_e=128, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _intersect(rows_a, rows_b, sentinel=sentinel, block_e=block_e,
                      interpret=interpret)


def bitmap_intersect_count(words_a, words_b, *, block_e=256, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _bitmap(words_a, words_b, block_e=block_e, interpret=interpret)


def embedding_bag(table, ids, mask, *, mode="sum", block_b=8, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _bag(table, ids, mask, mode=mode, block_b=block_b,
                interpret=interpret)


def segment_sum_sorted(values, seg_ids, *, num_segments, block_e=512,
                       rows=256, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _segsum(values, seg_ids, num_segments=num_segments,
                   block_e=block_e, rows=rows, interpret=interpret)


def flash_attention_gqa(q, k, v, *, scale, causal=True, window=0,
                        softcap=0.0, block_q=128, block_k=128,
                        interpret=None):
    """GQA wrapper: q [B,S,K,G,dh], k/v [B,T,K,dh] -> [B,S,K,G,dh].

    Folds (B, K, G) into the kernel batch dim (K/V repeated per group —
    the kernel-side view; on-chip the repeat is a broadcast, not a copy).
    """
    if interpret is None:
        interpret = default_interpret()
    b, s, kh, g, dh = q.shape
    t = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kh * g, s, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * kh, t, dh), g, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * kh, t, dh), g, axis=0)
    out = _flash(qf, kf, vf, scale=scale, causal=causal, window=window,
                 softcap=softcap, block_q=block_q, block_k=block_k,
                 interpret=interpret)
    return out.reshape(b, kh, g, s, dh).transpose(0, 3, 1, 2, 4)
