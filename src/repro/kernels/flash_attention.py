"""Pallas TPU kernel: blocked (flash) attention with online softmax.

LM hot path. Heads are pre-folded into the leading batch dim by the ops
wrapper (GQA grouping handled there), so the kernel sees:

  q [B, S, dh], k [B, T, dh], v [B, T, dh]  ->  out [B, S, dh]

  grid: (B, S/BQ, T/BK) — innermost axis sequential over KV blocks;
  VMEM scratch carries (m, l, acc) across KV steps (the online softmax);
  causal / sliding-window blocks wholly outside the mask are skipped with
  @pl.when (the structural analogue of the paper's "don't fetch rows you
  won't read").

Supports gemma2's attn-logit softcap. Validated in interpret mode against
models/attention.flash_attention_jnp (itself pinned to the dense oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, causal, window, softcap, bq, bk, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = qi * bq
    k_lo = ki * bk
    # static-shape mask bounds; skip blocks fully outside causal/window
    live = True
    if causal:
        live = jnp.asarray(k_lo <= q_lo + bq - 1)
    if window > 0:
        live = jnp.logical_and(live, k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # [BQ, dh]
        k = k_ref[0].astype(jnp.float32)  # [BK, dh]
        s = q @ k.T  # [BQ, BK]
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qp = q_lo + jax.lax.iota(jnp.int32, bq)[:, None]
        kp = k_lo + jax.lax.iota(jnp.int32, bk)[None, :]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kp <= qp)
        if window > 0:
            mask = jnp.logical_and(mask, (qp - kp) < window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(-1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + p @ v_ref[0].astype(
            jnp.float32
        )
        m_sc[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "block_q",
                     "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, S, dh] (heads folded into B)
    k: jnp.ndarray,  # [B, T, dh]
    v: jnp.ndarray,  # [B, T, dh]
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, dh = q.shape
    t = k.shape[1]
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0
    n_q, n_k = s // bq, t // bk
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_k=n_k,
    )
    return pl.pallas_call(
        kern,
        grid=(b, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
