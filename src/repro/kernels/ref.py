"""Pure-jnp oracles for every Pallas kernel (the per-kernel ref.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "intersect_count_ref",
    "resident_intersect_ref",
    "bitmap_intersect_count_ref",
    "embedding_bag_ref",
    "segment_sum_sorted_ref",
    "flash_attention_ref",
]


def intersect_count_ref(rows_a, rows_b, *, sentinel: int):
    eq = rows_a[:, :, None] == rows_b[:, None, :]
    eq = eq & (rows_a[:, :, None] < sentinel)
    return eq.sum(axis=(1, 2)).astype(jnp.int32)


def resident_intersect_ref(residency, slots_a, rows_b=None, *,
                           slots_b=None, sentinel: int):
    """Oracle for ``resident_intersect``: gather the resident rows, then
    the plain pairwise intersect. ``rows_b`` XOR ``slots_b``."""
    a = jnp.take(residency, slots_a, axis=0)
    b = rows_b if slots_b is None else jnp.take(residency, slots_b, axis=0)
    return intersect_count_ref(a, b, sentinel=sentinel)


def bitmap_intersect_count_ref(words_a, words_b):
    both = jnp.bitwise_and(words_a, words_b)
    return jax.lax.population_count(both).sum(axis=-1).astype(jnp.int32)


def embedding_bag_ref(table, ids, mask, *, mode: str = "sum"):
    emb = jnp.take(table, ids, axis=0).astype(jnp.float32)  # [B, L, D]
    w = mask.astype(jnp.float32)
    if mode == "mean":
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1.0)
    return (emb * w[..., None]).sum(axis=1)


def segment_sum_sorted_ref(values, seg_ids, *, num_segments: int):
    return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)


def flash_attention_ref(q, k, v, *, scale, causal=True, window=0,
                        softcap=0.0):
    """Dense attention on folded-head layout [B, S, dh] / [B, T, dh]."""
    s = q.shape[1]
    t = k.shape[1]
    srs = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32) * scale,
                     k.astype(jnp.float32))
    if softcap > 0:
        srs = softcap * jnp.tanh(srs / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    srs = jnp.where(mask[None], srs, -1e30)
    w = jax.nn.softmax(srs, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)
