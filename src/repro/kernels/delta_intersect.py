"""Batched delta-intersect wrapper over the Pallas ``intersect_count`` kernel.

The streaming engine's hot loop is the same primitive as the static
pipeline — |adj(u) ∩ adj(v)| over padded sorted rows — but a streaming
batch has a data-dependent number of row pairs, while ``intersect_count``
requires the edge dimension to be a multiple of ``block_e``. This wrapper:

- pads the pair batch up to the next ``block_e`` multiple with all-sentinel
  phantom rows (they intersect nothing, so the padding counts are 0), and
- clamps ``block_e`` down for tiny batches so a 3-edge delta doesn't pay
  a 128-row program.

``delta_intersect_masks`` is the companion membership primitive: the
incremental LCC update needs the *identities* of the closing vertices
(every common neighbor w of a new edge (u,v) gains a triangle), not just
the count. It is a vectorized binary-search membership over the same
padded-row layout; counts derived from the mask equal the kernel counts —
the streaming tests cross-check the two paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .bucketing import pow2_ceil
from .intersect_count import intersect_count as _intersect

__all__ = ["delta_intersect_counts", "delta_intersect_masks"]


def _pad_pairs(rows: np.ndarray, e_pad: int, sentinel: int) -> np.ndarray:
    e, w = rows.shape
    if e == e_pad:
        return rows
    out = np.full((e_pad, w), sentinel, rows.dtype)
    out[:e] = rows
    return out


def delta_intersect_counts(
    rows_a: np.ndarray,  # [E, WA] int32 sorted, sentinel-padded
    rows_b: np.ndarray,  # [E, WB]
    *,
    sentinel: int,
    block_e: int = 128,
    interpret: bool | None = None,
) -> np.ndarray:
    """|rows_a[e] ∩ rows_b[e]| per pair, any E >= 0. Returns int64 [E]."""
    rows_a = np.ascontiguousarray(rows_a, np.int32)
    rows_b = np.ascontiguousarray(rows_b, np.int32)
    e = rows_a.shape[0]
    assert rows_b.shape[0] == e
    if e == 0:
        return np.zeros((0,), np.int64)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    be = min(block_e, pow2_ceil(e, 8))
    e_pad = -(-e // be) * be
    cnt = _intersect(
        jnp.asarray(_pad_pairs(rows_a, e_pad, sentinel)),
        jnp.asarray(_pad_pairs(rows_b, e_pad, sentinel)),
        sentinel=sentinel,
        block_e=be,
        interpret=interpret,
    )
    return np.asarray(cnt[:e], np.int64)


def delta_intersect_masks(
    rows_a: np.ndarray,  # [E, WA]
    rows_b: np.ndarray,  # [E, WB]
    *,
    sentinel: int,
) -> np.ndarray:
    """Membership mask [E, WA]: mask[e, s] == (rows_a[e, s] ∈ rows_b[e]).

    Padding slots (>= sentinel) are always False. Vectorized host-side
    binary search (numpy), so the streaming engine can scatter triangle
    credit to the matched ids without a device round-trip.
    """
    rows_a = np.asarray(rows_a, np.int64)
    rows_b = np.asarray(rows_b, np.int64)
    e, wa = rows_a.shape
    if e == 0 or rows_b.shape[1] == 0:
        return np.zeros((e, wa), bool)
    # per-row searchsorted via rank trick: offset each row into its own
    # disjoint key space, then one global searchsorted.
    wb = rows_b.shape[1]
    span = int(sentinel) + 1
    off = np.arange(e, dtype=np.int64)[:, None] * span
    flat_b = (rows_b + off).ravel()  # sorted within rows, rows ascending
    keys = (rows_a + off).ravel()
    idx = np.searchsorted(flat_b, keys)
    idx = np.minimum(idx, flat_b.size - 1)
    hit = flat_b[idx] == keys
    hit &= (rows_a < sentinel).ravel()
    return hit.reshape(e, wa)
