"""Width-bucketed batched pair intersection for point-query serving.

The serving engine's unit of work is a *ragged* list of row pairs: one
microbatch mixes a hub query (rows of width ~max degree) with leaf
queries (width 2-3). Padding every pair to the global max width would
make the all-pairs compare pay O(W_max^2) for every pair, so this
wrapper:

- buckets pairs by the power-of-2 ceiling of their (|a|, |b|) widths, so
  padding waste is bounded by 2x per side while keeping the number of
  distinct padded shapes (= compiled kernel variants) at most
  log2(max degree)^2, and
- runs one batched intersection per bucket — the Pallas
  ``intersect_count`` kernel via ``delta_intersect_counts`` when
  ``use_kernel`` (TPU), else the vectorized host binary-search path —
  and scatters counts back to the original pair order.

Rows follow the repo-wide invariant: sorted ascending, deduplicated,
ids < sentinel (padding slots never match).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .delta_intersect import delta_intersect_counts, delta_intersect_masks

__all__ = ["batched_pair_counts"]


def _width_classes(widths: Sequence[int]) -> np.ndarray:
    """Power-of-2 ceiling per width, min 1 (vectorized)."""
    w = np.maximum(np.asarray(widths, np.int64), 1)
    exp = np.ceil(np.log2(w)).astype(np.int64)
    return (np.int64(1) << exp).astype(np.int64)


def _pack(rows: Sequence[np.ndarray], width: int, sentinel: int) -> np.ndarray:
    """Scatter ragged rows into a padded [E, width] matrix (vectorized)."""
    out = np.full((len(rows), width), sentinel, np.int32)
    if not rows:
        return out
    lens = np.fromiter((r.size for r in rows), np.int64, len(rows))
    total = int(lens.sum())
    if total == 0:
        return out
    flat = np.concatenate(rows)
    ei = np.repeat(np.arange(len(rows), dtype=np.int64), lens)
    starts = np.repeat(np.cumsum(lens) - lens, lens)
    out[ei, np.arange(total, dtype=np.int64) - starts] = flat
    return out


def batched_pair_counts(
    rows_a: Sequence[np.ndarray],
    rows_b: Sequence[np.ndarray],
    *,
    sentinel: int,
    use_kernel: bool = False,
    block_e: int = 128,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """``|rows_a[i] ∩ rows_b[i]|`` per pair of sorted 1-D rows.

    Returns int64 ``[len(rows_a)]`` in the input order.
    """
    n_pairs = len(rows_a)
    assert len(rows_b) == n_pairs
    out = np.zeros(n_pairs, np.int64)
    if n_pairs == 0:
        return out
    wa_cls = _width_classes([r.size for r in rows_a])
    wb_cls = _width_classes([r.size for r in rows_b])
    key = wa_cls << 32 | wb_cls
    for k in np.unique(key):
        idxs = np.flatnonzero(key == k)
        wa, wb = int(k >> 32), int(k & 0xFFFFFFFF)
        a = _pack([rows_a[i] for i in idxs], wa, sentinel)
        b = _pack([rows_b[i] for i in idxs], wb, sentinel)
        if use_kernel:
            cnt = delta_intersect_counts(
                a, b, sentinel=sentinel, block_e=block_e, interpret=interpret
            )
        else:
            cnt = delta_intersect_masks(a, b, sentinel=sentinel).sum(1)
        out[idxs] = cnt
    return out
