"""Width-bucketed batched pair intersection for point-query serving.

The serving engine's unit of work is a *ragged* list of row pairs: one
microbatch mixes a hub query (rows of width ~max degree) with leaf
queries (width 2-3). Padding every pair to the global max width would
make the all-pairs compare pay O(W_max^2) for every pair, so this
wrapper:

- buckets pairs by the power-of-2 ceiling of their (|a|, |b|) widths, so
  padding waste is bounded by 2x per side while keeping the number of
  distinct padded shapes (= compiled kernel variants) at most
  log2(max degree)^2, and
- runs one batched intersection per bucket — the Pallas
  ``intersect_count`` kernel via ``delta_intersect_counts`` when
  ``use_kernel`` (TPU), else the vectorized host binary-search path —
  and scatters counts back to the original pair order.

Rows follow the repo-wide invariant: sorted ascending, deduplicated,
ids < sentinel (padding slots never match).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .bucketing import iter_width_buckets, pack_rows
from .delta_intersect import delta_intersect_counts, delta_intersect_masks

__all__ = ["batched_pair_counts"]


def batched_pair_counts(
    rows_a: Sequence[np.ndarray],
    rows_b: Sequence[np.ndarray],
    *,
    sentinel: int,
    use_kernel: bool = False,
    block_e: int = 128,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """``|rows_a[i] ∩ rows_b[i]|`` per pair of sorted 1-D rows.

    Returns int64 ``[len(rows_a)]`` in the input order.
    """
    n_pairs = len(rows_a)
    assert len(rows_b) == n_pairs
    out = np.zeros(n_pairs, np.int64)
    if n_pairs == 0:
        return out
    for idxs, wa, wb in iter_width_buckets(
        [r.size for r in rows_a], [r.size for r in rows_b]
    ):
        a = pack_rows([rows_a[i] for i in idxs], wa, sentinel)
        b = pack_rows([rows_b[i] for i in idxs], wb, sentinel)
        if use_kernel:
            cnt = delta_intersect_counts(
                a, b, sentinel=sentinel, block_e=block_e, interpret=interpret
            )
        else:
            cnt = delta_intersect_masks(a, b, sentinel=sentinel).sum(1)
        out[idxs] = cnt
    return out
