"""Pallas TPU kernel: EmbeddingBag (gather + masked reduce).

The recsys hot path (taxonomy §RecSys): bags of ids gather rows from a
large table and reduce. TPU-natively the table stays in HBM/ANY and rows
stream through VMEM via dynamic-slice loads driven by **scalar-prefetched
ids** (the ids must be readable at tile-schedule time — this is the
Pallas idiom for data-dependent gathers).

  grid: (B / BLOCK_B,)
  scalar-prefetch: ids [B, L] i32, weights-mask [B, L] f32
  in:   table [N, D] (ANY/HBM — sliced manually)
  out:  pooled [BLOCK_B, D] f32 (VMEM)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag"]


def _kernel(ids_ref, w_ref, table_ref, out_ref, *, block_b: int, bag: int):
    i = pl.program_id(0)
    d = out_ref.shape[-1]
    acc = jnp.zeros((block_b, d), jnp.float32)
    for bi in range(block_b):
        row_acc = jnp.zeros((1, d), jnp.float32)
        for li in range(bag):
            idx = ids_ref[i * block_b + bi, li]
            w = w_ref[i * block_b + bi, li]
            row = table_ref[pl.dslice(idx, 1), :]
            row_acc = row_acc + w * row.astype(jnp.float32)
        acc = acc.at[bi].set(row_acc[0])
    out_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret")
)
def embedding_bag(
    table: jnp.ndarray,  # [N, D] float
    ids: jnp.ndarray,  # [B, L] int32
    mask: jnp.ndarray,  # [B, L] bool
    *,
    mode: str = "sum",
    block_b: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    b, l = ids.shape
    n, d = table.shape
    assert b % block_b == 0, (b, block_b)
    w = mask.astype(jnp.float32)
    if mode == "mean":
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1.0)
    elif mode != "sum":
        raise ValueError(mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((block_b, d), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_b=block_b, bag=l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, w, table)
