"""Pallas TPU kernel: batched sorted-row intersection counting.

The compute hot-spot of the paper (edge-centric |adj(u) ∩ adj(v)|),
adapted to the TPU: merge-SSI is sequential and anti-SIMD, so each edge's
pair of padded sorted rows is intersected by an **all-pairs tile compare**
on the VPU (the SIMD set-intersection idiom), tiled so the working set
lives in VMEM:

  grid: (E / BLOCK_E,)  — one program per edge block
  in:   rows_a [BLOCK_E, WA] i32 (VMEM), rows_b [BLOCK_E, WB] i32 (VMEM)
  out:  counts [BLOCK_E] i32

Inside the program the [BLOCK_E, WA, WB] compare is chunked over WB in
steps of LANES so the live tile is [BLOCK_E, WA, 128] — hardware-aligned
for the 8x128 VPU. Sentinel padding never matches (ids < sentinel only).

The paper's hybrid decision rule (Eq. 3) lives one level up: the engine
statically routes (skew-split) edge streams either here or to the bitmap
kernel — see core/intersect.py::tpu_regime_rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["intersect_count"]

LANES = 128


def _kernel(rows_a_ref, rows_b_ref, counts_ref, *, sentinel: int, wb: int):
    a = rows_a_ref[...]  # [BE, WA]
    valid_a = a < sentinel
    be, wa = a.shape
    acc = jnp.zeros((be,), jnp.int32)
    for lo in range(0, wb, LANES):
        hi = min(lo + LANES, wb)
        b = rows_b_ref[:, lo:hi]  # [BE, LANES]
        eq = a[:, :, None] == b[:, None, :]  # [BE, WA, LANES]
        eq = jnp.logical_and(eq, valid_a[:, :, None])
        acc = acc + eq.sum(axis=(1, 2)).astype(jnp.int32)
    counts_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("sentinel", "block_e", "interpret"))
def intersect_count(
    rows_a: jnp.ndarray,  # [E, WA] int32 sorted, sentinel-padded
    rows_b: jnp.ndarray,  # [E, WB]
    *,
    sentinel: int,
    block_e: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    e, wa = rows_a.shape
    _, wb = rows_b.shape
    assert e % block_e == 0, (e, block_e)
    grid = (e // block_e,)
    return pl.pallas_call(
        functools.partial(_kernel, sentinel=sentinel, wb=wb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, wa), lambda i: (i, 0)),
            pl.BlockSpec((block_e, wb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(rows_a, rows_b)
