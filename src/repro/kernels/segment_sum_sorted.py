"""Pallas TPU kernel: segment-sum over SORTED segment ids.

The GNN message-passing reduction (edges sorted by destination — the
layout the 1D-partition preprocessing produces). Each program owns an
edge block and accumulates into the output via a one-hot matmul
(MXU-friendly scatter substitute):

  grid: (E / BLOCK_E,)   — sequential; output revisited across steps
  in:   values [BLOCK_E, D] f32, seg_ids [BLOCK_E] i32
  out:  out [N, D] f32 (single block; accumulated with @pl.when init)

The one-hot trick: partial[n, d] = sum_e (seg_ids[e] == n) * values[e, d]
— a [N_BLOCK, BLOCK_E] x [BLOCK_E, D] matmul on the MXU instead of a
serial scatter. N is tiled in chunks of ROWS to bound the one-hot tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_sum_sorted"]


def _kernel(vals_ref, seg_ref, out_ref, *, n: int, rows: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]  # [BE, D]
    seg = seg_ref[...]  # [BE]
    for lo in range(0, n, rows):
        hi = min(lo + rows, n)
        onehot = (
            seg[None, :] == (lo + jax.lax.iota(jnp.int32, hi - lo))[:, None]
        ).astype(vals.dtype)  # [ROWS, BE]
        out_ref[lo:hi, :] += onehot @ vals


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_e", "rows", "interpret")
)
def segment_sum_sorted(
    values: jnp.ndarray,  # [E, D] float
    seg_ids: jnp.ndarray,  # [E] int32, sorted ascending (padding -> N)
    *,
    num_segments: int,
    block_e: int = 512,
    rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    e, d = values.shape
    assert e % block_e == 0, (e, block_e)
    return pl.pallas_call(
        functools.partial(_kernel, n=num_segments, rows=rows),
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), values.dtype),
        interpret=interpret,
    )(values, seg_ids)
