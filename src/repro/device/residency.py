"""ResidencyManager: the device-resident hot-row cache tier.

One fixed-capacity padded buffer ``rows [slots, max_width]`` lives on
device; each slot holds the sorted adjacency row of one hot vertex,
sentinel-padded. Selection uses the same CLaMPI-style application score
as the host tier — degree centrality (paper §III-B2, Observations
3.1/3.2: degree predicts reuse) — restricted to rows that fit the
padded width. A dense vertex→slot table answers residency probes in
O(1) vectorized.

Coherence under streaming deltas (the part the static
``StaticDegreeCache`` cannot do):

- **in-place row patch** — a mutated resident row is re-read from the
  authoritative store and re-uploaded into its slot (one row-granular
  DMA, not a buffer rebuild) as long as it still fits ``max_width``;
- **score-driven evict/admit** — mutated outsiders whose degree now
  strictly exceeds the weakest resident's displace it (strict
  comparison, so score ties never thrash slots); residents that outgrow
  the padded width or drop to degree 0 are evicted;
- **epoch-bumped slots** — every slot carries an epoch that bumps on
  any content change (patch, evict, admit). A consumer that captured
  ``(slot, epoch)`` handles before a batch fails ``check()`` after it,
  so a stale resident hit is impossible by construction; evicted slots
  are additionally overwritten with sentinel rows, which intersect
  nothing.

A host mirror of the buffer backs the non-kernel consumers: serving a
resident row from the mirror skips the per-batch ``DynamicCSR.row``
merge + padding + upload that the ISSUE calls host-row
materialization; ``stats.bytes_saved`` ledgers exactly those bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import numpy as np

from ..kernels.bucketing import pow2_ceil
from ..obs import cachescope as obs_cachescope
from ..obs import trace as obs_trace

__all__ = ["ResidencyStats", "ResidencyManager"]

ID_BYTES = 4


@dataclasses.dataclass
class ResidencyStats:
    """Flat counters (aggregable via ``merge_counter_dataclasses``)."""

    lookups: int = 0  # rows asked of the tier (claims + padded fills)
    hits: int = 0  # rows served from the resident buffer
    misses: int = 0
    bytes_saved: int = 0  # host materialization/upload bytes avoided
    admits: int = 0
    evicts: int = 0
    patches: int = 0  # in-place row re-uploads after a mutation
    uploads: int = 0  # rows shipped host -> device (admits + patches)
    upload_bytes: int = 0
    epoch_bumps: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class ResidencyManager:
    def __init__(
        self,
        store,
        *,
        slots: int,
        max_width: Optional[int] = None,
        exclude_range: Optional[Tuple[int, int]] = None,
    ):
        """``exclude_range=(lo, hi)`` makes vertices in ``[lo, hi)``
        ineligible — the per-rank hot-set mode: a rank's own owned
        block is served locally and never reads through the tier, so
        its slots should hold remote-heavy rows instead."""
        assert slots >= 1
        self.store = store
        self.n = int(store.n)
        self.sentinel = self.n
        self.slots = int(slots)
        self.exclude_range = (
            (int(exclude_range[0]), int(exclude_range[1]))
            if exclude_range is not None
            else None
        )
        if max_width is None:
            max_width = pow2_ceil(max(int(store.max_degree), 1))
        self.max_width = int(max_width)
        self.slot_ids = np.full(self.slots, -1, np.int64)  # -1: empty
        self.slot_epochs = np.zeros(self.slots, np.int64)
        self.widths = np.zeros(self.slots, np.int32)  # true degree per slot
        self._slot_table = np.full(self.n, -1, np.int32)
        self._host = np.full(
            (self.slots, self.max_width), self.sentinel, np.int32
        )
        self.rows = None  # device buffer, set by _sync_device
        self.stats = ResidencyStats()
        self.rebuilds = 0
        # optional workload-driven selection score: callable
        # degrees -> per-vertex score array (e.g. the traffic plane's
        # EWMA×degree blend). None = the paper's pure-degree prior,
        # bit-identical to the pre-hook behavior. Takes effect on the
        # next rebuild()/notify_batch().
        self.score_fn = None
        self.rebuild()

    # ---------------- selection ----------------
    def _selection_scores(self, deg: np.ndarray) -> Optional[np.ndarray]:
        """Workload scores (float) when a score_fn is attached, else
        None (degree prior)."""
        if self.score_fn is None:
            return None
        return np.asarray(self.score_fn(deg), np.float64)

    def _eligible_scores(self) -> np.ndarray:
        deg = np.asarray(self.store.degrees, np.int64)
        sc = self._selection_scores(deg)
        base = deg if sc is None else sc
        # eligibility stays structural (nonzero degree, fits the padded
        # width) regardless of what scores the ranking: a workload score
        # cannot admit a row the buffer cannot hold. NOTE rebuild keeps
        # only score > 0 — with a pure-frequency score (blend=1.0) a
        # never-accessed row scores 0 and is excluded; keep blend < 1 so
        # the degree term breaks ties among cold rows (docs/serving.md).
        score = np.where((deg > 0) & (deg <= self.max_width), base, -1)
        if self.exclude_range is not None:
            lo, hi = self.exclude_range
            score[lo:hi] = -1  # owned rows are local reads — never cached
        return score

    def rebuild(self) -> None:
        """Select the hot set from scratch: top-``slots`` eligible
        vertices by degree score (stable tie-break by vertex id, same
        rule as ``build_static_degree_cache``) and upload their rows."""
        with obs_trace.span("residency_rebuild", cat="device",
                            slots=self.slots):
            self._rebuild_impl()

    def _rebuild_impl(self) -> None:
        score = self._eligible_scores()
        order = np.lexsort((np.arange(self.n), score))
        order = order[score[order] > 0]
        chosen = np.sort(order[max(0, order.size - self.slots):])
        rec = obs_cachescope._recorder
        if rec is not None:
            # before any mutation: a stream registered here snapshots the
            # PRE-rebuild membership, then the "r" event installs `chosen`
            rec.on_dev_reset(self, chosen)
        self._slot_table[:] = -1
        self.slot_ids[:] = -1
        self.widths[:] = 0
        self._host[:] = self.sentinel
        for s, v in enumerate(chosen.tolist()):
            row = self.store.row(int(v))
            self.slot_ids[s] = v
            self.widths[s] = row.size
            self._host[s, : row.size] = row
            self._slot_table[v] = s
            self.stats.uploads += 1
            self.stats.upload_bytes += row.size * ID_BYTES
        self.slot_epochs += 1
        self.stats.epoch_bumps += self.slots
        self.rebuilds += 1
        self._sync_device()

    def _sync_device(self, changed_slots: Optional[np.ndarray] = None) -> None:
        import jax.numpy as jnp

        if self.rows is None or changed_slots is None:
            self.rows = jnp.asarray(self._host)
        elif changed_slots.size:
            idx = jnp.asarray(changed_slots.astype(np.int32))
            self.rows = self.rows.at[idx].set(
                jnp.asarray(self._host[changed_slots])
            )

    # ---------------- probes ----------------
    @property
    def resident_rows(self) -> int:
        return int(np.count_nonzero(self.slot_ids >= 0))

    def slot_of(self, v) -> np.ndarray:
        """Slot per vertex id, -1 if not resident (vectorized, no stats)."""
        return self._slot_table[np.asarray(v, np.int64)]

    def claim(self, vertices) -> Tuple[np.ndarray, np.ndarray]:
        """(slots, epochs) per vertex (-1 / 0 when not resident), with
        the ledger update: every resident row claimed is one host
        fetch+pack+upload avoided this kernel call."""
        vs = np.asarray(vertices, np.int64)
        slots = self._slot_table[vs].copy()
        hit = slots >= 0
        epochs = np.zeros(vs.size, np.int64)
        epochs[hit] = self.slot_epochs[slots[hit]]
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_dev_lookup(self, vs)
        st = self.stats
        st.lookups += int(vs.size)
        st.hits += int(np.count_nonzero(hit))
        st.misses += int(np.count_nonzero(~hit))
        st.bytes_saved += int(self.widths[slots[hit]].sum()) * ID_BYTES
        return slots, epochs

    def check(self, slots: np.ndarray, epochs: np.ndarray) -> None:
        """Fail on any stale ``(slot, epoch)`` handle — the guarantee
        that a resident hit can never observe pre-mutation content."""
        slots = np.asarray(slots, np.int64)
        epochs = np.asarray(epochs, np.int64)
        if slots.size and not np.array_equal(
            self.slot_epochs[slots], epochs
        ):
            bad = np.flatnonzero(self.slot_epochs[slots] != epochs)[:8]
            raise AssertionError(
                f"stale residency handles at slots {slots[bad].tolist()}"
            )

    # ---------------- serving ----------------
    def serve(self, v: int) -> Optional[np.ndarray]:
        """The trimmed resident row of ``v`` (None on miss), from the
        host mirror — the ``fetch_rows`` fast path."""
        s = int(self._slot_table[int(v)])
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_dev_lookup(self, [int(v)])
        st = self.stats
        st.lookups += 1
        if s < 0:
            st.misses += 1
            return None
        st.hits += 1
        w = int(self.widths[s])
        st.bytes_saved += w * ID_BYTES
        return self._host[s, :w].copy()

    def host_rows(self, slots: np.ndarray) -> np.ndarray:
        """Mirror rows for the given slots (host-side count fallback)."""
        return self._host[np.asarray(slots, np.int64)]

    def padded_rows(
        self, vertices, width: int, *, sentinel: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ``[len(vertices), width]`` row matrix where resident
        rows come from the mirror (no per-row merge) and the rest from
        the store. Returns ``(rows, resident_mask)``.

        Requires ``width`` >= every resident row's true width among
        ``vertices`` (callers size by max touched degree, which bounds
        resident widths)."""
        vs = np.asarray(vertices, np.int64)
        sent = int(self.sentinel if sentinel is None else sentinel)
        # resident tails copied from the mirror carry the manager's own
        # sentinel; a different caller sentinel would mix padding values
        # and let paddings match each other downstream
        assert sent == self.sentinel, "sentinel must equal store.n"
        out = np.full((vs.size, width), sent, np.int32)
        slots = self._slot_table[vs]
        resident = slots >= 0
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_dev_lookup(self, vs)
        st = self.stats
        st.lookups += int(vs.size)
        st.hits += int(np.count_nonzero(resident))
        st.misses += int(np.count_nonzero(~resident))
        res_idx = np.flatnonzero(resident)
        if res_idx.size:
            s = slots[res_idx]
            assert int(self.widths[s].max()) <= width, (
                "resident row wider than the target layout"
            )
            # one vectorized gather: the mirror is sentinel-padded past
            # each row's true width, so copying a rectangle is exact
            w_copy = min(width, self.max_width)
            out[res_idx, :w_copy] = self._host[s, :w_copy]
            st.bytes_saved += int(self.widths[s].sum()) * ID_BYTES
        for i in np.flatnonzero(~resident):
            r = self.store.row(int(vs[i]))[:width]
            out[i, : r.size] = r
        return out, resident

    # ---------------- coherence ----------------
    def _evict(self, s: int) -> None:
        v = int(self.slot_ids[s])
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_dev_evict(self, v)
        self._slot_table[v] = -1
        self.slot_ids[s] = -1
        self.widths[s] = 0
        self._host[s, :] = self.sentinel  # stale content can match nothing
        self.slot_epochs[s] += 1
        self.stats.evicts += 1
        self.stats.epoch_bumps += 1

    def _write(self, s: int, v: int, row: np.ndarray) -> None:
        self._host[s, :] = self.sentinel
        self._host[s, : row.size] = row
        self.slot_ids[s] = v
        self.widths[s] = row.size
        self._slot_table[v] = s
        self.slot_epochs[s] += 1
        st = self.stats
        st.epoch_bumps += 1
        st.uploads += 1
        st.upload_bytes += row.size * ID_BYTES

    def notify_batch(self, changed_ids: Iterable[int]) -> int:
        """Bring the tier up to date after one applied update batch
        mutated ``changed_ids``' rows. Returns slots touched."""
        changed = np.unique(np.asarray(list(changed_ids), np.int64))
        if changed.size == 0:
            return 0
        with obs_trace.span("residency_patch", cat="device",
                            n=changed.size):
            return self._notify_batch_impl(changed)

    def _notify_batch_impl(self, changed: np.ndarray) -> int:
        deg = np.asarray(self.store.degrees, np.int64)
        touched: list[int] = []
        # 1. resident mutations: patch in place or evict on overflow
        slots = self._slot_table[changed]
        for i in np.flatnonzero(slots >= 0):
            v = int(changed[i])
            s = int(slots[i])
            d = int(deg[v])
            if d == 0 or d > self.max_width:
                self._evict(s)
            else:
                rec = obs_cachescope._recorder
                if rec is not None:
                    rec.on_dev_patch(self, v)
                self._write(s, v, self.store.row(v))
                self.stats.patches += 1
            touched.append(s)
        # 2. score-driven admission: mutated outsiders displace the
        #    weakest resident only on a STRICT score win (no tie churn).
        #    With a workload score_fn attached, "weakest" and the
        #    candidate ranking use the blended score instead of degree.
        cand = changed[slots < 0]
        cand = cand[(deg[cand] > 0) & (deg[cand] <= self.max_width)]
        if self.exclude_range is not None:
            lo, hi = self.exclude_range
            cand = cand[(cand < lo) | (cand >= hi)]
        if cand.size:
            sc = self._selection_scores(deg)
            key = deg if sc is None else sc
            cand = cand[np.argsort(-key[cand], kind="stable")]
            for v in cand.tolist():
                v = int(v)
                free = np.flatnonzero(self.slot_ids < 0)
                if free.size:
                    s = int(free[0])
                elif sc is None:
                    s = int(np.argmin(self.widths))
                    if int(deg[v]) <= int(self.widths[s]):
                        break  # weakest resident >= best candidate left
                    self._evict(s)
                    touched.append(s)
                else:
                    res_sc = sc[self.slot_ids]  # no free slot: all occupied
                    s = int(np.argmin(res_sc))
                    if float(sc[v]) <= float(res_sc[s]):
                        break  # weakest resident >= best candidate left
                    self._evict(s)
                    touched.append(s)
                rec = obs_cachescope._recorder
                if rec is not None:
                    rec.on_dev_admit(self, v)
                self._write(s, v, self.store.row(v))
                self.stats.admits += 1
                touched.append(s)
        if touched:
            self._sync_device(np.unique(np.asarray(touched, np.int64)))
        return len(set(touched))

    # ---------------- audit ----------------
    def audit(self) -> Tuple[int, int]:
        """(resident_rows, stale_rows): every resident slot compared
        bit-exactly against the authoritative store row, and the device
        buffer against the host mirror."""
        occupied = np.flatnonzero(self.slot_ids >= 0)
        stale = 0
        dev = np.asarray(self.rows)
        for s in occupied.tolist():
            v = int(self.slot_ids[s])
            w = int(self.widths[s])
            want = self.store.row(v)
            got = self._host[s, :w]
            if want.size != w or not np.array_equal(got, want):
                stale += 1
            elif not np.array_equal(dev[s], self._host[s]):
                stale += 1  # mirror/device divergence is also staleness
        return int(occupied.size), stale
