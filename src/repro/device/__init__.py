"""Device-resident hot-row cache tier — the memory hierarchy, mapped.

The paper's caching story is one level of the hierarchy: remote rows
are expensive, so each rank keeps a CLaMPI cache of the hottest remote
adjacency rows, with **degree centrality as the application-defined
score** (§III-B2) and score-driven **eviction** of the weakest entry.
This package applies the same reuse argument one level further down —
host memory vs device (TPU) memory — giving each rank a two-tier stack:

===================  ==============================  =====================
paper / host tier    concept                          device tier (here)
===================  ==============================  =====================
``ClampiCache``      bounded cache of hot rows        ``ResidencyManager``
CLaMPI score         degree centrality picks          same degree score
(§III-B2)            what is worth keeping            picks the hot set
eviction             weakest-score victim when full   strict score-driven
                                                      evict/admit on drift
RMA get on miss      remote fetch into the cache      host row merge + pack
                                                      + upload into a slot
invalidation         drop mutated rows so a hit is    in-place row patch
(streaming)          never stale                      (small deltas) or
                                                      evict; epoch-bumped
                                                      slots make a stale
                                                      hit impossible
hit                  payload served from the cache    kernels gather the
                                                      row from the resident
                                                      ``[slots, max_width]``
                                                      buffer — zero upload
===================  ==============================  =====================

``ShardedRuntime.fetch_rows`` consults the residency tier *before* the
host cache (it is closer to compute); ``invalidate`` fans out to both
tiers. The compute path is ``kernels.resident_intersect`` — scalar-
prefetch gather fused with the width-bucketed pairwise intersect — used
by both consumers: serving routes resident-vertex pairs through it, and
streaming runs its old∩old delta intersections against resident hub
rows without re-materializing them on host each batch.
"""
from .residency import ResidencyManager, ResidencyStats  # noqa: F401

__all__ = ["ResidencyManager", "ResidencyStats"]
