"""TriC-style synchronous baseline (paper §IV-B, Ghosh & Halappanavar 2020).

TriC counts triangles per vertex with a blocking query/response pattern:
every process sends edge queries to owners via **blocking all-to-all**,
waits (global synchronization), receives responses, repeats. The paper
attributes TriC's limited scaling to exactly this synchronization and to
its buffer blow-up on scale-free graphs (hence "TriC Buffered" with capped
16 MiB buffers).

Two artifacts here:

- ``tric_lcc_jnp``: a compiled BSP engine — the SAME work as the async
  engine but with a single monolithic (non-pipelined, non-cached,
  non-deduplicated) exchange phase followed by the compute phase, i.e. a
  hard barrier between all communication and all computation. This is the
  apples-to-apples baseline for wall-time comparisons on real devices.
- ``simulate_tric``: host-level cost model with per-superstep barriers
  (makespan = sum over supersteps of the max per-device time) and
  per-query (non-deduplicated) message volume — used in the Fig. 9/10
  benchmark where the paper reports up to 100x advantage for the
  asynchronous RMA version on scale-free graphs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cache import NetworkModel
from .csr import CSRGraph
from .partition import partition_1d
from .rma import ShardedLCCProblem, _edge_worklist, build_sharded_problem

__all__ = ["tric_problem", "tric_lcc_jnp", "simulate_tric", "TriCStats"]


def tric_problem(csr: CSRGraph, p: int, **kw) -> ShardedLCCProblem:
    """The TriC-like schedule: one round (bulk exchange), no cache, no dedup."""
    return build_sharded_problem(
        csr, p, n_rounds=1, cache=None, dedup_rounds=False, **kw
    )


def tric_lcc_jnp(csr: CSRGraph, p: int, mesh=None, method: str = "bsearch"):
    """Compiled BSP baseline: monolithic fetch, barrier, compute."""
    from .async_engine import lcc_pipelined

    prob = tric_problem(csr, p)
    return lcc_pipelined(prob, mesh, method=method)


@dataclasses.dataclass
class TriCStats:
    makespan: float
    comm_time: np.ndarray  # [p]
    sync_time: float
    queries: np.ndarray  # [p]
    buffer_bytes: np.ndarray  # [p] peak response-buffer demand


def simulate_tric(
    csr: CSRGraph,
    p: int,
    *,
    network: Optional[NetworkModel] = None,
    supersteps: int = 8,
    buffer_cap_bytes: int = 16 << 20,
) -> TriCStats:
    """Superstep cost model of TriC's query/response all-to-all.

    Every remote edge issues a query (id, 8 B) and receives the adjacency
    list response; volume is NOT deduplicated (TriC re-requests per edge).
    Each superstep ends in a barrier: its cost is the max across devices.
    Buffered variant: when a device's response volume exceeds the 16 MiB
    cap, extra rounds are added (the protocol change the paper describes).
    """
    net = network or NetworkModel()
    part = partition_1d(csr.n, p)
    deg = csr.degrees
    per_dev_time = np.zeros((p, supersteps), np.float64)
    queries = np.zeros(p, np.int64)
    bufpeak = np.zeros(p, np.int64)
    for k in range(p):
        u_l, v_g = _edge_worklist(csr, part, k)
        owners = part.owner(v_g)
        remote = v_g[owners != k]
        queries[k] = remote.size
        sizes = deg[remote] * 4 + 8
        bufpeak[k] = int(sizes.sum())
        # split the query stream across supersteps (TriC phases by vertex
        # ranges); each chunk: a2a of queries + responses, then barrier.
        chunks = np.array_split(sizes, supersteps)
        for s, ch in enumerate(chunks):
            vol = float(ch.sum())
            n_msgs = max(len(ch), 1)
            # buffered variant: extra rounds if volume exceeds the cap
            extra = int(vol // buffer_cap_bytes)
            per_dev_time[k, s] = (
                net.alpha * (1 + extra) + vol * net.beta + n_msgs * net.alpha * 0.01
            )
    # barrier per superstep: everyone waits for the slowest device
    step_cost = per_dev_time.max(axis=0)
    makespan = float(step_cost.sum())
    sync = float(makespan - per_dev_time.sum(axis=1).mean())
    return TriCStats(
        makespan=makespan,
        comm_time=per_dev_time.sum(axis=1),
        sync_time=max(sync, 0.0),
        queries=queries,
        buffer_bytes=bufpeak,
    )
