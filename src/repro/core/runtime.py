"""Sharded RMA runtime: the shared partition/cache/transport substrate.

The paper's central claim is that ONE asynchronous RMA+caching layer
(1D partition, CLaMPI-style caches, degree-scored victim selection)
serves every consumer — the epoch sweep, streaming maintenance, and
point-query serving. This module is that layer, extracted so the three
consumers stop re-implementing single-rank views of it:

- **Ownership** — a partition (``Partition1D`` or ``HubPartition``)
  answers ``owner(v)`` for every consumer; rank ``k`` owns the
  contiguous block ``[lo(k), hi(k))``. The contract (owner/lo/hi/sizes
  /block/route — see ``core.partition`` and docs/partitioning.md) is
  all the runtime assumes, so swapping partition families never
  touches a consumer. With a hub-aware partition, remote misses of
  split hub rows charge one *fragment* serve per holding rank instead
  of one whole-row serve from the owner, and ``migrate(new_cuts)``
  moves ownership boundaries live (cache-invalidation fanout +
  device-residency handoff + schedule rebuild).
- **Transport** — ``fetch_rows(rank, vertices)`` is the rank-indexed
  remote-read path: rows owned by ``rank`` are free, remote rows pay the
  modeled ``NetworkModel`` get and pass through rank ``rank``'s
  ``ClampiCache`` (degree-scored admission, real payloads). The
  ``serve_rows`` matrix accumulates the all-to-all serve lists (rows
  shipped owner -> requester) the static engine compiles ahead of time.
- **Coherence** — ``invalidate(changed_ids)`` fans each mutated row out
  ONLY to the ranks whose cache holds it (``contains`` probe, no stats
  perturbation) instead of broadcasting to all p ranks; the fanout
  ledger records the saving. This is the correctness contract every
  payload-carrying cache relies on: a hit returns the payload captured
  at fetch time, so a mutated row must be dropped everywhere it is
  resident before the next read.
- **Schedule** — the runtime can carry the epoch engine's static pull
  schedule (``ShardedLCCProblem``) and keep it fresh under streaming
  deltas via ``maintain_schedule`` (incremental ``apply_delta`` with a
  width-overflow rebuild fallback).

Consumers hold *views*: a serving row provider is (runtime, rank); a
sharded query engine is p such views; the streaming engine shards its
delta worklists by ``runtime.part.owner``. None of them construct
partitions or caches themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace as obs_trace
from .cache import (
    CacheStats,
    ClampiCache,
    NetworkModel,
    StaticDegreeCache,
    build_static_degree_cache,
    merge_cache_stats,
    merge_counter_dataclasses,
)
from .partition import Partition1D, partition_1d

__all__ = ["FetchEvent", "ProviderStats", "ShardedRuntime"]

ID_BYTES = 4


@dataclasses.dataclass(frozen=True)
class FetchEvent:
    """One vertex's resolution inside ``fetch_rows`` — the control-plane
    record the SPMD executor turns into a data-plane placement.

    ``kind`` is how the read was served:

    - ``"local"``  — owned by the reading rank (free; row lives in the
      rank's own shard),
    - ``"device"`` — served by the device-resident tier (no host cache
      probe, no modeled bytes; content = the resident mirror row),
    - ``"hit"``    — host-cache hit (content = the captured payload),
    - ``"miss"``   — remote miss: the row was shipped owner -> reader
      and accounted in the ``serve_rows`` matrix. In SPMD execution this
      is exactly the set of rows that must travel through the
      ``all_to_all`` collective; everything else stays rank-resident.
    """

    v: int
    kind: str  # "local" | "device" | "hit" | "miss"
    owner: int


@dataclasses.dataclass
class ProviderStats:
    """Per-rank read-path accounting (one instance per runtime rank)."""

    local_reads: int = 0
    remote_reads: int = 0  # reads of non-local rows (pre-cache)
    cache_hits: int = 0
    cache_misses: int = 0
    device_hits: int = 0  # served by the device-resident tier (pre-host)
    device_bytes_saved: int = 0  # host materialization/upload avoided
    invalidations: int = 0
    stale_payloads_dropped: int = 0
    bytes_fetched: int = 0  # remote bytes actually moved (post-cache)
    modeled_comm_s: float = 0.0
    # multi-tenant accounting (empty until tenant-tagged fetches occur;
    # merge_counter_dataclasses sums dict fields key-wise)
    tenant_requests: Dict[str, int] = dataclasses.field(default_factory=dict)
    tenant_bytes_fetched: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def hit_rate(self) -> float:
        """Host-cache hit rate over host-cache *probes*. Device-tier
        hits resolve above the host cache and never probe it, so they
        belong in neither numerator nor denominator (using raw
        ``remote_reads`` would deflate the rate whenever the device
        tier is on)."""
        probes = self.cache_hits + self.cache_misses
        return self.cache_hits / probes if probes else 0.0

    @property
    def remote_hit_rate(self) -> float:
        """Fraction of remote reads served without moving bytes, by
        either tier (device-resident or host-cache hit)."""
        r = self.remote_reads
        return (self.cache_hits + self.device_hits) / r if r else 0.0


class ShardedRuntime:
    """Owns the vertex partition, p per-rank caches, the network model,
    the rank-indexed row transport, and (optionally) the static pull
    schedule. See the module docstring for the contracts.

    ``partition`` (optional) installs any object honoring the
    owner/lo/hi/sizes/block contract — ``partition_1d(n, p)`` by
    default, ``partition_hub(degrees, p)`` for hub-aware serving. Every
    consumer reads ownership through ``self.part``, so the choice is
    made exactly once, here."""

    def __init__(
        self,
        store=None,
        p: int = 4,
        *,
        n: Optional[int] = None,
        cache_bytes: int = 1 << 20,
        table_slots: Optional[int] = None,
        network: Optional[NetworkModel] = None,
        use_degree_score: bool = True,
        uncached: bool = False,
        device_slots: int = 0,
        device_width: Optional[int] = None,
        partition=None,
    ):
        if store is not None:
            n = int(store.n)
        assert n is not None, "need a store or an explicit vertex count n"
        self.store = store
        self.n = int(n)
        self.p = int(p)
        if partition is not None:
            assert partition.n == self.n and partition.p == self.p, (
                "partition shape mismatch",
                (partition.n, partition.p),
                (self.n, self.p),
            )
        self.part: Partition1D = (
            partition if partition is not None
            else partition_1d(self.n, self.p)
        )
        self.net = network or NetworkModel()
        self.use_degree_score = use_degree_score
        self.caches: Optional[List[ClampiCache]] = (
            None
            if uncached
            else [
                ClampiCache(
                    cache_bytes,
                    table_slots or max(1, self.n // 4),
                    mode="always",
                    network=self.net,
                )
                for _ in range(self.p)
            ]
        )
        if self.caches is not None:
            for k, c in enumerate(self.caches):
                c.rank = k  # cachescope stream labeling
                c.scope_label = "runtime"
        # payloads mirror each rank's cache residency: row copy at fetch
        self._payloads: List[Dict[int, np.ndarray]] = [
            {} for _ in range(self.p)
        ]
        self.stats: List[ProviderStats] = [
            ProviderStats() for _ in range(self.p)
        ]
        # all-to-all serve accounting: serve_rows[owner, requester] = rows
        # actually shipped (post-cache misses), the dynamic analogue of
        # the static engine's per-round serve lists.
        self.serve_rows = np.zeros((self.p, self.p), np.int64)
        # targeted-coherence ledger: fanout messages actually sent vs the
        # p * |changed| a broadcast scheme would pay.
        self.invalidations_sent = 0
        self.invalidations_broadcast_equiv = 0
        # optional shared static degree cache (epoch/coherence consumers)
        self.static_cache: Optional[StaticDegreeCache] = None
        # optional static pull schedule kept fresh under deltas
        self.problem = None
        self.schedule_rebuilds = 0
        self.schedule_deltas = 0
        self.schedule_residency_refreshes = 0
        # online repartitioning ledger (migrate())
        self.migrations = 0
        self.rows_migrated = 0
        # optional device-resident hot-row tier, below the host caches.
        # scope="replicated": one manager models the per-device
        # replicated buffer (content identical across ranks by
        # construction; per-rank hit counts live in ProviderStats).
        # scope="per_rank": p managers, each holding its OWN rank's
        # remote-heavy rows (a rank's owned range is excluded — those
        # reads are local and never touch the tier).
        self.device = None
        self._devices: Optional[list] = None
        self.device_scope = "replicated"
        self._device_slots = int(device_slots)
        self._device_width = device_width
        # one-shot set of ids whose device rows a producer has already
        # patched this batch (consumed by the next invalidate)
        self._device_fresh_once = None
        # coherence listeners beyond the built-in tiers (e.g. the SPMD
        # executor's resident shard buffer): called with the changed-id
        # list on every invalidate, and with None on a store swap.
        self._invalidation_listeners: list = []
        # optional live workload scorer (traffic.WorkloadScorer): when
        # attached, cache admission scores come from its EWMA×degree
        # blend instead of the static degree prior, and device-tier
        # selection reads the same scorer via score_fn.
        self.scorer = None
        if self._device_slots and self.store is not None:
            self.enable_device_tier(self._device_slots, self._device_width)

    # ---------------- wiring ----------------
    def bind_store(self, store) -> None:
        """Attach (or swap) the authoritative row store. Consumers that
        create their own store (e.g. the streaming engine) bind it here
        so every rank's transport reads the same live graph. Swapping an
        already-bound store flushes every rank's cache: payloads captured
        from the old store would otherwise be served as hits against the
        new one."""
        assert int(store.n) == self.n, "store/partition size mismatch"
        if store is self.store:
            return
        swapped = self.store is not None
        self.store = store
        if swapped and self.caches is not None:
            for k, cache in enumerate(self.caches):
                if cache.entries:
                    cache.flush()
                self._payloads[k].clear()
        if swapped:
            for fn in self._invalidation_listeners:
                fn(None)  # everything captured from the old store is dead
        if self._device_slots and (swapped or not self.has_device_tier):
            self.enable_device_tier(
                self._device_slots, self._device_width,
                scope=self.device_scope,
            )

    def enable_device_tier(
        self,
        slots: int,
        max_width: Optional[int] = None,
        *,
        scope: str = "replicated",
    ):
        """Build (or rebuild, against the current store) the device-
        resident hot-row tier: ``slots`` degree-scored rows padded to
        ``max_width``, consulted by ``fetch_rows`` before the host cache
        and kept coherent by ``invalidate``.

        ``scope="replicated"`` models one buffer identical on every
        device (the pre-PR-8 behavior). ``scope="per_rank"`` gives each
        rank a *distinct* hot set that excludes the rank's own owned
        range — local reads never touch the tier, so replicating an
        owner's rows on its own device wastes slots; each rank instead
        holds its hottest remote rows."""
        from ..device import ResidencyManager

        assert self.store is not None, "bind a store first"
        assert scope in ("replicated", "per_rank"), scope
        self.device_scope = scope
        if scope == "replicated":
            self.device = ResidencyManager(
                self.store, slots=slots, max_width=max_width
            )
            self.device.scope_label = "runtime"
            self.device.rank = -1
            self._devices = None
        else:
            self.device = None
            self._devices = []
            for k in range(self.p):
                mgr = ResidencyManager(
                    self.store,
                    slots=slots,
                    max_width=max_width,
                    exclude_range=(int(self.part.lo(k)),
                                   int(self.part.hi(k))),
                )
                mgr.scope_label = "runtime"
                mgr.rank = k
                self._devices.append(mgr)
        self._device_slots = int(slots)
        self._device_width = max_width
        return self.device if self.device is not None else self._devices

    @property
    def has_device_tier(self) -> bool:
        return self.device is not None or self._devices is not None

    def device_for(self, rank: int):
        """The device-tier manager serving ``rank``'s reads (None when
        the tier is off): the shared replicated manager, or rank's own
        hot set under ``scope="per_rank"``."""
        if self._devices is not None:
            return self._devices[int(rank)]
        return self.device

    def device_views(self) -> list:
        """All distinct device-tier managers (0 or 1 when replicated,
        p when per-rank) — for coherence fanout, audits, and metrics."""
        if self._devices is not None:
            return list(self._devices)
        return [self.device] if self.device is not None else []

    def merged_device_stats(self):
        """Summed ResidencyStats across the tier's views (None when the
        tier is off)."""
        views = self.device_views()
        if not views:
            return None
        return merge_counter_dataclasses(
            type(views[0].stats), [v.stats for v in views]
        )

    def add_invalidation_listener(self, fn) -> None:
        """Register a coherence listener: ``fn(changed_ids)`` on every
        invalidate, ``fn(None)`` (= drop everything) on a store swap."""
        if fn not in self._invalidation_listeners:
            self._invalidation_listeners.append(fn)

    def attach_scorer(self, scorer) -> None:
        """Install a live workload scorer (``traffic.WorkloadScorer``):
        every remote read through the host cache observes the vertex and
        scores admission by the EWMA×degree blend; the device tier's
        selection reads the same scorer (applied on its next rebuild —
        call ``refresh_device_scores()`` to force one)."""
        self.scorer = scorer
        if scorer is not None and self.store is not None:
            scorer.set_degree_scale(float(np.max(self.store.degrees,
                                                 initial=1)))
        for dev in self.device_views():
            dev.score_fn = (None if scorer is None
                            else scorer.score_array)

    def refresh_device_scores(self) -> int:
        """Re-rank the device tier under the current workload scores
        (no-op without a scorer or tier). Returns rebuilds performed.
        Called between serving windows, never inside one — rebuilds bump
        slot epochs, which would fault in-flight residency handles."""
        views = self.device_views()
        if self.scorer is None or not views:
            return 0
        for dev in views:
            dev.score_fn = self.scorer.score_array
            dev.rebuild()
        return len(views)

    def build_static_cache(self, capacity_rows: int) -> StaticDegreeCache:
        """Install a shared top-C degree-scored resident set."""
        deg = np.asarray(self.store.degrees)
        self.static_cache = build_static_degree_cache(deg, capacity_rows)
        return self.static_cache

    # ---------------- ownership ----------------
    def owner(self, v):
        """Owner rank per vertex id (vectorized), delegated to the
        installed partition. The contract (docs/partitioning.md):
        ``owner(v) == k  iff  part.lo(k) <= v < part.hi(k)`` — blocks
        are contiguous and tile ``[0, n)``, for both partition
        families, and stay true across ``migrate()`` (in-place cut
        moves)."""
        return self.part.owner(v)

    def shard_of(self, vertices: np.ndarray) -> np.ndarray:
        """Owner rank per vertex — the worklist-sharding helper."""
        return self.part.owner(np.asarray(vertices, np.int64))

    # ---------------- transport ----------------
    def _charge_remote_miss(
        self, st: ProviderStats, rank: int, owner: int, v: int,
        d: int, tenant: str,
    ) -> int:
        """Account one remote miss in the serve matrix + byte ledger.

        Non-hub row: one whole-row ship owner -> rank (``d`` ids).
        Split hub row: one *fragment* ship from every rank holding a
        nonempty fragment except the reader — the reader's own fragment
        is rank-resident and free, so the bytes moved are
        ``d - |own fragment|`` ids spread across up to p-1 servers.
        This is exactly what the SPMD executor ships (fragment keys over
        the all_to_all), so measured traffic reconciles row-for-row and
        byte-for-byte against this model. Returns bytes charged."""
        part = self.part
        if getattr(part, "has_hubs", False) and bool(part.is_hub(v)):
            sizes = part.fragment_sizes(d)
            bytes_moved = 0
            for q in range(self.p):
                if q == rank or sizes[q] == 0:
                    continue
                self.serve_rows[q, rank] += 1
                bytes_moved += int(sizes[q]) * ID_BYTES
        else:
            self.serve_rows[owner, rank] += 1
            bytes_moved = d * ID_BYTES
        st.bytes_fetched += bytes_moved
        if tenant:
            st.tenant_bytes_fetched[tenant] = (
                st.tenant_bytes_fetched.get(tenant, 0) + bytes_moved
            )
        return bytes_moved

    def fetch_rows(
        self,
        rank: int,
        vertices: Sequence[int],
        record: Optional[List[FetchEvent]] = None,
        tenants: Optional[Dict[int, str]] = None,
    ) -> Dict[int, np.ndarray]:
        """Sorted adjacency row per distinct vertex, as read by ``rank``.

        Rows owned by ``rank`` bypass the cache (free); remote rows go
        through rank ``rank``'s ClampiCache admission — a hit returns the
        payload captured at fetch time, a miss pays the modeled remote
        get and ships the row from its owner (serve matrix). Under a
        hub-aware partition a missed *hub* row ships as per-rank
        fragments instead (``_charge_remote_miss``): every holding rank
        serves one fragment, the reader's own fragment is free — the
        returned row is still the full sorted row either way.

        ``record`` (optional) collects one ``FetchEvent`` per vertex in
        resolution order: the SPMD executor replays it to decide which
        rows stay rank-resident on device and which must arrive through
        the all_to_all collective — by construction the recorded
        ``"miss"`` events are exactly the reads this same call charged to
        ``serve_rows``, so the measured collective traffic reconciles
        against the model without a second bookkeeping path.

        ``tenants`` (optional) maps vertex -> tenant tag: tagged reads
        are charged to the tenant in ``ProviderStats`` and tag the
        cache entry they admit (quota-aware eviction)."""
        rank = int(rank)
        with obs_trace.span("fetch_rows", rank=rank, cat="runtime",
                            n=len(vertices)):
            return self._fetch_rows_impl(rank, vertices, record, tenants)

    def _fetch_rows_impl(
        self,
        rank: int,
        vertices: Sequence[int],
        record: Optional[List[FetchEvent]],
        tenants: Optional[Dict[int, str]] = None,
    ) -> Dict[int, np.ndarray]:
        st = self.stats[rank]
        if tenants:
            for v in vertices:
                t = tenants.get(int(v), "")
                if t:
                    st.tenant_requests[t] = st.tenant_requests.get(t, 0) + 1
        out: Dict[int, np.ndarray] = {}
        store = self.store
        dev = self.device_for(rank)
        if self.caches is None:
            for v in vertices:
                v = int(v)
                owner = int(self.part.owner(v))
                if owner == rank:
                    st.local_reads += 1
                    out[v] = store.row(v)
                    if record is not None:
                        record.append(FetchEvent(v, "local", owner))
                    continue
                st.remote_reads += 1
                if dev is not None:
                    row = dev.serve(v)
                    if row is not None:
                        st.device_hits += 1
                        st.device_bytes_saved += row.size * ID_BYTES
                        out[v] = row
                        if record is not None:
                            record.append(FetchEvent(v, "device", owner))
                        continue
                row = store.row(v)
                st.cache_misses += 1
                tenant = tenants.get(v, "") if tenants else ""
                moved = self._charge_remote_miss(
                    st, rank, owner, v, int(row.size), tenant
                )
                st.modeled_comm_s += self.net.remote(moved)
                out[v] = row
                if record is not None:
                    record.append(FetchEvent(v, "miss", owner))
            return out
        cache = self.caches[rank]
        payloads = self._payloads[rank]
        deg = store.degrees
        scorer = self.scorer
        for v in vertices:
            v = int(v)
            owner = int(self.part.owner(v))
            if owner == rank:
                st.local_reads += 1
                out[v] = store.row(v)
                if record is not None:
                    record.append(FetchEvent(v, "local", owner))
                continue
            st.remote_reads += 1
            # the device tier sits below the host cache (closer to the
            # compute): a resident row is already on device, so the read
            # neither probes the host cache nor moves modeled bytes.
            if dev is not None:
                row = dev.serve(v)
                if row is not None:
                    st.device_hits += 1
                    st.device_bytes_saved += row.size * ID_BYTES
                    out[v] = row
                    if record is not None:
                        record.append(FetchEvent(v, "device", owner))
                    continue
            d = int(deg[v])
            size = d * ID_BYTES
            tenant = tenants.get(v, "") if tenants else ""
            if scorer is not None:
                # tick the EWMA at the cache-probe point — the same
                # place cachescope's trace ticks its access counter, so
                # the live frequency matches the offline replay's
                scorer.observe(v)
                score = scorer.cache_score(v, d)
            else:
                score = float(d) if self.use_degree_score else None
            if cache.get(v, size, score=score, tenant=tenant):
                st.cache_hits += 1
                row = payloads.get(v)
                if row is None:
                    # entry admitted without a payload (the coherence
                    # replay drives the same caches via get() directly);
                    # nothing invalidation-worthy happened since, so the
                    # store row IS the row at admission time — capture it
                    # and restore the payloads-mirror invariant.
                    row = store.row(v).copy()
                    payloads[v] = row
                out[v] = row
                if record is not None:
                    record.append(FetchEvent(v, "hit", owner))
                continue
            st.cache_misses += 1
            # the cache probe above still keys/charges the FULL row
            # (capacity + admission semantics are per-row); the serve
            # matrix and byte ledger charge what actually moves.
            self._charge_remote_miss(st, rank, owner, v, d, tenant)
            row = store.row(v).copy()
            if cache.contains(v):  # admitted after the miss
                payloads[v] = row
            else:
                payloads.pop(v, None)
            out[v] = row
            if record is not None:
                record.append(FetchEvent(v, "miss", owner))
        # single comm ledger: the cache already charges remote reads on
        # miss plus hit/insert probe costs (paper §IV-D1) — mirror it.
        st.modeled_comm_s = cache.stats.comm_time
        return out

    # ---------------- coherence ----------------
    def invalidate(self, changed_ids: Iterable[int]) -> int:
        """One applied update batch mutated ``changed_ids``' rows: drop
        their cached payloads on exactly the ranks that hold them.
        Returns the number of host-cache entries dropped."""
        changed = [int(v) for v in changed_ids]
        with obs_trace.span("cache_invalidate", cat="coherence",
                            n=len(changed)):
            return self._invalidate_impl(changed)

    def _invalidate_impl(self, changed: List[int]) -> int:
        # both tiers observe every mutation: the device tier patches the
        # touched resident rows in place (or evicts on width overflow)
        # and re-scores admission, so a later resident hit is fresh.
        # Rows a producer already synced mid-batch (mark_device_fresh)
        # are skipped once — they were patched against the same final
        # state, so a second merge+upload would only burn time and
        # double-count the patch/upload ledger.
        fresh = self._device_fresh_once or ()
        dev_ids = [v for v in changed if v not in fresh]
        if dev_ids:
            for dev in self.device_views():
                dev.notify_batch(dev_ids)
        self._device_fresh_once = None
        # external coherence listeners (e.g. the SPMD resident buffer)
        # observe every mutation, including producer-fresh ids: they key
        # content by id, not by the device tier's patch schedule.
        for fn in self._invalidation_listeners:
            fn(changed)
        if self.caches is None:
            return 0
        dropped = 0
        self.invalidations_broadcast_equiv += self.p * len(changed)
        for k, cache in enumerate(self.caches):
            st = self.stats[k]
            payloads = self._payloads[k]
            for v in changed:
                if not cache.contains(v):
                    continue  # targeted fanout: rank k never sees v
                self.invalidations_sent += 1
                if cache.invalidate(v):
                    st.invalidations += 1
                    dropped += 1
                if payloads.pop(v, None) is not None:
                    st.stale_payloads_dropped += 1
            self._prune_evicted(k)
        return dropped

    # hook-compatible alias: coherence layers call ``notify_batch`` on
    # every registered listener; the runtime is such a listener.
    def notify_batch(self, changed_ids: Iterable[int]) -> None:
        self.invalidate(changed_ids)

    def mark_device_fresh(self, ids: Iterable[int]) -> None:
        """Declare that the device rows of ``ids`` already reflect the
        batch's final state (a producer patched them mid-batch); the
        NEXT ``invalidate`` skips them on the device tier only — host
        payload caches are always invalidated."""
        self._device_fresh_once = {int(v) for v in ids}

    # ---------------- online repartitioning ----------------
    def migrate(self, new_cuts) -> int:
        """Move the ownership boundaries to ``new_cuts`` live, with the
        full handoff protocol (docs/partitioning.md):

        1. the partition's ``cuts`` mutate IN PLACE, so every consumer
           holding ``runtime.part`` (SPMD executor, coherence layer, row
           providers) sees the new ownership atomically;
        2. rows whose owner changed get the invalidation fanout — host
           payload caches drop them and coherence listeners observe
           them, so no rank serves a row it believes it still owns from
           a stale tier placement;
        3. per-rank device hot sets are rebuilt against the new
           exclusion ranges (a rank's newly-owned rows leave its remote
           hot set; newly-remote rows become eligible) — the
           device-residency handoff;
        4. an attached static pull schedule is recompiled against the
           new cuts (ownership is baked into its worklists).

        Call between batches only (single-writer; mid-batch migration
        would tear the measured-vs-modeled reconciliation). Returns the
        number of rows whose owner changed. Bit-exactness: ownership
        placement never affects answers, only where reads are served
        from — the tests pin this at p ∈ {1, 4, 8}."""
        part = self.part
        assert hasattr(part, "cuts"), (
            "migrate() needs a cut-based partition (HubPartition)"
        )
        new = np.asarray(new_cuts, np.int64)
        assert new.shape == part.cuts.shape, (new.shape, part.cuts.shape)
        assert new[0] == 0 and new[-1] == self.n
        assert bool(np.all(np.diff(new) >= 0)), "cuts must ascend"
        ids = np.arange(self.n, dtype=np.int64)
        before = part.owner(ids)
        part.cuts[:] = new
        after = part.owner(ids)
        moved = ids[before != after]
        if moved.size:
            self.invalidate(moved.tolist())
        if self._devices is not None:
            self.enable_device_tier(
                self._device_slots, self._device_width, scope="per_rank"
            )
        if self.problem is not None:
            from .rma import build_sharded_problem

            prob = self.problem
            csr = (
                self.store.to_csr()
                if hasattr(self.store, "to_csr")
                else self.store
            )
            cache = (
                StaticDegreeCache(vertex_ids=prob.cache_ids)
                if prob.cache_ids.size
                else None
            )
            self.problem = build_sharded_problem(
                csr,
                self.p,
                n_rounds=prob.n_rounds_requested,
                cache=cache,
                width=prob.width,
                dedup_rounds=prob.dedup_rounds,
                part=part,
            )
            self.schedule_rebuilds += 1
        self.migrations += 1
        self.rows_migrated += int(moved.size)
        return int(moved.size)

    def _prune_evicted(self, rank: int) -> None:
        """Payloads of entries the cache evicted on its own are dead
        weight (never returned — a future get misses); drop them."""
        if self.caches is None:
            return
        cache = self.caches[rank]
        payloads = self._payloads[rank]
        dead = [k for k in payloads if not cache.contains(k)]
        for k in dead:
            del payloads[k]

    def audit_rank(self, rank: int) -> Tuple[int, int]:
        """(cached_entries, stale_entries) for one rank: every resident
        payload compared against the authoritative store row."""
        if self.caches is None:
            return 0, 0
        self._prune_evicted(rank)
        payloads = self._payloads[rank]
        stale = 0
        for v, row in payloads.items():
            if not np.array_equal(row, self.store.row(v)):
                stale += 1
        return len(payloads), stale

    def audit_freshness(self) -> Tuple[int, int]:
        """(cached, stale) summed over every rank and the device tier —
        the freshness bound holds iff stale == 0 everywhere."""
        cached = stale = 0
        for k in range(self.p):
            c, s = self.audit_rank(k)
            cached += c
            stale += s
        for dev in self.device_views():
            c, s = dev.audit()
            cached += c
            stale += s
        return cached, stale

    # ---------------- aggregated metrics ----------------
    def aggregate_stats(self) -> ProviderStats:
        return merge_counter_dataclasses(ProviderStats, self.stats)

    def merged_cache_stats(self) -> CacheStats:
        if self.caches is None:
            return CacheStats()
        return merge_cache_stats([c.stats for c in self.caches])

    @property
    def invalidation_fanout_saved(self) -> int:
        """Messages a broadcast invalidation scheme would have sent that
        the targeted fanout did not."""
        return self.invalidations_broadcast_equiv - self.invalidations_sent

    def cross_rank_rows_served(self) -> int:
        return int(self.serve_rows.sum())

    # ---------------- static pull schedule ----------------
    def attach_problem(self, problem) -> None:
        """Carry the epoch engine's compiled pull schedule so streaming
        deltas can keep it fresh (``maintain_schedule``)."""
        self.problem = problem

    def maintain_schedule(
        self,
        ins: np.ndarray,
        dele: np.ndarray,
        *,
        rebuild_width: Optional[int] = None,
        new_cache_ids: Optional[np.ndarray] = None,
    ) -> bool:
        """Patch the attached schedule for one applied update batch.

        Uses ``ShardedLCCProblem.apply_delta`` (O(delta) row/worklist
        patching + vectorized schedule recompile); on width overflow —
        a touched vertex outgrew the padded row width — falls back to a
        from-scratch ``build_sharded_problem`` against the bound store,
        keeping the problem's build parameters (requested rounds, cache
        residency, dedup) and doubling the width for headroom unless
        ``rebuild_width`` overrides it. Returns True if the incremental
        path succeeded, False if the fallback rebuild ran.

        ``new_cache_ids`` is the drifted static residency set (e.g. the
        coherence layer's rescored top-C): ``apply_delta`` refreshes
        ``cache_ids``/``cache_rows`` in place and recompiles, so
        residency drift alone never forces a from-scratch rebuild —
        only width overflow does."""
        from .rma import ScheduleWidthOverflow, build_sharded_problem

        if self.problem is None:
            return True
        had_ids = self.problem.cache_ids.copy()
        try:
            self.problem.apply_delta(ins, dele, new_cache_ids=new_cache_ids)
            self.schedule_deltas += 1
            if new_cache_ids is not None and not np.array_equal(
                had_ids, self.problem.cache_ids
            ):
                self.schedule_residency_refreshes += 1
            return True
        except ScheduleWidthOverflow:
            prob = self.problem
            csr = (
                self.store.to_csr()
                if hasattr(self.store, "to_csr")
                else self.store
            )
            if rebuild_width is None:
                rebuild_width = max(2 * int(csr.max_degree), 2 * prob.width, 1)
            ids = (
                np.sort(np.unique(np.asarray(new_cache_ids, np.int64)))
                if new_cache_ids is not None
                else prob.cache_ids
            )
            cache = (
                StaticDegreeCache(vertex_ids=ids) if ids.size else None
            )
            self.problem = build_sharded_problem(
                csr,
                self.p,
                n_rounds=prob.n_rounds_requested,
                cache=cache,
                width=rebuild_width,
                dedup_rounds=prob.dedup_rounds,
            )
            self.schedule_rebuilds += 1
            return False
