"""Version-compatibility shims for JAX APIs that moved between releases.

``shard_map`` lived in ``jax.experimental.shard_map`` (with a ``check_rep``
kwarg) before being promoted to ``jax.shard_map`` (where the kwarg became
``check_vma``). Engine code imports the wrapper below and always passes
``check_vma``; the shim renames it for older installs.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map"]

try:  # jax >= 0.6: top-level export
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if _HAS_CHECK_VMA:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
