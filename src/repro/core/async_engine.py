"""Compiled asynchronous distributed LCC engine (paper Alg. 3 + §III-A).

``shard_map`` over a device axis ``"dev"`` of size p. Each device owns a
1D partition; per round one ``all_to_all`` ships exactly the adjacency
rows the static pull schedule (``rma.build_sharded_problem``) resolved as
remote+uncached. The ``lax.fori_loop`` carries next-round rows so round
``r``'s intersection overlaps round ``r+1``'s fetch — the paper's double
buffering; on TPU the XLA latency-hiding scheduler turns that structural
overlap into DMA/compute overlap.

Compute per edge: gather row_u (local) and row_v (local | cache | fetch
buffer — one combined gather), count |row_u ∩ row_v| with the regime-split
intersection, and segment-accumulate into S(u). LCC follows Eq. (2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

from .intersect import count_bsearch_jnp, count_pairwise_jnp, tpu_regime_rule
from .rma import ShardedLCCProblem

__all__ = ["lcc_pipelined", "make_lcc_fn", "run_distributed_lcc"]


def _shard_body(
    rows_ext,  # [n_loc+1, W]
    degrees,  # [n_loc]
    edge_u,  # [E_max]
    edge_vc,  # [E_max]
    edge_mask,  # [E_max]
    serve_idx,  # [NR, p, S_max]
    cache_rows,  # [C, W]
    *,
    axis: str,
    n_rounds: int,
    e_chunk: int,
    sentinel: int,
    method: str,
):
    # shard_map keeps the sharded leading axis at local size 1 — squeeze it.
    rows_ext = rows_ext[0]
    degrees = degrees[0]
    edge_u = edge_u[0]
    edge_vc = edge_vc[0]
    edge_mask = edge_mask[0]
    serve_idx = serve_idx[0]
    n_loc_p1, w = rows_ext.shape
    n_loc = n_loc_p1 - 1
    p = jax.lax.psum(1, axis)
    s_max = serve_idx.shape[-1]

    def fetch(r):
        # rows this device serves in round r -> one a2a -> rows it needs
        to_send = rows_ext[serve_idx[r]]  # [p, S_max, W]
        got = jax.lax.all_to_all(
            to_send, axis, split_axis=0, concat_axis=0, tiled=False
        )
        return got.reshape(p * s_max, w)

    def count(rows_a, rows_b, deg_a, deg_b):
        if method == "bsearch":
            return count_bsearch_jnp(rows_a, rows_b, sentinel)
        if method == "pairwise":
            return count_pairwise_jnp(rows_a, rows_b, sentinel)
        # hybrid: regime select per edge (Eq. 3 analogue)
        use_pw = tpu_regime_rule(deg_a, deg_b, rows_b.shape[-1])
        return jnp.where(
            use_pw,
            count_pairwise_jnp(rows_a, rows_b, sentinel),
            count_bsearch_jnp(rows_a, rows_b, sentinel),
        )

    deg_ext = jnp.concatenate([degrees, jnp.zeros((1,), degrees.dtype)])

    def body(r, carry):
        fetched_cur, acc = carry
        # double buffering: issue next round's fetch before this round's
        # compute so the collective overlaps the intersection work.
        fetched_nxt = fetch(jnp.minimum(r + 1, n_rounds - 1))
        combined = jnp.concatenate([rows_ext, cache_rows, fetched_cur], 0)
        eu = jax.lax.dynamic_slice(edge_u, (r * e_chunk,), (e_chunk,))
        evc = jax.lax.dynamic_slice(edge_vc, (r * e_chunk,), (e_chunk,))
        msk = jax.lax.dynamic_slice(edge_mask, (r * e_chunk,), (e_chunk,))
        rows_a = rows_ext[eu]
        rows_b = combined[evc]
        deg_a = deg_ext[eu]
        deg_b = (rows_b < sentinel).sum(-1)
        cnt = count(rows_a, rows_b, deg_a, deg_b)
        acc = acc.at[eu].add(jnp.where(msk, cnt, 0))
        return fetched_nxt, acc

    acc0 = jnp.zeros((n_loc + 1,), jnp.int32)
    fetched0 = fetch(0)
    _, acc = jax.lax.fori_loop(0, n_rounds, body, (fetched0, acc0))
    s = acc[:n_loc]
    t = s // 2  # undirected: each neighbor-edge seen twice in S(i)
    deg = degrees.astype(jnp.float32)
    denom = deg * (deg - 1.0)
    lcc = jnp.where(denom > 0, 2.0 * t.astype(jnp.float32) / denom, 0.0)
    return t[None], lcc[None]


def make_lcc_fn(
    prob: ShardedLCCProblem,
    mesh: Mesh,
    *,
    axis: str = "dev",
    method: str = "bsearch",
):
    """jit-compiled distributed LCC over ``mesh`` (1-D, axis name ``axis``)."""
    e_chunk = prob.e_max // prob.n_rounds
    body = functools.partial(
        _shard_body,
        axis=axis,
        n_rounds=prob.n_rounds,
        e_chunk=e_chunk,
        sentinel=prob.sentinel,
        method=method,
    )
    sharded = P(axis)
    repl = P()
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(sharded, sharded, sharded, sharded, sharded, sharded, repl),
        out_specs=(sharded, sharded),
        check_vma=False,
    )
    return jax.jit(fn)


def lcc_pipelined(
    prob: ShardedLCCProblem,
    mesh: Optional[Mesh] = None,
    *,
    method: str = "bsearch",
):
    """Run the engine; returns (t_per_vertex [p, n_loc], lcc [p, n_loc])."""
    if mesh is None:
        devs = np.array(jax.devices()[: prob.p])
        assert devs.size == prob.p, (
            f"need {prob.p} devices, have {len(jax.devices())}"
        )
        mesh = Mesh(devs, ("dev",))
    fn = make_lcc_fn(prob, mesh, method=method)
    t, lcc = fn(
        jnp.asarray(prob.rows_ext),
        jnp.asarray(prob.degrees),
        jnp.asarray(prob.edge_u),
        jnp.asarray(prob.edge_vc),
        jnp.asarray(prob.edge_mask),
        jnp.asarray(prob.serve_idx),
        jnp.asarray(prob.cache_rows),
    )
    return np.asarray(t), np.asarray(lcc)


def run_distributed_lcc(
    csr,
    p: int,
    *,
    n_rounds: int = 4,
    cache_rows: int = 0,
    method: str = "bsearch",
    mesh: Optional[Mesh] = None,
):
    """End-to-end: partition + schedule + compiled engine -> (t, lcc) global."""
    from .cache import build_static_degree_cache
    from .rma import build_sharded_problem

    cache = (
        build_static_degree_cache(csr.degrees, cache_rows)
        if cache_rows > 0
        else None
    )
    prob = build_sharded_problem(csr, p, n_rounds=n_rounds, cache=cache)
    t, lcc = lcc_pipelined(prob, mesh, method=method)
    # unstack device-padded rows back to global vertex order
    t_g = np.zeros(csr.n, np.int64)
    lcc_g = np.zeros(csr.n, np.float64)
    from .partition import partition_1d

    part = partition_1d(csr.n, p)
    for k in range(p):
        lo, hi = part.lo(k), part.hi(k)
        t_g[lo:hi] = t[k, : hi - lo]
        lcc_g[lo:hi] = lcc[k, : hi - lo]
    return t_g, lcc_g
