"""CLaMPI-style RMA cache (paper §II-F) + application-defined scores (§III-B2).

Two components:

1. ``ClampiCache`` — a faithful host-side simulator of the CLaMPI caching
   layer: hash-table-indexed variable-size entries in a bounded memory
   buffer with a free-list (the AVL tree of the real system is modeled as a
   sorted interval list — same first-fit semantics), external-fragmentation-
   aware victim selection (LRU weighted by a positional score), optional
   application-defined scores (the paper's extension: degree centrality),
   always-cache/transparent/user modes, and the adaptive table-resize
   heuristic (which flushes on resize, as in the paper). It reports the
   hit/miss/compulsory/eviction statistics and the modeled communication
   time ``t(s) = alpha + s * beta`` (§IV-D1) that the Fig. 7/8 benchmarks
   plot.

2. ``StaticDegreeCache`` — the TPU-native realization: because degree is
   known before the epoch and the paper's own Observations 3.1/3.2 say
   degree predicts reuse, the optimal degree-scored working set can be
   *precomputed*: the top-C highest-in-degree non-local vertices are made
   cache-resident per device before the compute loop. This is what the
   compiled shard_map engine consumes (static shapes — no data-dependent
   eviction inside the XLA program). The dynamic simulator above is used
   offline to pick C and to reproduce the paper's cache science.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs import cachescope as obs_cachescope
from ..obs import trace as obs_trace

__all__ = [
    "NetworkModel",
    "CacheStats",
    "merge_cache_stats",
    "ClampiCache",
    "StaticDegreeCache",
    "build_static_degree_cache",
    "StaticCacheRefresh",
    "refresh_static_degree_cache",
]


@dataclasses.dataclass
class NetworkModel:
    """Remote-read cost model t(s) = alpha + s*beta (paper §IV-D1).

    Defaults approximate a Cray Aries put/get: ~2 us setup, ~10 GB/s/link
    effective per-get streaming; the cache-hit path costs a hash probe.
    """

    alpha: float = 2.0e-6
    beta: float = 1.0e-10
    hit_cost: float = 5.0e-8
    insert_cost: float = 1.0e-7

    def remote(self, size_bytes: float) -> float:
        return self.alpha + size_bytes * self.beta


@dataclasses.dataclass
class CacheStats:
    gets: int = 0
    hits: int = 0
    misses: int = 0
    compulsory_misses: int = 0
    evictions: int = 0
    flushes: int = 0
    invalidations: int = 0  # coherence: entries dropped because stale
    bytes_hit: int = 0
    bytes_missed: int = 0
    # bytes of evicted entries later re-referenced: the live byte-
    # denominated "premature eviction" counter (cachescope audits the
    # access-window version offline)
    bytes_evicted_live: int = 0
    comm_time: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.gets if self.gets else 0.0

    @property
    def miss_rate(self) -> float:
        return 1.0 - self.hit_rate if self.gets else 0.0


def merge_counter_dataclasses(cls, items):
    """Field-wise sum over flat numeric-counter dataclasses (per-rank
    statistics aggregation). Enumerates ``dataclasses.fields`` so a new
    counter can never be silently dropped from an aggregate. Dict-valued
    fields (per-tenant counters) merge key-wise."""
    out = cls()
    for s in items:
        for f in dataclasses.fields(cls):
            cur, add = getattr(out, f.name), getattr(s, f.name)
            if isinstance(cur, dict):
                for k, v in add.items():
                    cur[k] = cur.get(k, 0) + v
            else:
                setattr(out, f.name, cur + add)
    return out


def merge_cache_stats(stats: List["CacheStats"]) -> CacheStats:
    """Aggregated view over per-rank cache statistics."""
    return merge_counter_dataclasses(CacheStats, stats)


@dataclasses.dataclass
class _Entry:
    key: int
    addr: int
    size: int
    last_use: int
    score: Optional[float]  # application-defined; None => LRU+positional
    # multi-tenant serving: who fetched this row first (quota-aware
    # eviction keys on it). Must stay LAST with a default — cachescope's
    # replay preload constructs _Entry positionally without it.
    tenant: str = ""


class ClampiCache:
    """Simulator of the CLaMPI RMA caching layer.

    mode: 'always' (read-only data, never flushed between epochs — the
    paper's configuration for LCC), 'transparent' (flush at epoch close),
    'user' (explicit ``flush()``).
    """

    # offline-replay caches set this True on the instance so an active
    # cachescope recorder never re-records a replay of its own trace
    _scope_exempt = False

    def __init__(
        self,
        capacity_bytes: int,
        table_slots: int,
        *,
        mode: str = "always",
        positional_weight: float = 0.5,
        adaptive: bool = False,
        network: Optional[NetworkModel] = None,
    ):
        assert mode in ("always", "transparent", "user")
        self.capacity = int(capacity_bytes)
        self.table_slots = int(table_slots)
        self.mode = mode
        self.positional_weight = positional_weight
        self.adaptive = adaptive
        self.net = network or NetworkModel()
        self.entries: Dict[int, _Entry] = {}
        self.free: List[Tuple[int, int]] = [(0, self.capacity)]  # (addr, size)
        self.clock = 0
        self.stats = CacheStats()
        self._seen: set[int] = set()
        self._conflicts = 0
        self._evicted_sizes: Dict[int, int] = {}  # victim key -> size
        # multi-tenant byte reservations: tenant -> fraction of capacity.
        # Empty (default) = tenancy off, every path bit-identical to the
        # single-tenant cache. NOTE: tenant-share eviction consults state
        # a recorded access trace does not carry, so runs with shares
        # active must not assert cachescope's deployed-replay invariant
        # (see docs/serving.md).
        self.tenant_shares: Dict[str, float] = {}

    # ---------------- multi-tenant accounting ----------------
    def set_tenant_shares(self, shares: Dict[str, float]) -> None:
        """Install per-tenant byte-share fractions (hard caps for tagged
        tenants; untagged traffic is best-effort in the remainder)."""
        assert all(0.0 < v <= 1.0 for v in shares.values())
        assert sum(shares.values()) <= 1.0 + 1e-9, "shares oversubscribed"
        self.tenant_shares = dict(shares)

    def tenant_bytes(self) -> Dict[str, int]:
        """Resident bytes per tenant ("" = untagged). Computed from the
        entry table so it can never drift from ``used_bytes``: the two
        sum identically by construction."""
        out: Dict[str, int] = {}
        for e in self.entries.values():
            out[e.tenant] = out.get(e.tenant, 0) + e.size
        return out

    def _share_cap(self, tenant: str) -> Optional[float]:
        if not tenant or not self.tenant_shares:
            return None
        share = self.tenant_shares.get(tenant)
        return None if share is None else share * self.capacity

    # ---------------- memory buffer management ----------------
    def _alloc(self, size: int) -> Optional[int]:
        """First-fit allocation from the free interval list."""
        for i, (addr, sz) in enumerate(self.free):
            if sz >= size:
                if sz == size:
                    self.free.pop(i)
                else:
                    self.free[i] = (addr + size, sz - size)
                return addr
        return None

    def _dealloc(self, addr: int, size: int) -> None:
        """Insert + coalesce (what the AVL free tree does in CLaMPI)."""
        self.free.append((addr, size))
        self.free.sort()
        merged: List[Tuple[int, int]] = []
        for a, s in self.free:
            if merged and merged[-1][0] + merged[-1][1] == a:
                merged[-1] = (merged[-1][0], merged[-1][1] + s)
            else:
                merged.append((a, s))
        self.free = merged

    def _positional_bonus(self, e: _Entry) -> float:
        """How much contiguous free space removing ``e`` would create,
        normalized by entry size — CLaMPI's anti-fragmentation score."""
        gain = e.size
        for a, s in self.free:
            if a + s == e.addr or e.addr + e.size == a:
                gain += s
        return gain / max(e.size, 1)

    # ---------------- victim selection ----------------
    def _select_victim(self, entries: Optional[List[_Entry]] = None) -> _Entry:
        if entries is None:
            entries = list(self.entries.values())
        has_user = any(e.score is not None for e in entries)
        if has_user:
            # paper §III-B2: application score dominates; positional/spatial
            # effect intentionally lost. Tie-break by LRU.
            return min(
                entries,
                key=lambda e: (
                    e.score if e.score is not None else float("inf"),
                    e.last_use,
                ),
            )
        # default: LRU weighted by positional (fragmentation) bonus
        return max(
            entries,
            key=lambda e: (self.clock - e.last_use)
            * (1.0 + self.positional_weight * self._positional_bonus(e)),
        )

    # ---------------- public API ----------------
    def get(self, key: int, size: int, *, score: Optional[float] = None,
            tenant: str = "") -> bool:
        """One RMA get of ``size`` bytes for entry ``key``.

        Returns True on hit. On miss, models the remote read and tries to
        cache the entry (CLaMPI caches a missing entry only if resources
        allow after eviction attempts). ``tenant`` tags the entry for
        quota-aware eviction; a hit keeps the original owner tag
        (first-fetcher semantics — shared rows stay charged to whoever
        brought them in).
        """
        rec = obs_cachescope._recorder  # one load + None check when off
        if rec is not None:
            # register the stream BEFORE any stat/clock mutation so the
            # baseline snapshot excludes this very access
            rec.touch(self)
        self.clock += 1
        st = self.stats
        st.gets += 1
        e = self.entries.get(key)
        if e is not None:
            e.last_use = self.clock
            if score is not None:
                e.score = score
            st.hits += 1
            st.bytes_hit += size
            st.comm_time += self.net.hit_cost
            if rec is not None:
                rec.on_get(self, key, size, score, True)
            return True
        st.misses += 1
        if key not in self._seen:
            st.compulsory_misses += 1
            self._seen.add(key)
        prev = self._evicted_sizes.pop(key, None)
        if prev is not None:
            st.bytes_evicted_live += prev
        st.bytes_missed += size
        st.comm_time += self.net.remote(size)
        if rec is not None:
            rec.on_get(self, key, size, score, False)
        self._insert(key, size, score, tenant)
        if self.adaptive:
            self._maybe_resize()
        return False

    def _insert(self, key: int, size: int, score: Optional[float],
                tenant: str = "") -> None:
        if size > self.capacity:
            return
        cap = self._share_cap(tenant)
        if cap is not None:
            if size > cap:
                return  # one entry larger than the tenant's whole share
            # evict-own-first: a tenant over its reservation reclaims
            # from itself before touching shared space — the isolation
            # contract. Refusal (own victims all score higher) means the
            # incoming entry loses to the tenant's own working set.
            while self.tenant_bytes().get(tenant, 0) + size > cap:
                own = [e for e in self.entries.values()
                       if e.tenant == tenant]
                if not own or not self._evict_one(
                    need_better_than=score, candidates=own
                ):
                    return
        # victim loop: evict while out of table slots or buffer space
        while True:
            if len(self.entries) >= self.table_slots:
                self._evict_one(need_better_than=score, requester=tenant)
                if len(self.entries) >= self.table_slots:
                    return  # refused (new entry scored lower than victims)
                continue
            addr = self._alloc(size)
            if addr is not None:
                self.entries[key] = _Entry(key, addr, size, self.clock,
                                           score, tenant)
                self.stats.comm_time += self.net.insert_cost
                if obs_trace.fine_enabled():  # per-entry; fine mode only
                    obs_trace.instant("cache_admit", cat="cache",
                                      key=key, bytes=size)
                return
            if not self.entries:
                return
            if not self._evict_one(need_better_than=score, requester=tenant):
                return

    def _quota_candidates(self, requester: str) -> List[_Entry]:
        """Victim pool under tenancy: the requester's own entries,
        untagged entries, and tenants at-or-over their reserved share.
        Tenants strictly *under* their share are spared — that working
        set is exactly what the reservation protects. Falls back to
        everything when the protected set is the whole cache."""
        if not self.tenant_shares:
            return list(self.entries.values())
        tb = self.tenant_bytes()
        under = {
            t for t, share in self.tenant_shares.items()
            if tb.get(t, 0) < share * self.capacity
        }
        pool = [e for e in self.entries.values()
                if e.tenant == requester or e.tenant not in under]
        return pool if pool else list(self.entries.values())

    def _evict_one(self, need_better_than: Optional[float] = None,
                   requester: str = "",
                   candidates: Optional[List[_Entry]] = None) -> bool:
        if not self.entries:
            return False
        if candidates is None:
            candidates = self._quota_candidates(requester)
        if not candidates:
            return False
        v = self._select_victim(candidates)
        if (
            need_better_than is not None
            and v.score is not None
            and v.score >= need_better_than
        ):
            return False  # incoming entry is less valuable than every victim
        del self.entries[v.key]
        self._dealloc(v.addr, v.size)
        self.stats.evictions += 1
        self._evicted_sizes[v.key] = v.size
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_evict(self, v.key, v.size, v.score)
        if obs_trace.fine_enabled():  # per-entry; fine mode only
            obs_trace.instant("cache_evict", cat="cache",
                              key=v.key, bytes=v.size)
        return True

    def _maybe_resize(self) -> None:
        """Adaptive heuristic (§II-F): grow the table when slot conflicts
        dominate; flushes the cache — so good initial values matter
        (§III-B1), which the Fig. 7 benchmark demonstrates."""
        st = self.stats
        if (
            len(self.entries) >= self.table_slots
            and st.evictions > 4 * self.table_slots
        ):
            self.table_slots *= 2
            self._flush_internal()

    def invalidate(self, key: int) -> bool:
        """Coherence hook: drop ``key`` because its backing data changed
        (streaming updates mutate adjacency rows in place). Unlike an
        eviction this is a *correctness* removal — the next get is a miss
        that refetches fresh data. Returns True if an entry was dropped."""
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_invalidate(self, key)
        e = self.entries.pop(key, None)
        if e is None:
            # data changed for an already-evicted key: its next miss is
            # a correctness refetch, not a premature-eviction signal
            self._evicted_sizes.pop(key, None)
            return False
        self._dealloc(e.addr, e.size)
        self.stats.invalidations += 1
        return True

    def invalidate_many(self, keys) -> int:
        """Batch coherence hook (one streaming update batch mutates many
        rows). Returns the number of entries dropped."""
        return sum(self.invalidate(int(k)) for k in keys)

    def contains(self, key: int) -> bool:
        """Residency probe without touching LRU/statistics — lets a
        payload-carrying layer (serving row provider) mirror this cache's
        admission/eviction decisions."""
        return key in self.entries

    def _flush_internal(self) -> None:
        """Flush without recording a trace event — used by paths the
        cache triggers itself (adaptive resize, transparent epoch close),
        which an offline replay regenerates deterministically."""
        self.entries.clear()
        self.free = [(0, self.capacity)]
        self.stats.flushes += 1
        self._evicted_sizes.clear()

    def flush(self) -> None:
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_flush(self)
        self._flush_internal()

    def close_epoch(self) -> None:
        rec = obs_cachescope._recorder
        if rec is not None:
            rec.on_close_epoch(self)
        if self.mode == "transparent":
            self._flush_internal()

    @property
    def used_bytes(self) -> int:
        return sum(e.size for e in self.entries.values())


# --------------------------------------------------------------------------
# Static degree-scored cache (device-side realization).
# --------------------------------------------------------------------------
@dataclasses.dataclass
class StaticDegreeCache:
    """Precomputed cache residency: the top-C in-degree non-local vertices.

    vertex_ids:  [C] global ids resident in every device's cache (sorted)
    capacity_rows: C
    The engine stores the corresponding padded rows replicated per device;
    lookup is a host-side precomputation (each edge's remote endpoint maps
    to a cache slot or -1), so the compiled program does plain gathers.
    """

    vertex_ids: np.ndarray

    @property
    def capacity_rows(self) -> int:
        return int(self.vertex_ids.shape[0])

    def slot_of(self, v: np.ndarray) -> np.ndarray:
        """Cache slot per vertex id (-1 if not resident). Vectorized."""
        v = np.asarray(v, np.int64)
        if self.capacity_rows == 0:
            return np.full(v.shape, -1, np.int32)
        idx = np.searchsorted(self.vertex_ids, v)
        idx = np.minimum(idx, self.capacity_rows - 1)
        ok = self.vertex_ids[idx] == v
        return np.where(ok, idx, -1).astype(np.int32)


def build_static_degree_cache(
    degrees: np.ndarray,
    capacity_rows: int,
    *,
    score_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> StaticDegreeCache:
    """Pick cache residents by score (default: degree centrality, §III-B2)."""
    n = degrees.shape[0]
    c = min(capacity_rows, n)
    score = degrees if score_fn is None else score_fn(degrees)
    if c <= 0:
        return StaticDegreeCache(vertex_ids=np.zeros((0,), np.int64))
    # stable tie-break by vertex id: equal-score residency must not
    # reshuffle between calls, or streaming rescores would count tie
    # noise as drift (power-law graphs have large tie classes).
    order = np.lexsort((np.arange(n), score))
    top = order[n - c :]
    return StaticDegreeCache(vertex_ids=np.sort(top.astype(np.int64)))


# --------------------------------------------------------------------------
# Streaming coherence for the static cache.
# --------------------------------------------------------------------------
@dataclasses.dataclass
class StaticCacheRefresh:
    """Outcome of rescoring a ``StaticDegreeCache`` after updates.

    stale_ids:   resident vertices whose adjacency changed — their cached
                 rows must be refetched regardless of ranking (correctness).
    evicted:     residents that fell out of the top-C by degree score.
    admitted:    vertices newly promoted into the top-C.
    rebuilt:     whether a new resident set was installed.
    """

    cache: StaticDegreeCache
    stale_ids: np.ndarray
    evicted: int
    admitted: int
    rebuilt: bool

    @property
    def stale_rows(self) -> int:
        return int(self.stale_ids.shape[0])


def refresh_static_degree_cache(
    cache: StaticDegreeCache,
    degrees: np.ndarray,
    changed_ids: np.ndarray,
    *,
    rebuild_fraction: float = 0.0,
    score_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> StaticCacheRefresh:
    """Rescore/invalidate cache residency after degrees changed.

    The paper's Observations 3.1/3.2 motivate degree as the residency
    score; once edges stream in, the score *drifts*. Residents whose
    adjacency changed are stale (rows must be refreshed in place); when
    the drift in the top-C membership exceeds ``rebuild_fraction`` of
    capacity, the resident set itself is rebuilt from current degrees.

    The full O(n log n) rescoring pass is skipped when no membership
    change is possible: no resident changed and every changed outsider
    still scores below the weakest resident — the common case for small
    batches, keeping per-batch cost proportional to the delta.
    """
    changed = np.asarray(changed_ids, np.int64)
    resident_mask = cache.slot_of(changed) >= 0
    stale_ids = changed[resident_mask]
    c = cache.capacity_rows
    if c == 0 or changed.size == 0:
        return StaticCacheRefresh(cache, stale_ids, 0, 0, False)
    score = np.asarray(degrees) if score_fn is None else score_fn(degrees)
    if stale_ids.size == 0:
        outsiders = changed[~resident_mask]
        if score[outsiders].max() < score[cache.vertex_ids].min():
            return StaticCacheRefresh(cache, stale_ids, 0, 0, False)
    fresh = build_static_degree_cache(degrees, c, score_fn=score_fn)
    drift = np.setdiff1d(cache.vertex_ids, fresh.vertex_ids, assume_unique=True)
    if drift.size and drift.size >= rebuild_fraction * c:
        admitted = np.setdiff1d(
            fresh.vertex_ids, cache.vertex_ids, assume_unique=True
        )
        return StaticCacheRefresh(
            fresh, stale_ids, int(drift.size), int(admitted.size), True
        )
    return StaticCacheRefresh(cache, stale_ids, 0, 0, False)
