"""CSR graph representation (paper §II-B).

The paper stores each process's partition as two arrays, ``offsets`` and
``adjacencies`` (Fig. 2). We keep the same two-array format host-side
(numpy, exact) and provide padded device layouts for the JAX engines.

Conventions
-----------
- vertices are ``int32`` ids in ``[0, n)``; the sentinel id ``n`` pads rows
  (it sorts *after* every real id, so padded rows stay sorted).
- adjacency rows are sorted ascending, deduplicated, loop-free.
- undirected graphs store both directions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "CSRGraph",
    "from_edges",
    "remove_low_degree",
    "random_relabel",
    "to_padded_rows",
    "rows_to_bitmap_words",
]


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR graph. ``offsets`` has length ``n + 1``."""

    offsets: np.ndarray  # int64 [n+1]
    adjacencies: np.ndarray  # int32 [m]
    n: int

    @property
    def m(self) -> int:
        return int(self.adjacencies.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int64)

    @property
    def max_degree(self) -> int:
        d = self.degrees
        return int(d.max()) if d.size else 0

    def row(self, v: int) -> np.ndarray:
        return self.adjacencies[self.offsets[v] : self.offsets[v + 1]]

    def csr_nbytes(self) -> int:
        """Size of the CSR representation (paper Table II reports this)."""
        return self.offsets.nbytes + self.adjacencies.nbytes

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) arrays, one entry per stored (directed) edge."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.adjacencies.astype(np.int32)


def from_edges(
    edges: np.ndarray, n: int, *, undirected: bool = True
) -> CSRGraph:
    """Build a CSR graph from an ``[E, 2]`` edge array.

    Self-loops are dropped and multi-edges deduplicated (paper §II-A
    considers simple graphs). For ``undirected`` both directions are stored.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    mask = edges[:, 0] != edges[:, 1]
    edges = edges[mask]
    if undirected and edges.size:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    if edges.size:
        # dedup via linearized key
        key = edges[:, 0] * n + edges[:, 1]
        key = np.unique(key)
        src = (key // n).astype(np.int64)
        dst = (key % n).astype(np.int32)
    else:
        src = np.zeros((0,), np.int64)
        dst = np.zeros((0,), np.int32)
    counts = np.bincount(src, minlength=n).astype(np.int64)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    # unique(key) is sorted, so rows come out sorted ascending.
    return CSRGraph(offsets=offsets, adjacencies=dst, n=n)


def remove_low_degree(csr: CSRGraph) -> Tuple[CSRGraph, np.ndarray]:
    """Drop vertices with degree < 2 (paper §II-B: they close no triangle).

    Single pass, as in the paper (not an iterative 2-core). Returns the
    filtered graph and ``keep_ids`` mapping new ids -> old ids.
    """
    deg = csr.degrees
    keep = np.flatnonzero(deg >= 2)
    if keep.size == csr.n:
        return csr, np.arange(csr.n, dtype=np.int64)
    old_to_new = np.full(csr.n + 1, -1, np.int64)
    old_to_new[keep] = np.arange(keep.size)
    rows = []
    for v in keep:
        r = old_to_new[csr.row(v)]
        rows.append(r[r >= 0])
    counts = np.array([r.size for r in rows], np.int64)
    offsets = np.zeros(keep.size + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    adj = (
        np.concatenate(rows).astype(np.int32)
        if rows
        else np.zeros((0,), np.int32)
    )
    out = CSRGraph(offsets=offsets, adjacencies=adj, n=int(keep.size))
    return out, keep.astype(np.int64)


def random_relabel(csr: CSRGraph, seed: int = 0) -> CSRGraph:
    """Random permutation of vertex ids (paper §II-B: avoids assigning all
    high-degree vertices of a degree-ordered input to one process)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(csr.n).astype(np.int64)  # old -> new
    inv = np.empty_like(perm)
    inv[perm] = np.arange(csr.n)
    counts = csr.degrees[inv]
    offsets = np.zeros(csr.n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    adj = np.empty(csr.m, np.int32)
    for new_v in range(csr.n):
        old_v = inv[new_v]
        r = perm[csr.row(old_v)]
        r.sort()
        adj[offsets[new_v] : offsets[new_v + 1]] = r
    return CSRGraph(offsets=offsets, adjacencies=adj, n=csr.n)


def to_padded_rows(
    csr: CSRGraph,
    width: Optional[int] = None,
    *,
    sentinel: Optional[int] = None,
    vertices: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Padded ``[n, width]`` row matrix, rows sorted, padded with sentinel.

    The sentinel defaults to ``n`` so padded rows remain sorted and
    searchsorted/membership tests never match padding.
    """
    width = int(width if width is not None else max(csr.max_degree, 1))
    sent = int(csr.n if sentinel is None else sentinel)
    vs = (
        np.arange(csr.n, dtype=np.int64)
        if vertices is None
        else np.asarray(vertices, np.int64)
    )
    out = np.full((vs.size, width), sent, np.int32)
    for i, v in enumerate(vs):
        r = csr.row(int(v))[:width]
        out[i, : r.size] = r
    return out


def rows_to_bitmap_words(
    rows: np.ndarray, n_bits: int, *, lo: int = 0
) -> np.ndarray:
    """Pack padded sorted rows into uint32 bitmap words over [lo, lo+n_bits).

    Elements outside the range (including sentinel padding) are dropped.
    Returns ``[rows.shape[0], ceil(n_bits/32)]`` uint32.
    """
    rows = np.asarray(rows)
    e, _ = rows.shape
    n_words = (n_bits + 31) // 32
    out = np.zeros((e, n_words), np.uint32)
    rel = rows.astype(np.int64) - lo
    valid = (rel >= 0) & (rel < n_bits)
    ei, si = np.nonzero(valid)
    bit = rel[ei, si]
    np.bitwise_or.at(out, (ei, bit // 32), (np.uint32(1) << (bit % 32).astype(np.uint32)))
    return out
