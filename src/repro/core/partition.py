"""Vertex partitioning: the ownership contract every consumer shares.

Two partition families live here, both exposing the SAME contract (see
docs/partitioning.md for the canonical statement):

- ``owner(v)`` — vectorized owner rank per vertex id;
- ``lo(k)`` / ``hi(k)`` — rank ``k`` owns exactly the contiguous block
  ``[lo(k), hi(k))``; blocks tile ``[0, n)`` in rank order with no gaps
  (``hi(k) == lo(k + 1)``), so ``owner(v) == k  iff  lo(k) <= v < hi(k)``;
- ``sizes()`` — per-rank block sizes, ``sizes()[k] == hi(k) - lo(k)``;
- ``block`` — an upper bound on every rank's block size (consumers size
  dense per-rank buffers with it);
- ``route(v)`` — the rank that should *execute* work keyed by ``v``
  (query routing, worklist sharding by initiator). For ``Partition1D``
  this is always ``owner(v)``; a ``HubPartition`` spreads hub-keyed
  work round-robin so a hot hub does not pin one rank.

``Partition1D`` is the paper's §III-A scheme: ``V_k = { v_i : i in
((k-1)n/p, k*n/p] }`` — contiguous equal-size blocks, generalized to
``p`` not dividing ``n`` with ceil-sized blocks so the owner function
stays a closed form (needed device-side).

``HubPartition`` breaks the 1D scaling wall on scale-free graphs
(ROADMAP item 2) with the two remedies the related work names
(Sanders & Uhl, arXiv 2302.11443; Tom & Karypis, arXiv 1907.09575):

1. **balance-aware cuts** — block boundaries come from degree-weighted
   prefix sums instead of equal vertex counts, so per-rank *work*
   (edges, not vertices) balances;
2. **hub splitting** — rows with degree >= ``threshold`` are additionally
   sharded into ``p`` per-rank *fragments*: fragment ``k`` of a sorted
   row of degree ``d`` is the contiguous slice
   ``row[d*k//p : d*(k+1)//p]``. Fragments are disjoint and concatenate
   in rank order back to the original sorted row, so any intersection
   against a fragmented row reduces deterministically over fragment
   counts: ``|A ∩ B| = sum_k |A ∩ frag_k(B)|`` (integer, order-free).
   Remote readers gather a hub row as ``p - 1`` remote fragments plus
   their own local fragment instead of one whole-row get from a single
   owner — the serve load of a hot hub spreads evenly over all ranks.

Ownership stays contiguous either way, so ``local_block`` slicing, the
static schedule's ``[lo, hi)`` worklists, and the device tier's
per-rank exclusion ranges work unchanged on both families.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .csr import CSRGraph

__all__ = [
    "Partition1D",
    "HubPartition",
    "partition_1d",
    "partition_hub",
    "default_hub_threshold",
    "balanced_cuts",
    "local_block",
]


@dataclasses.dataclass
class Partition1D:
    """Contiguous ceil-sized blocks (paper §III-A).

    Contract invariants (shared with ``HubPartition``):
    ``owner(v) == k  iff  lo(k) <= v < hi(k)``; blocks tile ``[0, n)``
    in rank order; ``sizes()[k] == hi(k) - lo(k) <= block``.
    """

    n: int
    p: int

    @property
    def block(self) -> int:
        """Upper bound on any rank's block size (here: the exact size of
        every non-trailing block)."""
        return -(-self.n // self.p)  # ceil

    def owner(self, v):
        """Owner process of vertex v (vectorized)."""
        return np.minimum(
            np.asarray(v, np.int64) // self.block, self.p - 1
        ).astype(np.int32)

    def route(self, v) -> int:
        """Executing rank for work keyed by ``v`` — for 1D always the
        owner (scalar)."""
        return int(self.owner(int(v)))

    def lo(self, k: int) -> int:
        return min(k * self.block, self.n)

    def hi(self, k: int) -> int:
        return min((k + 1) * self.block, self.n)

    def sizes(self) -> np.ndarray:
        return np.array(
            [self.hi(k) - self.lo(k) for k in range(self.p)], np.int64
        )

    @property
    def has_hubs(self) -> bool:
        return False


def partition_1d(n: int, p: int) -> Partition1D:
    return Partition1D(n=n, p=p)


def default_hub_threshold(degrees: np.ndarray) -> int:
    """Degree above which a row counts as a hub: 4x the mean degree
    (at least 2). On flat-degree graphs nothing crosses it and the
    partition degenerates to balance-aware 1D; on scale-free graphs it
    captures the heavy tail that dominates serve traffic."""
    degrees = np.asarray(degrees)
    if degrees.size == 0:
        return 2
    return max(2, int(np.ceil(4.0 * float(degrees.mean()))))


def balanced_cuts(
    weights: np.ndarray, p: int
) -> np.ndarray:
    """Contiguous cut points ``[p + 1]`` splitting ``weights`` into p
    blocks of near-equal weight sum (``cuts[0] == 0``,
    ``cuts[p] == len(weights)``, non-decreasing). Deterministic:
    boundary k lands at the first prefix position reaching
    ``k/p`` of the total weight."""
    w = np.asarray(weights, np.float64)
    n = w.size
    prefix = np.concatenate([[0.0], np.cumsum(w)])
    total = prefix[-1]
    if n == 0 or total <= 0:
        # degenerate: equal vertex counts (matches 1D for empty graphs)
        return np.minimum(
            np.arange(p + 1, dtype=np.int64) * (-(-n // max(p, 1))), n
        )
    targets = np.arange(1, p, dtype=np.float64) * (total / p)
    interior = np.searchsorted(prefix, targets, side="left")
    cuts = np.concatenate([[0], interior, [n]]).astype(np.int64)
    return np.maximum.accumulate(np.clip(cuts, 0, n))


@dataclasses.dataclass
class HubPartition:
    """Balance-aware contiguous ownership + degree-threshold hub
    splitting. Satisfies the same ``owner()/lo()/hi()/sizes()/block``
    contract as ``Partition1D`` (see the module docstring), with two
    additions:

    - ``hubs`` (sorted ids, degree >= ``threshold`` at build time) are
      transport-fragmented: every rank serves fragment
      ``row[d*k//p : d*(k+1)//p]`` of each hub row, so a remote hub
      read gathers fragments from all ranks instead of hammering the
      single owner (``fragment`` / ``fragment_sizes`` define the split;
      the reduction over fragment counts is a plain integer sum);
    - ``route(v)`` spreads hub-keyed work round-robin by hub position,
      so hot queries stop pinning the hub's home rank.

    ``cuts`` is mutable *in place* on purpose: the online migration path
    (``core.repartition``) moves boundaries while every consumer keeps
    holding this same object — ``owner()`` answers change atomically for
    all of them.
    """

    n: int
    p: int
    cuts: np.ndarray  # [p + 1] int64, cuts[0] == 0, cuts[p] == n
    hubs: np.ndarray  # sorted int64 hub vertex ids
    threshold: int

    def __post_init__(self):
        self.cuts = np.asarray(self.cuts, np.int64)
        self.hubs = np.asarray(self.hubs, np.int64)
        assert self.cuts.shape == (self.p + 1,), self.cuts.shape
        assert self.cuts[0] == 0 and self.cuts[-1] == self.n
        assert bool(np.all(np.diff(self.cuts) >= 0)), "cuts must ascend"

    @property
    def block(self) -> int:
        """Upper bound on any rank's block size (the largest block)."""
        return int(np.max(np.diff(self.cuts), initial=0))

    def owner(self, v):
        """Owner process of vertex v (vectorized): the rank whose
        ``[lo, hi)`` block contains it."""
        idx = np.searchsorted(self.cuts, np.asarray(v, np.int64),
                              side="right") - 1
        return np.clip(idx, 0, self.p - 1).astype(np.int32)

    def lo(self, k: int) -> int:
        return int(self.cuts[k])

    def hi(self, k: int) -> int:
        return int(self.cuts[k + 1])

    def sizes(self) -> np.ndarray:
        return np.diff(self.cuts).astype(np.int64)

    # ---------------- hub splitting ----------------
    @property
    def has_hubs(self) -> bool:
        return self.hubs.size > 0

    def is_hub(self, v) -> np.ndarray:
        """Vectorized membership in the hub set."""
        v = np.asarray(v, np.int64)
        if self.hubs.size == 0:
            return np.zeros(v.shape, bool)
        idx = np.minimum(
            np.searchsorted(self.hubs, v), self.hubs.size - 1
        )
        return self.hubs[idx] == v

    def route(self, v) -> int:
        """Executing rank for work keyed by ``v`` (scalar): hubs spread
        round-robin by hub position, everything else runs at its
        owner. Routing never changes answers — any rank can read any
        row through the transport — only where the read-side load
        lands."""
        v = int(v)
        i = int(np.searchsorted(self.hubs, v))
        if i < self.hubs.size and int(self.hubs[i]) == v:
            return i % self.p
        return int(self.owner(v))

    def fragment_bounds(self, d: int, k: int) -> Tuple[int, int]:
        """Slice bounds of rank ``k``'s fragment of a row of degree
        ``d``: ``[d*k//p, d*(k+1)//p)``. Fragments are disjoint,
        contiguous, and concatenate in rank order to the full row."""
        return d * k // self.p, d * (k + 1) // self.p

    def fragment(self, row: np.ndarray, k: int) -> np.ndarray:
        a, b = self.fragment_bounds(int(row.size), k)
        return row[a:b]

    def fragment_sizes(self, d: int) -> np.ndarray:
        """Per-rank fragment sizes for a row of degree ``d`` (sums to
        ``d``; the deterministic split both the transport model and the
        SPMD collective charge from)."""
        edges = (int(d) * np.arange(self.p + 1, dtype=np.int64)) // self.p
        return np.diff(edges)

    def refresh_hubs(
        self, degrees: np.ndarray, *, threshold: Optional[int] = None
    ) -> int:
        """Recompute the hub set (and, with ``threshold=None``, the
        threshold itself) against a drifted degree sequence; returns the
        new hub count. Batch-boundary only, like ``cuts`` mutation — but
        always *safe*: hub membership only changes when the row's degree
        changed, and every row mutation already invalidates cached
        copies on both tiers, while fragments of an unchanged row are
        byte-identical under the same ``p``."""
        degrees = np.asarray(degrees, np.int64)
        assert degrees.size == self.n, (degrees.size, self.n)
        if threshold is None:
            threshold = default_hub_threshold(degrees)
        self.threshold = int(threshold)
        self.hubs = np.flatnonzero(
            degrees >= self.threshold
        ).astype(np.int64)
        return int(self.hubs.size)


def partition_hub(
    degrees: np.ndarray,
    p: int,
    *,
    threshold: Optional[int] = None,
) -> HubPartition:
    """Build a hub-aware partition from the current degree sequence.

    Cut boundaries balance the degree-*weighted* prefix (weight
    ``1 + min(deg, threshold)``): a hub's serve cost above the threshold
    is spread over all ranks by fragmentation, so only the clipped part
    loads its home rank — charging the full degree would starve hub-
    heavy ranks of vertices for no balance gain."""
    degrees = np.asarray(degrees, np.int64)
    n = int(degrees.size)
    p = int(p)
    if threshold is None:
        threshold = default_hub_threshold(degrees)
    threshold = int(threshold)
    hubs = np.flatnonzero(degrees >= threshold).astype(np.int64)
    weights = 1 + np.minimum(degrees, threshold)
    cuts = balanced_cuts(weights, p)
    return HubPartition(
        n=n, p=p, cuts=cuts, hubs=hubs, threshold=threshold
    )


@dataclasses.dataclass
class LocalBlock:
    """Process-local CSR slab: rows [lo, hi) of the global CSR.

    ``offsets`` is re-based to 0; adjacency ids stay GLOBAL (remote reads
    need global ids — paper Fig. 2 stores global ids too).
    """

    rank: int
    lo: int
    hi: int
    offsets: np.ndarray  # [hi-lo+1] int64, local base
    adjacencies: np.ndarray  # int32 global ids

    @property
    def n_local(self) -> int:
        return self.hi - self.lo

    def row(self, v_global: int) -> np.ndarray:
        v = v_global - self.lo
        return self.adjacencies[self.offsets[v] : self.offsets[v + 1]]

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)


def local_block(csr: CSRGraph, part, rank: int) -> LocalBlock:
    """Slice rank ``rank``'s owned block out of the global CSR — works
    for any partition honoring the contiguous ``lo/hi`` contract."""
    lo, hi = part.lo(rank), part.hi(rank)
    a, b = csr.offsets[lo], csr.offsets[hi]
    return LocalBlock(
        rank=rank,
        lo=lo,
        hi=hi,
        offsets=(csr.offsets[lo : hi + 1] - a).astype(np.int64),
        adjacencies=csr.adjacencies[a:b].copy(),
    )


def all_blocks(csr: CSRGraph, p: int) -> List[LocalBlock]:
    part = partition_1d(csr.n, p)
    return [local_block(csr, part, k) for k in range(p)]
