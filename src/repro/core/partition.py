"""1D vertex partitioning (paper §III-A).

``V_k = { v_i : i in ((k-1)n/p, k*n/p] }`` — contiguous equal-size blocks.
We generalize to ``p`` not dividing ``n`` with ceil-sized blocks so that the
owner function stays a closed form (needed device-side).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .csr import CSRGraph

__all__ = ["Partition1D", "partition_1d", "local_block"]


@dataclasses.dataclass
class Partition1D:
    n: int
    p: int

    @property
    def block(self) -> int:
        return -(-self.n // self.p)  # ceil

    def owner(self, v):
        """Owner process of vertex v (vectorized)."""
        return np.minimum(
            np.asarray(v, np.int64) // self.block, self.p - 1
        ).astype(np.int32)

    def lo(self, k: int) -> int:
        return min(k * self.block, self.n)

    def hi(self, k: int) -> int:
        return min((k + 1) * self.block, self.n)

    def sizes(self) -> np.ndarray:
        return np.array(
            [self.hi(k) - self.lo(k) for k in range(self.p)], np.int64
        )


def partition_1d(n: int, p: int) -> Partition1D:
    return Partition1D(n=n, p=p)


@dataclasses.dataclass
class LocalBlock:
    """Process-local CSR slab: rows [lo, hi) of the global CSR.

    ``offsets`` is re-based to 0; adjacency ids stay GLOBAL (remote reads
    need global ids — paper Fig. 2 stores global ids too).
    """

    rank: int
    lo: int
    hi: int
    offsets: np.ndarray  # [hi-lo+1] int64, local base
    adjacencies: np.ndarray  # int32 global ids

    @property
    def n_local(self) -> int:
        return self.hi - self.lo

    def row(self, v_global: int) -> np.ndarray:
        v = v_global - self.lo
        return self.adjacencies[self.offsets[v] : self.offsets[v + 1]]

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)


def local_block(csr: CSRGraph, part: Partition1D, rank: int) -> LocalBlock:
    lo, hi = part.lo(rank), part.hi(rank)
    a, b = csr.offsets[lo], csr.offsets[hi]
    return LocalBlock(
        rank=rank,
        lo=lo,
        hi=hi,
        offsets=(csr.offsets[lo : hi + 1] - a).astype(np.int64),
        adjacencies=csr.adjacencies[a:b].copy(),
    )


def all_blocks(csr: CSRGraph, p: int) -> List[LocalBlock]:
    part = partition_1d(csr.n, p)
    return [local_block(csr, part, k) for k in range(p)]
