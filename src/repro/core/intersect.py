"""Adjacency-list intersection (paper §II-C, Algorithms 1 & 2, Eq. 3).

Three layers:

1. **Scalar reference** (`ssi_scalar`, `binary_search_scalar`) — literal
   transcriptions of the paper's Algorithms 1/2. Used as oracles and for
   the Table III benchmark.
2. **Vectorized host versions** (`*_np`) — numpy batch implementations used
   by the benchmarks (the CPU stand-ins for the OpenMP parallel region of
   §III-C).
3. **Device versions** (`*_jnp`) — jnp implementations for padded sorted
   rows with sentinel padding. These are the TPU adaptation: merge-SSI is
   sequential and anti-SIMD on a VPU, so the SSI regime is realized as an
   all-pairs tile compare (SIMD compare-all) and the binary-search regime
   as a vectorized ``searchsorted`` membership count. The hybrid decision
   rule (Eq. 3) is re-derived for this cost model in `tpu_regime_rule`.

Rows are sorted ascending; any id >= ``sentinel`` is padding and never
counted (the sentinel is chosen > every real id, so sorted order holds).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "ssi_scalar",
    "binary_search_scalar",
    "hybrid_scalar",
    "eq3_ssi_faster",
    "count_bsearch_np",
    "count_pairwise_np",
    "count_bsearch_jnp",
    "count_pairwise_jnp",
    "count_bitmap_jnp",
    "tpu_regime_rule",
    "count_hybrid_jnp",
]


# --------------------------------------------------------------------------
# 1. Scalar references — Algorithms 1 and 2, verbatim semantics.
# --------------------------------------------------------------------------
def ssi_scalar(a: np.ndarray, b: np.ndarray) -> int:
    """Sorted set intersection (Algorithm 2): O(|A| + |B|)."""
    counter = 0
    i = j = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        if a[i] == b[j]:
            counter += 1
            i += 1
            j += 1
        elif a[i] < b[j]:
            i += 1
        else:
            j += 1
    return counter


def binary_search_scalar(a: np.ndarray, b: np.ndarray) -> int:
    """Binary search (Algorithm 1): |A| lookups in B, O(|A| log |B|)."""
    counter = 0
    nb = len(b)
    for x in a:
        lo, hi = 0, nb
        while lo < hi:
            mid = (lo + hi) // 2
            if b[mid] < x:
                lo = mid + 1
            else:
                hi = mid
        if lo < nb and b[lo] == x:
            counter += 1
    return counter


def eq3_ssi_faster(len_a: int, len_b: int) -> bool:
    """Paper Eq. 3: SSI is (theoretically) faster iff |B|/|A| <= log2|B|-1.

    ``a`` is the shorter list.
    """
    if len_a == 0 or len_b == 0:
        return True
    if len_a > len_b:
        len_a, len_b = len_b, len_a
    return (len_b / len_a) <= max(np.log2(max(len_b, 2)) - 1.0, 0.0)


def hybrid_scalar(a: np.ndarray, b: np.ndarray) -> int:
    """Hybrid method (§III-C): pick by Eq. 3, always search the longer list."""
    if len(a) > len(b):
        a, b = b, a
    if eq3_ssi_faster(len(a), len(b)):
        return ssi_scalar(a, b)
    return binary_search_scalar(a, b)


# --------------------------------------------------------------------------
# 2. Vectorized host (numpy) versions — used by the shared-memory benchmarks.
# --------------------------------------------------------------------------
def count_bsearch_np(a: np.ndarray, b: np.ndarray) -> int:
    """Vectorized binary-search membership |a ∩ b| for 1-D sorted arrays."""
    if a.size == 0 or b.size == 0:
        return 0
    idx = np.searchsorted(b, a)
    idx = np.minimum(idx, b.size - 1)
    return int((b[idx] == a).sum())


def count_pairwise_np(a: np.ndarray, b: np.ndarray) -> int:
    """All-pairs compare (the SIMD-friendly SSI substitute), O(|A||B|)."""
    if a.size == 0 or b.size == 0:
        return 0
    return int((a[:, None] == b[None, :]).sum())


# --------------------------------------------------------------------------
# 3. Device (jnp) versions on padded sorted rows.
#    rows_a: [..., Wa] int32 sorted w/ sentinel padding; rows_b: [..., Wb].
# --------------------------------------------------------------------------
def count_bsearch_jnp(rows_a: jnp.ndarray, rows_b: jnp.ndarray, sentinel: int):
    """Membership count via vectorized binary search of A's elements in B.

    Batched over leading dims. Padding (>= sentinel) never matches.
    """
    idx = jax.vmap(jnp.searchsorted)(rows_b, rows_a) if rows_a.ndim == 2 else (
        jnp.searchsorted(rows_b, rows_a)
    )
    idx = jnp.minimum(idx, rows_b.shape[-1] - 1)
    hit = jnp.take_along_axis(rows_b, idx, axis=-1) == rows_a
    hit = hit & (rows_a < sentinel)
    return hit.sum(axis=-1).astype(jnp.int32)


def count_pairwise_jnp(rows_a: jnp.ndarray, rows_b: jnp.ndarray, sentinel: int):
    """All-pairs tile compare: counts[e] = sum_{s,t} (A[e,s] == B[e,t]).

    O(Wa*Wb) compares but pure vector ops — the TPU 'SSI regime'.
    """
    eq = rows_a[..., :, None] == rows_b[..., None, :]
    eq = eq & (rows_a[..., :, None] < sentinel)
    return eq.sum(axis=(-1, -2)).astype(jnp.int32)


def count_bitmap_jnp(words_a: jnp.ndarray, words_b: jnp.ndarray):
    """Bitmap AND + popcount over uint32 words (batched)."""
    both = jnp.bitwise_and(words_a, words_b)
    # popcount via jax.lax.population_count (uint32-safe)
    pc = jax.lax.population_count(both)
    return pc.sum(axis=-1).astype(jnp.int32)


def tpu_regime_rule(deg_a: jnp.ndarray, deg_b: jnp.ndarray, width_b: int):
    """Eq. 3 re-derived for the vectorized cost model.

    bsearch-regime cost ~ |A| * ceil(log2 Wb) vector gathers;
    pairwise-regime cost ~ |A| * Wb lane-compares (cheaper per op by ~G,
    the gather-vs-compare cost ratio; G ~= 8 on VPU-class hardware).
    pairwise (SSI regime) wins iff Wb <= G * log2(Wb)  ==  the same
    log-ratio structure as paper Eq. 3 with the constant re-fit.
    """
    g = 8.0
    log_wb = jnp.ceil(jnp.log2(jnp.maximum(width_b, 2).astype(jnp.float32)))
    lo = jnp.minimum(deg_a, deg_b).astype(jnp.float32)
    hi = jnp.maximum(deg_a, deg_b).astype(jnp.float32)
    # ratio rule, mirroring |B|/|A| <= log2|B| - 1 with vector constants
    return (hi / jnp.maximum(lo, 1.0)) <= g * jnp.maximum(log_wb - 1.0, 1.0)


def count_hybrid_jnp(
    rows_a: jnp.ndarray,
    rows_b: jnp.ndarray,
    deg_a: jnp.ndarray,
    deg_b: jnp.ndarray,
    sentinel: int,
):
    """Hybrid device intersection: per-edge regime select (paper §III-C).

    Both regimes are computed on the (cheap, padded) rows and selected by
    the rule; the static split into two streams (so only one regime runs
    per edge) is done by the distributed engine at preprocessing time —
    see ``core/async_engine.py``.
    """
    use_pairwise = tpu_regime_rule(deg_a, deg_b, rows_b.shape[-1])
    c_pw = count_pairwise_jnp(rows_a, rows_b, sentinel)
    c_bs = count_bsearch_jnp(rows_a, rows_b, sentinel)
    return jnp.where(use_pairwise, c_pw, c_bs)
