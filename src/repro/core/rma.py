"""RMA-style remote-read machinery (paper §III-A/B) adapted to XLA SPMD.

The paper reads remote adjacency lists with MPI one-sided gets over two
windows (``w_offsets`` and ``w_adj``). XLA has no one-sided get, so the
remote-read pattern is compiled into a **static pull schedule**:

- Host-side preprocessing walks each device's edge worklist, resolves every
  remote endpoint against the static degree cache, dedups within a round
  (the within-epoch reuse CLaMPI also captures), and emits, per round, a
  *serve list*: which of its local rows each device must ship to each peer.
- Device-side, one ``all_to_all`` per round moves exactly those rows; the
  pipelined engine overlaps round ``r``'s intersection with round
  ``r+1``'s fetch (the paper's double buffering, §III-A).

This module builds the schedule + stacked device arrays; the compiled
engine lives in ``async_engine.py``. A host-level trace simulator
(``simulate_rma_lcc``) replays the same access stream through the
``ClampiCache`` simulator to produce the paper's cache/communication
metrics (Figs. 4, 7, 8, 9, 10) without needing p physical devices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cache import CacheStats, ClampiCache, NetworkModel, StaticDegreeCache
from .csr import CSRGraph, to_padded_rows
from .partition import Partition1D, partition_1d

__all__ = [
    "ShardedLCCProblem",
    "ScheduleWidthOverflow",
    "build_sharded_problem",
    "assert_problems_equal",
    "RMATraceStats",
    "simulate_rma_lcc",
]

OFFSET_ENTRY_BYTES = 8  # (start, end) pair of int32 — paper §IV-D2
ID_BYTES = 4


class ScheduleWidthOverflow(ValueError):
    """A touched vertex's degree outgrew the problem's padded row width;
    the incremental patch cannot represent its row. Callers rebuild from
    scratch with a larger width (``ShardedRuntime.maintain_schedule``
    does so automatically, doubling the width for headroom)."""


@dataclasses.dataclass
class ShardedLCCProblem:
    """Stacked per-device arrays (leading axis p) + static metadata.

    Combined row-index space per round (per device):
      [0, n_loc+1)                         local rows (+1 phantom at n_loc)
      [n_loc+1, n_loc+1+C)                 replicated cache rows
      [n_loc+1+C, n_loc+1+C+p*S_max)       this round's fetched rows
    """

    # device data (leading axis p)
    rows_ext: np.ndarray  # [p, n_loc+1, W] int32 global ids, sentinel = n
    degrees: np.ndarray  # [p, n_loc] int32 true degrees
    edge_u: np.ndarray  # [p, E_max] int32 local u index (pad -> n_loc)
    edge_vc: np.ndarray  # [p, E_max] int32 combined row index of v
    edge_mask: np.ndarray  # [p, E_max] bool
    serve_idx: np.ndarray  # [p, NR, p, S_max] int32 local rows to send
    cache_rows: np.ndarray  # [C, W] int32 (replicated)
    # metadata
    n: int
    p: int
    width: int
    n_loc: int
    e_max: int
    n_rounds: int
    s_max: int
    cache_ids: np.ndarray  # [C] global ids
    # host-side schedule-maintenance state (not shipped to devices):
    # the build parameters before clamping, and the per-rank edge
    # worklists (u_local, v_global) the schedule was compiled from.
    n_rounds_requested: int = 4
    dedup_rounds: bool = True
    works: Optional[List[Tuple[np.ndarray, np.ndarray]]] = dataclasses.field(
        default=None, repr=False
    )

    @property
    def sentinel(self) -> int:
        return self.n

    def comm_bytes_per_round(self) -> np.ndarray:
        """[p, NR] payload bytes each device *receives* per round."""
        # serve_idx[q, r, k] = rows q sends to k; received-by-k = sum over q
        valid = self.serve_idx < self.n_loc
        per = valid.sum(axis=-1) * self.width * ID_BYTES  # [p(send), NR, p(dst)]
        return per.transpose(2, 1, 0).sum(axis=-1)  # [p(dst), NR]

    # ------------------------------------------------------------------
    # Incremental schedule maintenance.
    # ------------------------------------------------------------------
    def apply_delta(
        self,
        ins: np.ndarray,
        dele: np.ndarray,
        *,
        new_cache_ids: Optional[np.ndarray] = None,
    ) -> "ShardedLCCProblem":
        """Patch the compiled problem for one applied update batch.

        ``ins``/``dele`` are canonical ``[K, 2]`` edge arrays with the
        streaming contract: every insert absent from, and every delete
        present in, the graph the problem currently describes (exactly
        what ``normalize_batch`` emits). The patch

        1. rewrites the padded rows + degrees of the touched vertices
           (and their replicated cache-row copies) — O(delta) rows,
        2. splices the touched edges in/out of each rank's worklist —
           one vectorized merge per rank, and — when ``new_cache_ids``
           carries a drifted static residency set — swaps
           ``cache_ids``/``cache_rows`` in place (the replicated rows
           are gathered from the already-patched ``rows_ext``, so no
           graph pass is needed), then
        3. recompiles the pull schedule (round request lists, serve
           lists, combined indices) from the patched worklists with the
           vectorized compiler — bit-exact vs the per-edge reference in
           ``build_sharded_problem``.

        Residency drift therefore never forces a from-scratch rebuild;
        only a width overflow does. Raises ``ScheduleWidthOverflow``
        (leaving the problem untouched) when a touched vertex outgrows
        the padded width; callers rebuild with a larger width. Mutates
        and returns ``self``.
        """
        ins = np.asarray(ins, np.int64).reshape(-1, 2)
        dele = np.asarray(dele, np.int64).reshape(-1, 2)
        fresh_ids: Optional[np.ndarray] = None
        if new_cache_ids is not None:
            fresh_ids = np.sort(
                np.unique(np.asarray(new_cache_ids, np.int64).ravel())
            )
            if np.array_equal(fresh_ids, self.cache_ids):
                fresh_ids = None
        if ins.shape[0] == 0 and dele.shape[0] == 0 and fresh_ids is None:
            return self
        if self.works is None:
            raise ValueError(
                "problem carries no host worklists; rebuild it with "
                "build_sharded_problem before applying deltas"
            )
        # problems compiled against a custom partition carry it (see
        # build_sharded_problem); older pickles/tests fall back to 1D.
        part = getattr(self, "part", None)
        if part is None:
            part = partition_1d(self.n, self.p)
        sent = self.sentinel
        w = self.width

        # per-vertex delta neighbor lists (both directions of each edge)
        add_of: Dict[int, List[int]] = {}
        del_of: Dict[int, List[int]] = {}
        for a, b in ins:
            add_of.setdefault(int(a), []).append(int(b))
            add_of.setdefault(int(b), []).append(int(a))
        for a, b in dele:
            del_of.setdefault(int(a), []).append(int(b))
            del_of.setdefault(int(b), []).append(int(a))
        touched = sorted(set(add_of) | set(del_of))

        # validate EVERYTHING up front (width fit + splice consistency)
        # so any failure leaves the problem bit-identical — a failed
        # apply_delta must be safely retryable/rebuildable.
        for v in touched:
            k = int(part.owner(v))
            lu = v - part.lo(k)
            d_old = int(self.degrees[k, lu])
            d_new = d_old + len(add_of.get(v, ())) - len(del_of.get(v, ()))
            if d_old > w or d_new > w:
                raise ScheduleWidthOverflow(
                    f"vertex {v}: degree {max(d_old, d_new)} exceeds the "
                    f"padded row width {w}"
                )
        span = np.int64(self.n + 1)
        src_i = np.concatenate([ins[:, 0], ins[:, 1]])
        dst_i = np.concatenate([ins[:, 1], ins[:, 0]])
        src_d = np.concatenate([dele[:, 0], dele[:, 1]])
        dst_d = np.concatenate([dele[:, 1], dele[:, 0]])
        own_i = part.owner(src_i)
        own_d = part.owner(src_d)
        splices = []  # per rank: (del_positions, ins_locals, ins_globals)
        for k in range(self.p):
            u_l, v_g = self.works[k]
            # keys are strictly increasing: u ascending, v ascending
            # within u, (u, v) unique
            key = u_l.astype(np.int64) * span + v_g.astype(np.int64)
            mk = own_d == k
            dpos = np.zeros(0, np.int64)
            if mk.any():
                dkeys = np.sort((src_d[mk] - part.lo(k)) * span + dst_d[mk])
                dpos = np.searchsorted(key, dkeys)
                if dpos.size and (
                    dpos.max() >= key.size
                    or not np.array_equal(key[dpos], dkeys)
                ):
                    raise ValueError(
                        "delete of an edge absent from the schedule"
                    )
            mk = own_i == k
            s_loc = np.zeros(0, np.int64)
            d_glb = np.zeros(0, np.int64)
            if mk.any():
                s_loc = src_i[mk] - part.lo(k)
                d_glb = dst_i[mk]
                order = np.argsort(s_loc * span + d_glb, kind="stable")
                s_loc, d_glb = s_loc[order], d_glb[order]
                ikeys = s_loc * span + d_glb
                # the streaming contract makes ins/dele disjoint, so
                # presence in the PRE-delete keys is a contract breach
                pos = np.searchsorted(key, ikeys)
                probe = (
                    key[np.minimum(pos, max(key.size - 1, 0))]
                    if key.size
                    else ikeys + 1
                )
                if np.any((pos < key.size) & (probe == ikeys)):
                    raise ValueError(
                        "insert of an edge already in the schedule"
                    )
            splices.append((dpos, s_loc, d_glb))

        # 1. patch padded rows, degrees, and replicated cache rows
        for v in touched:
            k = int(part.owner(v))
            lu = v - part.lo(k)
            d_old = int(self.degrees[k, lu])
            row = self.rows_ext[k, lu, :d_old].astype(np.int64)
            dels = np.asarray(del_of.get(v, ()), np.int64)
            adds = np.asarray(add_of.get(v, ()), np.int64)
            if dels.size:
                row = row[~np.isin(row, dels)]
            if adds.size:
                row = np.sort(np.concatenate([row, adds]))
            self.rows_ext[k, lu, :] = sent
            self.rows_ext[k, lu, : row.size] = row.astype(np.int32)
            self.degrees[k, lu] = row.size
            if self.cache_ids.size:
                ci = int(np.searchsorted(self.cache_ids, v))
                if ci < self.cache_ids.size and self.cache_ids[ci] == v:
                    self.cache_rows[ci, :] = sent
                    self.cache_rows[ci, : row.size] = row.astype(np.int32)

        # 2. splice the touched edges in/out of each rank's worklist
        #    (pre-validated above, so this cannot fail midway)
        for k in range(self.p):
            u_l, v_g = self.works[k]
            dpos, s_loc, d_glb = splices[k]
            if dpos.size:
                keep = np.ones(u_l.size, bool)
                keep[dpos] = False
                u_l, v_g = u_l[keep], v_g[keep]
            if s_loc.size:
                key = u_l.astype(np.int64) * span + v_g.astype(np.int64)
                pos = np.searchsorted(key, s_loc * span + d_glb)
                u_l = np.insert(u_l, pos, s_loc.astype(u_l.dtype))
                v_g = np.insert(v_g, pos, d_glb.astype(v_g.dtype))
            self.works[k] = (u_l, v_g)

        # 2b. residency drift: install the rescored static set in place.
        #     Replicated cache rows are gathers of already-patched local
        #     rows (widths fit by construction), so this costs O(C W).
        if fresh_ids is not None:
            if fresh_ids.size:
                owners = part.owner(fresh_ids).astype(np.int64)
                lo_of = np.array(
                    [part.lo(k) for k in range(self.p)], np.int64
                )
                lus = fresh_ids - lo_of[owners]
                self.cache_rows = self.rows_ext[owners, lus].copy()
            else:
                self.cache_rows = np.zeros((0, w), np.int32)
            self.cache_ids = fresh_ids

        # 3. recompile the schedule from the patched worklists
        (
            self.edge_u,
            self.edge_vc,
            self.edge_mask,
            self.serve_idx,
            self.e_max,
            self.n_rounds,
            self.s_max,
        ) = _compile_schedule(
            self.works,
            part,
            n=self.n,
            n_loc=self.n_loc,
            cache_ids=self.cache_ids,
            n_rounds_req=self.n_rounds_requested,
            dedup_rounds=self.dedup_rounds,
        )
        return self


def _edge_worklist(
    csr: CSRGraph, part: Partition1D, rank: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(u_local, v_global) for every edge owned by ``rank``."""
    lo, hi = part.lo(rank), part.hi(rank)
    a, b = csr.offsets[lo], csr.offsets[hi]
    deg = np.diff(csr.offsets[lo : hi + 1])
    u_local = np.repeat(np.arange(hi - lo, dtype=np.int32), deg)
    v_global = csr.adjacencies[a:b].astype(np.int64)
    return u_local, v_global


def build_sharded_problem(
    csr: CSRGraph,
    p: int,
    *,
    n_rounds: int = 4,
    cache: Optional[StaticDegreeCache] = None,
    width: Optional[int] = None,
    dedup_rounds: bool = True,
    part=None,
) -> ShardedLCCProblem:
    """Compile the static pull schedule for a p-way contiguous
    partition — 1D by default; pass ``part`` (any owner/lo/hi/sizes
    contract holder, e.g. ``partition_hub``) to compile against
    variable cuts. Per-device row slabs are sized to the LARGEST block
    so the ``[p, n_loc, ...]`` layout stays rectangular."""
    n_rounds_requested = n_rounds
    if part is None:
        part = partition_1d(csr.n, p)
    n_loc = int(np.max(part.sizes(), initial=0))
    w = int(width if width is not None else max(csr.max_degree, 1))
    sent = csr.n
    cache_ids = (
        cache.vertex_ids if cache is not None else np.zeros((0,), np.int64)
    )
    c = cache_ids.shape[0]

    # local padded rows (+ phantom row) and true degrees, per device
    rows_ext = np.full((p, n_loc + 1, w), sent, np.int32)
    degrees = np.zeros((p, n_loc), np.int32)
    deg_all = csr.degrees
    for k in range(p):
        lo, hi = part.lo(k), part.hi(k)
        if hi > lo:
            vs = np.arange(lo, hi)
            rows_ext[k, : hi - lo] = to_padded_rows(
                csr, w, sentinel=sent, vertices=vs
            )
            degrees[k, : hi - lo] = deg_all[lo:hi]

    cache_rows = (
        to_padded_rows(csr, w, sentinel=sent, vertices=cache_ids)
        if c
        else np.zeros((0, w), np.int32)
    )
    cache_slot_of = (
        cache.slot_of if cache is not None else (lambda v: np.full(len(v), -1, np.int32))
    )

    # per-device worklists + per-round fetch sets
    works = [_edge_worklist(csr, part, k) for k in range(p)]
    e_max = max((u.size for u, _ in works), default=1) or 1
    n_rounds = max(1, min(n_rounds, e_max))
    e_chunk = -(-e_max // n_rounds)
    e_max = e_chunk * n_rounds  # pad to a whole number of equal chunks

    # first pass: compute per (initiator, round, owner) request lists
    # requests[k][r][q] = list of local row indices on q (order of first use)
    requests: List[List[Dict[int, List[int]]]] = [
        [dict() for _ in range(n_rounds)] for _ in range(p)
    ]
    # remember, per edge, how to find its row: (source, index)
    edge_src_kind = [np.zeros(e_max, np.int8) for _ in range(p)]  # 0 loc 1 cache 2 fetch
    edge_src_idx = [np.zeros(e_max, np.int64) for _ in range(p)]
    for k in range(p):
        u_l, v_g = works[k]
        owners = part.owner(v_g)
        slots = cache_slot_of(v_g)
        pos_maps: List[Dict[Tuple[int, int], int]] = [
            dict() for _ in range(n_rounds)
        ]
        for e in range(v_g.size):
            r = e // e_chunk
            v = int(v_g[e])
            if owners[e] == k:
                edge_src_kind[k][e] = 0
                edge_src_idx[k][e] = v - part.lo(k)
            elif slots[e] >= 0:
                edge_src_kind[k][e] = 1
                edge_src_idx[k][e] = slots[e]
            else:
                q = int(owners[e])
                lst = requests[k][r].setdefault(q, [])
                v_local = v - part.lo(q)
                key = (q, v_local)
                pm = pos_maps[r]
                if dedup_rounds and key in pm:
                    pos = pm[key]
                else:
                    pos = len(lst)
                    lst.append(v_local)
                    pm[key] = pos
                edge_src_kind[k][e] = 2
                edge_src_idx[k][e] = q * 10**9 + pos  # resolved after S_max known

    s_max = 1
    for k in range(p):
        for r in range(n_rounds):
            for q, lst in requests[k][r].items():
                s_max = max(s_max, len(lst))

    # serve lists: serve_idx[q, r, k] = rows q sends to k in round r
    serve_idx = np.full((p, n_rounds, p, s_max), n_loc, np.int32)
    for k in range(p):
        for r in range(n_rounds):
            for q, lst in requests[k][r].items():
                serve_idx[q, r, k, : len(lst)] = lst

    # finalize combined indices
    base_cache = n_loc + 1
    base_fetch = n_loc + 1 + c
    edge_u = np.full((p, e_max), n_loc, np.int32)
    edge_vc = np.full((p, e_max), n_loc, np.int32)  # phantom
    edge_mask = np.zeros((p, e_max), bool)
    for k in range(p):
        u_l, v_g = works[k]
        ne = u_l.size
        edge_u[k, :ne] = u_l
        edge_mask[k, :ne] = True
        kind = edge_src_kind[k]
        idx = edge_src_idx[k]
        vc = np.full(e_max, n_loc, np.int64)
        loc = kind == 0
        vc[: ne][loc[:ne]] = idx[:ne][loc[:ne]]
        cch = kind == 1
        vc[: ne][cch[:ne]] = base_cache + idx[:ne][cch[:ne]]
        ftc = kind == 2
        q = idx // 10**9
        pos = idx % 10**9
        vc[: ne][ftc[:ne]] = base_fetch + (q * s_max + pos)[:ne][ftc[:ne]]
        edge_vc[k] = vc.astype(np.int32)

    prob = ShardedLCCProblem(
        rows_ext=rows_ext,
        degrees=degrees,
        edge_u=edge_u,
        edge_vc=edge_vc,
        edge_mask=edge_mask,
        serve_idx=serve_idx,
        cache_rows=cache_rows,
        n=csr.n,
        p=p,
        width=w,
        n_loc=n_loc,
        e_max=e_max,
        n_rounds=n_rounds,
        s_max=s_max,
        cache_ids=cache_ids,
        n_rounds_requested=n_rounds_requested,
        dedup_rounds=dedup_rounds,
        works=works,
    )
    # the partition rides along as a plain attribute (not a dataclass
    # field, so assert_problems_equal keeps comparing arrays only):
    # apply_delta re-derives worklist ownership from it.
    prob.part = part
    return prob


# --------------------------------------------------------------------------
# Vectorized schedule compiler (the apply_delta recompile path).
# --------------------------------------------------------------------------
def _cumcount(groups: np.ndarray) -> np.ndarray:
    """Per-element index among prior occurrences of the same value, in
    the given order (vectorized group cumcount)."""
    if groups.size == 0:
        return np.zeros(0, np.int64)
    order = np.argsort(groups, kind="stable")
    gs = groups[order]
    starts = np.flatnonzero(np.r_[True, gs[1:] != gs[:-1]])
    lens = np.diff(np.r_[starts, gs.size])
    out = np.empty(gs.size, np.int64)
    out[order] = np.arange(gs.size) - np.repeat(starts, lens)
    return out


def _compile_schedule(
    works: List[Tuple[np.ndarray, np.ndarray]],
    part: Partition1D,
    *,
    n: int,
    n_loc: int,
    cache_ids: np.ndarray,
    n_rounds_req: int,
    dedup_rounds: bool,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int, int, int]:
    """Vectorized re-derivation of the pull schedule from edge worklists.

    Bit-exact vs the per-edge reference loops in ``build_sharded_problem``
    (the property tests assert every array): same round chunking, same
    order-of-first-use request dedup per (initiator, round), same serve
    lists and combined indices. One pass of numpy group ops per
    (rank, round) instead of one Python iteration per edge — this is
    what makes per-batch schedule maintenance cheap.

    Returns ``(edge_u, edge_vc, edge_mask, serve_idx, e_max, n_rounds,
    s_max)``.
    """
    p = part.p
    c = int(cache_ids.shape[0])
    slot_lookup = StaticDegreeCache(vertex_ids=cache_ids) if c else None
    e_max = max((u.size for u, _ in works), default=1) or 1
    n_rounds = max(1, min(n_rounds_req, e_max))
    e_chunk = -(-e_max // n_rounds)
    e_max = e_chunk * n_rounds
    base_cache = n_loc + 1
    span = np.int64(n_loc + 1)  # q * span + v_local keys are collision-free

    edge_u = np.full((p, e_max), n_loc, np.int32)
    edge_vc64 = np.full((p, e_max), n_loc, np.int64)
    edge_mask = np.zeros((p, e_max), bool)
    fetch_edges = []  # (rank, edge_idx, q, pos) awaiting s_max resolution
    serve_entries = []  # (rank, round, q, pos, v_local)
    s_max = 1
    for k in range(p):
        u_l, v_g = works[k]
        ne = int(v_g.size)
        if ne == 0:
            continue
        edge_u[k, :ne] = u_l
        edge_mask[k, :ne] = True
        v64 = v_g.astype(np.int64)
        owners = part.owner(v64).astype(np.int64)
        loc = owners == k
        slots = (
            slot_lookup.slot_of(v64)
            if slot_lookup is not None
            else np.full(ne, -1, np.int32)
        )
        cch = (~loc) & (slots >= 0)
        ftc = (~loc) & (slots < 0)
        vc = edge_vc64[k]
        idx_all = np.arange(ne)
        vc[idx_all[loc]] = v64[loc] - part.lo(k)
        vc[idx_all[cch]] = base_cache + slots[cch]
        r_of = idx_all // e_chunk
        lo_arr = np.array([part.lo(q) for q in range(p)], np.int64)
        for r in range(n_rounds):
            idx = np.flatnonzero(ftc & (r_of == r))
            if idx.size == 0:
                continue
            q = owners[idx]
            v_local = v64[idx] - lo_arr[q]
            keys = q * span + v_local
            if dedup_rounds:
                uniq, first, inv = np.unique(
                    keys, return_index=True, return_inverse=True
                )
                order = np.argsort(first, kind="stable")  # first-use order
                q_u = uniq[order] // span
                v_u = uniq[order] % span
                pos_u = _cumcount(q_u)  # index within q's request list
                rank_of = np.empty(uniq.size, np.int64)
                rank_of[order] = np.arange(uniq.size)
                pos_e = pos_u[rank_of[inv]]
                serve_entries.append((k, r, q_u, pos_u, v_u))
                counts = np.bincount(q_u, minlength=p)
            else:
                pos_e = _cumcount(q)  # every occurrence appends
                serve_entries.append((k, r, q, pos_e, v_local))
                counts = np.bincount(q, minlength=p)
            s_max = max(s_max, int(counts.max()))
            fetch_edges.append((k, idx, q, pos_e))

    serve_idx = np.full((p, n_rounds, p, s_max), n_loc, np.int32)
    for k, r, q_u, pos_u, v_u in serve_entries:
        serve_idx[q_u, r, k, pos_u] = v_u.astype(np.int32)
    base_fetch = n_loc + 1 + c
    for k, idx, q, pos_e in fetch_edges:
        edge_vc64[k][idx] = base_fetch + q * s_max + pos_e
    return (
        edge_u,
        edge_vc64.astype(np.int32),
        edge_mask,
        serve_idx,
        int(e_max),
        int(n_rounds),
        int(s_max),
    )


def assert_problems_equal(
    got: ShardedLCCProblem, want: ShardedLCCProblem
) -> None:
    """Field-wise bit-exact comparison of two compiled problems (the
    incremental-maintenance acceptance check)."""
    for f in ("n", "p", "width", "n_loc", "e_max", "n_rounds", "s_max"):
        g, w = getattr(got, f), getattr(want, f)
        assert g == w, f"{f}: {g} != {w}"
    for f in (
        "rows_ext",
        "degrees",
        "edge_u",
        "edge_vc",
        "edge_mask",
        "serve_idx",
        "cache_rows",
        "cache_ids",
    ):
        g, w = getattr(got, f), getattr(want, f)
        assert np.array_equal(g, w), f"{f} diverged"


# --------------------------------------------------------------------------
# Host trace simulator: replays the RMA access stream through ClampiCache.
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RMATraceStats:
    """Per-device communication statistics for one LCC computation."""

    remote_gets: np.ndarray  # [p] int64 — adjacency gets issued (pre-cache)
    remote_reads_unique: np.ndarray  # [p]
    comm_time: np.ndarray  # [p] float — modeled, caches applied
    compute_edges: np.ndarray  # [p]
    remote_bytes: np.ndarray = None  # [p] bytes fetched AFTER caching
    remote_bytes_raw: np.ndarray = None  # [p] bytes demanded (pre-cache)
    post_cache_gets: np.ndarray = None  # [p] gets that miss the caches
    offsets_stats: List[CacheStats] = dataclasses.field(default_factory=list)
    adj_stats: List[CacheStats] = dataclasses.field(default_factory=list)

    @property
    def makespan(self) -> float:
        return float(self.comm_time.max()) if self.comm_time.size else 0.0


def simulate_rma_lcc(
    csr: CSRGraph,
    p: int,
    *,
    offsets_cache_bytes: int = 0,
    adj_cache_bytes: int = 0,
    use_degree_score: bool = False,
    network: Optional[NetworkModel] = None,
    table_slots_offsets: Optional[int] = None,
    table_slots_adj: Optional[int] = None,
    positional_weight: float = 0.5,
    part=None,
) -> RMATraceStats:
    """Replay the per-device remote-access stream of Algorithm 3.

    Each remote adjacency read = one get on w_offsets (8 B) + one get on
    w_adj (deg * 4 B), both cached when cache bytes > 0 (always-cache
    mode). ``use_degree_score`` switches the adjacency cache's victim
    selection to the paper's application-defined degree score.
    """
    net = network or NetworkModel()
    if part is None:
        part = partition_1d(csr.n, p)
    deg = csr.degrees
    remote_gets = np.zeros(p, np.int64)
    uniq = np.zeros(p, np.int64)
    comm = np.zeros(p, np.float64)
    edges = np.zeros(p, np.int64)
    bytes_after = np.zeros(p, np.int64)
    bytes_raw = np.zeros(p, np.int64)
    gets_after = np.zeros(p, np.int64)
    o_stats: List[CacheStats] = []
    a_stats: List[CacheStats] = []
    for k in range(p):
        u_l, v_g = _edge_worklist(csr, part, k)
        owners = part.owner(v_g)
        remote = v_g[owners != k]
        remote_gets[k] = remote.size
        uniq[k] = np.unique(remote).size
        edges[k] = v_g.size
        c_off = (
            ClampiCache(
                offsets_cache_bytes,
                table_slots_offsets
                or max(1, offsets_cache_bytes // OFFSET_ENTRY_BYTES),
                network=net,
                positional_weight=positional_weight,
            )
            if offsets_cache_bytes > 0
            else None
        )
        if c_off is not None:
            c_off.rank = k  # cachescope stream labeling
            c_off.scope_label = "offsets"
        # hash-table sizing heuristic of §III-B1: n * 0.5**alpha with alpha=2
        default_adj_slots = max(1, int(csr.n * 0.25))
        c_adj = (
            ClampiCache(
                adj_cache_bytes,
                table_slots_adj or default_adj_slots,
                network=net,
                positional_weight=positional_weight,
            )
            if adj_cache_bytes > 0
            else None
        )
        if c_adj is not None:
            c_adj.rank = k
            c_adj.scope_label = "adj"
        t = 0.0
        for v in remote:
            v = int(v)
            size_adj = int(deg[v]) * ID_BYTES
            score = float(deg[v]) if use_degree_score else None
            bytes_raw[k] += OFFSET_ENTRY_BYTES + size_adj
            if c_off is not None:
                if not c_off.get(v, OFFSET_ENTRY_BYTES):
                    bytes_after[k] += OFFSET_ENTRY_BYTES
                    gets_after[k] += 1
            else:
                t += net.remote(OFFSET_ENTRY_BYTES)
                bytes_after[k] += OFFSET_ENTRY_BYTES
                gets_after[k] += 1
            if c_adj is not None:
                if not c_adj.get(v, size_adj, score=score):
                    bytes_after[k] += size_adj
                    gets_after[k] += 1
            else:
                t += net.remote(size_adj)
                bytes_after[k] += size_adj
                gets_after[k] += 1
        if c_off is not None:
            t += c_off.stats.comm_time
            o_stats.append(c_off.stats)
        if c_adj is not None:
            t += c_adj.stats.comm_time
            a_stats.append(c_adj.stats)
        comm[k] = t
    return RMATraceStats(
        remote_gets=remote_gets,
        remote_reads_unique=uniq,
        comm_time=comm,
        compute_edges=edges,
        remote_bytes=bytes_after,
        remote_bytes_raw=bytes_raw,
        post_cache_gets=gets_after,
        offsets_stats=o_stats,
        adj_stats=a_stats,
    )
