"""RMA-style remote-read machinery (paper §III-A/B) adapted to XLA SPMD.

The paper reads remote adjacency lists with MPI one-sided gets over two
windows (``w_offsets`` and ``w_adj``). XLA has no one-sided get, so the
remote-read pattern is compiled into a **static pull schedule**:

- Host-side preprocessing walks each device's edge worklist, resolves every
  remote endpoint against the static degree cache, dedups within a round
  (the within-epoch reuse CLaMPI also captures), and emits, per round, a
  *serve list*: which of its local rows each device must ship to each peer.
- Device-side, one ``all_to_all`` per round moves exactly those rows; the
  pipelined engine overlaps round ``r``'s intersection with round
  ``r+1``'s fetch (the paper's double buffering, §III-A).

This module builds the schedule + stacked device arrays; the compiled
engine lives in ``async_engine.py``. A host-level trace simulator
(``simulate_rma_lcc``) replays the same access stream through the
``ClampiCache`` simulator to produce the paper's cache/communication
metrics (Figs. 4, 7, 8, 9, 10) without needing p physical devices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cache import CacheStats, ClampiCache, NetworkModel, StaticDegreeCache
from .csr import CSRGraph, to_padded_rows
from .partition import Partition1D, partition_1d

__all__ = [
    "ShardedLCCProblem",
    "build_sharded_problem",
    "RMATraceStats",
    "simulate_rma_lcc",
]

OFFSET_ENTRY_BYTES = 8  # (start, end) pair of int32 — paper §IV-D2
ID_BYTES = 4


@dataclasses.dataclass
class ShardedLCCProblem:
    """Stacked per-device arrays (leading axis p) + static metadata.

    Combined row-index space per round (per device):
      [0, n_loc+1)                         local rows (+1 phantom at n_loc)
      [n_loc+1, n_loc+1+C)                 replicated cache rows
      [n_loc+1+C, n_loc+1+C+p*S_max)       this round's fetched rows
    """

    # device data (leading axis p)
    rows_ext: np.ndarray  # [p, n_loc+1, W] int32 global ids, sentinel = n
    degrees: np.ndarray  # [p, n_loc] int32 true degrees
    edge_u: np.ndarray  # [p, E_max] int32 local u index (pad -> n_loc)
    edge_vc: np.ndarray  # [p, E_max] int32 combined row index of v
    edge_mask: np.ndarray  # [p, E_max] bool
    serve_idx: np.ndarray  # [p, NR, p, S_max] int32 local rows to send
    cache_rows: np.ndarray  # [C, W] int32 (replicated)
    # metadata
    n: int
    p: int
    width: int
    n_loc: int
    e_max: int
    n_rounds: int
    s_max: int
    cache_ids: np.ndarray  # [C] global ids

    @property
    def sentinel(self) -> int:
        return self.n

    def comm_bytes_per_round(self) -> np.ndarray:
        """[p, NR] payload bytes each device *receives* per round."""
        # serve_idx[q, r, k] = rows q sends to k; received-by-k = sum over q
        valid = self.serve_idx < self.n_loc
        per = valid.sum(axis=-1) * self.width * ID_BYTES  # [p(send), NR, p(dst)]
        return per.transpose(2, 1, 0).sum(axis=-1)  # [p(dst), NR]


def _edge_worklist(
    csr: CSRGraph, part: Partition1D, rank: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(u_local, v_global) for every edge owned by ``rank``."""
    lo, hi = part.lo(rank), part.hi(rank)
    a, b = csr.offsets[lo], csr.offsets[hi]
    deg = np.diff(csr.offsets[lo : hi + 1])
    u_local = np.repeat(np.arange(hi - lo, dtype=np.int32), deg)
    v_global = csr.adjacencies[a:b].astype(np.int64)
    return u_local, v_global


def build_sharded_problem(
    csr: CSRGraph,
    p: int,
    *,
    n_rounds: int = 4,
    cache: Optional[StaticDegreeCache] = None,
    width: Optional[int] = None,
    dedup_rounds: bool = True,
) -> ShardedLCCProblem:
    """Compile the static pull schedule for a p-way 1D partition."""
    part = partition_1d(csr.n, p)
    n_loc = part.block
    w = int(width if width is not None else max(csr.max_degree, 1))
    sent = csr.n
    cache_ids = (
        cache.vertex_ids if cache is not None else np.zeros((0,), np.int64)
    )
    c = cache_ids.shape[0]

    # local padded rows (+ phantom row) and true degrees, per device
    rows_ext = np.full((p, n_loc + 1, w), sent, np.int32)
    degrees = np.zeros((p, n_loc), np.int32)
    deg_all = csr.degrees
    for k in range(p):
        lo, hi = part.lo(k), part.hi(k)
        if hi > lo:
            vs = np.arange(lo, hi)
            rows_ext[k, : hi - lo] = to_padded_rows(
                csr, w, sentinel=sent, vertices=vs
            )
            degrees[k, : hi - lo] = deg_all[lo:hi]

    cache_rows = (
        to_padded_rows(csr, w, sentinel=sent, vertices=cache_ids)
        if c
        else np.zeros((0, w), np.int32)
    )
    cache_slot_of = (
        cache.slot_of if cache is not None else (lambda v: np.full(len(v), -1, np.int32))
    )

    # per-device worklists + per-round fetch sets
    works = [_edge_worklist(csr, part, k) for k in range(p)]
    e_max = max((u.size for u, _ in works), default=1) or 1
    n_rounds = max(1, min(n_rounds, e_max))
    e_chunk = -(-e_max // n_rounds)
    e_max = e_chunk * n_rounds  # pad to a whole number of equal chunks

    # first pass: compute per (initiator, round, owner) request lists
    # requests[k][r][q] = list of local row indices on q (order of first use)
    requests: List[List[Dict[int, List[int]]]] = [
        [dict() for _ in range(n_rounds)] for _ in range(p)
    ]
    # remember, per edge, how to find its row: (source, index)
    edge_src_kind = [np.zeros(e_max, np.int8) for _ in range(p)]  # 0 loc 1 cache 2 fetch
    edge_src_idx = [np.zeros(e_max, np.int64) for _ in range(p)]
    for k in range(p):
        u_l, v_g = works[k]
        owners = part.owner(v_g)
        slots = cache_slot_of(v_g)
        pos_maps: List[Dict[Tuple[int, int], int]] = [
            dict() for _ in range(n_rounds)
        ]
        for e in range(v_g.size):
            r = e // e_chunk
            v = int(v_g[e])
            if owners[e] == k:
                edge_src_kind[k][e] = 0
                edge_src_idx[k][e] = v - part.lo(k)
            elif slots[e] >= 0:
                edge_src_kind[k][e] = 1
                edge_src_idx[k][e] = slots[e]
            else:
                q = int(owners[e])
                lst = requests[k][r].setdefault(q, [])
                v_local = v - part.lo(q)
                key = (q, v_local)
                pm = pos_maps[r]
                if dedup_rounds and key in pm:
                    pos = pm[key]
                else:
                    pos = len(lst)
                    lst.append(v_local)
                    pm[key] = pos
                edge_src_kind[k][e] = 2
                edge_src_idx[k][e] = q * 10**9 + pos  # resolved after S_max known

    s_max = 1
    for k in range(p):
        for r in range(n_rounds):
            for q, lst in requests[k][r].items():
                s_max = max(s_max, len(lst))

    # serve lists: serve_idx[q, r, k] = rows q sends to k in round r
    serve_idx = np.full((p, n_rounds, p, s_max), n_loc, np.int32)
    for k in range(p):
        for r in range(n_rounds):
            for q, lst in requests[k][r].items():
                serve_idx[q, r, k, : len(lst)] = lst

    # finalize combined indices
    base_cache = n_loc + 1
    base_fetch = n_loc + 1 + c
    edge_u = np.full((p, e_max), n_loc, np.int32)
    edge_vc = np.full((p, e_max), n_loc, np.int32)  # phantom
    edge_mask = np.zeros((p, e_max), bool)
    for k in range(p):
        u_l, v_g = works[k]
        ne = u_l.size
        edge_u[k, :ne] = u_l
        edge_mask[k, :ne] = True
        kind = edge_src_kind[k]
        idx = edge_src_idx[k]
        vc = np.full(e_max, n_loc, np.int64)
        loc = kind == 0
        vc[: ne][loc[:ne]] = idx[:ne][loc[:ne]]
        cch = kind == 1
        vc[: ne][cch[:ne]] = base_cache + idx[:ne][cch[:ne]]
        ftc = kind == 2
        q = idx // 10**9
        pos = idx % 10**9
        vc[: ne][ftc[:ne]] = base_fetch + (q * s_max + pos)[:ne][ftc[:ne]]
        edge_vc[k] = vc.astype(np.int32)

    return ShardedLCCProblem(
        rows_ext=rows_ext,
        degrees=degrees,
        edge_u=edge_u,
        edge_vc=edge_vc,
        edge_mask=edge_mask,
        serve_idx=serve_idx,
        cache_rows=cache_rows,
        n=csr.n,
        p=p,
        width=w,
        n_loc=n_loc,
        e_max=e_max,
        n_rounds=n_rounds,
        s_max=s_max,
        cache_ids=cache_ids,
    )


# --------------------------------------------------------------------------
# Host trace simulator: replays the RMA access stream through ClampiCache.
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RMATraceStats:
    """Per-device communication statistics for one LCC computation."""

    remote_gets: np.ndarray  # [p] int64 — adjacency gets issued (pre-cache)
    remote_reads_unique: np.ndarray  # [p]
    comm_time: np.ndarray  # [p] float — modeled, caches applied
    compute_edges: np.ndarray  # [p]
    remote_bytes: np.ndarray = None  # [p] bytes fetched AFTER caching
    remote_bytes_raw: np.ndarray = None  # [p] bytes demanded (pre-cache)
    post_cache_gets: np.ndarray = None  # [p] gets that miss the caches
    offsets_stats: List[CacheStats] = dataclasses.field(default_factory=list)
    adj_stats: List[CacheStats] = dataclasses.field(default_factory=list)

    @property
    def makespan(self) -> float:
        return float(self.comm_time.max()) if self.comm_time.size else 0.0


def simulate_rma_lcc(
    csr: CSRGraph,
    p: int,
    *,
    offsets_cache_bytes: int = 0,
    adj_cache_bytes: int = 0,
    use_degree_score: bool = False,
    network: Optional[NetworkModel] = None,
    table_slots_offsets: Optional[int] = None,
    table_slots_adj: Optional[int] = None,
    positional_weight: float = 0.5,
) -> RMATraceStats:
    """Replay the per-device remote-access stream of Algorithm 3.

    Each remote adjacency read = one get on w_offsets (8 B) + one get on
    w_adj (deg * 4 B), both cached when cache bytes > 0 (always-cache
    mode). ``use_degree_score`` switches the adjacency cache's victim
    selection to the paper's application-defined degree score.
    """
    net = network or NetworkModel()
    part = partition_1d(csr.n, p)
    deg = csr.degrees
    remote_gets = np.zeros(p, np.int64)
    uniq = np.zeros(p, np.int64)
    comm = np.zeros(p, np.float64)
    edges = np.zeros(p, np.int64)
    bytes_after = np.zeros(p, np.int64)
    bytes_raw = np.zeros(p, np.int64)
    gets_after = np.zeros(p, np.int64)
    o_stats: List[CacheStats] = []
    a_stats: List[CacheStats] = []
    for k in range(p):
        u_l, v_g = _edge_worklist(csr, part, k)
        owners = part.owner(v_g)
        remote = v_g[owners != k]
        remote_gets[k] = remote.size
        uniq[k] = np.unique(remote).size
        edges[k] = v_g.size
        c_off = (
            ClampiCache(
                offsets_cache_bytes,
                table_slots_offsets
                or max(1, offsets_cache_bytes // OFFSET_ENTRY_BYTES),
                network=net,
                positional_weight=positional_weight,
            )
            if offsets_cache_bytes > 0
            else None
        )
        # hash-table sizing heuristic of §III-B1: n * 0.5**alpha with alpha=2
        default_adj_slots = max(1, int(csr.n * 0.25))
        c_adj = (
            ClampiCache(
                adj_cache_bytes,
                table_slots_adj or default_adj_slots,
                network=net,
                positional_weight=positional_weight,
            )
            if adj_cache_bytes > 0
            else None
        )
        t = 0.0
        for v in remote:
            v = int(v)
            size_adj = int(deg[v]) * ID_BYTES
            score = float(deg[v]) if use_degree_score else None
            bytes_raw[k] += OFFSET_ENTRY_BYTES + size_adj
            if c_off is not None:
                if not c_off.get(v, OFFSET_ENTRY_BYTES):
                    bytes_after[k] += OFFSET_ENTRY_BYTES
                    gets_after[k] += 1
            else:
                t += net.remote(OFFSET_ENTRY_BYTES)
                bytes_after[k] += OFFSET_ENTRY_BYTES
                gets_after[k] += 1
            if c_adj is not None:
                if not c_adj.get(v, size_adj, score=score):
                    bytes_after[k] += size_adj
                    gets_after[k] += 1
            else:
                t += net.remote(size_adj)
                bytes_after[k] += size_adj
                gets_after[k] += 1
        if c_off is not None:
            t += c_off.stats.comm_time
            o_stats.append(c_off.stats)
        if c_adj is not None:
            t += c_adj.stats.comm_time
            a_stats.append(c_adj.stats)
        comm[k] = t
    return RMATraceStats(
        remote_gets=remote_gets,
        remote_reads_unique=uniq,
        comm_time=comm,
        compute_edges=edges,
        remote_bytes=bytes_after,
        remote_bytes_raw=bytes_raw,
        post_cache_gets=gets_after,
        offsets_stats=o_stats,
        adj_stats=a_stats,
    )
