"""Edge-centric triangle counting and LCC (paper §II-C, §II-D, Alg. 3).

Single-node reference implementations:

- ``triangles_per_vertex`` (numpy, exact, any intersection method): the
  oracle that all distributed/device paths are validated against.
- ``lcc_scores``: paper Eq. (2) (undirected).
- ``triangles_padded_jnp``: the vectorized single-device jnp path over
  padded rows — the building block the distributed engines reuse.

Semantics: with full (both-direction) adjacency, define
``S(i) = sum_{j in adj(i)} |adj(i) ∩ adj(j)|``. Every edge (j,k) between
two neighbors of i is seen twice in S(i), so the number of edges among
neighbors (== #triangles through i) is ``T(i) = S(i)/2`` and global
``#triangles = sum_i T(i) / 3``. The paper's upper-triangle offset trick
(count only k > j) is exposed via ``upper_only`` for the TC-only path.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax.numpy as jnp

from .csr import CSRGraph
from .intersect import (
    count_bsearch_np,
    hybrid_scalar,
    count_bsearch_jnp,
    count_pairwise_jnp,
)

__all__ = [
    "triangles_per_vertex",
    "global_triangle_count",
    "lcc_scores",
    "triangles_padded_jnp",
    "lcc_from_counts_jnp",
]


def triangles_per_vertex(
    csr: CSRGraph,
    method: Callable[[np.ndarray, np.ndarray], int] = count_bsearch_np,
    *,
    upper_only: bool = False,
) -> np.ndarray:
    """T(i) per vertex (undirected, both directions stored).

    ``upper_only`` counts each triangle once per *edge* (k > j offset, paper
    §II-C) — used by the TC benchmark; LCC needs the full per-vertex count.
    """
    t = np.zeros(csr.n, np.int64)
    for i in range(csr.n):
        row_i = csr.row(i)
        s = 0
        for j in row_i:
            row_j = csr.row(int(j))
            if upper_only:
                row_j = row_j[np.searchsorted(row_j, j + 1) :]
            s += method(row_i, row_j)
        t[i] = s
    if not upper_only:
        assert np.all(t % 2 == 0)
        t //= 2
    return t


def global_triangle_count(csr: CSRGraph) -> int:
    t = triangles_per_vertex(csr)
    total = int(t.sum())
    assert total % 3 == 0
    return total // 3


def lcc_scores(csr: CSRGraph, t: np.ndarray | None = None) -> np.ndarray:
    """Paper Eq. (2): C(i) = 2*T(i) / (deg(i) * (deg(i) - 1))."""
    if t is None:
        t = triangles_per_vertex(csr)
    deg = csr.degrees.astype(np.float64)
    denom = deg * (deg - 1.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        c = 2.0 * t / denom
    return np.where(denom > 0, c, 0.0)


# --------------------------------------------------------------------------
# jnp padded single-device path.
# --------------------------------------------------------------------------
def triangles_padded_jnp(
    rows: jnp.ndarray,  # [n, W] sorted padded rows, sentinel = n
    degrees: jnp.ndarray,  # [n] int32
    sentinel: int,
    *,
    method: str = "bsearch",
) -> jnp.ndarray:
    """Per-vertex T(i) from padded rows (single device, fits memory).

    For each vertex i and neighbor slot s: j = rows[i, s]; gather row_j and
    count |row_i ∩ row_j|. Padding slots gather row of the sentinel vertex —
    a zero-degree phantom row of sentinels — and contribute 0.
    """
    n, w = rows.shape
    # phantom row for the sentinel id so gathers are in-bounds
    rows_ext = jnp.concatenate(
        [rows, jnp.full((1, w), sentinel, rows.dtype)], axis=0
    )
    nbr_rows = rows_ext[rows]  # [n, W, W] — rows of each neighbor
    rows_b = jnp.broadcast_to(rows[:, None, :], (n, w, w))
    if method == "bsearch":
        flat_a = rows_b.reshape(n * w, w)
        flat_b = nbr_rows.reshape(n * w, w)
        cnt = count_bsearch_jnp(flat_a, flat_b, sentinel).reshape(n, w)
    elif method == "pairwise":
        cnt = count_pairwise_jnp(rows_b, nbr_rows, sentinel)
    else:
        raise ValueError(method)
    valid = rows < sentinel
    s = jnp.where(valid, cnt, 0).sum(axis=1)
    return (s // 2).astype(jnp.int32)


def lcc_from_counts_jnp(t: jnp.ndarray, degrees: jnp.ndarray) -> jnp.ndarray:
    deg = degrees.astype(jnp.float32)
    denom = deg * (deg - 1.0)
    return jnp.where(denom > 0, 2.0 * t.astype(jnp.float32) / denom, 0.0)
