"""Public API for triangle counting and LCC (the paper's contribution).

Single entry points used by examples/benchmarks/launchers:

- ``lcc_single(csr)``            exact single-node reference
- ``lcc_distributed(csr, p)``    compiled shard_map engine (needs p devices)
- ``triangle_count(csr)``        global triangle count
- ``lcc_simulated(csr, p, ...)`` host trace sim with CLaMPI caches (stats)
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .csr import CSRGraph, from_edges, random_relabel, remove_low_degree
from .rma import simulate_rma_lcc
from .triangles import lcc_scores, triangles_per_vertex

__all__ = [
    "prepare_graph",
    "lcc_single",
    "lcc_distributed",
    "triangle_count",
    "lcc_simulated",
]


def prepare_graph(
    edges: np.ndarray,
    n: int,
    *,
    undirected: bool = True,
    relabel_seed: Optional[int] = None,
    drop_low_degree: bool = True,
):
    """Paper §II-B preprocessing: simple graph, degree<2 removal, optional
    random relabeling (for degree-ordered inputs)."""
    csr = from_edges(edges, n, undirected=undirected)
    keep = np.arange(csr.n, dtype=np.int64)
    if drop_low_degree:
        csr, keep = remove_low_degree(csr)
    if relabel_seed is not None:
        csr = random_relabel(csr, relabel_seed)
    return csr, keep


def lcc_single(csr: CSRGraph) -> np.ndarray:
    return lcc_scores(csr)


def triangle_count(csr: CSRGraph) -> int:
    t = triangles_per_vertex(csr)
    return int(t.sum()) // 3


def lcc_distributed(csr: CSRGraph, p: int, **kw):
    from .async_engine import run_distributed_lcc

    return run_distributed_lcc(csr, p, **kw)


def lcc_simulated(csr: CSRGraph, p: int, **kw):
    return simulate_rma_lcc(csr, p, **kw)
