from . import csr, partition, intersect, triangles, cache, rma, lcc  # noqa: F401
