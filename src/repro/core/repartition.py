"""Online repartitioning: bounded ownership migration under drift.

The streaming engines mutate the degree sequence (hub churn under
``rmat_adversarial_stream`` is the adversarial case), so the cuts a
``HubPartition`` was built with slowly stop balancing. This module
plans *bounded* boundary moves back toward the degree-weighted balance
point and lets ``ShardedRuntime.migrate`` apply them live:

- ``plan_repartition`` compares the current cuts against freshly
  balanced cuts for the live degree sequence and shifts each boundary
  at most ``max_moves`` rows toward its target (monotonicity is
  enforced, so blocks never invert);
- ``Rebalancer`` watches the runtime's per-rank read counters (the
  same data the ``load_imbalance`` gauge summarizes) and triggers a
  plan only when imbalance crosses ``trigger``, with hysteresis and a
  cooldown so a single hot batch cannot thrash ownership back and
  forth.

Migration itself (cache invalidation fanout, device-residency handoff,
schedule rebuild) lives in ``ShardedRuntime.migrate``; the planner is
pure and side-effect free so tests can exercise it in isolation. See
docs/partitioning.md for the full protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .partition import HubPartition, balanced_cuts

__all__ = ["MigrationPlan", "plan_repartition", "Rebalancer"]


@dataclasses.dataclass
class MigrationPlan:
    """A bounded cut move: apply with ``runtime.migrate(plan.new_cuts)``."""

    old_cuts: np.ndarray
    new_cuts: np.ndarray
    moved: np.ndarray  # vertex ids whose owner changes

    @property
    def n_moved(self) -> int:
        return int(self.moved.size)


def _moved_ids(old_cuts: np.ndarray, new_cuts: np.ndarray) -> np.ndarray:
    """Vertex ids whose owner differs between two cut vectors — the
    union of the half-open ranges each boundary swept over."""
    ids = []
    for k in range(1, len(old_cuts) - 1):
        a, b = int(old_cuts[k]), int(new_cuts[k])
        if a != b:
            ids.append(np.arange(min(a, b), max(a, b), dtype=np.int64))
    if not ids:
        return np.zeros(0, np.int64)
    return np.unique(np.concatenate(ids))


def plan_repartition(
    part: HubPartition,
    degrees: np.ndarray,
    *,
    max_moves: int = 4096,
) -> Optional[MigrationPlan]:
    """Plan a bounded step from ``part.cuts`` toward the balanced cuts
    for the *current* degree sequence. Returns None when already at the
    target. Each interior boundary moves at most ``max_moves`` rows;
    repeated calls converge to the full rebalance."""
    degrees = np.asarray(degrees, np.int64)
    assert degrees.size == part.n, (degrees.size, part.n)
    weights = 1 + np.minimum(degrees, part.threshold)
    target = balanced_cuts(weights, part.p)
    old = part.cuts.astype(np.int64).copy()
    shift = np.clip(target - old, -int(max_moves), int(max_moves))
    new = old + shift
    new[0], new[-1] = 0, part.n
    new = np.maximum.accumulate(np.clip(new, 0, part.n))
    moved = _moved_ids(old, new)
    if moved.size == 0:
        return None
    return MigrationPlan(old_cuts=old, new_cuts=new, moved=moved)


class Rebalancer:
    """Gauge-driven migration trigger with hysteresis.

    Reads the runtime's per-rank ``local_reads + remote_reads`` deltas
    since the last check (the instantaneous form of the
    ``load_imbalance`` gauge), and fires ``plan_repartition`` +
    ``runtime.migrate`` only when the windowed imbalance exceeds
    ``trigger``. After a migration the trigger arms again only once
    ``cooldown`` checks have passed — ownership moves are bounded AND
    rate-limited. Call ``maybe_rebalance`` between batches only: the
    runtime is single-writer and migration mid-batch would tear the
    measured-vs-modeled reconciliation.
    """

    def __init__(
        self,
        runtime,
        *,
        trigger: float = 1.25,
        max_moves: int = 4096,
        cooldown: int = 2,
        hub_threshold: Optional[int] = None,
        refresh: bool = True,
        reads=None,
    ):
        self.runtime = runtime
        self.trigger = float(trigger)
        self.max_moves = int(max_moves)
        self.cooldown = int(cooldown)
        # reads: optional zero-arg callable returning the per-rank
        # cumulative load counters to window over. Default is the
        # runtime's provider read stats (the serving load gauge); the
        # streaming launcher passes the sharded-worklist pair counts
        # instead, since its delta replay does not flow through
        # fetch_rows.
        self._reads_fn = reads
        # refresh=True re-derives the hub set from the live degrees
        # before each planned migration (hub_threshold=None recomputes
        # the default threshold too) — required when the partition was
        # built against an empty store (stream_run) and the heavy tail
        # only emerges as the stream applies.
        self.hub_threshold = hub_threshold
        self.refresh = bool(refresh)
        self._cool = 0
        self._last_reads = self._reads()
        self.migrations = 0
        self.rows_moved = 0

    def _reads(self) -> np.ndarray:
        if self._reads_fn is not None:
            return np.asarray(self._reads_fn(), np.float64).copy()
        return np.array(
            [st.local_reads + st.remote_reads for st in self.runtime.stats],
            np.float64,
        )

    def window_imbalance(self) -> float:
        """max/mean of per-rank reads since the previous check (1.0 is
        perfectly balanced; ranks with no reads contribute 0)."""
        now = self._reads()
        delta = now - self._last_reads
        self._last_reads = now
        mean = float(delta.mean())
        if mean <= 0:
            return 1.0
        return float(delta.max()) / mean

    def maybe_rebalance(self, degrees: np.ndarray) -> Optional[MigrationPlan]:
        """Check the gauge; migrate if it crossed the trigger. Returns
        the applied plan (or None). Safe to call every batch."""
        imb = self.window_imbalance()
        if self._cool > 0:
            self._cool -= 1
            return None
        part = self.runtime.part
        if not isinstance(part, HubPartition):
            return None
        if imb <= self.trigger:
            return None
        if self.refresh:
            part.refresh_hubs(degrees, threshold=self.hub_threshold)
        plan = plan_repartition(part, degrees, max_moves=self.max_moves)
        if plan is None:
            return None
        self.runtime.migrate(plan.new_cuts)
        self.migrations += 1
        self.rows_moved += plan.n_moved
        self._cool = self.cooldown
        return plan
