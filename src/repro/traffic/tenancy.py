"""Multi-tenant admission: per-tenant rate limits and cache shares.

A shared serving stack needs isolation in two places:

1. **The front door** — each tenant gets a token bucket
   (``rate_qps`` sustained, ``burst`` depth). A submit that finds the
   tenant's bucket empty is shed with reason ``"quota"`` *before* it
   can occupy queue depth — an aggressive tenant saturates its own
   budget, not the scheduler.
2. **The cache** — each tenant gets a byte share of ``ClampiCache``
   capacity. Entries are tenant-tagged at admission; eviction is
   quota-aware (a tenant over its share evicts its *own* entries first,
   and general victim selection spares tenants strictly under their
   share), so one hot tenant cannot flush another's working set.
   Per-tenant request/byte counters surface in ``ProviderStats``.

The shares are a soft fairness contract, not a hard partition: bytes a
tenant is not using remain available to everyone (work-conserving),
and are reclaimed from over-share tenants on demand.

``TenantQuotas`` is the one object both layers read; construct it with
``TenantQuotas.uniform(n)`` for symmetric tenants or per-tenant
``TenantSpec`` entries for skewed contracts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

__all__ = ["TokenBucket", "TenantSpec", "TenantQuotas", "assign_tenants"]


class TokenBucket:
    """Lazy-refill token bucket: ``rate`` tokens/s up to ``burst``.

    No background thread — tokens owed since the last call are credited
    inside ``try_take``, so the bucket works under any clock (virtual,
    hybrid, wall)."""

    def __init__(self, rate: float, burst: float, *, t0: float = 0.0):
        assert rate > 0.0 and burst >= 1.0
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)  # start full: cold tenants can burst
        self._t = float(t0)

    def _refill(self, now: float) -> None:
        if now > self._t:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
        self._t = max(self._t, now)

    def try_take(self, now: float, n: float = 1.0) -> bool:
        self._refill(now)
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    def level(self, now: float) -> float:
        self._refill(now)
        return self._tokens


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract: sustained rate, burst depth, cache share
    (fraction of cache capacity; shares are normalized across tenants
    if they sum past 1)."""

    name: str
    rate_qps: float = 100.0
    burst: float = 16.0
    cache_share: float = 0.0  # 0 = no reserved share (best effort)


class TenantQuotas:
    """Admission + accounting for a fixed tenant set."""

    def __init__(self, specs: Sequence[TenantSpec], *, t0: float = 0.0):
        names = [s.name for s in specs]
        assert len(names) == len(set(names)), "duplicate tenant names"
        self.specs: Dict[str, TenantSpec] = {s.name: s for s in specs}
        self._buckets: Dict[str, TokenBucket] = {
            s.name: TokenBucket(s.rate_qps, s.burst, t0=t0) for s in specs
        }
        self.admitted: Dict[str, int] = {s.name: 0 for s in specs}
        self.rejected: Dict[str, int] = {s.name: 0 for s in specs}

    @staticmethod
    def uniform(n: int, *, rate_qps: float = 100.0, burst: float = 16.0,
                cache_share: Optional[float] = None,
                t0: float = 0.0) -> "TenantQuotas":
        """n symmetric tenants ``t0..t{n-1}`` splitting the cache
        evenly (pass ``cache_share=0.0`` for best-effort tenants)."""
        share = (1.0 / n) if cache_share is None else float(cache_share)
        return TenantQuotas(
            [TenantSpec(f"t{i}", rate_qps=rate_qps, burst=burst,
                        cache_share=share) for i in range(n)],
            t0=t0,
        )

    @property
    def tenants(self) -> List[str]:
        return list(self.specs)

    def admit(self, tenant: str, now: float) -> bool:
        """Charge one request against the tenant's bucket. Unknown or
        empty tenant tags are never rate-limited (the untagged path
        must keep working for single-tenant deployments)."""
        bucket = self._buckets.get(tenant)
        if bucket is None:
            return True
        ok = bucket.try_take(now)
        (self.admitted if ok else self.rejected)[tenant] += 1
        return ok

    def cache_shares(self) -> Dict[str, float]:
        """Per-tenant byte-share fractions, normalized to sum ≤ 1."""
        raw = {n: s.cache_share for n, s in self.specs.items()
               if s.cache_share > 0.0}
        total = sum(raw.values())
        if total > 1.0:
            raw = {n: v / total for n, v in raw.items()}
        return raw

    def bucket_levels(self, now: Optional[float] = None) -> Dict[str, float]:
        """Tokens per tenant; ``now=None`` reads as-of each bucket's
        last refill (pure snapshot, no clock needed)."""
        return {n: b.level(b._t if now is None else now)
                for n, b in self._buckets.items()}

    def counters(self) -> Dict[str, Dict[str, int]]:
        return {"admitted": dict(self.admitted),
                "rejected": dict(self.rejected)}


def assign_tenants(queries: Sequence, tenants: Sequence[str], *,
                   rng: Optional[np.random.Generator] = None,
                   weights: Optional[Mapping[str, float]] = None) -> List:
    """Tag each query with a tenant, sampled i.i.d. (optionally
    weighted — skew one tenant hot to exercise isolation). Deterministic
    under the caller's rng; returns new frozen Query instances."""
    rng = rng or np.random.default_rng(0)
    names = list(tenants)
    if weights is not None:
        w = np.asarray([weights.get(n, 0.0) for n in names], np.float64)
        assert w.sum() > 0.0
        p = w / w.sum()
    else:
        p = None
    idx = rng.choice(len(names), size=len(queries), p=p)
    return [dataclasses.replace(q, tenant=names[i])
            for q, i in zip(queries, idx)]
