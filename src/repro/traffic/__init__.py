"""Production traffic plane in front of the serving stack.

Four pillars, each deployed through the existing serving layers rather
than beside them:

- ``arrivals``  — open-loop arrival processes (Poisson / diurnal /
  burst / replayable trace files) + the virtual-time clocks
  (``VirtualClock``, ``HybridClock``) injectable into
  ``MicrobatchScheduler``, so p99 measures queueing, not batch compute.
- ``slo``       — per-class deadlines (lcc / triangles /
  common_neighbors / top_k_lcc), EDF window flush, shed-by-class.
- ``tenancy``   — per-tenant token-bucket admission and cache byte
  shares with quota-aware eviction in ``ClampiCache``.
- ``scoring``   — live request-frequency EWMA (cachescope's exact
  replay formula) blended with degree, feeding both ``ClampiCache``
  and ``ResidencyManager`` scores.
- ``loadgen``   — the open-loop runner tying trace + scheduler + clock
  into latency-vs-offered-load reports.

See docs/serving.md for the end-to-end story.
"""
from .arrivals import (
    ArrivalTrace,
    HybridClock,
    VirtualClock,
    burst_arrivals,
    diurnal_arrivals,
    make_arrivals,
    poisson_arrivals,
)
from .loadgen import OpenLoopReport, run_open_loop
from .scoring import WorkloadScorer
from .slo import DEFAULT_DEADLINES_S, SLOPolicy
from .tenancy import TenantQuotas, TenantSpec, TokenBucket, assign_tenants

__all__ = [
    "ArrivalTrace",
    "VirtualClock",
    "HybridClock",
    "poisson_arrivals",
    "diurnal_arrivals",
    "burst_arrivals",
    "make_arrivals",
    "SLOPolicy",
    "DEFAULT_DEADLINES_S",
    "TokenBucket",
    "TenantSpec",
    "TenantQuotas",
    "assign_tenants",
    "WorkloadScorer",
    "OpenLoopReport",
    "run_open_loop",
]
