"""Live workload-driven cache scores: request-frequency EWMA × degree.

The paper's CLaMPI extension argues application-defined scores steering
eviction beat generic LRU — but a *static* degree prior only predicts
reuse when popularity tracks degree. Real request streams drift: a
low-degree vertex a hot query keeps touching deserves cache residency
over a high-degree vertex nobody asks about. PR 7's cachescope replay
already showed a frequency-EWMA score winning offline on recorded
traces; this module deploys that exact estimator live.

``WorkloadScorer`` maintains, per vertex, the same recency-weighted
access frequency the cachescope ``"ewma"`` replay policy computes —
bit-identical update rule, so the live score path is validated by
replaying the very trace it produced:

    t   — global access counter (one tick per requested vertex)
    f   = 1 + f_prev * decay ** (t - t_prev)      # on access
    f(t)=     f_prev * decay ** (t - t_prev)      # read without access

The deployed score blends frequency with the degree prior::

    score = (1 - blend) * deg / deg_scale + blend * f / f_cap

with ``f_cap = 1 / (1 - decay)`` (the fixed point of the update under
constant access — an always-hot key saturates toward 1). ``blend=0``
degenerates to the pure-degree prior; ``blend=1`` is pure frequency.
The default 0.7 lets frequency dominate while degree still breaks ties
among never-accessed vertices — which matters for ``ResidencyManager``,
whose rebuild only admits rows with score > 0: with ``blend < 1``
every nonzero-degree row keeps a nonzero score before its first access.

The same scorer feeds both tiers: ``cache_score`` per-key for
``ClampiCache`` admission/eviction, ``score_array`` vectorized over all
vertices for ``ResidencyManager`` hot-set selection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["WorkloadScorer"]


class WorkloadScorer:
    def __init__(self, *, blend: float = 0.7, decay: float = 0.98,
                 deg_scale: Optional[float] = None):
        assert 0.0 <= blend <= 1.0
        assert 0.0 < decay < 1.0
        self.blend = float(blend)
        self.decay = float(decay)
        # f_cap: sum of decay^k — the saturation frequency of a key
        # accessed on every tick
        self.f_cap = 1.0 / (1.0 - self.decay)
        self.deg_scale = float(deg_scale) if deg_scale else 1.0
        self._freq: Dict[int, Tuple[float, int]] = {}  # key -> (f, t)
        self._t = 0
        self.n_observed = 0

    def set_degree_scale(self, max_degree: float) -> None:
        """Normalize the degree term by the graph's max degree so both
        blend terms live in [0, 1]."""
        self.deg_scale = max(1.0, float(max_degree))

    # ---------------- live update path ----------------
    def observe(self, key: int) -> float:
        """One requested vertex: advance the global access clock and
        bump the key's EWMA (cachescope's exact update rule). Returns
        the new frequency."""
        self._t += 1
        self.n_observed += 1
        f_prev, t_prev = self._freq.get(int(key), (0.0, self._t))
        f = 1.0 + f_prev * (self.decay ** (self._t - t_prev))
        self._freq[int(key)] = (f, self._t)
        return f

    def freq(self, key: int) -> float:
        """Current decayed frequency — a read, not an access."""
        f_prev, t_prev = self._freq.get(int(key), (0.0, self._t))
        return f_prev * (self.decay ** (self._t - t_prev))

    # ---------------- score surfaces ----------------
    def cache_score(self, key: int, degree: float) -> float:
        """Blended score for one key (host-cache admission/eviction).
        Call after ``observe(key)`` so the access that triggered the
        fetch is already counted."""
        f_prev, t_prev = self._freq.get(int(key), (0.0, self._t))
        f = f_prev * (self.decay ** (self._t - t_prev))
        return ((1.0 - self.blend) * float(degree) / self.deg_scale
                + self.blend * min(1.0, f / self.f_cap))

    def score_array(self, degrees: np.ndarray) -> np.ndarray:
        """Blended scores for ALL vertices (device-residency rebuild).
        Vectorized: decay every tracked frequency to the current tick,
        scatter into a dense array, blend with the degree prior."""
        deg = np.asarray(degrees, np.float64)
        f = np.zeros(deg.shape[0], np.float64)
        if self._freq:
            keys = np.fromiter(self._freq.keys(), np.int64,
                               count=len(self._freq))
            fs = np.fromiter((v[0] for v in self._freq.values()),
                             np.float64, count=len(self._freq))
            ts = np.fromiter((v[1] for v in self._freq.values()),
                             np.int64, count=len(self._freq))
            live = keys < deg.shape[0]
            f[keys[live]] = fs[live] * (
                self.decay ** (self._t - ts[live]).astype(np.float64)
            )
        return ((1.0 - self.blend) * deg / self.deg_scale
                + self.blend * np.minimum(1.0, f / self.f_cap))

    def reset(self) -> None:
        self._freq.clear()
        self._t = 0
