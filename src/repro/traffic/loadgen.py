"""Open-loop load generator: drive the scheduler from an arrival trace.

The runner walks a fixed ``ArrivalTrace``: for each arrival it lifts
the clock's virtual floor to the arrival time, submits the query
stamped with that arrival (``submit(q, at=t)``), and polls the
scheduler — which dispatches whatever its deadline/SLO policy says is
due. Crucially the schedule never waits for the server: if a batch's
real service time overruns the next arrival, that query is submitted
*late relative to its own arrival stamp*, and the backlog shows up as
queueing delay in the measured latency. That is the open-loop property
the latency-vs-offered-load curve needs — under saturation, p99 grows
with queue depth instead of flattening at batch compute time.

With a ``HybridClock`` the idle gaps between arrivals are free (the
floor jumps) while engine compute advances time at true cost; with a
``VirtualClock`` plus a caller-managed service model the whole run is
deterministic (tests). After the last arrival the runner drains the
queue by advancing time to each next-due deadline — shedding still
applies, so queries that were doomed at drain time are shed, not
quietly served.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .arrivals import ArrivalTrace, HybridClock

__all__ = ["OpenLoopReport", "run_open_loop"]


@dataclasses.dataclass
class OpenLoopReport:
    """One open-loop run: offered vs achieved load + the scheduler's
    latency summary (queueing included)."""

    process: str
    offered_qps: float
    n_arrivals: int
    n_admitted: int
    n_served: int
    duration_s: float
    summary: object  # LatencySummary
    by_class: Dict[str, object]
    results: list = dataclasses.field(default_factory=list, repr=False)

    @property
    def achieved_qps(self) -> float:
        return self.n_served / self.duration_s if self.duration_s > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "process": self.process,
            "offered_qps": round(self.offered_qps, 3),
            "achieved_qps": round(self.achieved_qps, 3),
            "n_arrivals": self.n_arrivals,
            "n_admitted": self.n_admitted,
            "n_served": self.n_served,
            "duration_s": round(self.duration_s, 4),
            "latency": self.summary.as_dict(),
            "by_class": {c: s.as_dict() for c, s in self.by_class.items()},
        }


def run_open_loop(
    scheduler,
    queries: Sequence,
    arrivals: ArrivalTrace,
    *,
    clock: Optional[object] = None,
    keep_results: bool = True,
) -> OpenLoopReport:
    """Replay ``queries[i]`` at ``arrivals.t[i]`` through ``scheduler``.

    ``clock`` must be the same object the scheduler reads (pass it to
    both); defaults to a fresh ``HybridClock`` ONLY if the scheduler
    was built with one via ``scheduler._clock`` — otherwise arrival
    stamps and the scheduler's notion of now would disagree.
    """
    n = min(len(queries), len(arrivals))
    assert n > 0, "empty run"
    clock = clock if clock is not None else scheduler._clock
    assert clock is scheduler._clock or isinstance(clock, HybridClock), (
        "loadgen and scheduler must share one clock"
    )
    # The trace is relative: shift it forward so the first arrival is
    # never before "now" (a HybridClock has been running through setup;
    # backdating arrivals into that dead time would charge queueing
    # delay nothing ever queued for). Under a fresh VirtualClock the
    # shift is zero and runs stay bit-deterministic.
    shift = max(0.0, float(clock()) - float(arrivals.t[0]))
    results: List = []
    t_start = float(arrivals.t[0]) + shift
    admitted = 0
    def _fire_timers_until(t_next: float) -> None:
        # A real server's flush timer fires between arrivals; polling
        # only at arrival instants would let deadlines expire in the
        # gaps (shed where a dispatch was promised). Advance to each
        # due time that falls before the next arrival and poll there.
        prev_due = -float("inf")
        while scheduler.pending:
            due_at = scheduler.next_due_at()
            if due_at is None or due_at >= t_next:
                return
            if due_at <= prev_due:  # no forward progress: livelock guard
                return
            prev_due = due_at
            clock.advance_to(due_at)
            results.extend(scheduler.poll())

    for i in range(n):
        t_arr = float(arrivals.t[i]) + shift
        _fire_timers_until(t_arr)
        clock.advance_to(t_arr)
        if scheduler.submit(queries[i], at=t_arr):
            admitted += 1
        results.extend(scheduler.poll())

    # Drain: advance time to each next dispatch deadline until the
    # queue empties. Shed policies keep applying — a query that is
    # already past shed_wait at drain time is dropped, as it would be
    # in steady state.
    while scheduler.pending:
        out = scheduler.poll()
        if out:
            results.extend(out)
            continue
        due_at = scheduler.next_due_at()
        if due_at is None or not hasattr(clock, "advance_to"):
            # no deadline machinery to wait for: close out the queue
            results.extend(scheduler.flush())
            break
        clock.advance_to(max(due_at, clock() + 1e-9))

    duration = max(float(clock()) - t_start, 0.0)
    return OpenLoopReport(
        process=arrivals.process,
        offered_qps=arrivals.offered_qps,
        n_arrivals=n,
        n_admitted=admitted,
        n_served=len(results),
        duration_s=duration,
        summary=scheduler.latency_summary(),
        by_class=scheduler.recorder.summary_by_class(),
        results=results if keep_results else [],
    )
