"""Per-class latency SLOs for the query service.

Every query carries an SLO class — its ``QueryKind`` name
(``lcc`` / ``triangles`` / ``common_neighbors`` / ``top_k_lcc``) — and
every class has a deadline: the submit-to-completion budget the service
promises. The scheduler turns the policy into behavior:

- **absolute deadlines** — each admitted query is stamped
  ``deadline = t_submit + budget(class)``;
- **EDF window selection** — when a window dispatches, pending queries
  are taken in earliest-deadline-first order (stable on submit time),
  so a late-arriving tight-deadline query jumps a queue of loose ones;
- **deadline-driven flush** — a window becomes due ``headroom_s``
  before its most urgent deadline, instead of waiting out ``max_wait``;
- **shed-by-class** — a query whose deadline has strictly passed is
  rejected with reason ``"slo"`` (and counted against its class in
  ``LatencySummary.shed_by_class``) rather than served late: under
  overload the classes with tight budgets shed first, which is the
  policy's whole point.

Deadlines compose with, not replace, the scheduler's existing
``max_wait``/``shed_wait`` machinery — those bound *any* query's wait;
the SLO bounds each class's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping

__all__ = ["SLOPolicy", "DEFAULT_DEADLINES_S"]

# Per-class submit-to-completion budgets (seconds). Pair lookups
# (common_neighbors) are the interactive tier; single-vertex counts sit
# in the middle; top-k is an analytics scan that tolerates batching.
DEFAULT_DEADLINES_S: Dict[str, float] = {
    "common_neighbors": 0.050,
    "lcc": 0.100,
    "triangles": 0.100,
    "top_k_lcc": 0.500,
}


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Immutable deadline table + dispatch headroom.

    ``headroom_s`` is how far *before* the most urgent pending deadline
    the scheduler starts a window — the dispatch margin covering batch
    service time. 0 means "dispatch exactly at the deadline", which
    only meets the SLO if service were instantaneous; size it to a
    typical window's service time.
    """

    deadline_s: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_DEADLINES_S)
    )
    default_deadline_s: float = 0.250
    headroom_s: float = 0.0

    def __post_init__(self):
        assert self.default_deadline_s > 0.0
        assert self.headroom_s >= 0.0
        assert all(v > 0.0 for v in self.deadline_s.values())

    def budget(self, cls: str) -> float:
        """Latency budget (seconds) for an SLO class."""
        return float(self.deadline_s.get(cls, self.default_deadline_s))

    def deadline(self, cls: str, t_submit: float) -> float:
        """Absolute completion deadline for a query of ``cls``
        submitted at ``t_submit``."""
        return t_submit + self.budget(cls)

    def scaled(self, factor: float) -> "SLOPolicy":
        """Uniformly loosened/tightened copy (benchmark sweeps)."""
        assert factor > 0.0
        return SLOPolicy(
            deadline_s={k: v * factor for k, v in self.deadline_s.items()},
            default_deadline_s=self.default_deadline_s * factor,
            headroom_s=self.headroom_s,
        )
