"""Open-loop arrival processes and the virtual-time clocks they drive.

A closed-loop harness (submit, wait, repeat) can never observe
queueing: the next request only exists once the previous one finished,
so reported p99 is batch compute time, not waiting time. An *open-loop*
load generator fixes the arrival schedule in advance — requests arrive
when the process says they arrive, whether or not the server has kept
up — which is the only regime where latency-vs-offered-load curves mean
anything (p99 must rise as offered load approaches capacity).

Four arrival processes, all bit-reproducible under one seed:

- ``poisson``  — homogeneous Poisson: i.i.d. exponential interarrivals
  at ``rate`` qps, the memoryless baseline.
- ``diurnal``  — nonhomogeneous Poisson with a sinusoidal rate
  ``rate(t) = base * (1 + amplitude * sin(2*pi*t / period))``, sampled
  by Lewis-Shedler thinning — the day/night envelope of a user-facing
  service, compressed to a benchmark-sized period.
- ``burst``    — a two-state MMPP (Markov-modulated Poisson process):
  exponential-duration quiet/burst phases at ``rate`` / ``burst_rate``,
  the flash-crowd regime admission control exists for.
- ``trace``    — replayable timestamp files (``save``/``load``), so a
  recorded production schedule — or any synthetic one — can be re-run
  bit-exactly across policy changes.

Two clocks make the schedules testable and measurable:

- ``VirtualClock`` — fully manual time. Injected into
  ``MicrobatchScheduler`` it makes every deadline/shed/EDF policy a
  deterministic function of explicit ``advance`` calls (no sleeping in
  tests, no wall-clock noise).
- ``HybridClock`` — virtual floor + real elapsed time:
  ``now() = offset + perf_counter()``. ``advance_to`` raises the floor
  (an idle server skips ahead to the next arrival for free), while real
  compute between calls advances time at true cost — so open-loop
  latency = queueing (virtual) + service (measured), which is exactly
  the decomposition the offered-load curve plots.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

__all__ = [
    "VirtualClock",
    "HybridClock",
    "ArrivalTrace",
    "poisson_arrivals",
    "diurnal_arrivals",
    "burst_arrivals",
    "make_arrivals",
]

ARRIVALS_SCHEMA = "repro.traffic.arrivals/v1"


class VirtualClock:
    """Deterministic manual clock (callable, seconds). Inject as
    ``MicrobatchScheduler(clock=...)`` so deadline behavior is a pure
    function of ``advance``/``advance_to`` calls."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, "time never runs backwards"
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        """Monotone jump: no-op when ``t`` is in the past."""
        self._t = max(self._t, float(t))
        return self._t


class HybridClock:
    """Virtual floor + real elapsed time.

    ``now()`` advances with the process's real clock (so engine compute
    is charged at true cost), while ``advance_to(t)`` lifts the floor
    without waiting (so the gap until the next scheduled arrival is
    free). The open-loop runner uses this to simulate hours of arrival
    schedule in seconds of wall time without distorting service time.
    """

    def __init__(self, *, start: float = 0.0, time_fn=time.perf_counter):
        self._fn = time_fn
        self._offset = float(start) - self._fn()

    def __call__(self) -> float:
        return self._offset + self._fn()

    def now(self) -> float:
        return self()

    def advance_to(self, t: float) -> float:
        now = self()
        if t > now:
            self._offset += float(t) - now
        return self()


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """One arrival schedule: sorted timestamps (seconds from t=0) plus
    the provenance needed to regenerate or gate on it."""

    t: np.ndarray  # [n] float64, nondecreasing
    process: str
    offered_qps: float  # nominal offered load (n / span for traces)
    seed: Optional[int] = None

    def __post_init__(self):
        t = np.asarray(self.t, np.float64)
        assert t.ndim == 1
        assert t.size == 0 or bool(np.all(np.diff(t) >= 0.0)), (
            "arrival timestamps must be sorted"
        )
        object.__setattr__(self, "t", t)

    def __len__(self) -> int:
        return int(self.t.size)

    @property
    def span_s(self) -> float:
        return float(self.t[-1] - self.t[0]) if self.t.size > 1 else 0.0

    @property
    def measured_qps(self) -> float:
        """Empirical rate over the realized span (vs the nominal)."""
        return (self.t.size - 1) / self.span_s if self.span_s > 0 else 0.0

    # ---------------- replayable trace files ----------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {
                    "schema": ARRIVALS_SCHEMA,
                    "process": self.process,
                    "offered_qps": self.offered_qps,
                    "seed": self.seed,
                    "t": self.t.tolist(),
                },
                f,
            )

    @staticmethod
    def load(path: str) -> "ArrivalTrace":
        with open(path) as f:
            obj = json.load(f)
        if obj.get("schema") != ARRIVALS_SCHEMA:
            raise ValueError(f"{path}: not an arrival trace "
                             f"({obj.get('schema')!r})")
        return ArrivalTrace(
            t=np.asarray(obj["t"], np.float64),
            process=str(obj["process"]),
            offered_qps=float(obj["offered_qps"]),
            seed=obj.get("seed"),
        )


def _rng(seed) -> np.random.Generator:
    return seed if isinstance(seed, np.random.Generator) else (
        np.random.default_rng(seed)
    )


def poisson_arrivals(n: int, rate_qps: float, *, seed=0,
                     t0: float = 0.0) -> ArrivalTrace:
    """Homogeneous Poisson: n arrivals at ``rate_qps``."""
    assert rate_qps > 0.0
    rng = _rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=int(n))
    return ArrivalTrace(
        t=t0 + np.cumsum(gaps),
        process="poisson",
        offered_qps=float(rate_qps),
        seed=seed if isinstance(seed, int) else None,
    )


def diurnal_arrivals(
    n: int,
    rate_qps: float,
    *,
    period_s: float = 8.0,
    amplitude: float = 0.8,
    seed=0,
    t0: float = 0.0,
) -> ArrivalTrace:
    """Nonhomogeneous Poisson with a sinusoidal day/night envelope,
    sampled by thinning: candidates at the peak rate, each kept with
    probability ``rate(t) / rate_max``."""
    assert rate_qps > 0.0 and 0.0 <= amplitude < 1.0 and period_s > 0.0
    rng = _rng(seed)
    rate_max = rate_qps * (1.0 + amplitude)
    out = np.empty(int(n), np.float64)
    t = float(t0)
    k = 0
    while k < n:
        t += float(rng.exponential(1.0 / rate_max))
        lam = rate_qps * (
            1.0 + amplitude * np.sin(2.0 * np.pi * t / period_s)
        )
        if rng.random() < lam / rate_max:
            out[k] = t
            k += 1
    return ArrivalTrace(
        t=out,
        process="diurnal",
        offered_qps=float(rate_qps),
        seed=seed if isinstance(seed, int) else None,
    )


def burst_arrivals(
    n: int,
    rate_qps: float,
    *,
    burst_rate_qps: Optional[float] = None,
    mean_quiet_s: float = 2.0,
    mean_burst_s: float = 0.5,
    seed=0,
    t0: float = 0.0,
) -> ArrivalTrace:
    """Two-state MMPP: exponential-duration quiet phases at
    ``rate_qps`` alternating with bursts at ``burst_rate_qps``
    (default 8x) — the flash-crowd arrival shape."""
    assert rate_qps > 0.0
    burst = float(burst_rate_qps if burst_rate_qps is not None
                  else 8.0 * rate_qps)
    rng = _rng(seed)
    out = np.empty(int(n), np.float64)
    t = float(t0)
    k = 0
    bursting = False
    phase_end = t + float(rng.exponential(mean_quiet_s))
    while k < n:
        lam = burst if bursting else rate_qps
        t_next = t + float(rng.exponential(1.0 / lam))
        if t_next >= phase_end:
            # phase flips before the candidate lands: resample from the
            # phase boundary at the new rate (memorylessness makes the
            # restart exact, not an approximation)
            t = phase_end
            bursting = not bursting
            phase_end = t + float(
                rng.exponential(mean_burst_s if bursting else mean_quiet_s)
            )
            continue
        t = t_next
        out[k] = t
        k += 1
    return ArrivalTrace(
        t=out,
        process="burst",
        offered_qps=float(rate_qps),
        seed=seed if isinstance(seed, int) else None,
    )


def make_arrivals(process: str, n: int, rate_qps: float, *, seed=0,
                  **kw) -> ArrivalTrace:
    """Dispatcher: ``poisson`` / ``diurnal`` / ``burst`` / a
    ``trace:<path>`` replay file (rate/seed ignored for traces)."""
    if process.startswith("trace:"):
        return ArrivalTrace.load(process[len("trace:"):])
    fns = {
        "poisson": poisson_arrivals,
        "diurnal": diurnal_arrivals,
        "burst": burst_arrivals,
    }
    if process not in fns:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(want {sorted(fns)} or trace:<path>)")
    return fns[process](n, rate_qps, seed=seed, **kw)
