"""Online query-serving launcher: batched LCC/triangle/neighborhood
queries with cache-backed remote reads over a live R-MAT graph.

    python -m repro.launch.query_serve --smoke
    python -m repro.launch.query_serve --scale 12 --queries 4000 \
        --workload zipf --batch-window 64 --write-frac 0.2 --p 8
    python -m repro.launch.query_serve --smoke --ranks 4   # cross-rank
    python -m repro.launch.query_serve --smoke --open-loop poisson \
        --rate 500 --slo --tenants 3                       # traffic plane

Builds the graph, stands up a ``LiveQueryService`` over the shared
``ShardedRuntime`` (streaming engine + degree-scored cache-backed row
providers + microbatching scheduler), and drives a closed-loop
read-write workload: query groups drain through the scheduler in
``--batch-window`` microbatches, update batches mutate the store and
invalidate cached rows through the runtime's targeted coherence fanout.

``--open-loop {poisson,diurnal,burst,trace:PATH}`` switches the driver
from the closed-loop read-write stream to **open-loop** arrivals at
``--rate`` offered q/s: queries enter the scheduler at sampled arrival
times that never wait for completions, so the reported latency includes
real queueing delay (the latency-vs-offered-load regime). Open-loop
runs are queries-only (the write stream is disabled). ``--slo`` turns
on per-class deadlines with EDF window selection and SLO-aware
flush/shed; ``--tenants N`` stands up N symmetric tenants with
token-bucket admission and cache byte shares; ``--ewma-scores``
replaces the static degree cache score with the live
request-frequency×degree blend. One ``--seed`` drives graph, workload,
arrivals, and tenant assignment through independent spawned streams —
the whole run is bit-reproducible.

``--ranks p`` switches on **cross-rank serving**: p provider/engine
instances over one runtime, every query routed to the rank that owns its
target vertex, remote rows shipped owner -> requester through that
rank's cache (the dynamic analogue of the static engine's all-to-all
serve lists). Per-rank cache/read stats and the cross-rank transport
totals are reported alongside the aggregate. ``--p`` without ``--ranks``
keeps the classic single-rank view of a p-way partition.

Reports throughput, p50/p99 latency, provider hit rate, and — with
``--verify`` (on in ``--smoke``) — recomputes every point query against
a from-scratch recount of the current snapshot (bit-exact) and audits
that zero cached rows are stale on any rank.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--queries", type=int, default=1000)
    ap.add_argument("--workload", choices=("uniform", "zipf"), default="zipf")
    ap.add_argument("--batch-window", type=int, default=64,
                    help="microbatch size (1 = one query at a time)")
    ap.add_argument("--queries-per-event", type=int, default=64)
    ap.add_argument("--write-frac", type=float, default=0.2,
                    help="fraction of events that are update batches")
    ap.add_argument("--updates-per-event", type=int, default=64)
    ap.add_argument("--p", type=int, default=4,
                    help="simulated ranks (owner partition for remote reads)")
    ap.add_argument("--partition", choices=("1d", "hub"), default="1d",
                    help="vertex ownership: '1d' equal blocks (paper "
                         "§III-A) or 'hub' balance-aware cuts + degree-"
                         "threshold hub splitting (hub rows served as "
                         "per-rank fragments; see docs/partitioning.md)")
    ap.add_argument("--hub-threshold", type=int, default=None,
                    help="with --partition hub: degree at/above which a "
                         "row is fragmented (default: 4x mean degree)")
    ap.add_argument("--rebalance", action="store_true",
                    help="with --partition hub: gauge-driven online "
                         "repartition — when the windowed read imbalance "
                         "crosses --rebalance-trigger, migrate bounded "
                         "row ranges toward the degree-balanced cuts "
                         "(closed-loop runs only)")
    ap.add_argument("--rebalance-trigger", type=float, default=1.25,
                    help="windowed max/mean read imbalance that arms a "
                         "migration")
    ap.add_argument("--max-moves", type=int, default=4096,
                    help="rows each cut boundary may move per migration")
    ap.add_argument("--ranks", type=int, default=0,
                    help="cross-rank serving: run this many provider/engine "
                         "instances over the runtime, routing each query to "
                         "its owner rank (0: single-rank view of --p)")
    ap.add_argument("--spmd", action="store_true",
                    help="execute the --ranks rank views as real SPMD "
                         "compute over a JAX device mesh (shard_map): "
                         "remote rows ship through an all_to_all whose "
                         "measured traffic is reconciled against the "
                         "modeled serve matrix; needs >= ranks devices "
                         "(host devices are forced automatically)")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --spmd: double-buffer microbatches — the "
                         "host pack + collective launch of window k+1 "
                         "overlaps window k's in-flight device intersect "
                         "(bit-identical results; end_batch is the only "
                         "device sync)")
    ap.add_argument("--device-scope", choices=("replicated", "per_rank"),
                    default="replicated",
                    help="with --device-tier: one hot set replicated on "
                         "every device, or a distinct per-rank hot set "
                         "of each rank's own remote-heavy rows")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="deadline-aware batching: flush a partial window "
                         "once its oldest query waited this long")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: bound the pending queue; "
                         "submits past the bound are shed with reason "
                         "'depth' (see the shed-rate counter)")
    ap.add_argument("--shed-wait-ms", type=float, default=None,
                    help="load shedding: poll() drops queries that "
                         "already waited this long instead of serving "
                         "them (reason 'deadline')")
    ap.add_argument("--open-loop", default=None, metavar="PROC",
                    help="open-loop arrivals instead of the closed-loop "
                         "stream: poisson | diurnal | burst | trace:PATH "
                         "(queries-only; latency includes queueing delay)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="offered load in queries/s for --open-loop")
    ap.add_argument("--arrivals-out", default=None, metavar="PATH",
                    help="with --open-loop: save the sampled arrival "
                         "trace for exact replay (trace:PATH)")
    ap.add_argument("--slo", action="store_true",
                    help="per-class deadlines (EDF window selection, "
                         "SLO-aware flush, shed past deadline with "
                         "reason 'slo', per-class shed rates)")
    ap.add_argument("--slo-scale", type=float, default=1.0,
                    help="multiply every class deadline (tighten <1, "
                         "relax >1)")
    ap.add_argument("--slo-headroom-ms", type=float, default=5.0,
                    help="dispatch a window this far before its most "
                         "urgent deadline (margin for batch service "
                         "time + poll granularity)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="N symmetric tenants: token-bucket admission "
                         "(shed reason 'quota') + even cache byte "
                         "shares with quota-aware eviction")
    ap.add_argument("--tenant-qps", type=float, default=100.0,
                    help="per-tenant sustained admission rate")
    ap.add_argument("--tenant-burst", type=float, default=16.0,
                    help="per-tenant token-bucket burst depth")
    ap.add_argument("--ewma-scores", action="store_true",
                    help="live workload-driven cache scores: blend the "
                         "request-frequency EWMA with degree for both "
                         "the host caches and the device tier")
    ap.add_argument("--ewma-blend", type=float, default=0.7,
                    help="frequency weight in the blended score "
                         "(0 = pure degree; must be < 1 so cold rows "
                         "stay device-tier eligible)")
    ap.add_argument("--ewma-decay", type=float, default=0.98,
                    help="per-access EWMA decay (cachescope-identical)")
    ap.add_argument("--device-tier", action="store_true",
                    help="enable the device-resident hot-row cache tier "
                         "(persistent TPU residency for hub adjacency; "
                         "resident pairs intersect via the "
                         "resident_intersect gather kernel)")
    ap.add_argument("--device-slots", type=int, default=256,
                    help="hot-set capacity (rows) of the device tier")
    ap.add_argument("--device-width", type=int, default=None,
                    help="padded row width of the device buffer "
                         "(default: pow2 ceiling of the max degree)")
    ap.add_argument("--cache-kib", type=int, default=1024)
    ap.add_argument("--uncached", action="store_true",
                    help="DirectRowProvider baseline instead of the cache")
    ap.add_argument("--verify", action="store_true",
                    help="check every point query vs a from-scratch recount")
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, verification on")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome-trace span timeline of the run "
                         "(open at ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--trace-fine", action="store_true",
                    help="with --trace: also emit per-cache-entry "
                         "admit/evict instants (bigger trace)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the labeled metrics snapshot (all ledgers "
                         "+ per-phase time; see docs/observability.md)")
    ap.add_argument("--cache-trace", default=None, metavar="PATH",
                    help="record every cache access on both tiers and "
                         "write the cachescope analysis sidecar (reuse "
                         "distances, Mattson hit-rate curve, eviction "
                         "audit, offline policy replay incl. Belady; "
                         "validated by repro.obs.validate --cachescope)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not 0.0 <= args.write_frac <= 0.9:
        ap.error("--write-frac must be in [0, 0.9] (queries must flow)")
    if args.uncached and args.device_tier:
        ap.error("--uncached is the no-cache baseline; a device tier on "
                 "top of it would serve remote reads from residency and "
                 "corrupt the comparison")
    if args.spmd and args.ranks <= 0:
        ap.error("--spmd executes the cross-rank views on devices; "
                 "pass --ranks p")
    if args.pipeline and not args.spmd:
        ap.error("--pipeline double-buffers SPMD microbatches; pass --spmd")
    if args.device_scope != "replicated" and not args.device_tier:
        ap.error("--device-scope shapes the device tier; pass --device-tier")
    if args.trace_fine and not args.trace:
        ap.error("--trace-fine needs --trace")
    if args.open_loop is not None:
        known = ("poisson", "diurnal", "burst")
        if args.open_loop not in known and \
                not args.open_loop.startswith("trace:"):
            ap.error(f"--open-loop must be one of {known} or trace:PATH")
        if args.rate <= 0.0:
            ap.error("--rate must be positive")
        args.write_frac = 0.0  # open-loop runs are queries-only
    if args.arrivals_out and not args.open_loop:
        ap.error("--arrivals-out records the --open-loop arrival trace")
    if args.hub_threshold is not None and args.partition != "hub":
        ap.error("--hub-threshold shapes the hub partition; pass "
                 "--partition hub")
    if args.rebalance and args.partition != "hub":
        ap.error("--rebalance migrates hub-partition cuts; pass "
                 "--partition hub")
    if args.rebalance and args.open_loop:
        ap.error("--rebalance checks the gauge between closed-loop "
                 "events; open-loop runs are queries-only")
    if args.tenants < 0:
        ap.error("--tenants must be >= 0")
    if args.ewma_scores and not 0.0 <= args.ewma_blend < 1.0:
        ap.error("--ewma-blend must be in [0, 1): the device tier only "
                 "admits rows with positive scores, so pure frequency "
                 "(1.0) would exclude every not-yet-requested row")
    if args.ewma_scores and args.cache_trace:
        print("note: --ewma-scores + --cache-trace — offline replay "
              "gates that assume the deployed degree policy (and any "
              "tenant cache shares) do not hold on this trace")
    tracer = None
    if args.trace:
        from ..obs import trace as obs_trace

        tracer = obs_trace.enable_tracing(fine=args.trace_fine)
    recorder = None
    if args.cache_trace:
        from ..obs import cachescope as obs_cachescope

        recorder = obs_cachescope.enable_recording()
    if args.smoke:
        args.scale = min(args.scale, 8)
        args.queries = min(args.queries, 256)
        args.verify = True
    if args.spmd:
        # must happen before anything initializes jax (device count is
        # locked at first init); preserves user/CI-provided XLA_FLAGS.
        from ..distributed.spmd_runtime import ensure_host_devices

        ensure_host_devices(args.ranks)

    from ..core.triangles import lcc_scores, triangles_per_vertex
    from ..graphs.rmat import rmat_graph
    from ..serving import LiveQueryService, QueryKind, read_write_stream

    # One --seed, independent derived streams: the graph and the
    # closed-loop workload keep the raw seed (bit-compatible with every
    # pre-traffic-plane run), arrivals and tenant assignment get spawned
    # children so adding --tenants never perturbs the arrival times.
    seed_root = np.random.SeedSequence(args.seed)
    arrival_seed, tenant_seed = (
        int(c.generate_state(1)[0]) for c in seed_root.spawn(2)
    )

    slo = quotas = scorer = clock = None
    if args.slo:
        from ..traffic import SLOPolicy

        slo = SLOPolicy(
            headroom_s=args.slo_headroom_ms * 1e-3
        ).scaled(args.slo_scale)
    if args.tenants:
        from ..traffic import TenantQuotas

        quotas = TenantQuotas.uniform(
            args.tenants, rate_qps=args.tenant_qps, burst=args.tenant_burst
        )
    if args.ewma_scores:
        from ..traffic import WorkloadScorer

        scorer = WorkloadScorer(blend=args.ewma_blend,
                                decay=args.ewma_decay)
    if args.open_loop:
        from ..traffic import HybridClock

        clock = HybridClock()

    n = 1 << args.scale
    csr = rmat_graph(args.scale, args.edge_factor, seed=args.seed)
    cross_rank = args.ranks > 0
    p = args.ranks if cross_rank else args.p
    print(f"R-MAT S{args.scale} EF{args.edge_factor}: n={n}, m={csr.m} "
          f"(directed), max deg {csr.max_degree}"
          + (f"  [cross-rank serving, p={p}"
             f"{', SPMD device mesh' if args.spmd else ''}]"
             if cross_rank else ""))

    partition = None
    if args.partition == "hub":
        from ..core.partition import partition_hub

        partition = partition_hub(
            csr.degrees, p, threshold=args.hub_threshold
        )
        sizes = partition.sizes()
        print(f"hub partition: {partition.hubs.size} hubs (degree >= "
              f"{partition.threshold}) fragmented across {p} ranks, "
              f"blocks {int(sizes.min())}..{int(sizes.max())} rows")

    svc = LiveQueryService(
        csr,
        p=p,
        cross_rank=cross_rank,
        partition=partition,
        cache_bytes=args.cache_kib << 10,
        max_batch=args.batch_window,
        max_wait=(args.max_wait_ms * 1e-3
                  if args.max_wait_ms is not None else None),
        max_queue=args.max_queue,
        shed_wait=(args.shed_wait_ms * 1e-3
                   if args.shed_wait_ms is not None else None),
        device_slots=args.device_slots if args.device_tier else 0,
        device_width=args.device_width,
        uncached=args.uncached,
        execution="spmd" if args.spmd else "loop",
        pipeline=args.pipeline,
        device_scope=args.device_scope,
        slo=slo,
        quotas=quotas,
        scorer=scorer,
        clock=clock,
    )

    rebalancer = None
    if args.rebalance:
        from ..core.repartition import Rebalancer

        rebalancer = Rebalancer(
            svc.runtime,
            trigger=args.rebalance_trigger,
            max_moves=args.max_moves,
            hub_threshold=args.hub_threshold,
        )

    served = 0
    n_updates = 0
    n_verified = 0
    open_report = None

    def _verify_results(results):
        nonlocal n_verified
        snap = svc.store.to_csr()
        t_ref = triangles_per_vertex(snap)
        lcc_ref = lcc_scores(snap, t_ref)
        for r in results:
            q = r.query
            if q.kind == QueryKind.TRIANGLES:
                assert r.value == t_ref[q.u], (q, r.value, t_ref[q.u])
            elif q.kind == QueryKind.LCC:
                assert r.value == lcc_ref[q.u], (q, r.value, lcc_ref[q.u])
            elif q.kind == QueryKind.COMMON_NEIGHBORS:
                want = np.intersect1d(snap.row(q.u), snap.row(q.v))
                assert r.value == want.size and np.array_equal(r.ids, want)
            else:  # TOP_K_LCC: compare ranking vs the recount
                order = np.lexsort((np.arange(snap.n), -lcc_ref))[: q.k]
                assert np.array_equal(r.ids, order), (q, r.ids, order)
            n_verified += 1

    t_start = time.perf_counter()
    if args.open_loop:
        # -------- open-loop: arrivals never wait for completions ------
        from ..serving import make_queries
        from ..traffic import assign_tenants, make_arrivals, run_open_loop

        queries = make_queries(
            svc.store.degrees, args.queries, kind=args.workload,
            seed=args.seed,
        )
        if quotas is not None:
            queries = assign_tenants(
                queries, quotas.tenants,
                rng=np.random.default_rng(tenant_seed),
            )
        arrivals = make_arrivals(
            args.open_loop, len(queries), args.rate, seed=arrival_seed
        )
        if args.arrivals_out:
            arrivals.save(args.arrivals_out)
            print(f"arrival trace: {len(arrivals)} arrivals "
                  f"({arrivals.measured_qps:,.0f} q/s measured) -> "
                  f"{args.arrivals_out}")
        open_report = run_open_loop(
            svc.scheduler, queries, arrivals, clock=clock
        )
        served = open_report.n_served
        if args.verify:
            _verify_results(open_report.results)
    else:
        # -------- closed-loop read-write stream -----------------------
        # 2x safety factor: event kinds are drawn i.i.d., so an unlucky
        # write-heavy prefix must not end the stream before --queries
        # served.
        n_query_events = -(-args.queries // args.queries_per_event)
        n_events = int(2 * n_query_events / (1.0 - args.write_frac)) + 1
        for ev in read_write_stream(
            lambda: svc.store.degrees,
            n,
            n_events=n_events,
            write_frac=args.write_frac,
            queries_per_event=args.queries_per_event,
            updates_per_event=args.updates_per_event,
            kind=args.workload,
            seed=args.seed,
        ):
            if ev.is_update:
                res = svc.apply_updates(ev.update)
                n_updates += res.n_inserted + res.n_deleted
                if rebalancer is not None:
                    # batch boundary: the scheduler is drained (single-
                    # writer), so ownership may move here and nowhere
                    # else.
                    rebalancer.maybe_rebalance(svc.store.degrees)
                continue
            if args.max_wait_ms is None:
                results = svc.scheduler.run(ev.queries)
            else:
                # deadline-aware serving: submit one at a time and poll
                # — full windows dispatch immediately, the trailing
                # partial window sits until its oldest query ages past
                # the deadline
                results = []
                for q in ev.queries:
                    svc.scheduler.submit(q)
                    results.extend(svc.scheduler.poll())
                while svc.scheduler.pending:
                    time.sleep(args.max_wait_ms * 1e-3 / 8)
                    results.extend(svc.scheduler.poll())
            served += len(results)
            if args.verify:
                _verify_results(results)
            if served >= args.queries:
                break
    wall = time.perf_counter() - t_start
    if served < args.queries and not args.open_loop:
        print(f"note: stream exhausted at {served}/{args.queries} queries")

    lat = svc.scheduler.latency_summary()
    if open_report is not None:
        print(f"open-loop[{open_report.process}]: offered "
              f"{open_report.offered_qps:,.0f} q/s -> achieved "
              f"{open_report.achieved_qps:,.0f} q/s, "
              f"{open_report.n_arrivals} arrivals / "
              f"{open_report.n_admitted} admitted / "
              f"{open_report.n_served} served over "
              f"{open_report.duration_s:.2f}s virtual")
    if args.slo:
        sch = svc.scheduler
        print(f"slo: hit rate {lat.slo_hit_rate:.1%} "
              f"({lat.slo_violations} violations), "
              f"{sch.n_slo_flushes} slo flushes, "
              f"{sch.n_shed_slo} shed past deadline")
        for cls in sorted(lat.shed_rate_by_class):
            print(f"  {cls}: shed rate "
                  f"{lat.shed_rate_by_class[cls]:.1%} "
                  f"({lat.shed_by_class.get(cls, 0)} shed)")
    if quotas is not None:
        qc = quotas.counters()
        adm, rej = sum(qc["admitted"].values()), sum(qc["rejected"].values())
        print(f"tenants[{args.tenants}]: {adm} admitted / {rej} "
              f"quota-shed ({svc.scheduler.n_shed_quota} at the door)")
        if svc.runtime.caches is not None:
            tb = {}
            for c in svc.runtime.caches:
                for t, b in c.tenant_bytes().items():
                    tb[t] = tb.get(t, 0) + b
            total = sum(c.used_bytes for c in svc.runtime.caches)
            shares = " ".join(
                f"{t or '_'}={b}B" for t, b in sorted(tb.items())
            )
            print(f"  cache shares: {shares} (sum {sum(tb.values())} "
                  f"== used {total})")
            assert sum(tb.values()) == total, \
                "per-tenant cache accounting does not sum to used bytes"
    if scorer is not None:
        print(f"ewma scores: blend {args.ewma_blend} decay "
              f"{args.ewma_decay}, {len(scorer._freq)} vertices tracked")
    rt = svc.runtime
    st = rt.aggregate_stats() if cross_rank else svc.provider.stats
    print(f"served {served} queries in {wall:.2f}s wall "
          f"({served / max(wall, 1e-9):,.0f} q/s end-to-end; "
          f"{lat.throughput_qps:,.0f} q/s in-engine), "
          f"{n_updates} interleaved updates, T={svc.triangle_count}")
    print(f"latency: p50 {lat.p50_ms:.2f} ms  p90 {lat.p90_ms:.2f} ms  "
          f"p99 {lat.p99_ms:.2f} ms  max {lat.max_ms:.2f} ms  "
          f"(window={args.batch_window})"
          + (f"  deadline flushes {svc.scheduler.n_deadline_flushes}, "
             f"priority {svc.scheduler.n_priority_flushes}"
             if args.max_wait_ms is not None else ""))
    scope = f"runtime[p={p}]" if cross_rank else "provider"
    print(f"{scope}: {st.local_reads} local / {st.remote_reads} remote "
          f"reads, hit rate {st.hit_rate:.1%}, "
          f"{st.invalidations} invalidations, "
          f"{st.bytes_fetched} B fetched, "
          f"modeled remote time {st.modeled_comm_s * 1e3:.2f} ms")
    if cross_rank:
        for k, sk in enumerate(rt.stats):
            print(f"  rank {k}: {sk.local_reads} local / "
                  f"{sk.remote_reads} remote, hit rate {sk.hit_rate:.1%}, "
                  f"{sk.cache_misses} misses, {sk.invalidations} inval, "
                  f"{sk.bytes_fetched} B")
        print(f"cross-rank transport: {rt.cross_rank_rows_served()} rows "
              f"shipped owner->requester, invalidation fanout saved "
              f"{rt.invalidation_fanout_saved} msgs vs broadcast")
    if rebalancer is not None:
        print(f"rebalance: {rebalancer.migrations} migrations moved "
              f"{rebalancer.rows_moved} rows "
              f"(trigger {args.rebalance_trigger}x, "
              f"<= {args.max_moves} rows/boundary); runtime saw "
              f"{rt.rows_migrated} ownership changes")
    if args.spmd:
        led = svc.engine.spmd.ledger
        modeled_rows = rt.cross_rank_rows_served()
        modeled_bytes = sum(s.bytes_fetched for s in rt.stats)
        agree = (led.total_rows == modeled_rows
                 and led.bytes_payload == modeled_bytes)
        print(f"spmd[{led.p} devices]: {led.n_collectives} all_to_all "
              f"collectives, {led.total_rows} rows / {led.bytes_payload} B "
              f"payload shipped (modeled {modeled_rows} rows / "
              f"{modeled_bytes} B — {'EXACT match' if agree else 'MISMATCH'}"
              f"), {led.bytes_on_wire} B on the padded wire, "
              f"{led.n_pairs} pairs intersected on-device in "
              f"{led.device_wall_s:.2f}s")
        print(f"  async plane: {led.bytes_uploaded} B uploaded in "
              f"{led.n_patches} resident-buffer patches, "
              f"{led.upload_bytes_saved} B re-upload saved; wire padding "
              f"saved {led.wire_padding_saved} B vs single-width "
              f"({led.bytes_on_wire_single} B)"
              + (f"; overlap wait {led.overlap_wait_s:.2f}s"
                 if args.pipeline else ""))
        assert agree, "measured collective traffic != modeled serve matrix"
    print(f"pair dedup: {svc.engine.n_pairs_raw} raw -> "
          f"{svc.engine.n_pairs_total} intersected")
    if args.max_queue is not None or args.shed_wait_ms is not None:
        sch = svc.scheduler
        print(f"admission: queue bound {args.max_queue}, shed "
              f"{sch.n_shed_depth} depth + {sch.n_shed_deadline} deadline "
              f"(shed rate {lat.shed_rate:.1%})")
    if args.device_tier:
        views = svc.runtime.device_views()
        ds = svc.runtime.merged_device_stats()
        resident = sum(v.resident_rows for v in views)
        slots = sum(v.slots for v in views)
        label = (f"{len(views)} per-rank hot sets"
                 if args.device_scope == "per_rank" else "replicated")
        print(f"device tier[{label}, {resident}/{slots} slots x "
              f"width {views[0].max_width}]: {svc.engine.n_pairs_resident} "
              f"resident pairs, hit rate {ds.hit_rate:.1%}, "
              f"{ds.bytes_saved} B host materialization saved "
              f"({svc.engine.host_pack_bytes} B still packed), "
              f"{ds.patches} patches / {ds.admits} admits / "
              f"{ds.evicts} evicts, {ds.upload_bytes} B uploaded")
    if args.verify:
        svc.verify()
        print(f"verified: {n_verified} point queries bit-exact vs recount, "
              "0 stale cached rows")
    cache_report = None
    if recorder is not None:
        from ..obs import cachescope as obs_cachescope

        obs_cachescope.disable_recording()
        cache_report = obs_cachescope.analyze(recorder)
        obs_cachescope.save_report(cache_report, args.cache_trace)
        print(obs_cachescope.summarize(cache_report))
        print(f"cache trace: {recorder.n_events()} events -> "
              f"{args.cache_trace}")
    if args.metrics:
        reg = svc.metrics_registry(tracer=tracer)
        if cache_report is not None:
            from ..obs.metrics import record_cachescope

            record_cachescope(reg, cache_report)
        snap = reg.to_dict()
        reg.save(args.metrics)
        print(f"metrics: {len(snap['counters'])} counters, "
              f"{len(snap['gauges'])} gauges, "
              f"{len(snap['histograms'])} histograms -> {args.metrics}  "
              f"[load imbalance "
              f"{reg.get_gauge('load_imbalance', tier='host'):.2f}x, "
              f"serve-matrix skew "
              f"{reg.get_gauge('serve_matrix_skew', tier='wire'):.2f}x]")
    if tracer is not None:
        from ..obs import trace as obs_trace

        obs_trace.disable_tracing()
        tracer.export(args.trace)
        print(f"trace: {len(tracer)} events -> {args.trace} "
              "(open at ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
