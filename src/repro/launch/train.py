"""Production training launcher.

    python -m repro.launch.train --arch qwen2.5-14b --smoke --steps 20
    python -m repro.launch.train --arch din --smoke --steps 50
    python -m repro.launch.train --arch gat-cora --smoke --steps 30

``--smoke`` selects the reduced config (CPU-runnable); without it the full
assigned config is used (needs the real mesh; on this container that only
makes sense through dryrun.py). The launcher wires: config -> model ->
data pipeline -> optimizer -> TrainRunner (checkpoint/restart, straggler
monitor). ``--resume`` continues from the newest checkpoint.
"""
from __future__ import annotations

import argparse
import importlib

import jax
import numpy as np

from ..configs.inputs import make_smoke_batch
from ..configs.registry import get_arch
from ..data.recsys import CTRStream
from ..data.tokens import TokenStream
from ..distributed.fault_tolerance import StragglerMonitor, TrainRunner
from ..models import transformer as tfm
from ..train import train_loop as tl
from ..train.checkpoint import CheckpointManager
from ..train.optimizer import adamw, cosine_schedule

GNN_MODULES = {
    "mace": "repro.models.gnn.mace",
    "pna": "repro.models.gnn.pna",
    "gin-tu": "repro.models.gnn.gin",
    "gat-cora": "repro.models.gnn.gat",
}


def build(arch_id: str, smoke: bool, steps: int, seed: int):
    arch = get_arch(arch_id)
    rng = np.random.default_rng(seed)
    if arch.family == "lm":
        cfg = arch.smoke_config() if smoke else arch.config()
        optim = adamw(lr=cosine_schedule(3e-4, min(20, steps // 4 + 1), steps))
        params = tfm.init_params(cfg, jax.random.key(seed))
        stream = TokenStream(cfg.vocab, 4, 64, seed=seed)
        step = jax.jit(tl.make_lm_train_step(cfg, optim, n_microbatches=2))
        return params, optim, step, stream.batch_at
    if arch.family == "gnn":
        cfg, batch = make_smoke_batch(arch_id, "gnn_train", rng)
        mod = importlib.import_module(GNN_MODULES[arch_id])
        optim = adamw(lr=1e-3, weight_decay=0.0)
        params = mod.init_params(cfg, jax.random.key(seed))
        step = jax.jit(tl.make_gnn_train_step(mod.apply, cfg, optim))
        return params, optim, step, lambda s: batch
    if arch.family == "recsys":
        from ..models.recsys import din

        cfg = arch.smoke_config() if smoke else arch.config()
        optim = adamw(lr=1e-3, weight_decay=0.0)
        params = din.init_params(cfg, jax.random.key(seed))
        stream = CTRStream(cfg.n_items, cfg.n_cats, 128,
                           seq_len=cfg.seq_len, d_profile=cfg.d_profile,
                           seed=seed)
        step = jax.jit(tl.make_recsys_train_step(din.apply, cfg, optim))
        return params, optim, step, stream.batch_at
    raise ValueError(f"--arch {arch_id}: family {arch.family} has no "
                     f"train step (use lcc_run for paper-lcc)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    params, optim, step, data_fn = build(args.arch, args.smoke, args.steps,
                                         args.seed)
    opt_state = optim.init(params)
    start = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and ckpt and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(
            {"params": params, "opt_state": opt_state}
        )
        params, opt_state = state["params"], state["opt_state"]
        start = meta["next_step"]
        print(f"resumed from step {start}")

    runner = TrainRunner(step_fn=step, data_fn=data_fn, ckpt=ckpt,
                         ckpt_every=args.ckpt_every,
                         monitor=StragglerMonitor())
    params, opt_state, log = runner.run(
        params, opt_state, start_step=start, n_steps=args.steps - start,
        meta={"arch": args.arch},
    )
    print(f"[{args.arch}] loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f} "
          f"over {len(log)} steps "
          f"({np.mean([m['dt'] for m in log]) * 1e3:.0f} ms/step)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
