"""Serving launcher: batched prefill+decode (LM) or CTR scoring (recsys).

    python -m repro.launch.serve --arch gemma2-27b --smoke --tokens 16
    python -m repro.launch.serve --arch din --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_arch
from ..models import transformer as tfm
from ..train import train_loop as tl


def serve_lm(arch_id: str, smoke: bool, batch: int, prompt: int, tokens: int):
    arch = get_arch(arch_id)
    cfg = arch.smoke_config() if smoke else arch.config()
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, prompt)).astype(np.int32))
    max_len = prompt + tokens
    prefill = jax.jit(tl.make_lm_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(tl.make_lm_decode_step(cfg))
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    tp = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(tokens):
        logits, cache = decode(params, tok, jnp.int32(prompt + t), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    td = time.perf_counter() - t0
    print(f"[{arch_id}] prefill {tp * 1e3:.1f} ms | "
          f"decode {td / tokens * 1e3:.2f} ms/tok | "
          f"throughput {batch * tokens / td:.0f} tok/s")


def serve_recsys(smoke: bool, batch: int):
    from ..data.recsys import CTRStream
    from ..models.recsys import din

    arch = get_arch("din")
    cfg = arch.smoke_config() if smoke else arch.config()
    params = din.init_params(cfg, jax.random.key(0))
    stream = CTRStream(cfg.n_items, cfg.n_cats, batch, seq_len=cfg.seq_len,
                       d_profile=cfg.d_profile, seed=0)
    step = jax.jit(tl.make_recsys_serve_step(din.apply, cfg))
    b = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    probs = step(params, b)
    jax.block_until_ready(probs)
    # pre-materialize batches and block on EVERY iteration's output:
    # timing dispatch of async step calls (or host-side batch prep)
    # instead of device execution under-reports serving latency.
    n_iters = 3
    batches = [
        {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        for i in range(1, 1 + n_iters)
    ]
    t0 = time.perf_counter()
    outs = [step(params, b) for b in batches]
    jax.block_until_ready(outs)
    dt = (time.perf_counter() - t0) / n_iters
    print(f"[din] {batch} reqs in {dt * 1e3:.1f} ms "
          f"({batch / dt:.0f} req/s)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)
    arch = get_arch(args.arch)
    if arch.family == "lm":
        serve_lm(args.arch, args.smoke, args.batch, args.prompt, args.tokens)
    elif arch.family == "recsys":
        serve_recsys(args.smoke, max(args.batch, 8))
    else:
        raise SystemExit(f"{args.arch}: no serving path for {arch.family}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
