"""Production meshes.

Single pod: TPU v5e-256 as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the 'pod'
axis carries only data parallelism (gradient all-reduce crosses DCN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first
jax init; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e-ish hardware constants for the roofline model."""

    PEAK_FLOPS_BF16 = 197e12  # per chip
    HBM_BW = 819e9  # bytes/s per chip
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 << 30
