"""Paper-workload launcher: distributed LCC/TC with RMA-style caching.

    python -m repro.launch.lcc_run --scale 11 --p 8 --cache-rows 256
    python -m repro.launch.lcc_run --graph livejournal --max-n 8192

Runs the compiled async engine on however many host devices are
available (set XLA_FLAGS=--xla_force_host_platform_device_count=N before
invoking for multi-device CPU runs; on a TPU slice it uses the real
devices), verifies exactness against the single-node reference for small
graphs, and reports communication statistics + the CLaMPI-simulator view.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", default=None,
                    help="named Table-II stand-in instead of R-MAT")
    ap.add_argument("--max-n", type=int, default=1 << 13)
    ap.add_argument("--p", type=int, default=0, help="0 = all devices")
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--n-rounds", type=int, default=4)
    ap.add_argument("--method", default="hybrid",
                    choices=["bsearch", "pairwise", "hybrid"])
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome-trace span timeline of the run "
                         "(open at ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the labeled metrics snapshot (per-rank "
                         "cache stats + modeled comm + per-phase time)")
    ap.add_argument("--cache-trace", default=None, metavar="PATH",
                    help="record the CLaMPI-sim access streams and write "
                         "the cachescope analysis sidecar (Mattson "
                         "hit-rate curve, eviction audit, policy replay)")
    args = ap.parse_args(argv)
    from ..obs import trace as obs_trace

    tracer = obs_trace.enable_tracing() if args.trace else None
    recorder = None
    if args.cache_trace:
        from ..obs import cachescope as obs_cachescope

        recorder = obs_cachescope.enable_recording()

    from ..core.async_engine import lcc_pipelined
    from ..core.cache import build_static_degree_cache
    from ..core.rma import build_sharded_problem, simulate_rma_lcc
    from ..graphs.datasets import get as get_graph
    from ..graphs.rmat import rmat_graph

    if args.graph:
        csr = get_graph(args.graph, max_n=args.max_n)
        name = args.graph
    else:
        csr = rmat_graph(args.scale, args.edge_factor, seed=0)
        name = f"R-MAT S{args.scale} EF{args.edge_factor}"
    p = args.p or len(jax.devices())
    print(f"graph {name}: n={csr.n} m={csr.m}; p={p} devices")

    cache = (build_static_degree_cache(csr.degrees, args.cache_rows)
             if args.cache_rows else None)
    prob = build_sharded_problem(csr, p, n_rounds=args.n_rounds, cache=cache)
    t, lcc = lcc_pipelined(prob, method=args.method)  # compile
    t0 = time.perf_counter()
    with obs_trace.span("intersect_kernel", cat="epoch",
                        rounds=prob.n_rounds):
        t, lcc = lcc_pipelined(prob, method=args.method)
    dt = time.perf_counter() - t0
    total_t = int(t.sum()) // 3
    print(f"triangles={total_t}  wall={dt * 1e3:.1f} ms  "
          f"comm_bytes={prob.comm_bytes_per_round().sum():,}")

    if args.verify:
        from ..core.triangles import triangles_per_vertex

        want = triangles_per_vertex(csr)
        from ..core.partition import partition_1d

        part = partition_1d(csr.n, p)
        got = np.concatenate(
            [t[k, : part.hi(k) - part.lo(k)] for k in range(p)])
        assert np.array_equal(got, want), "MISMATCH vs reference"
        print("verified exact vs single-node reference")

    with obs_trace.span("delta_replay", cat="epoch"):
        st = simulate_rma_lcc(
            csr, p,
            adj_cache_bytes=csr.csr_nbytes() // 4,
            offsets_cache_bytes=csr.n * 2,
            use_degree_score=True,
        )
    hits = sum(s.hits for s in st.adj_stats)
    gets = sum(s.gets for s in st.adj_stats)
    print(f"CLaMPI-sim: adj hit rate {hits / max(gets, 1):.1%}, "
          f"modeled comm {st.makespan * 1e3:.2f} ms")
    cache_report = None
    if recorder is not None:
        from ..obs import cachescope as obs_cachescope

        obs_cachescope.disable_recording()
        cache_report = obs_cachescope.analyze(recorder)
        obs_cachescope.save_report(cache_report, args.cache_trace)
        print(obs_cachescope.summarize(cache_report))
        print(f"cache trace: {recorder.n_events()} events -> "
              f"{args.cache_trace}")
    if args.metrics:
        from ..obs.metrics import (
            MetricRegistry,
            fold_trace,
            imbalance,
            record_cache_stats,
            record_cachescope,
        )

        reg = MetricRegistry()
        for k, s in enumerate(st.adj_stats):
            record_cache_stats(reg, s, rank=k)
        if cache_report is not None:
            record_cachescope(reg, cache_report)
        reg.counter("rma_bytes_modeled",
                    float(prob.comm_bytes_per_round().sum()),
                    tier="wire", phase="fetch_rows")
        reg.counter("modeled_comm_s", float(st.makespan), tier="wire")
        reg.counter("epoch_wall_s", float(dt), phase="intersect_kernel")
        reg.gauge("cache_get_imbalance",
                  imbalance([s.gets for s in st.adj_stats]),
                  tier="host_cache")
        if tracer is not None:
            fold_trace(reg, tracer)
        snap = reg.to_dict()
        reg.save(args.metrics)
        print(f"metrics: {len(snap['counters'])} counters, "
              f"{len(snap['gauges'])} gauges -> {args.metrics}")
    if tracer is not None:
        obs_trace.disable_tracing()
        tracer.export(args.trace)
        print(f"trace: {len(tracer)} events -> {args.trace} "
              "(open at ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
