"""Collective-byte + loop-corrected FLOP census over partitioned HLO text.

``compiled.cost_analysis()`` (a) does not report collective traffic and
(b) visits each instruction ONCE — while-loop bodies (how XLA lowers
``lax.scan`` over layers / microbatches) are not multiplied by their trip
count (verified empirically: an 8-step scan reports 1/8 the FLOPs of the
unrolled loop). This module parses the compiled module text instead:

- splits computations, builds the call graph (fusions `calls=`,
  collectives `to_apply=`, `while` body/condition, conditional branches),
- recovers while trip counts from the loop-condition constant,
- multiplies per-computation op costs by execution multiplicity,
- censuses collective bytes (largest operand/result tensor per op) and
  analytic dot FLOPs (2 x result_elems x contracted_elems).

Byte factors (documented in EXPERIMENTS.md §Roofline):
  all-reduce 2x; all-gather / reduce-scatter / all-to-all /
  collective-permute 1x.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["collective_census"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_TYPE_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _header_name(line: str) -> Optional[Tuple[str, bool]]:
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    is_entry = s.startswith("ENTRY")
    if is_entry:
        s = s[len("ENTRY"):].strip()
    if not s.startswith("%"):
        return None
    name = s.split()[0].split("(")[0].lstrip("%")
    return name, is_entry


def _split_computations(text: str):
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        h = _header_name(line)
        if h is not None:
            cur = h[0]
            comps[cur] = []
            if h[1]:
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps, entry


def collective_census(text: str) -> dict:
    comps, entry = _split_computations(text)

    # ---- call graph with while-trip multiplication ----
    calls: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            if "while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                trip = 1
                if mc and mc.group(1) in comps:
                    consts = [
                        int(c)
                        for l2 in comps[mc.group(1)]
                        for c in _CONST_RE.findall(l2)
                    ]
                    if consts:
                        trip = max(consts)
                if mb:
                    calls[name].append((mb.group(1), float(max(trip, 1))))
                if mc:
                    calls[name].append((mc.group(1), 0.0))  # negligible
                continue
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
                calls[name].append((m.group(1), 1.0))
            m = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if m:
                for b in m.group(1).split(","):
                    calls[name].append((b.strip().lstrip("%"), 1.0))

    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps or m <= 0:
            return
        mult[name] += m
        for child, k in calls.get(name, []):
            visit(child, m * k, depth + 1)

    if entry is None and comps:
        entry = list(comps)[-1]
    if entry:
        visit(entry, 1.0)

    # ---- per-computation op census ----
    per_op: Dict[str, dict] = {}
    total_bytes = 0.0
    weighted = 0.0
    dot_flops = 0.0
    max_trip = max([1.0] + [k for es in calls.values() for _, k in es])
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        types: Dict[str, str] = {}
        for ln in lines:
            nm = _NAME_TYPE_RE.match(ln)
            if nm:
                types[nm.group(1)] = nm.group(2)
        for ln in lines:
            hit = None
            for op in COLLECTIVES:
                if f" {op}(" in ln or ln.startswith(f"{op}("):
                    # count -start, skip -done (avoid double counting async)
                    if f"{op}-done" in ln:
                        hit = "skip"
                        break
                    hit = op
                    break
            if hit == "skip":
                continue
            if hit is not None:
                b = _shape_bytes(ln.split(" metadata=")[0])
                factor = COLLECTIVES[hit]
                d = per_op.setdefault(
                    hit, {"count": 0.0, "bytes": 0.0, "weighted_bytes": 0.0}
                )
                d["count"] += m
                d["bytes"] += b * m
                d["weighted_bytes"] += b * m * factor
                total_bytes += b * m
                weighted += b * m * factor
                continue
            if " dot(" in ln:
                dot_flops += _dot_flops(ln, types) * m
    return {
        "per_op": per_op,
        "bytes": total_bytes,
        "weighted_bytes": weighted,
        "dot_flops": dot_flops,
        "n_computations": len(comps),
        "max_trip": max_trip,
    }


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(ln: str, types: Dict[str, str]) -> float:
    """2 * result_elems * prod(lhs contracting dims); operand shapes are
    resolved through the per-computation name->type map."""
    nm = _NAME_TYPE_RE.match(ln)
    if not nm:
        return 0.0
    result_dims = _dims_of(nm.group(2))
    result_elems = 1
    for d in result_dims:
        result_elems *= d
    # operands: first parenthesized group after 'dot'
    after = ln.split(" dot(", 1)[-1]
    operands = after.split(")", 1)[0]
    first = operands.split(",")[0].strip()
    lhs_name = first.lstrip("%").split()[0] if first.startswith("%") else None
    k = 1
    contract = _LHS_CONTRACT_RE.search(ln)
    if lhs_name and contract and lhs_name in types:
        lhs_dims = _dims_of(types[lhs_name])
        for ci in [int(c) for c in contract.group(1).split(",") if c]:
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * result_elems * k
