import os  # noqa: F401 — kept first: flag setup precedes every jax use
# MUST run before anything initializes jax: jax locks the device count
# on first init. ensure_host_devices PRESERVES user/CI-provided
# XLA_FLAGS (an explicit external device-count directive wins; other
# flags are kept either way). Non-strict: a deliberately smaller
# external count falls through to the mesh-size checks below.
from ..distributed.spmd_runtime import ensure_host_devices

ensure_host_devices(512, strict=False)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

Per cell:
  - build the step function (train/prefill/decode/serve/retrieval) with
    the arch's full config,
  - build ShapeDtypeStruct stand-ins for params/opt-state/batch with
    NamedShardings on the target mesh (no allocation),
  - ``jax.jit(step).lower(...).compile()`` — success proves the sharding
    config is coherent (no mismatched specs, no OOM-at-compile, all
    collectives supported),
  - record ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
    (FLOPs/bytes) and the collective-byte census parsed from the
    partitioned HLO (with while-loop trip-count multiplication).

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.inputs import input_specs, step_kind
from ..configs.registry import cells, get_arch
from ..distributed.sharding import gnn_specs, lm_rules, recsys_specs
from ..models import transformer as tfm
from ..train import train_loop as tl
from ..train.optimizer import adamw, zero1_specs
from .hlo_census import collective_census
from .mesh import HW, make_production_mesh

I32 = jnp.int32


def _ns(mesh, spec):
    return NamedSharding(mesh, spec if spec is not None else P())


def _tree_ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: _ns(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _fit_spec(mesh, spec, shape):
    """Trim a PartitionSpec to the leaf rank and drop axes that do not
    divide the corresponding dim (e.g. batch=1 retrieval can't shard)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = list(spec)[: len(shape)]
    parts += [None] * (len(shape) - len(parts))
    fitted = []
    for dim, part in zip(shape, parts):
        if part is None:
            fitted.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        extent = 1
        for a in axes:
            extent *= mesh_shape.get(a, 1)
        fitted.append(part if dim % extent == 0 and dim >= extent else None)
    return P(*fitted)


def _batch_sharding(mesh, batch_sds, family, cfg, rules=None):
    """NamedShardings for the batch dict."""
    if family == "lm":
        rules = rules if rules is not None else lm_rules(mesh)
        dp = rules.dp
        out = {}
        for k, v in batch_sds.items():
            spec = P(dp) if v.ndim == 1 else P(dp, *([None] * (v.ndim - 1)))
            out[k] = _ns(mesh, _fit_spec(mesh, spec, v.shape))
        return out
    table = gnn_specs(mesh) if family == "gnn" else recsys_specs(mesh)
    return {
        k: _ns(mesh, _fit_spec(mesh, table.get(k, P()), v.shape))
        for k, v in batch_sds.items()
    }


def _pad_gnn_batch(batch_sds, mesh):
    """Pad edge/node axes to multiples of the device count (masked padding
    is free; uneven shardings are what we avoid)."""
    ndev = mesh.devices.size
    out = {}
    for k, v in batch_sds.items():
        if k in ("edge_src", "edge_dst", "edge_mask", "node_mask",
                 "graph_ids", "labels", "label_mask", "node_feat") and v.ndim == 1:
            out[k] = jax.ShapeDtypeStruct((_pad_to(v.shape[0], ndev),), v.dtype)
        elif k in ("node_feat", "positions") and v.ndim == 2:
            out[k] = jax.ShapeDtypeStruct(
                (_pad_to(v.shape[0], ndev), v.shape[1]), v.dtype
            )
        else:
            out[k] = v
    return out


# --------------------------------------------------------------------------
# per-cell setup: returns (fn, args, in_shardings, meta)
# --------------------------------------------------------------------------
def setup_cell(arch_id: str, shape_id: str, mesh: Mesh, *, opt: bool = False):
    """``opt=True`` applies the §Perf beyond-baseline configuration:
    LM: flash attention from 2k ctx + MoE capacity-axis sharding +
    Megatron-style sequence parallelism; GNN: node arrays sharded over
    every mesh axis (not just data)."""
    arch = get_arch(arch_id)
    if arch.family == "graph-analytics":
        return _setup_lcc(arch.config(), mesh,
                          {"arch": arch_id, "shape": shape_id, "kind": "lcc"})
    cfg, shape, batch_sds = input_specs(arch_id, shape_id)
    kind = step_kind(arch, shape)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    meta = {"arch": arch_id, "shape": shape_id, "kind": kind, "opt": opt}

    if arch.family == "lm":
        rules = lm_rules(mesh)
        if opt:
            # §Perf iteration 2: flash attention + MoE capacity sharding.
            # Sequence parallelism was tried in iteration 1 and REFUTED
            # (GSPMD re-gathers activations around attention, 3x collective
            # regression — see EXPERIMENTS.md §Perf), so it stays off.
            cfg = dataclasses.replace(
                cfg, flash_cutoff=2048, flash_block=1024,
                moe_impl="local_ep",
            )
            rules = dataclasses.replace(rules, mesh=mesh)
            # §Perf iteration 5: right-size the parallelism — a 1.6B dense
            # model at TP=16 drowns in activation all-reduces (the Fig-9
            # "over-partitioning" effect the paper observes for graphs).
            # Fold the model axis into data parallelism when the model is
            # small enough that pure DP fits (params+opt < HBM/4).
            if (not cfg.is_moe and cfg.param_count() * 14 <
                    HW.HBM_BYTES * 0.25 * mesh.devices.size
                    and kind == "lm_train"
                    and cfg.param_count() < 3e9):
                all_ax = tuple(mesh.axis_names)
                rules = dataclasses.replace(
                    rules, data=all_ax, model=(), mesh=mesh)
        pspecs = tfm.param_specs(cfg, rules)
        params_sds = jax.eval_shape(
            partial(tfm.init_params, cfg), jax.random.key(0)
        )
        params_ns = _tree_ns(mesh, pspecs)
        meta["params"] = int(cfg.param_count())
        meta["active_params"] = int(cfg.active_param_count())

        if kind == "lm_train":
            optim = adamw(lr=3e-4)
            opt_sds = jax.eval_shape(optim.init, params_sds)
            mspecs = zero1_specs(pspecs, params_sds, rules.data, mesh_shape)
            opt_ns = type(opt_sds)(
                mu=_tree_ns(mesh, mspecs.mu),
                nu=_tree_ns(mesh, mspecs.nu),
                count=_ns(mesh, P()),
            )
            # §Perf iteration 4: smaller microbatches bound the per-layer
            # activation working set (temp memory halves; same math).
            # §Perf iteration 7: bf16 gradient accumulation halves both
            # the accumulator memory and the grad all-reduce bytes.
            n_micro = 8 if opt else 4
            accum = jnp.bfloat16 if opt else jnp.float32
            step = tl.make_lm_train_step(cfg, optim, rules,
                                         n_microbatches=n_micro,
                                         accum_dtype=accum)
            meta["n_microbatches"] = n_micro
            meta["tokens_per_step"] = shape.global_batch * shape.seq_len
            batch_ns = _batch_sharding(mesh, batch_sds, "lm", cfg, rules)
            return (step, (params_sds, opt_sds, batch_sds),
                    (params_ns, opt_ns, batch_ns), meta)

        if kind == "lm_prefill":
            step = tl.make_lm_prefill_step(cfg, rules, max_len=shape.seq_len)
            batch_ns = _batch_sharding(mesh, batch_sds, "lm", cfg)
            return (step, (params_sds, batch_sds["tokens"]),
                    (params_ns, batch_ns["tokens"]), meta)

        # decode
        b = shape.global_batch
        t = shape.seq_len
        cache_sds = jax.eval_shape(
            partial(tfm.init_kv_cache, cfg, b, t)
        )
        dp = rules.dp
        tp = rules.tp
        data_extent = int(np.prod([mesh_shape[a] for a in rules.data])) if rules.data else 1
        if b >= data_extent:
            kv_spec = {"k": P(None, dp, tp, None, None),
                       "v": P(None, dp, tp, None, None),
                       "pos": P(None, dp, None)}
            tok_spec = P(dp)
        else:  # long-context single stream: shard the sequence everywhere
            seq_ax = tuple(rules.data) + tuple(rules.model)
            kv_spec = {"k": P(None, None, seq_ax, None, None),
                       "v": P(None, None, seq_ax, None, None),
                       "pos": P(None, None, seq_ax)}
            tok_spec = P()
        cache_ns = {
            key: {kk: _ns(mesh, kv_spec[kk]) for kk in ("k", "v", "pos")}
            for key in cache_sds
        }
        step = tl.make_lm_decode_step(cfg, rules)
        pos_sds = jax.ShapeDtypeStruct((), I32)
        return (
            step,
            (params_sds, batch_sds["token"], pos_sds, cache_sds),
            (params_ns, _ns(mesh, tok_spec), _ns(mesh, P()), cache_ns),
            meta,
        )

    if arch.family == "gnn":
        import importlib

        mod = importlib.import_module(
            {
                "mace": "repro.models.gnn.mace",
                "pna": "repro.models.gnn.pna",
                "gin-tu": "repro.models.gnn.gin",
                "gat-cora": "repro.models.gnn.gat",
            }[arch_id]
        )
        batch_sds = _pad_gnn_batch(batch_sds, mesh)
        if opt:
            # §Perf iteration 6c: node-sharded aggregation — segment
            # reductions constrain their [N, ...] outputs to the full mesh
            # so the combine becomes reduce-scatter, not a replicated
            # accumulator + all-reduce (the measured GNN bottleneck).
            from ..models.gnn.common import set_node_spec

            set_node_spec(tuple(mesh.axis_names))
        if opt and arch_id == "gat-cora" and shape_id in ("ogb_products",
                                                          "minibatch_lg"):
            # §Perf iteration 6 — the PAPER's technique on the GNN gather:
            # statically split edges into a hot stream (src in the top-C
            # highest-degree nodes, features replicated = the degree-score
            # cache) and a cold stream (cross-shard gather). Hot share
            # measured on the power-law stand-in: C = 2.7%% of n absorbs
            # 35%% of edge-src gathers (see EXPERIMENTS.md).
            ndev = mesh.devices.size
            e_tot = batch_sds["edge_src"].shape[0]
            hub_c = 65536
            e_hot = _pad_to(int(e_tot * 0.35), ndev)
            e_cold = _pad_to(e_tot - e_hot, ndev)
            i32 = batch_sds["edge_src"].dtype
            for key in ("edge_src", "edge_dst", "edge_mask"):
                del batch_sds[key]
            batch_sds["edge_src_cold"] = jax.ShapeDtypeStruct((e_cold,), i32)
            batch_sds["edge_src_hub_pos"] = jax.ShapeDtypeStruct((e_hot,), i32)
            batch_sds["hub_ids"] = jax.ShapeDtypeStruct((hub_c,), i32)
            batch_sds["edge_dst_cold"] = jax.ShapeDtypeStruct((e_cold,), i32)
            batch_sds["edge_dst_hot"] = jax.ShapeDtypeStruct((e_hot,), i32)
            batch_sds["edge_mask_cold"] = jax.ShapeDtypeStruct(
                (e_cold,), jnp.bool_)
            batch_sds["edge_mask_hot"] = jax.ShapeDtypeStruct(
                (e_hot,), jnp.bool_)
            meta["hub_split"] = {"C": hub_c, "hot_share": 0.35}
        params_sds = jax.eval_shape(
            partial(mod.init_params, cfg), jax.random.key(0)
        )
        params_ns = jax.tree.map(lambda _: _ns(mesh, P()), params_sds)
        optz = adamw(lr=1e-3, weight_decay=0.0)
        opt_sds = jax.eval_shape(optz.init, params_sds)
        opt_ns = jax.tree.map(lambda _: _ns(mesh, P()), opt_sds)
        step = tl.make_gnn_train_step(mod.apply, cfg, optz)
        batch_ns = _batch_sharding(mesh, batch_sds, "gnn", cfg)
        if opt:
            # §Perf iteration 2 (GNN): feature-dimension sharding of the
            # node table — gathers by edge index then move NO rows across
            # devices (each device gathers its own feature columns); only
            # the small post-projection [N, H, D] partials cross the mesh.
            # (iteration 1 — node rows over all axes — was refuted: the
            # cross-shard row gather got slightly WORSE, 0.404 -> 0.423 s.)
            data_ax = tuple(a for a in mesh.axis_names if a != "model")
            if "node_feat" in batch_sds and batch_sds["node_feat"].ndim == 2:
                v = batch_sds["node_feat"]
                batch_ns["node_feat"] = _ns(
                    mesh, _fit_spec(mesh, P(data_ax, "model"), v.shape)
                )
        n_par = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_sds))
        meta["params"] = n_par
        return (step, (params_sds, opt_sds, batch_sds),
                (params_ns, opt_ns, batch_ns), meta)

    if arch.family == "recsys":
        from ..models.recsys import din as din_mod

        # pad the candidate axis to a device-count multiple (masked padding)
        ndev = mesh.devices.size
        for key in ("cand_items", "cand_cats"):
            if key in batch_sds:
                v = batch_sds[key]
                batch_sds[key] = jax.ShapeDtypeStruct(
                    (_pad_to(v.shape[0], ndev),), v.dtype
                )
        params_sds = jax.eval_shape(
            partial(din_mod.init_params, cfg), jax.random.key(0)
        )
        tp = tuple(a for a in mesh.axis_names if a == "model")
        pspecs = jax.tree.map(lambda _: P(), params_sds)
        pspecs["item_table"] = P(tp, None)
        pspecs["cat_table"] = P(tp, None)
        params_ns = _tree_ns(mesh, pspecs)
        batch_ns = _batch_sharding(mesh, batch_sds, "recsys", cfg)
        n_par = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_sds))
        meta["params"] = n_par
        if kind == "recsys_train":
            optim = adamw(lr=1e-3, weight_decay=0.0)
            opt_sds = jax.eval_shape(optim.init, params_sds)
            mspecs = type(opt_sds)(mu=pspecs, nu=pspecs, count=P())
            opt_ns = type(opt_sds)(
                mu=_tree_ns(mesh, mspecs.mu),
                nu=_tree_ns(mesh, mspecs.nu),
                count=_ns(mesh, P()),
            )
            step = tl.make_recsys_train_step(din_mod.apply, cfg, optim)
            return (step, (params_sds, opt_sds, batch_sds),
                    (params_ns, opt_ns, batch_ns), meta)
        if kind == "recsys_serve":
            step = tl.make_recsys_serve_step(din_mod.apply, cfg)
            return (step, (params_sds, batch_sds), (params_ns, batch_ns), meta)
        step = tl.make_retrieval_step(din_mod.retrieval_score, cfg, top_k=100)
        return (step, (params_sds, batch_sds), (params_ns, batch_ns), meta)

    if arch.family == "graph-analytics":
        return _setup_lcc(cfg, mesh, meta)
    raise ValueError(arch.family)


def _setup_lcc(cfg, mesh: Mesh, meta):
    """The paper's own engine on a flattened mesh (extra, non-assigned)."""
    from ..core.async_engine import make_lcc_fn
    from ..core.rma import ShardedLCCProblem

    p = int(mesh.devices.size)
    flat = Mesh(mesh.devices.reshape(p), ("dev",))
    n = cfg.n_vertices
    n_loc = -(-n // p)
    w = cfg.row_width
    e_max = _pad_to(n_loc * cfg.avg_degree, cfg.n_rounds)
    s_max = max(e_max // cfg.n_rounds // max(p - 1, 1), 8)
    prob = ShardedLCCProblem(
        rows_ext=np.zeros((1,), np.int32),  # placeholder, shapes only
        degrees=None, edge_u=None, edge_vc=None, edge_mask=None,
        serve_idx=None, cache_rows=None,
        n=n, p=p, width=w, n_loc=n_loc, e_max=e_max,
        n_rounds=cfg.n_rounds, s_max=s_max,
        cache_ids=np.zeros((cfg.cache_rows,), np.int64),
    )
    fn = make_lcc_fn(prob, flat, method="bsearch")
    c = cfg.cache_rows
    sds = (
        jax.ShapeDtypeStruct((p, n_loc + 1, w), I32),
        jax.ShapeDtypeStruct((p, n_loc), I32),
        jax.ShapeDtypeStruct((p, e_max), I32),
        jax.ShapeDtypeStruct((p, e_max), I32),
        jax.ShapeDtypeStruct((p, e_max), jnp.bool_),
        jax.ShapeDtypeStruct((p, cfg.n_rounds, p, s_max), I32),
        jax.ShapeDtypeStruct((c, w), I32),
    )
    shards = tuple(
        NamedSharding(flat, P("dev"))
        for _ in range(6)
    ) + (NamedSharding(flat, P()),)
    meta["note"] = "paper LCC engine; flat 1D mesh over all chips"
    return fn, sds, shards, meta


# --------------------------------------------------------------------------
# run one cell
# --------------------------------------------------------------------------
def run_cell(arch_id: str, shape_id: str, mesh_kind: str,
             *, opt: bool = False, keep_hlo: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.time()
    out = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
           "mesh_shape": list(mesh.devices.shape), "ok": False}
    try:
        fn, args, shardings, meta = setup_cell(arch_id, shape_id, mesh,
                                               opt=opt)
        out.update(meta)
        # donate what a real deployment donates: params/opt state for train
        # steps, the KV cache for decode (memory_analysis double-counts
        # in/out buffers otherwise).
        kind = meta.get("kind", "")
        if kind.endswith("_train") or kind == "gnn_train":
            donate = (0, 1)
        elif kind == "lm_decode":
            donate = (3,)
        else:
            donate = ()
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        census = collective_census(hlo)
        out.update(
            ok=True,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_comp - t_lower, 2),
            memory={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            },
            cost={
                "flops": float(cost.get("flops", 0.0)),
                "transcendentals": float(cost.get("transcendentals", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            collectives=census,
            hlo_bytes=len(hlo),
        )
        if keep_hlo:
            out["hlo_text"] = hlo[:2_000_000]
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
    out["total_s"] = round(time.time() - t0, 2)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-lcc", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf beyond-baseline configuration")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print cell ids (for per-cell subprocess sweeps)")
    args = ap.parse_args(argv)

    if args.list:
        for aid, sid in cells():
            print(f"{aid} {sid}")
        if args.include_lcc:
            print("paper-lcc default")
        return 0

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for aid, sid in cells():
            todo += [(aid, sid, m) for m in meshes]
        if args.include_lcc:
            todo += [("paper-lcc", "default", m) for m in meshes]
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape, m) for m in meshes]

    for aid, sid, m in todo:
        tag = f"{aid}__{sid}__{m}".replace("/", "_").replace(".", "_")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            try:
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip] {tag}")
                        continue
            except Exception:  # noqa: BLE001 — malformed -> rerun
                pass
        print(f"[run ] {tag}", flush=True)
        res = run_cell(aid, sid, m, opt=args.opt)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = "OK" if res["ok"] else "FAIL " + res.get("error", "")[:200]
        print(f"[done] {tag}: {status} ({res['total_s']}s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
