"""Streaming-workload launcher: incremental triangle counting + LCC over
a replayed R-MAT edge stream with batched insert/delete updates.

    python -m repro.launch.stream_run --scale 10 --batches 8
    python -m repro.launch.stream_run --scale 12 --batches 32 \
        --delete-frac 0.2 --cache-rows 512 --ranks 8 --checkpoint-every 4 \
        --maintain-schedule

Each batch flows through ``StreamingLCCEngine`` over the shared
``ShardedRuntime``: the delta worklist is partitioned by owner rank and
each shard's row pairs are intersected via the batched Pallas
``intersect_count`` path, per-vertex triangle tallies and LCC are patched
in place, the ``DynamicCSR`` absorbs the updates (compacting when the
delta buffer outgrows its threshold), and the coherence layer replays the
delta access stream through the runtime's per-rank CLaMPI caches +
static degree cache, fanning invalidations only to the ranks that cached
the touched rows. At every checkpoint the engine state is verified
**bit-exactly** against a from-scratch ``triangles_per_vertex`` /
``lcc_scores`` recount of the compacted graph.

With ``--maintain-schedule`` the runtime also carries the epoch engine's
compiled pull schedule and keeps it fresh per batch via the incremental
``ShardedLCCProblem.apply_delta`` (falling back to a from-scratch build
on padded-width overflow); every checkpoint additionally verifies the
maintained schedule bit-exact against ``build_sharded_problem`` on the
current snapshot.

Reports per batch: effective ops, updates/sec, triangle count; at the
end: total throughput, per-rank worklist balance, cache hit rate on the
delta stream, invalidation fanout savings, static-cache rebuilds,
schedule maintenance counts, and compactions.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batches", type=int, default=8,
                    help="number of update batches the stream is split into")
    ap.add_argument("--delete-frac", type=float, default=0.15,
                    help="fraction of each batch that deletes prior edges")
    ap.add_argument("--p", type=int, default=4,
                    help="runtime ranks (1D partition for sharded worklists "
                         "and the coherence replay)")
    ap.add_argument("--ranks", type=int, default=None,
                    help="alias for --p (overrides it when given)")
    ap.add_argument("--spmd", action="store_true",
                    help="execute the per-rank delta shards as real SPMD "
                         "compute over a JAX device mesh (shard_map): "
                         "remote rows ship owner->rank through an "
                         "all_to_all and the old-intersect-old counts run "
                         "on-device, cross-checked against the host "
                         "membership masks; needs >= ranks devices")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --spmd: double-buffer the two batch phases "
                         "— the insert phase's host pack + collective "
                         "launch overlaps the delete phase's in-flight "
                         "device intersect (bit-identical results)")
    ap.add_argument("--device-scope", choices=("replicated", "per_rank"),
                    default="replicated",
                    help="with --device-tier: one hot set replicated on "
                         "every device, or a distinct per-rank hot set "
                         "of each rank's own remote-heavy rows")
    ap.add_argument("--adversarial", action="store_true",
                    help="hub-targeted deletes (stresses degree-score drift)")
    ap.add_argument("--partition", choices=("1d", "hub"), default="1d",
                    help="vertex ownership: '1d' equal blocks or 'hub' "
                         "balance-aware cuts + hub splitting. The stream "
                         "starts empty, so hub cuts degenerate to 1D at "
                         "batch 0 — pair with --rebalance to chase the "
                         "emerging heavy tail (docs/partitioning.md)")
    ap.add_argument("--hub-threshold", type=int, default=None,
                    help="with --partition hub: degree at/above which a "
                         "row is fragmented (default: recomputed from the "
                         "live degrees at each rebalance)")
    ap.add_argument("--rebalance", action="store_true",
                    help="with --partition hub: between batches, when the "
                         "windowed read imbalance crosses "
                         "--rebalance-trigger, refresh the hub set and "
                         "migrate bounded row ranges toward the degree-"
                         "balanced cuts (invalidation fanout + residency "
                         "handoff + schedule rebuild; checkpoints stay "
                         "bit-exact)")
    ap.add_argument("--rebalance-trigger", type=float, default=1.25,
                    help="windowed max/mean read imbalance that arms a "
                         "migration")
    ap.add_argument("--max-moves", type=int, default=4096,
                    help="rows each cut boundary may move per migration")
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--clampi-kib", type=int, default=1024)
    ap.add_argument("--maintain-schedule", action="store_true",
                    help="keep a compiled pull schedule fresh incrementally "
                         "(verified vs a from-scratch build per checkpoint); "
                         "carries the coherence layer's static residency, "
                         "refreshed in place when it drifts")
    ap.add_argument("--device-tier", action="store_true",
                    help="device-resident hot-row tier: oo delta "
                         "intersections run against persistently resident "
                         "hub rows (resident_intersect gather kernel)")
    ap.add_argument("--device-slots", type=int, default=256,
                    help="hot-set capacity (rows) of the device tier")
    ap.add_argument("--device-width", type=int, default=None,
                    help="padded row width of the device buffer")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="verify vs from-scratch recount every k batches "
                         "(<= 0: only the final verification)")
    ap.add_argument("--compact-threshold", type=float, default=0.25)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--no-kernel", action="store_true",
                    help="skip the Pallas path (pure-numpy masks only)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome-trace span timeline of the run "
                         "(open at ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--trace-fine", action="store_true",
                    help="with --trace: also emit per-cache-entry "
                         "admit/evict instants (bigger trace)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the labeled metrics snapshot (all ledgers "
                         "+ per-phase time; see docs/observability.md)")
    ap.add_argument("--cache-trace", default=None, metavar="PATH",
                    help="record every cache access on both tiers and "
                         "write the cachescope analysis sidecar (reuse "
                         "distances, Mattson hit-rate curve, eviction "
                         "audit, offline policy replay incl. Belady; "
                         "validated by repro.obs.validate --cachescope)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.trace_fine and not args.trace:
        ap.error("--trace-fine needs --trace")
    if args.pipeline and not args.spmd:
        ap.error("--pipeline double-buffers SPMD phases; pass --spmd")
    if args.device_scope != "replicated" and not args.device_tier:
        ap.error("--device-scope shapes the device tier; pass --device-tier")
    if args.hub_threshold is not None and args.partition != "hub":
        ap.error("--hub-threshold shapes the hub partition; pass "
                 "--partition hub")
    if args.rebalance and args.partition != "hub":
        ap.error("--rebalance migrates hub-partition cuts; pass "
                 "--partition hub")
    tracer = None
    if args.trace:
        from ..obs import trace as obs_trace

        tracer = obs_trace.enable_tracing(fine=args.trace_fine)
    recorder = None
    if args.cache_trace:
        from ..obs import cachescope as obs_cachescope

        recorder = obs_cachescope.enable_recording()
    ranks = args.ranks if args.ranks is not None else args.p
    if args.spmd:
        # before anything initializes jax (the device count is locked at
        # first init); preserves user/CI-provided XLA_FLAGS.
        from ..distributed.spmd_runtime import ensure_host_devices

        ensure_host_devices(ranks)

    from ..core.rma import assert_problems_equal, build_sharded_problem
    from ..graphs.rmat import rmat_adversarial_stream, rmat_stream
    from ..streaming import StreamingCacheCoherence, StreamingLCCEngine

    n = 1 << args.scale
    total_ops = args.edge_factor << args.scale
    batch_size = -(-total_ops // args.batches)
    print(f"R-MAT S{args.scale} EF{args.edge_factor} stream: n={n}, "
          f"{total_ops} inserts (+{args.delete_frac:.0%} deletes"
          f"{', hub-targeted' if args.adversarial else ''}) in "
          f"{args.batches} batches of {batch_size}, ranks={ranks}"
          + ("  [SPMD device mesh]" if args.spmd else ""))

    partition = None
    if args.partition == "hub":
        from ..core.partition import partition_hub

        # built against the empty store: no hubs yet, equal cuts — the
        # rebalancer refreshes both as the heavy tail emerges.
        partition = partition_hub(
            np.zeros(n, np.int64), ranks, threshold=args.hub_threshold
        )
        print(f"hub partition: starting empty (threshold "
              f"{partition.threshold}), "
              + ("rebalancer will chase the live degrees"
                 if args.rebalance else "static cuts (no --rebalance)"))
    coh = StreamingCacheCoherence(
        n,
        np.zeros(n, np.int64),
        p=ranks,
        cache_rows=args.cache_rows,
        clampi_bytes=args.clampi_kib << 10,
        partition=partition,
    )
    eng = StreamingLCCEngine.empty(
        n,
        use_kernel=not args.no_kernel,
        compact_threshold=args.compact_threshold,
        coherence=coh,
        execution="spmd" if args.spmd else "loop",
        pipeline=args.pipeline,
    )
    runtime = eng.runtime
    if args.device_tier:
        # the stream starts from an empty graph, so the width cannot be
        # inferred from current degrees; 256 covers R-MAT hubs at the
        # launcher's scales (wider rows simply stay host-side).
        runtime.enable_device_tier(
            args.device_slots,
            args.device_width if args.device_width is not None else 256,
            scope=args.device_scope,
        )
    if args.maintain_schedule:
        # compile the schedule WITH the coherence layer's static
        # residency: when churn drifts the top-C, maintain_schedule
        # refreshes cache_ids in place instead of rebuilding.
        runtime.attach_problem(
            build_sharded_problem(
                eng.store.to_csr(), ranks, width=64, cache=coh.static,
                part=runtime.part,
            )
        )
    rebalancer = None
    if args.rebalance:
        from ..core.repartition import Rebalancer

        # load signal: the sharded delta worklist (what shard_imbalance
        # summarizes) — the coherence replay bypasses fetch_rows, so the
        # runtime's provider read stats would never move here.
        rebalancer = Rebalancer(
            runtime,
            trigger=args.rebalance_trigger,
            max_moves=args.max_moves,
            hub_threshold=args.hub_threshold,
            reads=lambda: eng.shard_pairs,
        )

    def check_schedule():
        from repro.core.cache import StaticDegreeCache

        snap = eng.store.to_csr()
        prob = runtime.problem
        cache = (
            StaticDegreeCache(vertex_ids=prob.cache_ids.copy())
            if prob.cache_ids.size
            else None
        )
        fresh = build_sharded_problem(
            snap,
            ranks,
            n_rounds=prob.n_rounds_requested,
            cache=cache,
            width=prob.width,
            dedup_rounds=prob.dedup_rounds,
            part=runtime.part,
        )
        assert_problems_equal(prob, fresh)

    stream = (
        rmat_adversarial_stream(
            args.scale, args.edge_factor, batch_size=batch_size,
            delete_frac=args.delete_frac, seed=args.seed,
        )
        if args.adversarial
        else rmat_stream(
            args.scale, args.edge_factor, batch_size=batch_size,
            delete_frac=args.delete_frac, seed=args.seed,
        )
    )
    wall = 0.0
    verified_last = False
    for i, batch in enumerate(stream):
        t0 = time.perf_counter()
        res = eng.apply_batch(batch)
        plan = (rebalancer.maybe_rebalance(eng.store.degrees)
                if rebalancer is not None else None)
        dt = time.perf_counter() - t0
        wall += dt
        verified_last = False
        ops = res.n_inserted + res.n_deleted
        line = (f"batch {i:3d}: +{res.n_inserted} -{res.n_deleted} "
                f"(noop {res.n_noop})  T={eng.triangle_count}  "
                f"{ops / max(dt, 1e-9):,.0f} upd/s"
                + ("  [compacted]" if res.compacted else "")
                + ("  [schedule rebuilt]"
                   if res.schedule_incremental is False else "")
                + (f"  [migrated {plan.n_moved} rows]"
                   if plan is not None else ""))
        if (not args.no_verify and args.checkpoint_every > 0
                and (i + 1) % args.checkpoint_every == 0):
            eng.verify()
            if args.maintain_schedule:
                check_schedule()
            verified_last = True
            line += "  checkpoint: exact vs recount"
            if args.maintain_schedule:
                line += " + schedule"
        print(line, flush=True)

    rep = coh.report
    shares = eng.shard_pairs / max(int(eng.shard_pairs.sum()), 1)
    print(f"\n{eng.n_updates} effective updates in {wall:.2f}s "
          f"({eng.n_updates / max(wall, 1e-9):,.0f} upd/s), "
          f"{eng.delta_pairs_total} delta row pairs, "
          f"{eng.store.n_compactions} compactions")
    print(f"shards[p={ranks}]: worklist shares "
          f"[{', '.join(f'{s:.0%}' for s in shares)}]")
    if rebalancer is not None:
        part = runtime.part
        sizes = part.sizes()
        print(f"rebalance: {rebalancer.migrations} migrations moved "
              f"{rebalancer.rows_moved} rows; final cuts "
              f"{int(sizes.min())}..{int(sizes.max())} rows/rank, "
              f"{part.hubs.size} hubs (degree >= {part.threshold})")
    print(f"coherence[p={ranks}]: delta-stream hit rate {rep.hit_rate:.1%} "
          f"(static {rep.static_hits}, clampi {rep.clampi_hits} hits / "
          f"{rep.remote_reads} remote reads), "
          f"{rep.invalidations} invalidations "
          f"(fanout saved {runtime.invalidation_fanout_saved} msgs vs "
          f"broadcast), "
          f"{rep.static_rebuilds} static rebuilds, "
          f"{coh.clampi.stats.evictions} evictions, "
          f"modeled comm {coh.total_comm_time * 1e3:.2f} ms")
    if args.spmd:
        led = eng.spmd.ledger
        print(f"spmd[{led.p} devices]: {led.n_collectives} all_to_all "
              f"collectives, {led.total_rows} remote rows / "
              f"{led.bytes_payload} B payload shipped owner->rank, "
              f"{led.bytes_on_wire} B on the padded wire, "
              f"{led.n_pairs} oo pairs intersected on-device in "
              f"{led.device_wall_s:.2f}s (counts cross-checked vs host "
              f"masks every batch)")
        print(f"  async plane: {led.bytes_uploaded} B uploaded in "
              f"{led.n_patches} resident-buffer patches, "
              f"{led.upload_bytes_saved} B re-upload saved; wire padding "
              f"saved {led.wire_padding_saved} B vs single-width "
              f"({led.bytes_on_wire_single} B)"
              + (f"; overlap wait {led.overlap_wait_s:.2f}s"
                 if args.pipeline else ""))
    if args.maintain_schedule:
        print(f"schedule: {runtime.schedule_deltas} incremental deltas, "
              f"{runtime.schedule_rebuilds} width-overflow rebuilds, "
              f"{runtime.schedule_residency_refreshes} in-place residency "
              f"refreshes (width {runtime.problem.width}, e_max "
              f"{runtime.problem.e_max}, s_max {runtime.problem.s_max})")
    if args.device_tier:
        views = runtime.device_views()
        ds = runtime.merged_device_stats()
        resident = sum(v.resident_rows for v in views)
        slots = sum(v.slots for v in views)
        label = (f"{len(views)} per-rank hot sets"
                 if args.device_scope == "per_rank" else "replicated")
        print(f"device tier[{label}, {resident}/{slots} slots x "
              f"width {views[0].max_width}]: {eng.oo_resident_pairs} oo pairs "
              f"on-device, hit rate {ds.hit_rate:.1%}, "
              f"{ds.bytes_saved} B host materialization saved "
              f"({eng.oo_host_bytes} B still built), "
              f"{ds.patches} patches / {ds.admits} admits / "
              f"{ds.evicts} evicts, {ds.upload_bytes} B uploaded")
    if not args.no_verify:
        if not verified_last:  # last batch's checkpoint already recounted
            eng.verify()
            if args.maintain_schedule:
                check_schedule()
        print("final state verified bit-exact vs from-scratch recount"
              + (" (incl. maintained schedule)"
                 if args.maintain_schedule else ""))
    cache_report = None
    if recorder is not None:
        from ..obs import cachescope as obs_cachescope

        obs_cachescope.disable_recording()
        cache_report = obs_cachescope.analyze(recorder)
        obs_cachescope.save_report(cache_report, args.cache_trace)
        print(obs_cachescope.summarize(cache_report))
        print(f"cache trace: {recorder.n_events()} events -> "
              f"{args.cache_trace}")
    if args.metrics:
        from ..obs.metrics import (
            MetricRegistry,
            fold_trace,
            imbalance,
            record_cachescope,
            record_coherence_report,
            record_collective_ledger,
            record_runtime,
        )

        reg = MetricRegistry()
        record_runtime(reg, runtime)
        record_coherence_report(reg, rep)
        if cache_report is not None:
            record_cachescope(reg, cache_report)
        # streaming's load dimension is the sharded delta worklist
        for k in range(ranks):
            reg.counter("shard_pairs", int(eng.shard_pairs[k]), rank=k,
                        tier="host", phase="intersect_kernel")
        reg.gauge("shard_imbalance", imbalance(eng.shard_pairs),
                  tier="host")
        if args.spmd:
            # measured wire traffic only — no reconciliation claim: the
            # loop-path counterpart of these reads goes straight to the
            # store, so the serve matrix models none of this traffic
            record_collective_ledger(reg, eng.spmd.ledger)
        if tracer is not None:
            fold_trace(reg, tracer)
        snap = reg.to_dict()
        reg.save(args.metrics)
        print(f"metrics: {len(snap['counters'])} counters, "
              f"{len(snap['gauges'])} gauges -> {args.metrics}  "
              f"[shard imbalance "
              f"{reg.get_gauge('shard_imbalance', tier='host'):.2f}x]")
    if tracer is not None:
        from ..obs import trace as obs_trace

        obs_trace.disable_tracing()
        tracer.export(args.trace)
        print(f"trace: {len(tracer)} events -> {args.trace} "
              "(open at ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
