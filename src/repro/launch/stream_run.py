"""Streaming-workload launcher: incremental triangle counting + LCC over
a replayed R-MAT edge stream with batched insert/delete updates.

    python -m repro.launch.stream_run --scale 10 --batches 8
    python -m repro.launch.stream_run --scale 12 --batches 32 \
        --delete-frac 0.2 --cache-rows 512 --p 8 --checkpoint-every 4

Each batch flows through ``StreamingLCCEngine``: the delta row pairs are
intersected via the batched Pallas ``intersect_count`` path, per-vertex
triangle tallies and LCC are patched in place, the ``DynamicCSR`` absorbs
the updates (compacting when the delta buffer outgrows its threshold),
and the coherence layer replays the delta access stream through the
CLaMPI simulator + static degree cache. At every checkpoint the engine
state is verified **bit-exactly** against a from-scratch
``triangles_per_vertex`` / ``lcc_scores`` recount of the compacted graph.

Reports per batch: effective ops, updates/sec, triangle count; at the
end: total throughput, cache hit rate on the delta stream, invalidations,
static-cache rebuilds, and compactions.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batches", type=int, default=8,
                    help="number of update batches the stream is split into")
    ap.add_argument("--delete-frac", type=float, default=0.15,
                    help="fraction of each batch that deletes prior edges")
    ap.add_argument("--p", type=int, default=4,
                    help="simulated ranks for the coherence replay")
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--clampi-kib", type=int, default=1024)
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="verify vs from-scratch recount every k batches "
                         "(<= 0: only the final verification)")
    ap.add_argument("--compact-threshold", type=float, default=0.25)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--no-kernel", action="store_true",
                    help="skip the Pallas path (pure-numpy masks only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..graphs.rmat import rmat_stream
    from ..streaming import StreamingCacheCoherence, StreamingLCCEngine

    n = 1 << args.scale
    total_ops = args.edge_factor << args.scale
    batch_size = -(-total_ops // args.batches)
    print(f"R-MAT S{args.scale} EF{args.edge_factor} stream: n={n}, "
          f"{total_ops} inserts (+{args.delete_frac:.0%} deletes) in "
          f"{args.batches} batches of {batch_size}")

    coh = StreamingCacheCoherence(
        n,
        np.zeros(n, np.int64),
        p=args.p,
        cache_rows=args.cache_rows,
        clampi_bytes=args.clampi_kib << 10,
    )
    eng = StreamingLCCEngine.empty(
        n,
        use_kernel=not args.no_kernel,
        compact_threshold=args.compact_threshold,
        coherence=coh,
    )

    wall = 0.0
    verified_last = False
    for i, batch in enumerate(
        rmat_stream(
            args.scale,
            args.edge_factor,
            batch_size=batch_size,
            delete_frac=args.delete_frac,
            seed=args.seed,
        )
    ):
        t0 = time.perf_counter()
        res = eng.apply_batch(batch)
        dt = time.perf_counter() - t0
        wall += dt
        verified_last = False
        ops = res.n_inserted + res.n_deleted
        line = (f"batch {i:3d}: +{res.n_inserted} -{res.n_deleted} "
                f"(noop {res.n_noop})  T={eng.triangle_count}  "
                f"{ops / max(dt, 1e-9):,.0f} upd/s"
                + ("  [compacted]" if res.compacted else ""))
        if (not args.no_verify and args.checkpoint_every > 0
                and (i + 1) % args.checkpoint_every == 0):
            eng.verify()
            verified_last = True
            line += "  checkpoint: exact vs recount"
        print(line, flush=True)

    rep = coh.report
    print(f"\n{eng.n_updates} effective updates in {wall:.2f}s "
          f"({eng.n_updates / max(wall, 1e-9):,.0f} upd/s), "
          f"{eng.delta_pairs_total} delta row pairs, "
          f"{eng.store.n_compactions} compactions")
    print(f"coherence[p={args.p}]: delta-stream hit rate {rep.hit_rate:.1%} "
          f"(static {rep.static_hits}, clampi {rep.clampi_hits} hits / "
          f"{rep.remote_reads} remote reads), "
          f"{rep.invalidations} invalidations, "
          f"{rep.static_rebuilds} static rebuilds, "
          f"{coh.clampi.stats.evictions} evictions, "
          f"modeled comm {coh.total_comm_time * 1e3:.2f} ms")
    if not args.no_verify:
        if not verified_last:  # last batch's checkpoint already recounted
            eng.verify()
        print("final state verified bit-exact vs from-scratch recount")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
