from . import tokens, recsys  # noqa: F401
