"""Synthetic LM token pipeline: deterministic, step-indexed, restart-safe.

``TokenStream.batch_at(step)`` is a pure function of (seed, step) so a
restarted job resumes the exact stream — the checkpoint stores only
(seed, next_step). Data follows a Zipf unigram distribution with a
repeated-ngram structure so the model has something learnable (loss
decreases over a few hundred steps in the end-to-end example).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed << 32) ^ step)
        # zipf-ish unigrams, clipped to vocab
        base = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1))
        toks = (base - 1) % self.vocab
        # inject learnable bigram structure: token t+1 = f(t) half the time
        follow = (toks[:, :-1] * 31 + 7) % self.vocab
        mask = rng.random((self.batch, self.seq)) < 0.5
        toks[:, 1:] = np.where(mask, follow, toks[:, 1:])
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def state(self, next_step: int) -> dict:
        return {"seed": self.seed, "next_step": next_step}

    @classmethod
    def from_state(cls, vocab, batch, seq, state: dict) -> "TokenStream":
        return cls(vocab=vocab, batch=batch, seq=seq, seed=state["seed"])
