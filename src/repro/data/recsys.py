"""Synthetic CTR stream for DIN: Zipf-distributed item ids (the power-law
id popularity that makes the paper's hot-row cache effective), correlated
labels so training is learnable, deterministic per (seed, step).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CTRStream"]


@dataclasses.dataclass
class CTRStream:
    n_items: int
    n_cats: int
    batch: int
    seq_len: int = 100
    d_profile: int = 8
    seed: int = 0
    zipf_a: float = 1.3

    def _zipf_ids(self, rng, shape, hi):
        return ((rng.zipf(self.zipf_a, size=shape) - 1) % hi).astype(np.int32)

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed << 32) ^ (step * 2 + 1))
        hist_items = self._zipf_ids(rng, (self.batch, self.seq_len), self.n_items)
        hist_cats = (hist_items % self.n_cats).astype(np.int32)
        lengths = rng.integers(5, self.seq_len + 1, size=self.batch)
        hist_mask = np.arange(self.seq_len)[None, :] < lengths[:, None]
        target_item = self._zipf_ids(rng, (self.batch,), self.n_items)
        target_cat = (target_item % self.n_cats).astype(np.int32)
        profile = rng.normal(size=(self.batch, self.d_profile)).astype(np.float32)
        # label correlates with whether target's category appears in history
        seen = (hist_cats == target_cat[:, None]) & hist_mask
        p = np.where(seen.any(axis=1), 0.75, 0.2)
        label = (rng.random(self.batch) < p).astype(np.float32)
        return {
            "hist_items": hist_items,
            "hist_cats": hist_cats,
            "hist_mask": hist_mask,
            "target_item": target_item,
            "target_cat": target_cat,
            "user_profile": profile,
            "label": label,
        }
