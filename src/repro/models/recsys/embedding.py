"""EmbeddingBag built from gather + segment-reduce.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — per the
assignment this IS part of the system: ``jnp.take`` over the (sharded)
table + ``jax.ops.segment_sum`` over bag offsets. Two layouts:

- fixed-shape bags [B, L] with a mask (the DIN history layout), and
- ragged bags (ids + offsets, torch-EmbeddingBag-compatible semantics).

Tables shard rows over the 'model' axis (``P(tp, None)``). Lookup of a
row then lowers to a cross-shard gather; the paper's degree-score cache
reappears here as the *hot-row replication cache* (id frequency in CTR
traffic is power-law, exactly the reuse structure of §III-B) — see
``distributed/hub_gather.py``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..common import trunc_normal

__all__ = [
    "embedding_init",
    "embedding_specs",
    "lookup",
    "bag_fixed",
    "bag_ragged",
]


def embedding_init(key, n_rows: int, dim: int, dtype=jnp.float32):
    return trunc_normal(key, (n_rows, dim), scale=1.0).astype(dtype)


def embedding_specs(tp):
    return P(tp, None)  # row-sharded table


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def bag_fixed(
    table: jnp.ndarray,
    ids: jnp.ndarray,  # [B, L]
    mask: Optional[jnp.ndarray] = None,  # [B, L] bool
    *,
    mode: str = "sum",
    weights: Optional[jnp.ndarray] = None,  # [B, L]
) -> jnp.ndarray:
    emb = lookup(table, ids)  # [B, L, D]
    w = jnp.ones(ids.shape, emb.dtype) if weights is None else weights
    if mask is not None:
        w = w * mask.astype(emb.dtype)
    s = (emb * w[..., None]).sum(axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    if mode == "max":
        neg = jnp.where(
            (w > 0)[..., None], emb, jnp.full_like(emb, -jnp.inf)
        )
        m = neg.max(axis=1)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(mode)


def bag_ragged(
    table: jnp.ndarray,
    ids: jnp.ndarray,  # [NNZ]
    offsets: jnp.ndarray,  # [B] start offsets (torch convention)
    n_bags: int,
    *,
    mode: str = "sum",
    weights: Optional[jnp.ndarray] = None,  # [NNZ]
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag semantics: bag b = reduce(ids[off[b]:off[b+1]])."""
    nnz = ids.shape[0]
    seg = jnp.searchsorted(offsets, jnp.arange(nnz), side="right") - 1
    emb = lookup(table, ids)  # [NNZ, D]
    if weights is not None:
        emb = emb * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(emb, seg, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(emb, seg, num_segments=n_bags)
        cnt = jax.ops.segment_sum(
            jnp.ones((nnz, 1), emb.dtype), seg, num_segments=n_bags
        )
        return s / jnp.maximum(cnt, 1e-9)
    if mode == "max":
        m = jax.ops.segment_max(emb, seg, num_segments=n_bags)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(mode)
