from . import embedding, din  # noqa: F401
