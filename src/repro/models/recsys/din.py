"""DIN — Deep Interest Network (Zhou et al., arXiv:1706.06978).

Assigned config: embed_dim=18, seq_len=100, attention MLP 80-40,
output MLP 200-80, interaction = target attention.

Structure (faithful to the paper):
- item-id + category-id embedding tables (18-d each; item repr = concat,
  36-d), looked up through the EmbeddingBag substrate
- local activation unit: per (history item, target): MLP([h, t, h-t, h*t])
  -> 80 -> 40 -> 1, *unnormalized* weights (DIN explicitly does not
  softmax), weighted sum-pool of history
- concat(pooled history, target, user profile) -> 200 -> 80 -> 1 with Dice
  activations -> CTR logit.

Shapes: train_batch 65536 / serve_p99 512 / serve_bulk 262144 /
retrieval_cand (1 user x 1e6 candidates — batched scoring, no loop;
``retrieval_score`` broadcasts one user's pooled state against all
candidate embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..common import trunc_normal
from .embedding import embedding_init, lookup

__all__ = ["DINConfig", "init_params", "apply", "retrieval_score"]


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 100_000_000
    n_cats: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    d_profile: int = 8
    attn_hidden: tuple = (80, 40)
    mlp_hidden: tuple = (200, 80)
    dtype: Any = jnp.float32

    @property
    def d_item(self) -> int:
        return 2 * self.embed_dim  # item ++ category


def _mlp_init(key, sizes, dtype):
    out = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        k, key = jax.random.split(key)
        out.append({"w": trunc_normal(k, (a, b)).astype(dtype),
                    "b": jnp.zeros((b,), dtype)})
    return out


def init_params(cfg: DINConfig, key) -> Dict[str, Any]:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d = cfg.d_item
    attn_sizes = (4 * d,) + cfg.attn_hidden + (1,)
    mlp_sizes = (2 * d + cfg.d_profile,) + cfg.mlp_hidden + (1,)
    return {
        "item_table": embedding_init(k1, cfg.n_items, cfg.embed_dim, cfg.dtype),
        "cat_table": embedding_init(k2, cfg.n_cats, cfg.embed_dim, cfg.dtype),
        "attn": _mlp_init(k3, attn_sizes, cfg.dtype),
        "mlp": _mlp_init(k4, mlp_sizes, cfg.dtype),
        "dice_alpha": jnp.zeros((len(cfg.mlp_hidden),), cfg.dtype),
    }


def _dice(x, alpha):
    """Dice activation: adaptive PReLU gated by batch statistics."""
    mu = x.mean(axis=0, keepdims=True)
    var = x.var(axis=0, keepdims=True)
    ps = jax.nn.sigmoid((x - mu) * jax.lax.rsqrt(var + 1e-8))
    return ps * x + (1.0 - ps) * alpha * x


def _mlp(params, x, alphas=None):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = _dice(x, alphas[i]) if alphas is not None else jax.nn.relu(x)
    return x


def _item_repr(params, items, cats):
    return jnp.concatenate(
        [lookup(params["item_table"], items), lookup(params["cat_table"], cats)],
        axis=-1,
    )


def _attention_pool(params, hist, target, mask):
    """hist [B, L, D], target [B, D] -> pooled [B, D] (local activation)."""
    b, l, d = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (b, l, d))
    feats = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = _mlp(params["attn"], feats)[..., 0]  # [B, L], unnormalized
    w = jnp.where(mask, w, 0.0)
    return jnp.einsum("bl,bld->bd", w, hist)


def apply(params, batch: Dict[str, jnp.ndarray], cfg: DINConfig):
    """Returns CTR logits [B]."""
    hist = _item_repr(params, batch["hist_items"], batch["hist_cats"])
    target = _item_repr(params, batch["target_item"], batch["target_cat"])
    pooled = _attention_pool(params, hist, target, batch["hist_mask"])
    x = jnp.concatenate([pooled, target, batch["user_profile"]], axis=-1)
    return _mlp(params["mlp"], x, alphas=params["dice_alpha"])[..., 0]


def retrieval_score(params, batch: Dict[str, jnp.ndarray], cfg: DINConfig):
    """One user vs N candidates [N]: batched dot/attention, no loop.

    batch: hist_items/hist_cats/hist_mask [1, L]; cand_items/cand_cats [N];
    user_profile [1, d_profile].
    """
    hist = _item_repr(params, batch["hist_items"], batch["hist_cats"])  # [1,L,D]
    cands = _item_repr(params, batch["cand_items"], batch["cand_cats"])  # [N,D]
    n = cands.shape[0]
    l = hist.shape[1]
    h = jnp.broadcast_to(hist, (n,) + hist.shape[1:])  # [N, L, D] (view)
    pooled = _attention_pool(params, h, cands, jnp.broadcast_to(
        batch["hist_mask"], (n, l)))
    prof = jnp.broadcast_to(batch["user_profile"], (n, batch["user_profile"].shape[-1]))
    x = jnp.concatenate([pooled, cands, prof], axis=-1)
    return _mlp(params["mlp"], x, alphas=params["dice_alpha"])[..., 0]
