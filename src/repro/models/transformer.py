"""Decoder-only LM transformer covering the five assigned LM architectures.

One config-driven implementation:
  - GQA attention (any H/K ratio), RoPE, optional QKV bias (qwen2.5)
  - alternating local(sliding-window)/global layers + attn & final logit
    soft-capping + post-norms + zero-centered RMSNorm (gemma2)
  - SwiGLU MLP or top-k MoE FFN (moonshot 64e/top-6, phi3.5 16e/top-2)
  - scan-over-layers (one repeating *block pattern*, e.g. ("local","global")
    for gemma2) so compile time is O(1) in depth, with optional remat
  - train (full-seq logits), prefill (build KV cache) and decode (one
    token against a ring-buffer KV cache — local layers cache only the
    window) paths sharing the same layer code.

Params are plain pytrees; sharding is annotated via PartitionSpec trees
(``param_specs``) + activation constraints, resolved against the mesh by
jit — the same code runs on 1 CPU device (smoke tests) and on the 512-chip
dry-run mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (
    apply_rope,
    cross_entropy_loss,
    rms_norm,
    rope_table,
    shard,
    silu,
    softcap,
    trunc_normal,
)
from .moe import moe_apply, moe_init, moe_param_specs

__all__ = [
    "TransformerConfig",
    "AxisRules",
    "init_params",
    "param_specs",
    "forward_train",
    "loss_fn",
    "forward_prefill",
    "forward_decode",
    "init_kv_cache",
    "kv_cache_specs",
]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Logical -> mesh axis mapping. ``data`` may be ('pod', 'data').

    ``seq_parallel``: between layers, activations shard the sequence dim
    over the model axis (Megatron-SP) — turns the 2x-per-layer activation
    all-reduce into all-gather + reduce-scatter pairs and shards the norm
    compute (§Perf iteration).
    """

    data: Tuple[str, ...] = ()
    model: Tuple[str, ...] = ()
    seq_parallel: bool = False
    mesh: Any = None  # needed by the shard_map MoE path (moe_impl=local_ep)

    @property
    def dp(self):
        return self.data if self.data else None

    @property
    def tp(self):
        return self.model if self.model else None

    def act3(self):  # [B, S, d]
        if not self.data:
            return None
        if self.seq_parallel:
            return P(self.dp, self.tp, None)
        return P(self.dp, None, None)

    def act_heads(self):  # [B, S, H, dh]
        return P(self.dp, None, self.tp, None) if self.data else None


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    zero_centered_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0  # >0: block pattern alternates (local, global)
    post_norms: bool = False
    norm_eps: float = 1e-6
    # MoE (0 experts = dense MLP)
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity: float = 1.25
    # numerics / compilation
    dtype: Any = jnp.bfloat16
    query_scale: Optional[float] = None  # None -> 1/sqrt(d_head)
    tie_embeddings: bool = False
    remat: bool = True
    # perf knobs (§Perf): sequence length at/above which the flash
    # (online-softmax, block-skipping) attention path is used, and whether
    # the MoE dispatch shards its capacity axis over data
    flash_cutoff: int = 8192
    flash_block: int = 1024
    moe_shard_capacity: bool = False
    moe_impl: str = "dense"  # 'dense' | 'local_ep' (shard_map, §Perf it.3)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return ("local", "global") if self.sliding_window > 0 else ("global",)

    @property
    def n_blocks(self) -> int:
        lp = len(self.pattern)
        assert self.n_layers % lp == 0, (self.n_layers, lp)
        return self.n_layers // lp

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def window_for(self, kind: str) -> int:
        return self.sliding_window if kind == "local" else 0

    def param_count(self) -> int:
        """Total parameters (for 6ND MODEL_FLOPS accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        attn += self.n_heads * self.d_head * d
        if self.is_moe:
            ffn = self.moe_experts * 3 * d * f + d * self.moe_experts
        else:
            ffn = 3 * d * f
        norms = d * (4 if self.post_norms else 2)
        per_layer = attn + ffn + norms
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k of E experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_ffn = self.moe_top_k * 3 * d * f + d * self.moe_experts
        full_ffn = self.moe_experts * 3 * d * f + d * self.moe_experts
        return self.param_count() - self.n_layers * (full_ffn - dense_ffn)


# --------------------------------------------------------------------------
# init + sharding specs
# --------------------------------------------------------------------------
def _layer_init(cfg: TransformerConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, h, k_, dh, f = (
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_head,
        cfg.d_ff,
    )
    dt = cfg.dtype
    p: Dict[str, Any] = {
        "attn_norm": jnp.zeros((d,), dt)
        if cfg.zero_centered_norm
        else jnp.ones((d,), dt),
        "wq": trunc_normal(ks[0], (d, h * dh)).astype(dt),
        "wk": trunc_normal(ks[1], (d, k_ * dh)).astype(dt),
        "wv": trunc_normal(ks[2], (d, k_ * dh)).astype(dt),
        "wo": trunc_normal(ks[3], (h * dh, d)).astype(dt),
        "ffn_norm": jnp.zeros((d,), dt)
        if cfg.zero_centered_norm
        else jnp.ones((d,), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((k_ * dh,), dt)
        p["bv"] = jnp.zeros((k_ * dh,), dt)
    if cfg.post_norms:
        zero = jnp.zeros((d,), dt)
        one = jnp.ones((d,), dt)
        p["attn_post_norm"] = zero if cfg.zero_centered_norm else one
        p["ffn_post_norm"] = zero if cfg.zero_centered_norm else one
    if cfg.is_moe:
        p["moe"] = moe_init(ks[4], d, f, cfg.moe_experts, dt)
    else:
        p["w_gate"] = trunc_normal(ks[5], (d, f)).astype(dt)
        p["w_up"] = trunc_normal(ks[6], (d, f)).astype(dt)
        p["w_down"] = trunc_normal(ks[7], (f, d)).astype(dt)
    return p


def init_params(cfg: TransformerConfig, key) -> Dict[str, Any]:
    k_emb, k_out, k_l = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": trunc_normal(k_emb, (cfg.vocab, cfg.d_model), scale=1.0).astype(
            cfg.dtype
        ),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype)
        if cfg.zero_centered_norm
        else jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = trunc_normal(
            k_out, (cfg.d_model, cfg.vocab)
        ).astype(cfg.dtype)
    # stacked layers per pattern entry: leaves get leading dim n_blocks
    layers: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        per_block = [
            _layer_init(cfg, jax.random.fold_in(k_l, b * 8 + i))
            for b in range(cfg.n_blocks)
        ]
        layers[f"sub{i}_{kind}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *per_block
        )
    params["layers"] = layers
    return params


def _layer_specs(cfg: TransformerConfig, rules: AxisRules) -> Dict[str, Any]:
    tp = rules.tp
    L = None  # leading stacked-block dim is replicated
    s: Dict[str, Any] = {
        "attn_norm": P(L, None),
        "wq": P(L, None, tp),
        "wk": P(L, None, tp),
        "wv": P(L, None, tp),
        "wo": P(L, tp, None),
        "ffn_norm": P(L, None),
    }
    if cfg.qkv_bias:
        s["bq"] = P(L, tp)
        s["bk"] = P(L, tp)
        s["bv"] = P(L, tp)
    if cfg.post_norms:
        s["attn_post_norm"] = P(L, None)
        s["ffn_post_norm"] = P(L, None)
    if cfg.is_moe:
        s["moe"] = moe_param_specs(tp, stacked=True)
    else:
        s["w_gate"] = P(L, None, tp)
        s["w_up"] = P(L, None, tp)
        s["w_down"] = P(L, tp, None)
    return s


def param_specs(cfg: TransformerConfig, rules: AxisRules) -> Dict[str, Any]:
    tp = rules.tp
    specs: Dict[str, Any] = {
        "embed": P(tp, None),  # vocab-sharded
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, tp)
    specs["layers"] = {
        f"sub{i}_{kind}": _layer_specs(cfg, rules)
        for i, kind in enumerate(cfg.pattern)
    }
    return specs


# --------------------------------------------------------------------------
# attention / layer bodies
# --------------------------------------------------------------------------
def _qkv(x, p, cfg: TransformerConfig):
    b, s, _ = x.shape
    h, k_, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"] + (p.get("bq", 0.0))
    k = x @ p["wk"] + (p.get("bk", 0.0))
    v = x @ p["wv"] + (p.get("bv", 0.0))
    return (
        q.reshape(b, s, h, dh),
        k.reshape(b, s, k_, dh),
        v.reshape(b, s, k_, dh),
    )


def _attn_scores(q, k, cfg: TransformerConfig):
    """q: [B,S,H,dh]; k: [B,T,K,dh] -> scores [B,K,G,S,T] (GQA grouped)."""
    b, s, h, dh = q.shape
    k_heads = k.shape[2]
    g = h // k_heads
    q = q.reshape(b, s, k_heads, g, dh)
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if cfg.attn_softcap > 0:
        scores = softcap(scores, cfg.attn_softcap)
    return scores


def _attn_out(scores, v, mask, p, cfg: TransformerConfig):
    """scores [B,K,G,S,T], v [B,T,K,dh], mask broadcastable to scores."""
    b, k_heads, g, s, t = scores.shape
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    out = out.reshape(b, s, k_heads * g * cfg.d_head)
    return out @ p["wo"]


def _causal_mask(s: int, window: int):
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    m = kp <= qp
    if window > 0:
        m &= (qp - kp) < window
    return m  # [S, T]


def _mlp(x, p):
    return (silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def _ffn(x_flat, p, cfg: TransformerConfig, rules: AxisRules):
    if cfg.is_moe:
        if cfg.moe_impl == "local_ep" and rules.mesh is not None:
            mesh_shape = dict(zip(rules.mesh.axis_names,
                                  rules.mesh.devices.shape))
            dp_extent = 1
            for a in rules.data:
                dp_extent *= mesh_shape.get(a, 1)
            if x_flat.shape[0] % max(dp_extent, 1) == 0:
                from .moe import moe_apply_local_ep

                return moe_apply_local_ep(
                    p["moe"], x_flat,
                    n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                    capacity_factor=cfg.moe_capacity,
                    rules=rules, mesh=rules.mesh,
                )
        return moe_apply(
            p["moe"],
            x_flat,
            n_experts=cfg.moe_experts,
            top_k=cfg.moe_top_k,
            capacity_factor=cfg.moe_capacity,
            rules=rules,
            shard_capacity=cfg.moe_shard_capacity,
        )
    return _mlp(x_flat, p)


def _layer(x, p, kind: str, cfg: TransformerConfig, rules: AxisRules, sin, cos):
    """Full-sequence layer (train/prefill). x: [B,S,d]."""
    from .attention import DENSE_CUTOFF, flash_attention_jnp

    b, s, d = x.shape
    h = rms_norm(x, p["attn_norm"], eps=cfg.norm_eps,
                 zero_centered=cfg.zero_centered_norm)
    q, k, v = _qkv(h, p, cfg)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = shard(q, rules.act_heads())
    if s >= cfg.flash_cutoff:
        # flash path: online softmax over KV blocks, O(block^2) memory.
        # static unroll (differentiable + dead-block elimination) when the
        # block grid is small; scanned online-softmax otherwise.
        kh = cfg.n_kv_heads
        g = cfg.n_heads // kh
        scale = (cfg.query_scale if cfg.query_scale is not None
                 else 1.0 / math.sqrt(cfg.d_head))
        ctx = flash_attention_jnp(
            q.reshape(b, s, kh, g, cfg.d_head), k, v,
            scale=scale, causal=True, window=cfg.window_for(kind),
            softcap=cfg.attn_softcap,
            block_q=cfg.flash_block, block_k=cfg.flash_block,
            static_unroll=s <= 8192,
        )
        attn = ctx.reshape(b, s, cfg.n_heads * cfg.d_head) @ p["wo"]
    else:
        scores = _attn_scores(q, k, cfg)
        mask = _causal_mask(s, cfg.window_for(kind))
        attn = _attn_out(scores, v, mask[None, None, None], p, cfg)
    if cfg.post_norms:
        attn = rms_norm(attn, p["attn_post_norm"], eps=cfg.norm_eps,
                        zero_centered=cfg.zero_centered_norm)
    x = x + attn
    x = shard(x, rules.act3())
    hn = rms_norm(x, p["ffn_norm"], eps=cfg.norm_eps,
                  zero_centered=cfg.zero_centered_norm)
    y = _ffn(hn.reshape(b * s, d), p, cfg, rules).reshape(b, s, d)
    if cfg.post_norms:
        y = rms_norm(y, p["ffn_post_norm"], eps=cfg.norm_eps,
                     zero_centered=cfg.zero_centered_norm)
    x = x + y
    return shard(x, rules.act3()), (k, v)


# --------------------------------------------------------------------------
# train / prefill forward (scan over blocks)
# --------------------------------------------------------------------------
def forward_train(params, tokens, cfg: TransformerConfig,
                  rules: AxisRules = AxisRules()):
    """tokens [B, S] -> logits [B, S, V]."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x = shard(x, rules.act3())
    sin, cos = rope_table(jnp.arange(s), cfg.d_head, cfg.rope_theta)

    def block(x, block_params):
        for i, kind in enumerate(cfg.pattern):
            x, _ = _layer(x, block_params[f"sub{i}_{kind}"], kind, cfg, rules,
                          sin, cos)
        return x, None

    blk = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(blk, x, params["layers"])
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 zero_centered=cfg.zero_centered_norm)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = x @ unembed.astype(cfg.dtype)
    if rules.data:
        logits = shard(logits, P(rules.dp, None, rules.tp))  # vocab-sharded
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def loss_fn(params, tokens, labels, cfg: TransformerConfig,
            rules: AxisRules = AxisRules()):
    logits = forward_train(params, tokens, cfg, rules)
    return cross_entropy_loss(logits, labels)


# --------------------------------------------------------------------------
# KV cache (ring buffer; local layers cache only the window)
# --------------------------------------------------------------------------
def _cache_len(cfg: TransformerConfig, kind: str, max_len: int) -> int:
    w = cfg.window_for(kind)
    return min(w, max_len) if w > 0 else max_len


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int):
    cache = {}
    for i, kind in enumerate(cfg.pattern):
        t = _cache_len(cfg, kind, max_len)
        cache[f"sub{i}_{kind}"] = {
            "k": jnp.zeros((cfg.n_blocks, batch, t, cfg.n_kv_heads, cfg.d_head),
                           cfg.dtype),
            "v": jnp.zeros((cfg.n_blocks, batch, t, cfg.n_kv_heads, cfg.d_head),
                           cfg.dtype),
            "pos": jnp.full((cfg.n_blocks, batch, t), -1, jnp.int32),
        }
    return cache


def kv_cache_specs(cfg: TransformerConfig, rules: AxisRules):
    tp = rules.tp
    dp = rules.dp
    spec = {"k": P(None, dp, None, tp, None),
            "v": P(None, dp, None, tp, None),
            "pos": P(None, dp, None)}
    return {f"sub{i}_{kind}": dict(spec)
            for i, kind in enumerate(cfg.pattern)}


def forward_prefill(params, tokens, cfg: TransformerConfig,
                    rules: AxisRules = AxisRules(), *, max_len: int):
    """Run the prompt; returns (last-token logits [B, V], kv cache)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x = shard(x, rules.act3())
    sin, cos = rope_table(jnp.arange(s), cfg.d_head, cfg.rope_theta)

    def block(x, block_params):
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, (k, v) = _layer(x, block_params[f"sub{i}_{kind}"], kind, cfg,
                               rules, sin, cos)
            t = _cache_len(cfg, kind, max_len)
            start = max(s - t, 0)
            idx = (start + jnp.arange(min(t, s))) % t
            kc = jnp.zeros((b, t, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
            vc = jnp.zeros_like(kc)
            pc = jnp.full((b, t), -1, jnp.int32)
            kc = kc.at[:, idx].set(k[:, start:])
            vc = vc.at[:, idx].set(v[:, start:])
            pc = pc.at[:, idx].set(start + jnp.arange(min(t, s)))
            caches[f"sub{i}_{kind}"] = {"k": kc, "v": vc, "pos": pc}
        return x, caches

    blk = jax.checkpoint(block) if cfg.remat else block
    x, caches = jax.lax.scan(blk, x, params["layers"])
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 zero_centered=cfg.zero_centered_norm)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x[:, -1] @ unembed.astype(cfg.dtype)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits, caches


def _decode_layer(x, p, kind, cache, pos, cfg: TransformerConfig,
                  rules: AxisRules):
    """One-token layer. x: [B,1,d]; cache entries [B,T,K,dh]."""
    b = x.shape[0]
    t = cache["k"].shape[1]
    h = rms_norm(x, p["attn_norm"], eps=cfg.norm_eps,
                 zero_centered=cfg.zero_centered_norm)
    q, k, v = _qkv(h, p, cfg)
    sin_q, cos_q = rope_table(pos[None], cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, sin_q[None], cos_q[None])
    k = apply_rope(k, sin_q[None], cos_q[None])
    slot = pos % t
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    pc = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((b, 1), pos, jnp.int32), slot, axis=1
    )
    scores = _attn_scores(q, kc, cfg)  # [B,K,G,1,T]
    valid = (pc >= 0) & (pc <= pos)
    w = cfg.window_for(kind)
    if w > 0:
        valid &= (pos - pc) < w
    attn = _attn_out(scores, vc, valid[:, None, None, None, :], p, cfg)
    if cfg.post_norms:
        attn = rms_norm(attn, p["attn_post_norm"], eps=cfg.norm_eps,
                        zero_centered=cfg.zero_centered_norm)
    x = x + attn
    hn = rms_norm(x, p["ffn_norm"], eps=cfg.norm_eps,
                  zero_centered=cfg.zero_centered_norm)
    y = _ffn(hn.reshape(b, -1), p, cfg, rules).reshape(b, 1, -1)
    if cfg.post_norms:
        y = rms_norm(y, p["ffn_post_norm"], eps=cfg.norm_eps,
                     zero_centered=cfg.zero_centered_norm)
    return x + y, {"k": kc, "v": vc, "pos": pc}


def forward_decode(params, token, pos, cache, cfg: TransformerConfig,
                   rules: AxisRules = AxisRules()):
    """token [B] int32, pos scalar int32 -> (logits [B,V], new cache)."""
    b = token.shape[0]
    x = params["embed"][token][:, None, :].astype(cfg.dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)

    def block(x, scanned):
        block_params, block_cache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"sub{i}_{kind}"
            x, new_cache[key] = _decode_layer(
                x, block_params[key], kind, block_cache[key], pos, cfg, rules
            )
        return x, new_cache

    x, new_cache = jax.lax.scan(block, x, (params["layers"], cache))
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 zero_centered=cfg.zero_centered_norm)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x[:, 0] @ unembed.astype(cfg.dtype)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_cache
