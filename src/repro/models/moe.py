"""Top-k MoE FFN with sort-based capacity dispatch (GShard-style, dropless
up to the capacity factor).

Dispatch: flatten (token, k) assignments, stable-sort by expert, compute
position-in-expert from group starts, drop past-capacity assignments to a
phantom slot, gather tokens into [E, C, d], run the batched SwiGLU expert
FFN, and combine back with the (renormalized) router gates. All shapes are
static — no ragged tensors — so the same code jit-compiles for the smoke
tests and for expert-parallel sharding (experts over the 'model' axis; the
token gather/scatter across the data<->expert shardings lowers to
all-to-all, which is exactly the paper-family dispatch collective).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map
from .common import shard, silu, trunc_normal

__all__ = ["moe_init", "moe_param_specs", "moe_apply", "moe_apply_local_ep"]


def moe_init(key, d_model: int, d_ff: int, n_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": trunc_normal(ks[0], (d_model, n_experts)).astype(jnp.float32),
        "w_gate": trunc_normal(ks[1], (n_experts, d_model, d_ff)).astype(dtype),
        "w_up": trunc_normal(ks[2], (n_experts, d_model, d_ff)).astype(dtype),
        "w_down": trunc_normal(ks[3], (n_experts, d_ff, d_model)).astype(dtype),
    }


def moe_param_specs(tp, *, stacked: bool = False):
    lead = (None,) if stacked else ()
    return {
        "router": P(*lead, None, None),
        "w_gate": P(*lead, tp, None, None),  # expert-parallel
        "w_up": P(*lead, tp, None, None),
        "w_down": P(*lead, tp, None, None),
    }


def moe_apply(
    p,
    x: jnp.ndarray,  # [T, d]
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    rules=None,
    shard_capacity: bool = False,
) -> jnp.ndarray:
    t, d = x.shape
    e, k = n_experts, top_k
    c = max(int(capacity_factor * t * k / e), 1)

    # router (f32 for numerics)
    logits = x.astype(jnp.float32) @ p["router"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, k)  # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # sort assignments by expert
    flat_e = expert_ids.reshape(-1)  # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < c
    slot = jnp.where(keep, sorted_e * c + pos_in_e, e * c)  # overflow -> pad

    tok = (order // k).astype(jnp.int32)
    gate_sorted = gates.reshape(-1)[order]

    # dispatch tables ([E*C+1]; the +1 row swallows drops & empty slots)
    disp_tok = jnp.full((e * c + 1,), t, jnp.int32).at[slot].set(
        jnp.where(keep, tok, t)
    )
    disp_gate = jnp.zeros((e * c + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, gate_sorted, 0.0)
    )

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[disp_tok[: e * c]].reshape(e, c, d)
    if rules is not None and rules.tp:
        # baseline EP shards experts only; ``shard_capacity`` additionally
        # shards the capacity axis over the data axes — without it every
        # data replica redundantly computes the full expert batch
        # (measured 16x wasted FLOPs in §Perf).
        cap_ax = rules.dp if shard_capacity else None
        xe = shard(xe, P(rules.tp, cap_ax, None))

    h = silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, C, d]
    if rules is not None and rules.tp:
        cap_ax = rules.dp if shard_capacity else None
        ye = shard(ye, P(rules.tp, cap_ax, None))

    # combine: scatter-add weighted expert outputs back to tokens
    ye_flat = ye.reshape(e * c, d) * disp_gate[: e * c, None].astype(ye.dtype)
    y = jnp.zeros((t + 1, d), ye.dtype).at[disp_tok[: e * c]].add(ye_flat)
    return y[:t].astype(x.dtype)


# --------------------------------------------------------------------------
# shard_map expert parallelism with LOCAL dispatch (§Perf iteration 3).
#
# Key observation: in this framework's LM sharding the activations are
# replicated across the 'model' axis (P(dp, None)), so every model column
# already HOLDS every token of its data row. Expert dispatch therefore
# needs NO communication at all: each column selects the tokens routed to
# ITS E/M experts locally, runs them, and the only collective is ONE psum
# of the [T_loc, d] output per MoE layer — the same cost as a dense
# tensor-parallel MLP. This removes both the 16x replicated-compute waste
# (baseline dense dispatch) and the all-gather storm GSPMD emits for the
# capacity-sharded gather (iterations 1/2, measured in EXPERIMENTS.md).
# --------------------------------------------------------------------------
def moe_apply_local_ep(
    p,
    x: jnp.ndarray,  # [T, d] global (inside jit)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    rules,
    mesh,
) -> jnp.ndarray:
    t, d = x.shape
    e, k = n_experts, top_k
    model_axes = tuple(rules.model)
    data_axes = tuple(rules.data)
    m = 1
    for a in model_axes:
        m *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    assert e % m == 0, (e, m)
    e_loc = e // m

    # routing outside the shard_map (small, differentiable, GSPMD-sharded)
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, k)  # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dp = data_axes if data_axes else None
    tp = model_axes if model_axes else None
    model_axis_name = model_axes if len(model_axes) > 1 else model_axes[0]

    def body(x_loc, eids_loc, gates_loc, wg, wu, wd):
        # x_loc [T_loc, d]; wg/wu/wd [E_loc, ...] (this column's experts)
        t_loc = x_loc.shape[0]
        c = max(int(capacity_factor * t_loc * k / e), 1)
        col = jax.lax.axis_index(model_axis_name)
        e_lo = col * e_loc
        flat_e = eids_loc.reshape(-1)  # [T_loc*K] global expert ids
        mine = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
        local_e = jnp.where(mine, flat_e - e_lo, e_loc)  # e_loc = drop bucket
        order = jnp.argsort(local_e, stable=True)
        sorted_le = local_e[order]
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[sorted_le].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * k, dtype=jnp.int32) - starts[sorted_le]
        keep = (sorted_le < e_loc) & (pos < c)
        slot = jnp.where(keep, sorted_le * c + pos, e_loc * c)
        tok = (order // k).astype(jnp.int32)
        gate_sorted = gates_loc.reshape(-1)[order]

        disp_tok = jnp.full((e_loc * c + 1,), t_loc, jnp.int32).at[slot].set(
            jnp.where(keep, tok, t_loc))
        disp_gate = jnp.zeros((e_loc * c + 1,), jnp.float32).at[slot].set(
            jnp.where(keep, gate_sorted, 0.0))
        x_pad = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], 0)
        xe = x_pad[disp_tok[: e_loc * c]].reshape(e_loc, c, d)
        h = silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
            "ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * c, d)
        ye = ye * disp_gate[: e_loc * c, None].astype(ye.dtype)
        y = jnp.zeros((t_loc + 1, d), ye.dtype).at[
            disp_tok[: e_loc * c]].add(ye)[:t_loc]
        # the ONLY collective: combine partial expert outputs across columns
        return jax.lax.psum(y, model_axis_name)

    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None), P(dp, None, None), P(dp, None, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp, None),
        check_vma=False,
    )(x, expert_ids[:, None, :], gates[:, None, :],
      p["w_gate"], p["w_up"], p["w_down"])
    return out.astype(x.dtype)
