from . import common, gat, gin, pna, mace, so3  # noqa: F401
