"""PNA (Corso et al., arXiv:2004.05718) — pna assigned config:
4 layers, d_hidden=75, aggregators {mean, max, min, std},
scalers {identity, amplification, attenuation}.

Each layer: message = MLP([h_i || h_j]); aggregate with the 4 aggregators;
apply the 3 degree scalers (log(d+1)/log(delta) amplification and its
inverse); concat (4 agg x 3 scalers) and project back with an MLP + skip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import (
    GraphBatch,
    degree_counts,
    gather_src,
    mlp_apply,
    mlp_init,
    segment_max,
    segment_mean,
    segment_sum,
)

__all__ = ["PNAConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_in: int = 16
    d_hidden: int = 75
    n_classes: int = 1  # regression head (ZINC-style)
    delta: float = 2.5  # avg log-degree normalizer of the train set
    dtype: Any = jnp.float32


def init_params(cfg: PNAConfig, key) -> Dict[str, Any]:
    k_in, key = jax.random.split(key)
    layers = []
    d = cfg.d_hidden
    for _ in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append(
            {
                "msg": mlp_init(k1, (2 * d, d), cfg.dtype),
                "upd": mlp_init(k2, (12 * d + d, d), cfg.dtype),
            }
        )
    k_out, key = jax.random.split(key)
    return {
        "encode": mlp_init(k_in, (cfg.d_in, cfg.d_hidden), cfg.dtype),
        "layers": layers,
        "decode": mlp_init(k_out, (cfg.d_hidden, cfg.d_hidden, cfg.n_classes),
                           cfg.dtype),
    }


def _aggregate(msg, dst, mask, n, deg, cfg: PNAConfig):
    msg = jnp.where(mask[:, None], msg, 0.0)
    mean = segment_mean(msg, dst, n)
    mx = segment_max(jnp.where(mask[:, None], msg, -jnp.inf), dst, n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mn = -segment_max(jnp.where(mask[:, None], -msg, -jnp.inf), dst, n)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    sq = segment_mean(msg * msg, dst, n)
    std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-8)
    agg = jnp.concatenate([mean, mx, mn, std], axis=-1)  # [N, 4D]
    # scalers
    logd = jnp.log(deg + 1.0)[:, None] / cfg.delta
    amp = agg * logd
    att = agg / jnp.maximum(logd, 1e-2)
    return jnp.concatenate([agg, amp, att], axis=-1)  # [N, 12D]


def apply(params, batch: GraphBatch, cfg: PNAConfig) -> jnp.ndarray:
    """Returns graph-level prediction [n_graphs, n_classes] if graph_ids
    are present, else node-level [N, n_classes]."""
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    x = mlp_apply(params["encode"], batch["node_feat"].astype(cfg.dtype))
    n = x.shape[0]
    deg = degree_counts(dst, mask, n)
    for p in params["layers"]:
        m_in = jnp.concatenate([gather_src(x, src), x[dst]], axis=-1)
        msg = mlp_apply(p["msg"], m_in, act=jax.nn.relu, final_act=True)
        agg = _aggregate(msg, dst, mask, n, deg, cfg)
        x = x + mlp_apply(p["upd"], jnp.concatenate([x, agg], -1))
    x = jnp.where(batch["node_mask"][:, None], x, 0.0)
    if "graph_ids" in batch:
        n_graphs = batch["labels"].shape[0]  # static: one target per graph
        pooled = segment_mean(x, batch["graph_ids"], n_graphs)
        return mlp_apply(params["decode"], pooled)
    return mlp_apply(params["decode"], x)
