"""GIN (Xu et al., arXiv:1810.00826) — gin-tu assigned config:
5 layers, d_hidden=64, sum aggregator, learnable eps.

h_i' = MLP((1 + eps) h_i + sum_{j in N(i)} h_j); graph-level readout sums
node embeddings of every layer (jumping knowledge, as in the paper) and
classifies with a linear head per layer, summed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import GraphBatch, gather_src, mlp_apply, mlp_init, segment_sum

__all__ = ["GINConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_in: int = 7  # TU molecule node labels (one-hot)
    d_hidden: int = 64
    n_classes: int = 2
    dtype: Any = jnp.float32


def init_params(cfg: GINConfig, key) -> Dict[str, Any]:
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append(
            {
                "mlp": mlp_init(k1, (d, cfg.d_hidden, cfg.d_hidden), cfg.dtype),
                "eps": jnp.zeros((), cfg.dtype),
                "head": mlp_init(k2, (cfg.d_hidden, cfg.n_classes), cfg.dtype),
            }
        )
        d = cfg.d_hidden
    return {"layers": layers}


def apply(params, batch: GraphBatch, cfg: GINConfig) -> jnp.ndarray:
    """Graph logits [n_graphs, C] when ``graph_ids`` present (sum readout
    per layer, jumping knowledge); node logits [N, C] otherwise."""
    x = batch["node_feat"].astype(cfg.dtype)
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    n = x.shape[0]
    graph_level = "graph_ids" in batch
    node_mask = batch["node_mask"][:, None]
    if graph_level:
        gid = batch["graph_ids"]
        n_graphs = batch["labels"].shape[0]  # static: one label per graph
        out = jnp.zeros((n_graphs, cfg.n_classes), cfg.dtype)
    else:
        out = jnp.zeros((n, cfg.n_classes), cfg.dtype)
    for p in params["layers"]:
        msg = jnp.where(mask[:, None], gather_src(x, src), 0.0)
        agg = segment_sum(msg, dst, n)
        x = mlp_apply(p["mlp"], (1.0 + p["eps"]) * x + agg,
                      act=jax.nn.relu, final_act=True)
        x = jnp.where(node_mask, x, 0.0)
        pooled = segment_sum(x, gid, n_graphs) if graph_level else x
        out = out + mlp_apply(p["head"], pooled)
    return out
