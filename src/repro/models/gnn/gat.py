"""GAT (Velickovic et al., arXiv:1710.10903) — gat-cora assigned config:
2 layers, d_hidden=8, 8 heads, attention aggregator.

Layer: per-edge score e_ij = LeakyReLU(a_src . Wh_i + a_dst . Wh_j), then
segment-softmax over each destination's incoming edges (SDDMM -> edge
softmax -> SpMM regime per the taxonomy) and a weighted segment-sum.
First layer concatenates heads, final layer averages them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..common import trunc_normal
from .common import GraphBatch, gather_src, segment_softmax, segment_sum

__all__ = ["GATConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


def init_params(cfg: GATConfig, key) -> Dict[str, Any]:
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append(
            {
                "w": trunc_normal(k1, (d_in, cfg.n_heads, d_out)).astype(cfg.dtype),
                "a_src": trunc_normal(k2, (cfg.n_heads, d_out)).astype(cfg.dtype),
                "a_dst": trunc_normal(k3, (cfg.n_heads, d_out)).astype(cfg.dtype),
                "b": jnp.zeros((cfg.n_heads, d_out), cfg.dtype),
            }
        )
        d_in = cfg.d_hidden * cfg.n_heads if not last else d_out
    return {"layers": layers}


def _gat_layer(p, x, batch: GraphBatch, cfg: GATConfig, *, last: bool):
    """One GAT layer. Two source-gather modes:

    - plain: ``edge_src`` indexes the (possibly sharded) node table.
    - hub-split (the paper's degree-score cache applied to GNN reads,
      §Perf): edges are STATICALLY split into a cold stream
      (``edge_src_cold`` — cross-shard gather) and a hot stream
      (``edge_src_hub_pos`` — slots into the replicated top-degree hub
      table ``hub_ids``); concat order is [cold, hot] and ``edge_dst`` /
      ``edge_mask`` follow that order. The hot stream's rows never cross
      devices — exactly the communication the paper's cache removes.
    """
    n = x.shape[0]
    h = jnp.einsum("nf,fhd->nhd", x, p["w"])  # [N, H, D]
    s_src = (h * p["a_src"]).sum(-1)  # [N, H]
    s_dst = (h * p["a_dst"]).sum(-1)
    if "edge_src_cold" in batch:
        agg = _hub_split_attention(p, h, s_src, s_dst, batch, cfg, n)
    else:
        src = batch["edge_src"]
        dst, mask = batch["edge_dst"], batch["edge_mask"]
        e = s_src[src] + s_dst[dst]  # [E, H]
        e = jax.nn.leaky_relu(e, cfg.negative_slope)
        w = segment_softmax(e, dst, n, mask=mask[:, None])  # [E, H]
        msg = gather_src(h, src) * w[..., None]  # [E, H, D]
        msg = jnp.where(mask[:, None, None], msg, 0.0)
        agg = segment_sum(msg, dst, n)
    agg = agg + p["b"]  # [N, H, D]
    if last:
        return agg.mean(axis=1)  # average heads -> logits
    return jax.nn.elu(agg.reshape(n, -1))  # concat heads


def _hub_split_attention(p, h, s_src, s_dst, batch, cfg, n):
    """Two-stream edge attention: the hot stream reads the replicated hub
    table (zero cross-shard traffic — the paper's degree-score cache), the
    cold stream does the sharded gather. The softmax is fused across
    streams via explicit (max, exp-sum, weighted-sum) segment reductions —
    NO concatenation, so each stream keeps its own sharding (a concat of
    differently-sharded streams made GSPMD replicate everything: 204 GB
    temps, §Perf iteration 6a)."""
    from ..common import shard as _shard
    from jax.sharding import PartitionSpec as P

    hub = batch["hub_ids"]  # [C] replicated ids
    h_hub = _shard(h[hub], P())  # [C, H, D] replicated hub features
    s_hub = _shard(s_src[hub], P())  # [C, H]
    cold, hot = batch["edge_src_cold"], batch["edge_src_hub_pos"]
    dst_c, dst_h = batch["edge_dst_cold"], batch["edge_dst_hot"]
    msk_c, msk_h = batch["edge_mask_cold"], batch["edge_mask_hot"]

    e_c = jax.nn.leaky_relu(s_src[cold] + s_dst[dst_c], cfg.negative_slope)
    e_h = jax.nn.leaky_relu(s_hub[hot] + s_dst[dst_h], cfg.negative_slope)
    e_c = jnp.where(msk_c[:, None], e_c, -jnp.inf)
    e_h = jnp.where(msk_h[:, None], e_h, -jnp.inf)
    from .common import segment_max

    m = jnp.maximum(segment_max(e_c, dst_c, n), segment_max(e_h, dst_h, n))
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    x_c = jnp.where(msk_c[:, None], jnp.exp(e_c - m[dst_c]), 0.0)
    x_h = jnp.where(msk_h[:, None], jnp.exp(e_h - m[dst_h]), 0.0)
    denom = segment_sum(x_c, dst_c, n) + segment_sum(x_h, dst_h, n)  # [N, H]
    num = segment_sum(gather_src(h, cold) * x_c[..., None], dst_c, n) + \
        segment_sum(h_hub[hot] * x_h[..., None], dst_h, n)  # [N, H, D]
    return num / jnp.maximum(denom, 1e-9)[..., None]


def apply(params, batch: GraphBatch, cfg: GATConfig) -> jnp.ndarray:
    """Returns node logits [N, n_classes]."""
    x = batch["node_feat"].astype(cfg.dtype)
    for i, p in enumerate(params["layers"]):
        x = _gat_layer(p, x, batch, cfg, last=i == cfg.n_layers - 1)
    return x
