"""MACE (Batatia et al., arXiv:2206.07697) — assigned config:
2 interaction layers, 128 channels, l_max=2, correlation order 3, 8 radial
Bessel functions, E(3)-equivariant (ACE product basis).

Compact from-scratch implementation (no e3nn in this container) on top of
``so3.py``:

- node features are dicts {l: [N, 2l+1, C]} for l = 0..l_max
- **interaction**: for each edge, couple the sender's l1 features with the
  spherical harmonics Y_l2 of the edge direction through real CG tensors
  into l3 channels, weighted by a learned radial MLP over Bessel RBFs;
  scatter-sum into receivers (the A-basis of MACE)
- **product basis**: correlation order 3 via iterated CG self-couplings of
  the A-basis (A x A -> B2, B2 x A -> B3), per-channel weights (this is the
  symmetric-contraction step MACE makes cheap; iterated pairwise coupling
  spans the same space for nu<=3)
- **readout**: per-layer linear on the l=0 channel -> per-node scalar,
  summed over layers and nodes for the graph energy.

Equivariance is pinned by tests: rotating input positions transforms every
l-block by the corresponding real Wigner-D and leaves outputs invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import trunc_normal
from .common import GraphBatch, mlp_apply, mlp_init, segment_sum
from .so3 import cg_real, real_sph_harm

__all__ = ["MACEConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    channels: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    n_species: int = 4
    r_cut: float = 5.0
    radial_hidden: int = 64
    dtype: Any = jnp.float32

    @property
    def ls(self) -> Tuple[int, ...]:
        return tuple(range(self.l_max + 1))


def _couplings(l_max: int) -> List[Tuple[int, int, int]]:
    """All (l1, l2, l3) with l1,l2,l3 <= l_max satisfying the triangle rule
    and parity (l1+l2+l3 even — SH tensor products of polynomial features)."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                if (l1 + l2 + l3) % 2 == 0:
                    out.append((l1, l2, l3))
    return out


def bessel_rbf(r, n_rbf: int, r_cut: float):
    """Radial Bessel basis with smooth cutoff (DimeNet-style)."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * r[..., None] / r_cut) / r[..., None]
    # polynomial cutoff envelope
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5
    return rb * env[..., None]


def init_params(cfg: MACEConfig, key) -> Dict[str, Any]:
    coup = _couplings(cfg.l_max)
    layers = []
    c = cfg.channels
    for i in range(cfg.n_layers):
        k1, k2, k3, k4, k5, key = jax.random.split(key, 6)
        layer = {
            # radial MLP: rbf -> weight per coupling path & channel
            "radial": mlp_init(
                k1, (cfg.n_rbf, cfg.radial_hidden, len(coup) * c), cfg.dtype
            ),
            # linear mix per l after aggregation
            "mix": {
                str(l): trunc_normal(k2, (c, c)).astype(cfg.dtype)
                for l in cfg.ls
            },
            # product-basis weights (correlation 2 and 3 contributions)
            "prod2": {
                str(l): trunc_normal(k3, (c, c)).astype(cfg.dtype)
                for l in cfg.ls
            },
            "prod3": {
                str(l): trunc_normal(k4, (c, c)).astype(cfg.dtype)
                for l in cfg.ls
            },
            "readout": mlp_init(k5, (c, 16, 1), cfg.dtype),
        }
        layers.append(layer)
    k_emb, key = jax.random.split(key)
    return {
        "embed": trunc_normal(k_emb, (cfg.n_species, cfg.channels)).astype(
            cfg.dtype
        ),
        "layers": layers,
    }


def _interaction(p, feats, batch, sh, rbf, cfg: MACEConfig):
    """A-basis: edge-wise CG coupling + radial weights + scatter to nodes."""
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    n = feats[0].shape[0]
    c = cfg.channels
    coup = _couplings(cfg.l_max)
    radial = mlp_apply(p["radial"], rbf, act=jax.nn.silu)  # [E, P*C]
    radial = radial.reshape(radial.shape[0], len(coup), c)
    agg = {l: jnp.zeros((n, 2 * l + 1, c), cfg.dtype) for l in cfg.ls}
    for pi, (l1, l2, l3) in enumerate(coup):
        cgt = jnp.asarray(cg_real(l1, l2, l3), cfg.dtype)  # [m1, m2, m3]
        h_src = feats[l1][src]  # [E, 2l1+1, C]
        y = sh[l2]  # [E, 2l2+1]
        w = radial[:, pi, :]  # [E, C]
        msg = jnp.einsum("eac,eb,abk->ekc", h_src, y, cgt) * w[:, None, :]
        msg = jnp.where(mask[:, None, None], msg, 0.0)
        agg[l3] = agg[l3] + segment_sum(msg, dst, n)
    # per-l linear mix
    return {l: jnp.einsum("nmc,cd->nmd", agg[l], p["mix"][str(l)])
            for l in cfg.ls}


def _product_basis(p, a, cfg: MACEConfig):
    """B-basis: iterated CG self-couplings, channel-wise (correlation <= 3)."""
    c = cfg.channels
    # nu=2: (A x A)_l
    b2 = {l: jnp.zeros_like(a[l]) for l in cfg.ls}
    for (l1, l2, l3) in _couplings(cfg.l_max):
        cgt = jnp.asarray(cg_real(l1, l2, l3), a[0].dtype)
        b2[l3] = b2[l3] + jnp.einsum("nac,nbc,abk->nkc", a[l1], a[l2], cgt)
    # nu=3: (B2 x A)_l
    b3 = {l: jnp.zeros_like(a[l]) for l in cfg.ls}
    for (l1, l2, l3) in _couplings(cfg.l_max):
        cgt = jnp.asarray(cg_real(l1, l2, l3), a[0].dtype)
        b3[l3] = b3[l3] + jnp.einsum("nac,nbc,abk->nkc", b2[l1], a[l2], cgt)
    out = {}
    for l in cfg.ls:
        out[l] = (
            a[l]
            + jnp.einsum("nmc,cd->nmd", b2[l], p["prod2"][str(l)])
            + jnp.einsum("nmc,cd->nmd", b3[l], p["prod3"][str(l)])
        )
    return out


def apply(params, batch: GraphBatch, cfg: MACEConfig):
    """Returns (node_energies [N], graph_energy scalar or [n_graphs])."""
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    pos = batch["positions"].astype(cfg.dtype)
    species = batch["node_feat"].astype(jnp.int32).reshape(-1)  # ids
    n = pos.shape[0]
    c = cfg.channels

    vec = pos[dst] - pos[src]  # [E, 3]
    dist = jnp.sqrt((vec * vec).sum(-1) + 1e-12)
    sh = real_sph_harm(vec, cfg.l_max)  # {l: [E, 2l+1]}
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.r_cut)  # [E, n_rbf]

    h0 = params["embed"][species]  # [N, C]
    feats = {l: jnp.zeros((n, 2 * l + 1, c), cfg.dtype) for l in cfg.ls}
    feats[0] = h0[:, None, :]

    node_e = jnp.zeros((n,), cfg.dtype)
    for p in params["layers"]:
        a = _interaction(p, feats, batch, sh, rbf, cfg)
        feats = _product_basis(p, a, cfg)
        scalar = feats[0][:, 0, :]  # invariant channel
        node_e = node_e + mlp_apply(p["readout"], scalar, act=jax.nn.silu)[:, 0]
    node_e = jnp.where(batch["node_mask"], node_e, 0.0)
    if "graph_ids" in batch:
        n_graphs = batch["labels"].shape[0]  # static: one energy per graph
        e = segment_sum(node_e, batch["graph_ids"], n_graphs)
    else:
        e = node_e.sum()
    return node_e, e
