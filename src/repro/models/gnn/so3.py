"""Minimal real-SO(3) representation machinery for MACE (no e3nn available).

Provides, for l <= L_MAX (default 3):
- real spherical harmonics of unit vectors (closed forms, orthonormalized)
- real-basis Clebsch-Gordan coupling tensors C^{l1 l2 l3} built from the
  complex CG coefficients (Racah's formula) conjugated by the unitary
  complex->real change of basis, with the i^{l1+l2-l3} phase folded in so
  the result is purely real.

Conventions: real SH ordered m = -l..l; the l=1 triple is (y, z, x) in the
standard real-SH convention, i.e. S_{1,-1} ∝ y, S_{1,0} ∝ z, S_{1,1} ∝ x.
Correctness is pinned by tests: norm-invariance of couplings under random
rotations and the Gaunt selection rules.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = ["real_sph_harm", "cg_real", "wigner_d_real", "irrep_dims"]


def irrep_dims(l_max: int):
    return {l: 2 * l + 1 for l in range(l_max + 1)}


# --------------------------------------------------------------------------
# complex Clebsch-Gordan via Racah's formula
# --------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return math.factorial(n)


def _cg_complex_coeff(j1, m1, j2, m2, j3, m3) -> float:
    """<j1 m1 j2 m2 | j3 m3> (Condon-Shortley), Racah's formula."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    if abs(m1) > j1 or abs(m2) > j2 or abs(m3) > j3:
        return 0.0
    pref = (2 * j3 + 1) * _fact(j3 + j1 - j2) * _fact(j3 - j1 + j2) * _fact(
        j1 + j2 - j3
    ) / _fact(j1 + j2 + j3 + 1)
    pref *= (
        _fact(j3 + m3)
        * _fact(j3 - m3)
        * _fact(j1 - m1)
        * _fact(j1 + m1)
        * _fact(j2 - m2)
        * _fact(j2 + m2)
    )
    pref = math.sqrt(pref)
    s = 0.0
    for k in range(0, j1 + j2 + j3 + 1):
        d1 = j1 + j2 - j3 - k
        d2 = j1 - m1 - k
        d3 = j2 + m2 - k
        d4 = j3 - j2 + m1 + k
        d5 = j3 - j1 - m2 + k
        if min(d1, d2, d3, d4, d5) < 0:
            continue
        s += (-1.0) ** k / (
            _fact(k) * _fact(d1) * _fact(d2) * _fact(d3) * _fact(d4) * _fact(d5)
        )
    return pref * s


@lru_cache(maxsize=None)
def _cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """[2l1+1, 2l2+1, 2l3+1] complex-basis CG, index m = -l..l."""
    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    for i1, m1 in enumerate(range(-l1, l1 + 1)):
        for i2, m2 in enumerate(range(-l2, l2 + 1)):
            for i3, m3 in enumerate(range(-l3, l3 + 1)):
                out[i1, i2, i3] = _cg_complex_coeff(l1, m1, l2, m2, l3, m3)
    return out


@lru_cache(maxsize=None)
def _complex_to_real(l: int) -> np.ndarray:
    """U with S_real = U @ Y_complex (rows m_r = -l..l, cols m_c = -l..l)."""
    d = 2 * l + 1
    u = np.zeros((d, d), complex)
    for i, m in enumerate(range(-l, l + 1)):
        if m < 0:
            u[i, l + m] = 1j / math.sqrt(2)
            u[i, l - m] = -1j * (-1) ** m / math.sqrt(2)
        elif m == 0:
            u[i, l] = 1.0
        else:
            u[i, l - m] = 1 / math.sqrt(2)
            u[i, l + m] = (-1) ** m / math.sqrt(2)
    return u


@lru_cache(maxsize=None)
def cg_real_racah(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling via the algebraic U CG U^dagger route (kept for
    cross-checks; the model uses :func:`cg_real`, which is pinned to the
    same convention as :func:`real_sph_harm` by construction)."""
    cg = _cg_complex(l1, l2, l3)
    u1 = _complex_to_real(l1)
    u2 = _complex_to_real(l2)
    u3 = _complex_to_real(l3)
    c = np.einsum("am,bn,ko,mno->abk", u1, u2, np.conj(u3), cg)
    phase = (-1j) ** (l1 + l2 - l3)
    c = phase * c
    assert np.abs(c.imag).max() < 1e-10, (l1, l2, l3, np.abs(c.imag).max())
    return np.ascontiguousarray(c.real)


@lru_cache(maxsize=None)
def cg_real(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor C[a, b, c] such that

        f_c(x, y) = sum_ab C[a,b,c] x_a y_b   satisfies
        f(D1 x, D2 y) = D3 f(x, y)            for every rotation,

    with D_l the real Wigner matrices OF THIS MODULE's spherical-harmonic
    convention. Constructed numerically as the (multiplicity-1) invariant
    subspace of the rep constraint — exact to machine precision, and
    immune to phase/ordering convention mismatches between the algebraic
    CG route and the SH closed forms (which bit us at l=2).
    """
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((d1, d2, d3))
    rng = np.random.default_rng(1234 + 100 * l1 + 10 * l2 + l3)
    rows = []
    for _ in range(6):
        a = rng.normal(size=(3, 3))
        q, r = np.linalg.qr(a)
        q *= np.sign(np.diag(r))
        if np.linalg.det(q) < 0:
            q[:, 0] *= -1
        dd1 = wigner_d_real(l1, q)
        dd2 = wigner_d_real(l2, q)
        dd3 = wigner_d_real(l3, q)
        # linear map L(C)[a',b',c] = sum_ab C[a,b,c] D1[a,a'] D2[b,b']
        #                            - sum_c' D3[c,c'] C[a',b',c']
        lhs = np.einsum("aA,bB->abAB", dd1, dd2).reshape(d1 * d2, d1 * d2)
        m = np.kron(lhs.T, np.eye(d3)) - np.kron(np.eye(d1 * d2), dd3)
        # vec ordering: C[a,b,c] -> index ((a*d2)+b)*d3 + c
        rows.append(m)
    m = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(m)
    c = vt[-1].reshape(d1, d2, d3)
    # precision floor set by the lstsq-derived Wigner matrices (~1e-7)
    assert s[-1] < 1e-5, (l1, l2, l3, s[-1])
    if d1 * d2 * d3 > 1:
        assert s[-2] > 1e-3, ("multiplicity > 1?", l1, l2, l3)
    # deterministic sign + unit Frobenius norm (scale absorbed by weights)
    flat = c.ravel()
    c = c * np.sign(flat[np.argmax(np.abs(flat))])
    return np.ascontiguousarray(c / np.linalg.norm(c))


# --------------------------------------------------------------------------
# real spherical harmonics (orthonormal, m = -l..l), closed forms to l=3
# --------------------------------------------------------------------------
def real_sph_harm(vec, l_max: int) -> Dict[int, jnp.ndarray]:
    """vec: [..., 3] (need not be normalized — we normalize). Returns
    {l: [..., 2l+1]} orthonormal real SH values.

    Degenerate (near-zero) vectors get Y_l = 0 for l >= 1: the direction
    of a zero vector is undefined and any nonzero value would break
    rotation equivariance (self-loop edges hit this)."""
    eps = 1e-12
    r = jnp.sqrt((vec * vec).sum(-1, keepdims=True) + eps)
    nondegenerate = (r[..., 0] > 1e-6)[..., None]
    x, y, z = (vec / r)[..., 0], (vec / r)[..., 1], (vec / r)[..., 2]
    out: Dict[int, jnp.ndarray] = {}
    c0 = 0.5 * math.sqrt(1.0 / math.pi)
    out[0] = jnp.full(vec.shape[:-1] + (1,), c0, vec.dtype)
    if l_max >= 1:
        c1 = math.sqrt(3.0 / (4 * math.pi))
        out[1] = jnp.stack([c1 * y, c1 * z, c1 * x], axis=-1)
    if l_max >= 2:
        c2 = [
            0.5 * math.sqrt(15.0 / math.pi),   # xy
            0.5 * math.sqrt(15.0 / math.pi),   # yz
            0.25 * math.sqrt(5.0 / math.pi),   # 3z^2-1
            0.5 * math.sqrt(15.0 / math.pi),   # zx
            0.25 * math.sqrt(15.0 / math.pi),  # x^2-y^2
        ]
        out[2] = jnp.stack(
            [
                c2[0] * x * y,
                c2[1] * y * z,
                c2[2] * (3 * z * z - 1.0),
                c2[3] * z * x,
                c2[4] * (x * x - y * y),
            ],
            axis=-1,
        )
    if l_max >= 3:
        c3 = [
            0.25 * math.sqrt(35.0 / (2 * math.pi)),
            0.5 * math.sqrt(105.0 / math.pi),
            0.25 * math.sqrt(21.0 / (2 * math.pi)),
            0.25 * math.sqrt(7.0 / math.pi),
            0.25 * math.sqrt(21.0 / (2 * math.pi)),
            0.25 * math.sqrt(105.0 / math.pi),
            0.25 * math.sqrt(35.0 / (2 * math.pi)),
        ]
        out[3] = jnp.stack(
            [
                c3[0] * y * (3 * x * x - y * y),
                c3[1] * x * y * z,
                c3[2] * y * (5 * z * z - 1.0),
                c3[3] * z * (5 * z * z - 3.0),
                c3[4] * x * (5 * z * z - 1.0),
                c3[5] * z * (x * x - y * y),
                c3[6] * x * (x * x - 3 * y * y),
            ],
            axis=-1,
        )
    if l_max >= 4:
        raise NotImplementedError("real_sph_harm implemented to l=3")
    for l in range(1, l_max + 1):
        out[l] = jnp.where(nondegenerate, out[l], 0.0)
    return out


def wigner_d_real(l: int, rot: np.ndarray) -> np.ndarray:
    """Real Wigner-D for rotation matrix ``rot`` (3x3), via the SH of a
    frame of probe vectors — numerically robust for tests (l <= 3).

    Wrapped in ``ensure_compile_time_eval``: cg_real() may be first called
    lazily INSIDE a jit trace (omnistaging would otherwise turn these
    constant-building jnp ops into tracers and np.asarray would fail)."""
    import jax

    # Build D by least squares: SH(R v_i) = D @ SH(v_i) for probe set v_i.
    rng = np.random.default_rng(0)
    v = rng.normal(size=(max(16, 4 * (2 * l + 1)), 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    with jax.ensure_compile_time_eval():
        a = np.asarray(real_sph_harm(jnp.asarray(v), l)[l])  # [P, 2l+1]
        b = np.asarray(real_sph_harm(jnp.asarray(v @ rot.T), l)[l])
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T  # SH(Rv) = D @ SH(v)
