"""GNN message-passing primitives.

JAX sparse is BCOO-only, so message passing is built on edge-index
scatter/gather: gather source-node features by ``edge_src``, transform,
``segment_sum``/``segment_max`` into destination nodes (this is the system
the assignment calls out, not a gap). Edge arrays are padded to static
shapes with ``edge_mask``; padded edges point at a phantom node slot so
compiled shapes never change.

The distributed path 1D-partitions nodes (the paper's partitioning!) and
shards edges; cross-partition feature reads reuse the paper's machinery
(hub-replication cache + gather) — see ``distributed/hub_gather.py``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..common import trunc_normal

__all__ = [
    "segment_sum",
    "segment_mean",
    "segment_max",
    "segment_softmax",
    "gather_src",
    "degree_counts",
    "mlp_init",
    "mlp_apply",
    "GraphBatch",
]

# A graph batch is a plain dict with keys:
#   node_feat [N, F], edge_src [E], edge_dst [E], edge_mask [E],
#   node_mask [N], (optional) positions [N, 3], graph_ids [N], n_graphs
GraphBatch = Dict[str, jnp.ndarray]


def gather_src(node_feat: jnp.ndarray, edge_src: jnp.ndarray) -> jnp.ndarray:
    return node_feat[edge_src]


# --- optional node-dimension sharding for aggregation outputs (§Perf) ---
# When set (dry-run --opt / production launch), segment reductions whose
# output is node-indexed are constrained to the node sharding, so GSPMD
# lowers the cross-device combine as reduce-scatter instead of keeping a
# replicated [N, ...] accumulator + all-reduce.
_NODE_SPEC = {"spec": None, "min_segments": 4097}


def set_node_spec(spec, min_segments: int = 4097):
    _NODE_SPEC["spec"] = spec
    _NODE_SPEC["min_segments"] = min_segments


def _node_shard(out, num_segments: int):
    spec = _NODE_SPEC["spec"]
    if spec is None or num_segments < _NODE_SPEC["min_segments"]:
        return out
    from ..common import shard
    from jax.sharding import PartitionSpec as P

    parts = (spec,) + (None,) * (out.ndim - 1)
    return shard(out, P(*parts))


def segment_sum(values, segment_ids, num_segments: int):
    out = jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    return _node_shard(out, num_segments)


def segment_max(values, segment_ids, num_segments: int):
    out = jax.ops.segment_max(values, segment_ids, num_segments=num_segments)
    return _node_shard(out, num_segments)


def segment_mean(values, segment_ids, num_segments: int):
    s = segment_sum(values, segment_ids, num_segments)
    ones = jnp.ones(values.shape[:1] + (1,) * (values.ndim - 1), values.dtype)
    cnt = segment_sum(ones, segment_ids, num_segments)
    return s / jnp.maximum(cnt, 1.0)


def segment_softmax(scores, segment_ids, num_segments: int, mask=None):
    """Numerically-stable softmax over edges grouped by destination node."""
    if mask is not None:
        scores = jnp.where(mask, scores, -jnp.inf)
    mx = segment_max(scores, segment_ids, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - mx[segment_ids])
    if mask is not None:
        ex = jnp.where(mask, ex, 0.0)
    denom = segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-9)


def degree_counts(edge_dst, edge_mask, num_nodes: int):
    ones = jnp.where(edge_mask, 1.0, 0.0)
    return segment_sum(ones, edge_dst, num_nodes)


def mlp_init(key, sizes, dtype=jnp.float32):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append(
            {"w": trunc_normal(k1, (a, b)).astype(dtype),
             "b": jnp.zeros((b,), dtype)}
        )
    return params


def mlp_apply(params, x, act=jax.nn.relu, *, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x
