from . import common, transformer, moe  # noqa: F401
from . import gnn, recsys  # noqa: F401
