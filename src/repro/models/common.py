"""Shared model building blocks (pure-JAX, pytree params — no flax).

Sharding is expressed with ``jax.lax.with_sharding_constraint`` against
logical axis names resolved through ``distributed.sharding`` rules; when no
mesh is active the constraints are no-ops, so the same model code runs in
smoke tests (1 CPU device) and in the 512-device dry-run.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "shard",
    "rms_norm",
    "layer_norm",
    "dense",
    "gelu",
    "silu",
    "softcap",
    "rope_table",
    "apply_rope",
    "trunc_normal",
    "cross_entropy_loss",
]


def shard(x: jnp.ndarray, spec: Optional[P]) -> jnp.ndarray:
    """Constraint ``x`` to ``spec`` if a mesh is active, else no-op."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (single-device smoke tests)


def trunc_normal(key, shape, scale=1.0, dtype=jnp.float32):
    """Fan-in-scaled truncated normal init."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / math.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x, weight, *, eps=1e-6, zero_centered=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + weight) if zero_centered else weight
    return (x * w).astype(dt)


def layer_norm(x, weight, bias, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dt)


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def rope_table(positions, d_head: int, theta: float = 10000.0):
    """Returns (sin, cos) of shape [..., d_head/2]."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: [..., S, H, d_head]; sin/cos: [..., S, d_head/2] (broadcast over H)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0):
    """Mean token cross-entropy in f32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    loss = lse - ll
    if z_loss > 0:
        loss = loss + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)
