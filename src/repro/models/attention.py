"""Memory-efficient (flash-style) attention in pure jnp.

Online-softmax over KV blocks, scanned over Q blocks — peak memory is one
[B, K, G, block_q, block_k] score tile instead of the full [S, T] matrix
(at 32k x 32k the dense tile would be ~0.5 TB/device; chunked it is tens
of MB). This is the jnp oracle the Pallas flash kernel is validated
against, and the long-sequence path of the transformer (> ``DENSE_CUTOFF``
tokens).

Supports causal masking, sliding windows (gemma2 local layers) and attn
logit soft-capping. The sliding-window path *statically skips* KV blocks
wholly outside the window via the inner fori_loop bounds — the paper-style
"don't fetch what you won't read" trick applied to attention blocks
(§Perf logs the win).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_jnp", "DENSE_CUTOFF"]

DENSE_CUTOFF = 8192  # use the dense path below this many KV positions
NEG = -1e30


def flash_attention_jnp(
    q: jnp.ndarray,  # [B, S, K, G, dh] (GQA-grouped)
    k: jnp.ndarray,  # [B, T, K, dh]
    v: jnp.ndarray,  # [B, T, K, dh]
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,  # 0 = global
    softcap: float = 0.0,
    block_q: int = 2048,
    block_k: int = 2048,
    q_offset: int = 0,  # global position of q[0] (for prefill chunks)
    static_unroll: bool = False,
) -> jnp.ndarray:
    if static_unroll:
        return _flash_static(q, k, v, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             block_q=block_q, block_k=block_k,
                             q_offset=q_offset)
    b, s, kh, g, dh = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    n_q, n_k = s // bq, t // bk

    q = q.reshape(b, n_q, bq, kh, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # q_blocks: [n_q, B, K, G, bq, dh]
    k_blocks = k.reshape(b, n_k, bk, kh, dh).transpose(1, 0, 3, 2, 4)
    v_blocks = v.reshape(b, n_k, bk, kh, dh).transpose(1, 0, 3, 2, 4)
    # k/v_blocks: [n_k, B, K, bk, dh]

    def q_step(_, qi_qb):
        qi, qb = qi_qb  # qb: [B, K, G, bq, dh]
        q_lo = qi * bq + q_offset

        def kv_step(ki, carry):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(k_blocks, ki, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(v_blocks, ki, 0, keepdims=False)
            srs = jnp.einsum(
                "bkgqd,bkcd->bkgqc",
                qb.astype(jnp.float32) * scale,
                kb.astype(jnp.float32),
            )  # [B, K, G, bq, bk]
            if softcap > 0:
                srs = softcap * jnp.tanh(srs / softcap)
            qpos = q_lo + jnp.arange(bq)[:, None]
            kpos = ki * bk + jnp.arange(bk)[None, :]
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= (qpos - kpos) < window
            srs = jnp.where(mask, srs, NEG)
            m_new = jnp.maximum(m, srs.max(-1))
            p = jnp.exp(srs - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vb.astype(jnp.float32)
            )
            return m_new, l_new, acc_new

        m0 = jnp.full((b, kh, g, bq), NEG, jnp.float32)
        l0 = jnp.zeros((b, kh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kh, g, bq, dh), jnp.float32)

        # static KV-block bounds: causal upper bound; sliding-window lower
        if causal or window > 0:
            hi = jnp.minimum(
                (q_lo + bq - 1) // bk + 1, n_k
            ) if causal else n_k
            lo = jnp.maximum((q_lo - window + 1) // bk, 0) if window > 0 else 0
        else:
            lo, hi = 0, n_k
        m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_q), q))
    # outs: [n_q, B, K, G, bq, dh] -> [B, S, K, G, dh]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, kh, g, dh)


def _flash_static(q, k, v, *, scale, causal, window, softcap,
                  block_q, block_k, q_offset):
    """Fully static (python-unrolled) blocked attention: the KV-block
    bounds per Q block are compile-time constants, so out-of-mask blocks
    are NEVER built (vs lax.fori_loop's dynamic bounds, which also cannot
    be reverse-differentiated — this is the TRAIN path)."""
    b, s, kh, g, dh = q.shape
    t = k.shape[1]
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    n_q, n_k = s // bq, t // bk
    outs = []
    for qi in range(n_q):
        q_lo = qi * bq + q_offset
        qb = q[:, qi * bq:(qi + 1) * bq].transpose(0, 2, 3, 1, 4)
        # qb: [B, K, G, bq, dh]
        lo = max((q_lo - window + 1) // bk, 0) if window > 0 else 0
        hi = min((q_lo + bq - 1) // bk + 1, n_k) if causal else n_k
        m = jnp.full((b, kh, g, bq), NEG, jnp.float32)
        l = jnp.zeros((b, kh, g, bq), jnp.float32)
        acc = jnp.zeros((b, kh, g, bq, dh), jnp.float32)
        for ki in range(lo, hi):
            kb = k[:, ki * bk:(ki + 1) * bk].transpose(0, 2, 1, 3)
            vb = v[:, ki * bk:(ki + 1) * bk].transpose(0, 2, 1, 3)
            srs = jnp.einsum(
                "bkgqd,bkcd->bkgqc",
                qb.astype(jnp.float32) * scale, kb.astype(jnp.float32),
            )
            if softcap > 0:
                srs = softcap * jnp.tanh(srs / softcap)
            qpos = q_lo + jnp.arange(bq)[:, None]
            kpos = ki * bk + jnp.arange(bk)[None, :]
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= (qpos - kpos) < window
            srs = jnp.where(mask, srs, NEG)
            m_new = jnp.maximum(m, srs.max(-1))
            p = jnp.exp(srs - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vb.astype(jnp.float32))
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.astype(q.dtype))  # [B, K, G, bq, dh]
    out = jnp.stack(outs, axis=0)  # [n_q, B, K, G, bq, dh]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, kh, g, dh)
