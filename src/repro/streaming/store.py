"""DynamicCSR: a CSR graph plus delta buffers, with periodic compaction.

The static pipeline's ``CSRGraph`` is immutable (two packed arrays). A
live graph absorbs updates far faster than it can afford full rebuilds,
so ``DynamicCSR`` keeps

- ``base``     — the last compacted ``CSRGraph`` (sorted rows), and
- ``_added``   — per-vertex sorted arrays of neighbors inserted since,
- ``_removed`` — per-vertex sets of base neighbors deleted since.

``row(v)`` merges the three on demand (sorted, deduplicated — the same
invariants every intersection kernel relies on). ``compact()`` folds the
deltas back into a fresh ``CSRGraph``; ``maybe_compact()`` triggers when
the delta exceeds a configurable fraction of the base edges, which keeps
merged-row reads amortized O(deg).

Invariants (matching ``core/csr.py``):
- vertices are ids in ``[0, n)``; rows sorted ascending, deduplicated,
  loop-free; both directions stored for undirected edges.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

from ..core.csr import CSRGraph, from_edges

__all__ = ["DynamicCSR"]


class DynamicCSR:
    def __init__(self, base: CSRGraph, *, compact_threshold: float = 0.25):
        self.base = base
        self.n = base.n
        self.compact_threshold = float(compact_threshold)
        self._added: Dict[int, np.ndarray] = {}
        self._removed: Dict[int, set] = {}
        self._degree = base.degrees.copy()
        self._delta_edges = 0  # directed insert+delete entries outstanding
        self.n_compactions = 0

    # ---------------- constructors ----------------
    @staticmethod
    def from_csr(csr: CSRGraph, *, compact_threshold: float = 0.25) -> "DynamicCSR":
        return DynamicCSR(csr, compact_threshold=compact_threshold)

    @staticmethod
    def empty(n: int, *, compact_threshold: float = 0.25) -> "DynamicCSR":
        base = CSRGraph(
            offsets=np.zeros(n + 1, np.int64),
            adjacencies=np.zeros((0,), np.int32),
            n=n,
        )
        return DynamicCSR(base, compact_threshold=compact_threshold)

    # ---------------- queries ----------------
    @property
    def m(self) -> int:
        """Number of stored (directed) edges."""
        return int(self._degree.sum())

    @property
    def degrees(self) -> np.ndarray:
        return self._degree

    def degree(self, v: int) -> int:
        return int(self._degree[v])

    @property
    def max_degree(self) -> int:
        return int(self._degree.max()) if self.n else 0

    @property
    def delta_edges(self) -> int:
        return self._delta_edges

    def row(self, v: int) -> np.ndarray:
        """Merged sorted adjacency row of ``v`` (int32)."""
        r = self.base.row(v)
        rem = self._removed.get(v)
        if rem:
            r = r[~np.isin(r, np.fromiter(rem, np.int64, len(rem)))]
        add = self._added.get(v)
        if add is not None and add.size:
            r = np.sort(np.concatenate([r.astype(np.int64), add])).astype(
                np.int32
            )
        return r

    def has_edge(self, u: int, v: int) -> bool:
        return bool(self.has_edges(np.array([u]), np.array([v]))[0])

    def has_edges(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Vectorized membership: is (u[i], v[i]) currently an edge?"""
        u = np.asarray(u, np.int64)
        v = np.asarray(v, np.int64)
        out = np.zeros(u.shape, bool)
        for i in range(u.size):
            ui, vi = int(u[i]), int(v[i])
            add = self._added.get(ui)
            if add is not None and add.size and _sorted_contains(add, vi):
                out[i] = True
                continue
            r = self.base.row(ui)
            if r.size and _sorted_contains(r, vi):
                rem = self._removed.get(ui)
                out[i] = not (rem and vi in rem)
        return out

    # ---------------- mutation ----------------
    def insert_edges(self, pairs: np.ndarray) -> None:
        """Insert canonical (u < v) edges known to be absent (both dirs)."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        for u, v in pairs:
            self._insert_directed(int(u), int(v))
            self._insert_directed(int(v), int(u))

    def delete_edges(self, pairs: np.ndarray) -> None:
        """Delete canonical (u < v) edges known to be present (both dirs)."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        for u, v in pairs:
            self._delete_directed(int(u), int(v))
            self._delete_directed(int(v), int(u))

    def _insert_directed(self, u: int, v: int) -> None:
        rem = self._removed.get(u)
        if rem and v in rem:  # re-insert of a base edge deleted earlier
            rem.discard(v)
            if not rem:
                del self._removed[u]
            self._delta_edges -= 1  # cancels an outstanding removal
        else:
            add = self._added.get(u)
            if add is None:
                self._added[u] = np.array([v], np.int64)
            else:
                pos = int(np.searchsorted(add, v))
                self._added[u] = np.insert(add, pos, v)
            self._delta_edges += 1
        self._degree[u] += 1

    def _delete_directed(self, u: int, v: int) -> None:
        add = self._added.get(u)
        if add is not None and add.size and _sorted_contains(add, v):
            self._added[u] = np.delete(add, int(np.searchsorted(add, v)))
            if not self._added[u].size:
                del self._added[u]
            self._delta_edges -= 1  # cancels an outstanding insert
        else:
            self._removed.setdefault(u, set()).add(v)
            self._delta_edges += 1
        self._degree[u] -= 1

    # ---------------- compaction ----------------
    def to_csr(self) -> CSRGraph:
        """Compacted snapshot (does not mutate the store)."""
        if not self._added and not self._removed:
            return self.base
        rows = [self.row(v) for v in range(self.n)]
        counts = np.array([r.size for r in rows], np.int64)
        offsets = np.zeros(self.n + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        adj = (
            np.concatenate(rows).astype(np.int32)
            if counts.sum()
            else np.zeros((0,), np.int32)
        )
        return CSRGraph(offsets=offsets, adjacencies=adj, n=self.n)

    def compact(self) -> CSRGraph:
        """Fold deltas into a fresh base CSR; returns the new base."""
        self.base = self.to_csr()
        self._added.clear()
        self._removed.clear()
        self._delta_edges = 0
        self.n_compactions += 1
        assert np.array_equal(self.base.degrees, self._degree)
        return self.base

    def maybe_compact(self) -> bool:
        """Compact when the outstanding delta exceeds the threshold
        fraction of the base edge count."""
        base_m = max(self.base.m, 1)
        if self._delta_edges > self.compact_threshold * base_m:
            self.compact()
            return True
        return False

    # ---------------- device layout ----------------
    def padded_rows(
        self,
        vertices: Iterable[int],
        width: Optional[int] = None,
        *,
        sentinel: Optional[int] = None,
    ) -> np.ndarray:
        """Padded ``[len(vertices), width]`` sorted row matrix (cf.
        ``core.csr.to_padded_rows``), built from the merged rows."""
        vs = np.asarray(list(vertices), np.int64)
        w = int(width if width is not None else max(self.max_degree, 1))
        sent = int(self.n if sentinel is None else sentinel)
        out = np.full((vs.size, w), sent, np.int32)
        for i, v in enumerate(vs):
            r = self.row(int(v))[:w]
            out[i, : r.size] = r
        return out


def _sorted_contains(arr: np.ndarray, x: int) -> bool:
    i = int(np.searchsorted(arr, x))
    return i < arr.size and int(arr[i]) == x
