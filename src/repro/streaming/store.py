"""DynamicCSR: a CSR graph plus delta buffers, with periodic compaction.

The static pipeline's ``CSRGraph`` is immutable (two packed arrays). A
live graph absorbs updates far faster than it can afford full rebuilds,
so ``DynamicCSR`` keeps

- ``base``     — the last compacted ``CSRGraph`` (sorted rows), and
- ``_added``   — per-vertex sorted arrays of neighbors inserted since,
- ``_removed`` — per-vertex sorted arrays of base neighbors deleted since.

``row(v)`` merges the three on demand (sorted, deduplicated — the same
invariants every intersection kernel relies on). ``compact()`` folds the
deltas back into a fresh ``CSRGraph``; ``maybe_compact()`` triggers when
the delta exceeds a configurable fraction of the base edges, which keeps
merged-row reads amortized O(deg).

Mutations and membership queries are grouped by endpoint vertex: a batch
touching a row pays one sorted merge (or one vectorized binary search)
for that row, not one ``np.insert``/probe per edge — the batch cost is
O(sum of touched-row degrees), independent of how the batch's edges are
ordered.

Invariants (matching ``core/csr.py``):
- vertices are ids in ``[0, n)``; rows sorted ascending, deduplicated,
  loop-free; both directions stored for undirected edges.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, Optional, Tuple

import numpy as np

from ..core.csr import CSRGraph, from_edges

__all__ = ["DynamicCSR"]


def _in_sorted(sorted_arr: Optional[np.ndarray], values: np.ndarray) -> np.ndarray:
    """Membership mask of ``values`` in the sorted array (vectorized)."""
    values = np.asarray(values)
    if sorted_arr is None or sorted_arr.size == 0:
        return np.zeros(values.shape, bool)
    idx = np.searchsorted(sorted_arr, values)
    idx = np.minimum(idx, sorted_arr.size - 1)
    return sorted_arr[idx] == values


def _ragged_membership(
    flat: np.ndarray, lo: np.ndarray, hi: np.ndarray, vals: np.ndarray
) -> np.ndarray:
    """Membership of ``vals[i]`` in the sorted slice ``flat[lo[i]:hi[i]]``.

    One lock-step vectorized binary search over all queries at once
    (O(Q log max_row) numpy steps, no Python loop per row) — the ragged
    row boundaries ride along as per-query [lo, hi) windows."""
    if flat.size == 0:
        return np.zeros(vals.shape, bool)
    lo = np.asarray(lo, np.int64).copy()
    hi0 = np.asarray(hi, np.int64)
    hi = hi0.copy()
    while True:
        active = lo < hi
        if not active.any():
            break
        mid = (lo + hi) >> 1
        fv = flat[np.where(active, mid, 0)]
        go_right = active & (fv < vals)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(active & ~go_right, mid, hi)
    found = lo < hi0  # insertion point inside the window
    return found & (flat[np.where(found, lo, 0)] == vals)


def _group_by_vertex(
    a: np.ndarray, b: np.ndarray
) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
    """Yield ``(u, vs, positions)`` per distinct endpoint ``u`` of the
    directed pairs ``(a[i], b[i])`` — one group per touched row."""
    order = np.argsort(a, kind="stable")
    a_s, b_s = a[order], b[order]
    starts = np.flatnonzero(np.r_[True, a_s[1:] != a_s[:-1]])
    ends = np.r_[starts[1:], a_s.size]
    for s, e in zip(starts, ends):
        yield int(a_s[s]), b_s[s:e], order[s:e]


class DynamicCSR:
    def __init__(self, base: CSRGraph, *, compact_threshold: float = 0.25):
        self.base = base
        self.n = base.n
        self.compact_threshold = float(compact_threshold)
        self._added: Dict[int, np.ndarray] = {}
        self._removed: Dict[int, np.ndarray] = {}  # sorted int64 per vertex
        self._degree = base.degrees.copy()
        self._delta_edges = 0  # directed insert+delete entries outstanding
        self.n_compactions = 0
        self.n_mutations = 0  # monotone: bumps on every effective batch

    # ---------------- constructors ----------------
    @staticmethod
    def from_csr(csr: CSRGraph, *, compact_threshold: float = 0.25) -> "DynamicCSR":
        return DynamicCSR(csr, compact_threshold=compact_threshold)

    @staticmethod
    def empty(n: int, *, compact_threshold: float = 0.25) -> "DynamicCSR":
        base = CSRGraph(
            offsets=np.zeros(n + 1, np.int64),
            adjacencies=np.zeros((0,), np.int32),
            n=n,
        )
        return DynamicCSR(base, compact_threshold=compact_threshold)

    # ---------------- queries ----------------
    @property
    def m(self) -> int:
        """Number of stored (directed) edges."""
        return int(self._degree.sum())

    @property
    def degrees(self) -> np.ndarray:
        return self._degree

    def degree(self, v: int) -> int:
        return int(self._degree[v])

    @property
    def max_degree(self) -> int:
        return int(self._degree.max()) if self.n else 0

    @property
    def delta_edges(self) -> int:
        return self._delta_edges

    def row(self, v: int) -> np.ndarray:
        """Merged sorted adjacency row of ``v`` (int32)."""
        r = self.base.row(v)
        rem = self._removed.get(v)
        if rem is not None and rem.size:
            r = r[~_in_sorted(rem, r)]
        add = self._added.get(v)
        if add is not None and add.size:
            r = np.sort(np.concatenate([r.astype(np.int64), add])).astype(
                np.int32
            )
        return r

    def has_edge(self, u: int, v: int) -> bool:
        return bool(self.has_edges(np.array([u]), np.array([v]))[0])

    def has_edges(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Vectorized membership: is (u[i], v[i]) currently an edge?

        Fully vectorized — one lock-step binary search over the base CSR
        (per-query [offset, offset+deg) windows) plus one over the
        concatenated delta buffers of the touched rows; the only Python
        iteration left is a dict lookup per distinct touched vertex."""
        u = np.asarray(u, np.int64).ravel()
        v = np.asarray(v, np.int64).ravel()
        if u.size == 0:
            return np.zeros(u.shape, bool)
        base = self.base
        in_base = _ragged_membership(
            base.adjacencies, base.offsets[u], base.offsets[u + 1], v
        )
        if not self._added and not self._removed:
            return in_base
        uu, inv = np.unique(u, return_inverse=True)
        in_add = self._delta_membership(self._added, uu, inv, v)
        in_rem = self._delta_membership(self._removed, uu, inv, v)
        return in_add | (in_base & ~in_rem)

    def _delta_membership(
        self, table: Dict[int, np.ndarray], uu, inv, v
    ) -> np.ndarray:
        """Membership of ``v[i]`` in ``table[u[i]]`` (u factored as
        ``uu[inv]``): concatenate the touched rows' delta arrays once,
        then one ragged binary search over all queries."""
        arrs = [table.get(int(x)) for x in uu]
        sizes = np.array(
            [0 if a is None else a.size for a in arrs], np.int64
        )
        if not sizes.any():
            return np.zeros(v.shape, bool)
        offs = np.zeros(uu.size + 1, np.int64)
        np.cumsum(sizes, out=offs[1:])
        flat = np.concatenate(
            [a for a in arrs if a is not None and a.size]
        )
        return _ragged_membership(flat, offs[:-1][inv], offs[1:][inv], v)

    # ---------------- mutation ----------------
    def insert_edges(self, pairs: np.ndarray) -> None:
        """Insert canonical (u < v) edges known to be absent (both dirs)."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        if pairs.shape[0] == 0:
            return
        self.n_mutations += 1
        a = np.concatenate([pairs[:, 0], pairs[:, 1]])
        b = np.concatenate([pairs[:, 1], pairs[:, 0]])
        for u, vs, _ in _group_by_vertex(a, b):
            self._insert_row(u, np.sort(vs))
            self._degree[u] += vs.size

    def delete_edges(self, pairs: np.ndarray) -> None:
        """Delete canonical (u < v) edges known to be present (both dirs)."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        if pairs.shape[0] == 0:
            return
        self.n_mutations += 1
        a = np.concatenate([pairs[:, 0], pairs[:, 1]])
        b = np.concatenate([pairs[:, 1], pairs[:, 0]])
        for u, vs, _ in _group_by_vertex(a, b):
            self._delete_row(u, np.sort(vs))
            self._degree[u] -= vs.size

    def _insert_row(self, u: int, vs: np.ndarray) -> None:
        """Insert the sorted distinct neighbors ``vs`` into row ``u``."""
        rem = self._removed.get(u)
        if rem is not None and rem.size:
            # re-inserts of base edges deleted earlier cancel the removal
            cancel = _in_sorted(rem, vs)
            n_cancel = int(cancel.sum())
            if n_cancel:
                rem = rem[~_in_sorted(vs[cancel], rem)]
                if rem.size:
                    self._removed[u] = rem
                else:
                    del self._removed[u]
                self._delta_edges -= n_cancel
                vs = vs[~cancel]
        if vs.size:
            add = self._added.get(u)
            if add is not None and add.size:
                vs = np.sort(np.concatenate([add, vs]))
            self._added[u] = vs
            self._delta_edges += int(vs.size - (0 if add is None else add.size))

    def _delete_row(self, u: int, vs: np.ndarray) -> None:
        """Delete the sorted distinct neighbors ``vs`` from row ``u``."""
        add = self._added.get(u)
        in_add = _in_sorted(add, vs)
        n_in_add = int(in_add.sum())
        if n_in_add:
            add = add[~_in_sorted(vs[in_add], add)]
            if add.size:
                self._added[u] = add
            else:
                del self._added[u]
            self._delta_edges -= n_in_add  # cancels outstanding inserts
        vs = vs[~in_add]
        if vs.size:
            rem = self._removed.get(u)
            if rem is not None and rem.size:
                vs = np.sort(np.concatenate([rem, vs]))
            self._removed[u] = vs
            self._delta_edges += int(
                vs.size - (0 if rem is None else rem.size)
            )

    # ---------------- compaction ----------------
    def to_csr(self) -> CSRGraph:
        """Compacted snapshot (does not mutate the store)."""
        if not self._added and not self._removed:
            return self.base
        rows = [self.row(v) for v in range(self.n)]
        counts = np.array([r.size for r in rows], np.int64)
        offsets = np.zeros(self.n + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        adj = (
            np.concatenate(rows).astype(np.int32)
            if counts.sum()
            else np.zeros((0,), np.int32)
        )
        return CSRGraph(offsets=offsets, adjacencies=adj, n=self.n)

    def compact(self) -> CSRGraph:
        """Fold deltas into a fresh base CSR; returns the new base."""
        self.base = self.to_csr()
        self._added.clear()
        self._removed.clear()
        self._delta_edges = 0
        self.n_compactions += 1
        assert np.array_equal(self.base.degrees, self._degree)
        return self.base

    def maybe_compact(self) -> bool:
        """Compact when the outstanding delta exceeds the threshold
        fraction of the base edge count."""
        base_m = max(self.base.m, 1)
        if self._delta_edges > self.compact_threshold * base_m:
            self.compact()
            return True
        return False

    # ---------------- device layout ----------------
    def padded_rows(
        self,
        vertices: Iterable[int],
        width: Optional[int] = None,
        *,
        sentinel: Optional[int] = None,
    ) -> np.ndarray:
        """Padded ``[len(vertices), width]`` sorted row matrix (cf.
        ``core.csr.to_padded_rows``), built from the merged rows."""
        vs = np.asarray(list(vertices), np.int64)
        w = int(width if width is not None else max(self.max_degree, 1))
        sent = int(self.n if sentinel is None else sentinel)
        out = np.full((vs.size, w), sent, np.int32)
        for i, v in enumerate(vs):
            r = self.row(int(v))[:w]
            out[i, : r.size] = r
        return out
