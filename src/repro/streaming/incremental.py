"""Exact incremental triangle counting + LCC under batched edge updates.

Per batch the engine computes the per-vertex triangle delta without
touching unaffected parts of the graph. For an *insertion* set D applied
to graph G (all D edges absent from G), split every endpoint neighborhood
into its old part ``N(x)`` (rows of G) and its new part ``N_D(x)``
(neighbors within the batch). A new triangle {u, v, w} with exactly

- 1 batch edge is discovered once   (w ∈ N(u) ∩ N(v)        for that edge),
- 2 batch edges is discovered twice (once per batch edge, via N ∩ N_D),
- 3 batch edges is discovered 3×    (w ∈ N_D(u) ∩ N_D(v) per edge),

so crediting each discovery to u, v and w with weights 6 / 3 / 2
(old∩old / old∩new / new∩new) gives every new triangle weight exactly 6
at each of its three corners — integer arithmetic, no double counting
(Tangwongsan et al.'s batched wedge-closure corrections in scaled form).
Deletions are the time-reverse: remove the edges from the store, compute
the same insertion delta against the post-delete rows, and subtract.

The old∩old intersections — the hot path, row widths up to the max
degree — are routed through the Pallas ``intersect_count`` kernel via the
batched ``delta_intersect_counts`` wrapper; the membership masks that
identify the closing vertices w come from the vectorized binary-search
companion ``delta_intersect_masks`` and are cross-checked against the
kernel counts. LCC is patched in place for exactly the dirty vertices
with the same arithmetic as ``lcc_scores`` (bit-exact vs a recount).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.csr import CSRGraph
from ..core.runtime import ShardedRuntime
from ..core.triangles import lcc_scores, triangles_per_vertex
from ..kernels.delta_intersect import (
    delta_intersect_counts,
    delta_intersect_masks,
)
from ..kernels.resident_intersect import resident_intersect_counts
from ..obs import trace as obs_trace
from .store import DynamicCSR
from .updates import EdgeBatch, normalize_batch

__all__ = ["BatchResult", "StreamingLCCEngine"]


@dataclasses.dataclass
class BatchResult:
    """Per-batch accounting returned by ``apply_batch``."""

    n_inserted: int
    n_deleted: int
    n_noop: int
    d_triangles: int  # global triangle-count delta
    n_dirty: int  # vertices whose T or LCC changed
    delta_pairs: int  # row pairs intersected (Pallas kernel or host path)
    compacted: bool
    # True/False: the attached pull schedule was patched incrementally /
    # rebuilt on width overflow; None: no schedule attached
    schedule_incremental: Optional[bool] = None


class StreamingLCCEngine:
    """Maintains exact per-vertex triangle counts and LCC for a
    ``DynamicCSR`` under batched insert/delete updates.

    ``t``/``lcc`` always equal ``triangles_per_vertex``/``lcc_scores`` of
    the compacted current graph (the streaming tests assert this after
    arbitrary update sequences).

    With a ``ShardedRuntime`` attached (directly or via the coherence
    layer), each batch's delta worklist is partitioned by the owner rank
    of its first endpoint — the same ownership rule the static engine's
    edge worklists follow — and the batched old∩old intersections run
    through the ``delta_intersect`` path once per shard. The per-vertex
    deltas are integer scatter-adds, so the sharded result is bit-exact
    vs the unsharded one at any p. The runtime also carries the optional
    static pull schedule, kept fresh per batch via ``maintain_schedule``.

    ``execution="spmd"`` runs the per-rank shards as ONE rank-sharded
    ``shard_map`` call per batch phase (``SpmdIntersectExecutor``):
    remote rows ship owner -> rank through an ``all_to_all`` and the
    old∩old counts come back from the device, cross-checked against the
    host membership masks — bit-exact vs ``execution="loop"`` at any p
    (property-tested field-for-field, including every ledger).
    """

    def __init__(
        self,
        csr: CSRGraph,
        *,
        use_kernel: bool = True,
        block_e: int = 128,
        interpret: Optional[bool] = None,
        auto_compact: bool = True,
        compact_threshold: float = 0.25,
        coherence=None,
        runtime: Optional[ShardedRuntime] = None,
        execution: str = "loop",
        pipeline: bool = False,
    ):
        assert execution in ("loop", "spmd"), execution
        assert not pipeline or execution == "spmd", (
            "pipeline overlaps the two SPMD phase dispatches of a batch "
            "— pass execution='spmd'"
        )
        self.store = DynamicCSR.from_csr(
            csr, compact_threshold=compact_threshold
        )
        self.t = triangles_per_vertex(csr).astype(np.int64)
        self.lcc = lcc_scores(csr, self.t)
        self.use_kernel = use_kernel
        self.block_e = block_e
        self.interpret = interpret
        self.auto_compact = auto_compact
        self.coherence = coherence
        if runtime is None and coherence is not None:
            runtime = getattr(coherence, "runtime", None)
        self.runtime = runtime
        if runtime is not None:
            runtime.bind_store(self.store)
        assert execution == "loop" or runtime is not None, (
            "SPMD execution shards the worklist by the runtime's owner "
            "partition — attach a ShardedRuntime (or coherence layer)"
        )
        self.execution = execution
        self.pipeline = bool(pipeline)
        self.spmd = None
        if execution == "spmd":
            from ..distributed.spmd_runtime import SpmdIntersectExecutor

            # runtime= registers the executor's resident-buffer
            # invalidation on the runtime's coherence fanout, so
            # end-of-batch invalidates keep the device mirror fresh.
            self.spmd = SpmdIntersectExecutor(
                runtime.part,
                runtime.n,
                use_kernel=use_kernel,
                block_e=block_e,
                interpret=interpret,
                runtime=runtime,
            )
        self.shard_pairs = np.zeros(
            runtime.p if runtime is not None else 1, np.int64
        )  # row pairs processed per owner rank (worklist balance)
        self.n_batches = 0
        self.n_updates = 0  # effective (non-noop) undirected updates
        self.delta_pairs_total = 0
        # host-row-materialization ledger for the oo path: rows/bytes
        # merged+packed from the store per batch (resident rows served
        # from the device tier's persistent mirror are NOT counted here
        # — their savings accrue in runtime.device.stats.bytes_saved).
        self.oo_host_rows = 0
        self.oo_host_bytes = 0
        self.oo_resident_pairs = 0  # oo pairs counted on-device

    # ---------------- public API ----------------
    @staticmethod
    def empty(n: int, **kw) -> "StreamingLCCEngine":
        base = CSRGraph(
            offsets=np.zeros(n + 1, np.int64),
            adjacencies=np.zeros((0,), np.int32),
            n=n,
        )
        return StreamingLCCEngine(base, **kw)

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def triangle_count(self) -> int:
        total = int(self.t.sum())
        assert total % 3 == 0
        return total // 3

    def apply_batch(self, batch: EdgeBatch) -> BatchResult:
        with obs_trace.span("stream_batch", cat="streaming",
                            n=batch.u.size):
            return self._apply_batch_impl(batch)

    def _apply_batch_impl(self, batch: EdgeBatch) -> BatchResult:
        ins, dele, n_noop = normalize_batch(batch, self.store)
        delta6 = np.zeros(self.n, np.int64)
        delta_pairs = 0
        pipelined = (
            self.pipeline and ins.shape[0] > 0 and dele.shape[0] > 0
        )
        if dele.shape[0]:
            # time-reverse: destroyed triangles == triangles an insertion
            # of ``dele`` into the post-delete graph would create.
            self.store.delete_edges(dele)
            self._sync_device_after_delete(dele)
        if pipelined:
            # double-buffered batch: both phases read the same store
            # state (post-delete, pre-insert), so the insert phase's
            # host pack + collective launch overlaps the delete phase's
            # in-flight device intersect. The host-side scatter math of
            # each phase runs at its finish — integer scatter-adds, so
            # the result is bit-exact vs the sequential path.
            fin_del = self._delta6_begin(dele, sign=-1)
            fin_ins = self._delta6_begin(ins, sign=+1)
            self.store.insert_edges(ins)
            delta_pairs += fin_del(delta6)
            delta_pairs += fin_ins(delta6)
        else:
            if dele.shape[0]:
                delta_pairs += self._accumulate_insertion_delta6(
                    dele, delta6, sign=-1
                )
            if ins.shape[0]:
                delta_pairs += self._accumulate_insertion_delta6(
                    ins, delta6, sign=+1
                )
                self.store.insert_edges(ins)

        assert (delta6 % 6 == 0).all(), "triangle weights must close to 6"
        dt = delta6 // 6
        self.t += dt
        endpoints = np.concatenate([ins.ravel(), dele.ravel()]).astype(
            np.int64
        )
        dirty = np.unique(np.concatenate([endpoints, np.flatnonzero(dt)]))
        if dirty.size:
            self._patch_lcc(dirty)

        compacted = self.store.maybe_compact() if self.auto_compact else False
        self.n_batches += 1
        self.n_updates += int(ins.shape[0] + dele.shape[0])
        self.delta_pairs_total += delta_pairs
        if (
            self.runtime is not None
            and self.runtime.has_device_tier
            and dele.shape[0]
        ):
            # delete-only rows were already patched by the mid-batch
            # sync against what is also their final state; tell the
            # coming invalidate fanout not to patch them a second time
            # (ids the insert phase touched again are NOT marked).
            fresh = np.setdiff1d(
                np.unique(dele.ravel()), np.unique(ins.ravel())
            )
            if fresh.size:
                self.runtime.mark_device_fresh(fresh.tolist())
        if self.coherence is not None:
            self.coherence.on_batch(ins, dele, self.store)
        elif self.runtime is not None:
            # no coherence layer to fan the mutations out: the engine
            # itself invalidates through the runtime, so both tiers
            # (host payload caches + device residency) stay fresh — the
            # next batch's oo rows are served from the resident mirror.
            changed = np.unique(
                np.concatenate([ins.ravel(), dele.ravel()])
            ).astype(np.int64)
            if changed.size:
                self.runtime.invalidate(changed.tolist())
        schedule_incremental = None
        if self.runtime is not None and self.runtime.problem is not None:
            # residency drift: hand the coherence layer's rescored
            # static set to the schedule so cache_ids refresh in place
            # (a drifted top-C alone never forces a full rebuild).
            new_ids = None
            static = getattr(self.coherence, "static", None)
            if static is not None and self.runtime.problem.cache_ids.size:
                new_ids = static.vertex_ids
            schedule_incremental = self.runtime.maintain_schedule(
                ins, dele, new_cache_ids=new_ids
            )
        return BatchResult(
            n_inserted=int(ins.shape[0]),
            n_deleted=int(dele.shape[0]),
            n_noop=n_noop,
            d_triangles=int(dt.sum()) // 3,
            n_dirty=int(dirty.size),
            delta_pairs=delta_pairs,
            compacted=compacted,
            schedule_incremental=schedule_incremental,
        )

    def verify(self) -> None:
        """Assert engine state == from-scratch recount (bit-exact)."""
        csr = self.store.to_csr()
        want_t = triangles_per_vertex(csr)
        if not np.array_equal(self.t, want_t):
            bad = np.flatnonzero(self.t != want_t)[:8]
            raise AssertionError(
                f"incremental T diverged at vertices {bad.tolist()}"
            )
        want_lcc = lcc_scores(csr, want_t)
        if not np.array_equal(self.lcc, want_lcc):
            bad = np.flatnonzero(self.lcc != want_lcc)[:8]
            raise AssertionError(
                f"incremental LCC diverged at vertices {bad.tolist()}"
            )

    # ---------------- internals ----------------
    def _sync_device_after_delete(self, dele: np.ndarray) -> None:
        """The delta intersections of this batch read POST-delete rows:
        patch the touched resident rows in every device view now so the
        device tier serves the same state mid-batch (the end-of-batch
        coherence fanout re-syncs after the inserts land), and drop the
        SPMD executor's resident-buffer copies of the same ids — a
        stale buffer row would break the loop-vs-SPMD bit-exactness
        contract."""
        changed = np.unique(dele.ravel())
        if self.runtime is not None and self.runtime.has_device_tier:
            ids = changed.tolist()
            for dv in self.runtime.device_views():
                dv.notify_batch(ids)
        if self.spmd is not None:
            self.spmd.invalidate(changed)

    @staticmethod
    def _batch_adjacency(pairs: np.ndarray) -> Dict[int, np.ndarray]:
        """Batch-internal adjacency N_D (sorted per vertex) — built over
        the WHOLE batch: a shard's wedge-closure corrections must see
        batch edges owned by other ranks too."""
        d_adj: Dict[int, np.ndarray] = {}
        for a, b in pairs:
            d_adj.setdefault(int(a), []).append(int(b))
            d_adj.setdefault(int(b), []).append(int(a))
        for x in d_adj:
            d_adj[x] = np.array(sorted(d_adj[x]), np.int64)
        return d_adj

    def _delta6_begin(self, pairs: np.ndarray, *, sign: int):
        """Dispatch one phase's rank-sharded device intersect WITHOUT
        waiting: all host row materialization happens here (against the
        current post-delete / pre-insert store), so the returned
        ``finish(delta6) -> n_pairs`` closure only waits on the device
        counts and runs the host scatter math."""
        assert self.spmd is not None, "pipelining is SPMD-only"
        d_adj = self._batch_adjacency(pairs)
        owners = self.runtime.part.owner(pairs[:, 0])
        shards = [
            pairs[owners == rank] for rank in range(self.runtime.p)
        ]
        pending, rowdata = self._delta6_spmd_dispatch(shards, d_adj)

        def finish(delta6: np.ndarray) -> int:
            return self._delta6_spmd_finish(
                pending, shards, rowdata, d_adj, delta6, sign=sign
            )

        return finish

    def _accumulate_insertion_delta6(
        self, pairs: np.ndarray, delta6: np.ndarray, *, sign: int
    ) -> int:
        """Add ``sign *`` (scaled-by-6 per-vertex triangle delta of
        inserting ``pairs``) into ``delta6``. Rows of ``self.store`` are
        the *old* neighborhoods (callers guarantee ``pairs`` are absent).
        Returns the number of row pairs sent through delta-intersect."""
        d_adj = self._batch_adjacency(pairs)

        spmd = self.spmd is not None
        if self.runtime is not None and (self.runtime.p > 1 or spmd):
            # shard the delta worklist by owner rank of the first
            # endpoint; per-shard scatter-adds are integer, so the sum
            # over shards is bit-exact vs the single-shard path.
            owners = self.runtime.part.owner(pairs[:, 0])
            shards = [
                pairs[owners == rank] for rank in range(self.runtime.p)
            ]
            if spmd:
                return self._delta6_spmd(shards, d_adj, delta6, sign=sign)
            total = 0
            for rank, shard in enumerate(shards):
                if shard.shape[0] == 0:
                    continue
                total += self._delta6_for_shard(
                    shard, d_adj, delta6, sign=sign, rank=rank
                )
                self.shard_pairs[rank] += shard.shape[0]
            return total
        n = self._delta6_for_shard(pairs, d_adj, delta6, sign=sign)
        self.shard_pairs[0] += n
        return n

    def _delta6_spmd(
        self,
        shards,
        d_adj: Dict[int, np.ndarray],
        delta6: np.ndarray,
        *,
        sign: int,
    ) -> int:
        """Device-parallel variant of the per-shard loop: every shard's
        old∩old counts run as ONE rank-sharded ``shard_map`` call — rows
        owned by the executing rank (or resident in the device tier's
        mirror) stay rank-local, remote rows ship owner -> requester
        through the collective — then the per-shard host math (masks,
        wedge corrections, scatters) proceeds unchanged against those
        counts. The engine's kernel-vs-mask cross-check still runs, so
        SPMD counts are verified against the host membership masks on
        every batch."""
        pending, rowdata = self._delta6_spmd_dispatch(shards, d_adj)
        return self._delta6_spmd_finish(
            pending, shards, rowdata, d_adj, delta6, sign=sign
        )

    def _delta6_spmd_dispatch(self, shards, d_adj: Dict[int, np.ndarray]):
        """Pack every shard and launch the rank-sharded intersect; all
        store reads happen here, so the in-flight unit is immune to
        later store mutations. Returns ``(PendingUnit, rowdata)``."""
        from ..distributed.spmd_runtime import ShardWork

        rt = self.runtime
        store = self.store
        empty = np.zeros(0, np.int64)
        rowdata = [None] * rt.p
        works = []
        for rank, shard in enumerate(shards):
            if shard.shape[0] == 0:
                works.append(ShardWork(rank, empty, empty, {}))
                continue
            rd = self._shard_rows(shard, rank)
            rowdata[rank] = rd
            rows_u, rows_v, res_u, res_v, w_old = rd
            u, v = shard[:, 0], shard[:, 1]
            held: Dict[int, np.ndarray] = {}
            fetched: List[int] = []
            dev = rt.device_for(rank)
            resident = set(u[res_u].tolist()) | set(v[res_v].tolist())
            for x in np.unique(np.concatenate([u, v])):
                x = int(x)
                if x in resident:
                    # content the loop path would read: the device
                    # tier's persistent mirror row, not a store merge
                    slot = int(dev.slot_of(x))
                    w_true = int(dev.widths[slot])
                    held[x] = dev.host_rows(
                        np.array([slot])
                    )[0, :w_true].copy()
                elif int(rt.part.owner(x)) == rank:
                    held[x] = np.asarray(store.row(x))
                else:
                    fetched.append(x)
            works.append(
                ShardWork(
                    rank,
                    u.astype(np.int64),
                    v.astype(np.int64),
                    held,
                    fetched,
                )
            )
        return self.spmd.dispatch(works, store), rowdata

    def _delta6_spmd_finish(
        self,
        pending,
        shards,
        rowdata,
        d_adj: Dict[int, np.ndarray],
        delta6: np.ndarray,
        *,
        sign: int,
    ) -> int:
        """Reconciliation barrier of one dispatched phase: wait for the
        device counts, then per-shard host math (masks, corrections,
        scatters)."""
        counts, _unit = pending.wait()
        total = 0
        for rank, shard in enumerate(shards):
            if shard.shape[0] == 0:
                continue
            total += self._delta6_for_shard(
                shard,
                d_adj,
                delta6,
                sign=sign,
                rank=rank,
                rowdata=rowdata[rank],
                oo_counts=counts[rank],
            )
            self.shard_pairs[rank] += shard.shape[0]
        return total

    def _shard_rows(self, pairs: np.ndarray, rank: int = 0):
        """Materialize one shard's old-neighborhood rows (the executing
        rank's device-tier view for resident endpoints, store merges for
        the rest) with the host-materialization ledger updates. Returns
        ``(rows_u, rows_v, res_u, res_v, w_old)``."""
        store = self.store
        sent = store.n
        k = pairs.shape[0]
        u, v = pairs[:, 0], pairs[:, 1]
        w_old = max(int(store.degrees[np.concatenate([u, v])].max()), 1)
        dev = (
            self.runtime.device_for(rank)
            if self.runtime is not None
            else None
        )
        if dev is not None:
            # resident hub rows come from the tier's persistent mirror
            # (no per-batch DynamicCSR merge); only the rest are
            # materialized from the store.
            rows_u, res_u = dev.padded_rows(u, w_old, sentinel=sent)
            rows_v, res_v = dev.padded_rows(v, w_old, sentinel=sent)
            built = np.concatenate([u[~res_u], v[~res_v]])
            self.oo_host_rows += int(built.size)
            self.oo_host_bytes += int(store.degrees[built].sum()) * 4
        else:
            rows_u = store.padded_rows(u, w_old, sentinel=sent)
            rows_v = store.padded_rows(v, w_old, sentinel=sent)
            res_u = res_v = np.zeros(k, bool)
            both = np.concatenate([u, v])
            self.oo_host_rows += int(both.size)
            self.oo_host_bytes += int(store.degrees[both].sum()) * 4
        return rows_u, rows_v, res_u, res_v, w_old

    def _delta6_for_shard(
        self,
        pairs: np.ndarray,
        d_adj: Dict[int, np.ndarray],
        delta6: np.ndarray,
        *,
        sign: int,
        rank: int = 0,
        rowdata=None,
        oo_counts: Optional[np.ndarray] = None,
    ) -> int:
        """One shard's worth of batched intersections (see caller).
        ``oo_counts`` injects old∩old counts computed elsewhere (the
        SPMD executor) — they are still cross-checked against the host
        membership masks below."""
        with obs_trace.span("intersect_kernel", rank=rank, cat="streaming",
                            pairs=pairs.shape[0]):
            return self._delta6_for_shard_impl(
                pairs, d_adj, delta6, sign=sign, rank=rank,
                rowdata=rowdata, oo_counts=oo_counts,
            )

    def _delta6_for_shard_impl(
        self,
        pairs: np.ndarray,
        d_adj: Dict[int, np.ndarray],
        delta6: np.ndarray,
        *,
        sign: int,
        rank: int = 0,
        rowdata=None,
        oo_counts: Optional[np.ndarray] = None,
    ) -> int:
        store = self.store
        sent = store.n
        k = pairs.shape[0]
        u, v = pairs[:, 0], pairs[:, 1]

        if rowdata is None:
            rowdata = self._shard_rows(pairs, rank)
        rows_u, rows_v, res_u, res_v, w_old = rowdata
        dev = (
            self.runtime.device_for(rank)
            if self.runtime is not None
            else None
        )
        w_new = max(max(len(r) for r in d_adj.values()), 1)
        rows_du = _padded_from_dict(d_adj, u, w_new, sent)
        rows_dv = _padded_from_dict(d_adj, v, w_new, sent)

        # old ∩ old — the wide hot path: Pallas kernel for the counts,
        # membership masks for the identities of the closing vertices.
        mask_oo = delta_intersect_masks(rows_u, rows_v, sentinel=sent)
        if oo_counts is not None:
            c_oo = np.asarray(oo_counts, np.int64)
            assert np.array_equal(c_oo, mask_oo.sum(1)), (
                "SPMD counts disagree with membership masks"
            )
            if dev is not None:
                self.oo_resident_pairs += int(
                    np.count_nonzero(res_u | res_v)
                )
        elif self.use_kernel:
            c_oo = self._oo_counts(
                u, v, rows_u, rows_v, res_u, res_v, dev, sent
            )
            assert np.array_equal(c_oo, mask_oo.sum(1)), (
                "kernel counts disagree with membership masks"
            )
        else:
            c_oo = mask_oo.sum(1).astype(np.int64)
        # wedge-closure corrections: old ∩ new (both orientations), new ∩ new
        mask_on = delta_intersect_masks(rows_u, rows_dv, sentinel=sent)
        mask_no = delta_intersect_masks(rows_du, rows_v, sentinel=sent)
        mask_nn = delta_intersect_masks(rows_du, rows_dv, sentinel=sent)
        c_on = mask_on.sum(1).astype(np.int64)
        c_no = mask_no.sum(1).astype(np.int64)
        c_nn = mask_nn.sum(1).astype(np.int64)

        end6 = sign * (6 * c_oo + 3 * (c_on + c_no) + 2 * c_nn)
        np.add.at(delta6, u, end6)
        np.add.at(delta6, v, end6)
        for mask, rows, coef in (
            (mask_oo, rows_u, 6),
            (mask_on, rows_u, 3),
            (mask_no, rows_du, 3),
            (mask_nn, rows_du, 2),
        ):
            w_ids = rows[mask].astype(np.int64)
            if w_ids.size:
                np.add.at(delta6, w_ids, sign * coef)
        return k

    def _oo_counts(
        self,
        u: np.ndarray,
        v: np.ndarray,
        rows_u: np.ndarray,
        rows_v: np.ndarray,
        res_u: np.ndarray,
        res_v: np.ndarray,
        dev,
        sent: int,
    ) -> np.ndarray:
        """Kernel-path old∩old counts, routed per pair: both sides
        resident -> slot-vs-slot gather on device (zero upload); one
        side resident -> gather vs the packed other side; neither ->
        the classic ``delta_intersect`` path."""
        k = u.shape[0]
        if dev is None or not (res_u.any() or res_v.any()):
            return delta_intersect_counts(
                rows_u, rows_v, sentinel=sent,
                block_e=self.block_e, interpret=self.interpret,
            )
        c = np.zeros(k, np.int64)
        slots_u = dev.slot_of(u)
        slots_v = dev.slot_of(v)
        both = res_u & res_v
        only_u = res_u & ~both
        only_v = res_v & ~both
        neither = ~(res_u | res_v)
        if both.any():
            c[both] = resident_intersect_counts(
                dev.rows, slots_u[both], slots_b=slots_v[both],
                sentinel=sent, interpret=self.interpret,
            )
            self.oo_resident_pairs += int(np.count_nonzero(both))
        if only_u.any():
            c[only_u] = resident_intersect_counts(
                dev.rows, slots_u[only_u], rows_v[only_u],
                sentinel=sent, interpret=self.interpret,
            )
            self.oo_resident_pairs += int(np.count_nonzero(only_u))
        if only_v.any():
            c[only_v] = resident_intersect_counts(
                dev.rows, slots_v[only_v], rows_u[only_v],
                sentinel=sent, interpret=self.interpret,
            )
            self.oo_resident_pairs += int(np.count_nonzero(only_v))
        if neither.any():
            c[neither] = delta_intersect_counts(
                rows_u[neither], rows_v[neither], sentinel=sent,
                block_e=self.block_e, interpret=self.interpret,
            )
        return c

    def _patch_lcc(self, vs: np.ndarray) -> None:
        # identical arithmetic to core.triangles.lcc_scores, elementwise,
        # so checkpoints compare bit-exact against a recount.
        deg = self.store.degrees[vs].astype(np.float64)
        denom = deg * (deg - 1.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            c = 2.0 * self.t[vs] / denom
        self.lcc[vs] = np.where(denom > 0, c, 0.0)


def _padded_from_dict(
    d_adj: Dict[int, np.ndarray], vs: np.ndarray, width: int, sentinel: int
) -> np.ndarray:
    out = np.full((vs.size, width), sentinel, np.int32)
    for i, x in enumerate(vs):
        r = d_adj.get(int(x))
        if r is not None:
            out[i, : r.size] = r
    return out
