"""Cache coherence for the streaming setting.

The static pipeline's cache science (paper §II-F, §III-B) assumes a
read-only graph: rows are fetched once and never change. Streaming breaks
that — every applied edge mutates two adjacency rows — so this module
extends both cache layers with coherence, running over the shared
``ShardedRuntime`` (which owns the 1D partition and the p per-rank
``ClampiCache`` instances — this layer constructs neither):

1. Per-rank ClampiCache replay: each batch's delta row-pair reads are
   replayed through the runtime's caches exactly like the static access
   stream (owner(u) pulls row v through *its own rank's* cache), but
   stale entries — cached rows of vertices whose adjacency just
   changed — are *invalidated* first, fanned out by the runtime only to
   the ranks that actually hold them, so hit/miss/eviction/invalidation
   statistics stay meaningful.
2. ``StaticDegreeCache`` rescoring: degree drift moves vertices in and
   out of the top-C residency set; ``refresh_static_degree_cache``
   invalidates stale resident rows and rebuilds the set when drift
   crosses a threshold.

The incremental engine reads from the authoritative ``DynamicCSR``; this
layer models what a distributed deployment (1D partition, remote pulls)
would pay, reporting per-stream hit rate and modeled communication time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.cache import (
    NetworkModel,
    StaticDegreeCache,
    build_static_degree_cache,
    refresh_static_degree_cache,
)
from ..core.runtime import ShardedRuntime
from ..obs import trace as obs_trace

__all__ = ["CoherenceReport", "StreamingCacheCoherence"]

ID_BYTES = 4


@dataclasses.dataclass
class CoherenceReport:
    """Cumulative statistics over the replayed delta access stream."""

    local_reads: int = 0
    static_hits: int = 0
    clampi_hits: int = 0
    clampi_misses: int = 0
    invalidations: int = 0  # ClampiCache entries dropped as stale
    static_stale_rows: int = 0  # resident rows refreshed in place
    static_evictions: int = 0  # residents dropped by rescoring
    static_rebuilds: int = 0
    comm_time: float = 0.0  # modeled, misses + refreshes

    @property
    def remote_reads(self) -> int:
        return self.static_hits + self.clampi_hits + self.clampi_misses

    @property
    def hit_rate(self) -> float:
        """Fraction of remote row reads served by either cache layer."""
        r = self.remote_reads
        return (self.static_hits + self.clampi_hits) / r if r else 0.0


class _RuntimeCacheView:
    """Aggregated statistics view over the runtime's p caches (the
    drop-in replacement for the old single shared simulator)."""

    def __init__(self, runtime: ShardedRuntime):
        self._runtime = runtime

    @property
    def stats(self):
        return self._runtime.merged_cache_stats()


class StreamingCacheCoherence:
    """Replays each batch's delta access stream through both cache layers.

    The runtime's p ranks give the 1D-partition notion of *remote*: the
    owner of u processes edge (u, v) and pulls row v through its own
    rank's cache iff owner(v) differs and v is not static-cache resident.
    """

    def __init__(
        self,
        n: int,
        degrees: np.ndarray,
        *,
        p: int = 4,
        cache_rows: int = 256,
        clampi_bytes: int = 1 << 20,
        table_slots: Optional[int] = None,
        rebuild_fraction: float = 0.05,
        network: Optional[NetworkModel] = None,
        runtime: Optional[ShardedRuntime] = None,
        partition=None,
    ):
        if runtime is None:
            runtime = ShardedRuntime(
                n=n,
                p=p,
                cache_bytes=clampi_bytes,
                table_slots=table_slots,
                network=network,
                partition=partition,
            )
        assert runtime.caches is not None, (
            "coherence replay needs a cached runtime"
        )
        self.runtime = runtime
        self.part = runtime.part
        self.p = runtime.p
        self.net = runtime.net
        self.rebuild_fraction = rebuild_fraction
        self.static: StaticDegreeCache = build_static_degree_cache(
            np.asarray(degrees), cache_rows
        )
        self.cache_rows = cache_rows
        self.clampi = _RuntimeCacheView(runtime)
        self.report = CoherenceReport()
        self.providers: list = []  # serving listeners to notify

    def attach_provider(self, provider) -> None:
        """Register a serving listener (a provider or a whole runtime)
        whose cached payloads must be invalidated on every applied
        batch — the freshness contract of the query service."""
        self.providers.append(provider)

    def on_batch(
        self, ins: np.ndarray, dele: np.ndarray, store
    ) -> CoherenceReport:
        """Called by the engine after applying a batch (``ins``/``dele``
        are the effective ``[K, 2]`` edge arrays; ``store`` holds the
        post-batch graph). Returns the cumulative report."""
        pairs = np.concatenate([ins, dele], axis=0)
        if pairs.shape[0] == 0:
            return self.report
        with obs_trace.span("delta_replay", cat="coherence",
                            n=pairs.shape[0]):
            return self._on_batch_impl(pairs, store)

    def _on_batch_impl(self, pairs: np.ndarray, store) -> CoherenceReport:
        rep = self.report
        changed = np.unique(pairs.ravel())

        # 1. coherence: cached copies of mutated rows are stale — the
        #    runtime fans the drop out only to the ranks that hold each
        #    row, both for the replay caches and any attached listener.
        self.runtime.invalidate(changed)
        for provider in self.providers:
            provider.notify_batch(changed)

        # 2. replay the delta access stream (both directions of each
        #    edge: owner(u) pulls row v through rank owner(u)'s cache).
        deg = store.degrees
        a = np.concatenate([pairs[:, 0], pairs[:, 1]])
        b = np.concatenate([pairs[:, 1], pairs[:, 0]])
        owners_a = self.part.owner(a)
        owners_b = self.part.owner(b)
        remote = owners_a != owners_b
        rep.local_reads += int(np.count_nonzero(~remote))
        b_rem = b[remote]
        k_rem = owners_a[remote]
        in_static = self.static.slot_of(b_rem) >= 0
        rep.static_hits += int(np.count_nonzero(in_static))
        caches = self.runtime.caches
        for v, k in zip(b_rem[~in_static], k_rem[~in_static]):
            size = int(deg[int(v)]) * ID_BYTES
            caches[int(k)].get(int(v), size, score=float(deg[int(v)]))

        # 3. rescore static residency against the drifted degrees.
        refresh = refresh_static_degree_cache(
            self.static,
            deg,
            changed,
            rebuild_fraction=self.rebuild_fraction,
        )
        rep.static_stale_rows += refresh.stale_rows
        # refreshing a stale resident row = one remote read of fresh data
        rep.comm_time += float(
            sum(self.net.remote(int(deg[int(v)]) * ID_BYTES)
                for v in refresh.stale_ids)
        )
        if refresh.rebuilt:
            self.static = refresh.cache
            rep.static_evictions += refresh.evicted
            rep.static_rebuilds += 1

        st = self.clampi.stats
        rep.clampi_hits = st.hits
        rep.clampi_misses = st.misses
        rep.invalidations = st.invalidations
        return rep

    @property
    def total_comm_time(self) -> float:
        return self.report.comm_time + self.clampi.stats.comm_time
