"""Streaming graph subsystem: exact incremental triangle counting + LCC
under batched edge insertions/deletions.

Layers (mirroring the static pipeline's architecture):

- ``updates``      — ``EdgeBatch`` op batches + normalization against a store
- ``store``        — ``DynamicCSR``: base CSR + delta buffers + compaction
- ``incremental``  — ``StreamingLCCEngine``: exact ΔT / ΔLCC per batch via
                     the batched delta-intersect kernel path
- ``coherence``    — cache-coherence hooks: ``ClampiCache`` replay of the
                     delta access stream + ``StaticDegreeCache`` rescoring
"""
from .updates import INSERT, DELETE, EdgeBatch, normalize_batch  # noqa: F401
from .store import DynamicCSR  # noqa: F401
from .incremental import BatchResult, StreamingLCCEngine  # noqa: F401
from .coherence import CoherenceReport, StreamingCacheCoherence  # noqa: F401
