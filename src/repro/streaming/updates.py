"""Edge-update batches for the streaming subsystem.

An ``EdgeBatch`` is an ordered sequence of (u, v, op) tuples. Semantics:
ops apply in order, but triangle counts are only observed at batch
boundaries, so only the *net* effect of the batch matters. Normalization
canonicalizes endpoints (u < v, self-loops dropped), keeps the last op per
edge, and splits the result against the current store state into

- effective inserts: net-INSERT edges not currently in the graph,
- effective deletes: net-DELETE edges currently in the graph,
- no-ops: duplicate inserts, deletes of absent edges, self-loops, and
  insert+delete pairs that cancel within the batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["INSERT", "DELETE", "EdgeBatch", "normalize_batch"]

INSERT = 1
DELETE = -1


@dataclasses.dataclass
class EdgeBatch:
    """One update batch: parallel arrays of endpoints and ops (+1/-1)."""

    u: np.ndarray  # [B] int64
    v: np.ndarray  # [B] int64
    op: np.ndarray  # [B] int8, INSERT or DELETE

    def __post_init__(self):
        self.u = np.asarray(self.u, np.int64).ravel()
        self.v = np.asarray(self.v, np.int64).ravel()
        self.op = np.asarray(self.op, np.int8).ravel()
        assert self.u.shape == self.v.shape == self.op.shape

    @property
    def size(self) -> int:
        return int(self.u.shape[0])

    @staticmethod
    def inserts(edges: np.ndarray) -> "EdgeBatch":
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        return EdgeBatch(
            u=edges[:, 0],
            v=edges[:, 1],
            op=np.full(edges.shape[0], INSERT, np.int8),
        )

    @staticmethod
    def deletes(edges: np.ndarray) -> "EdgeBatch":
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        return EdgeBatch(
            u=edges[:, 0],
            v=edges[:, 1],
            op=np.full(edges.shape[0], DELETE, np.int8),
        )


def normalize_batch(batch: EdgeBatch, store) -> tuple[np.ndarray, np.ndarray, int]:
    """Net effect of ``batch`` against ``store`` (a ``DynamicCSR``).

    Returns ``(ins, del, n_noop)`` where ``ins``/``del`` are ``[K, 2]``
    int64 canonical (u < v) edge arrays, disjoint, with every insert
    currently absent from the store and every delete currently present.
    """
    u, v, op = batch.u, batch.v, batch.op
    keep = u != v  # self-loops never change triangle counts
    u, v, op = u[keep], v[keep], op[keep]
    n_noop = int(batch.size - u.size)
    if u.size == 0:
        z = np.zeros((0, 2), np.int64)
        return z, z, n_noop
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    key = lo * np.int64(store.n) + hi
    # last op per edge wins: stable unique on reversed order
    _, first_rev = np.unique(key[::-1], return_index=True)
    last = key.size - 1 - first_rev
    n_noop += int(key.size - last.size)
    lo, hi, op = lo[last], hi[last], op[last]
    present = store.has_edges(lo, hi)
    ins_mask = (op == INSERT) & ~present
    del_mask = (op == DELETE) & present
    n_noop += int(lo.size - ins_mask.sum() - del_mask.sum())
    ins = np.stack([lo[ins_mask], hi[ins_mask]], axis=1)
    dele = np.stack([lo[del_mask], hi[del_mask]], axis=1)
    return ins, dele, n_noop
