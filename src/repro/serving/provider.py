"""Row providers: how the query engine reads adjacency rows.

The 1D partition gives each device rank a contiguous vertex block; rows
of locally-owned vertices are free, rows of remote vertices cost a
modeled RMA get (``NetworkModel``, paper §IV-D1). Two providers:

- ``DirectRowProvider`` — every remote read goes to the owner
  (uncached baseline; always fresh).
- ``CacheBackedRowProvider`` — remote reads are admitted/evicted by a
  ``ClampiCache`` scored with the paper's degree centrality (§III-B2),
  and — unlike the trace-only simulators in ``core/rma.py`` — this
  provider *carries the row payloads*: a cache hit returns the payload
  captured at fetch time, NOT the authoritative store row. Coherence is
  therefore a correctness property here, not bookkeeping: if the graph
  mutates and nobody calls ``notify_batch``, hits serve stale rows and
  query answers diverge from a recount. ``StreamingCacheCoherence``
  (or ``ProviderCoherenceHook``) delivers exactly that notification
  after every applied update batch, restoring the staleness bound of
  zero applied-but-unobserved batches — ``audit_freshness`` verifies it.

Point-query workloads are degree-skewed (a hub appears in the neighbor
lists of many queried vertices), which is the paper's Observation 3.1
reuse argument in its strongest form — the reason this provider exists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from ..core.cache import ClampiCache, NetworkModel
from ..core.partition import Partition1D, partition_1d

__all__ = [
    "ProviderStats",
    "DirectRowProvider",
    "CacheBackedRowProvider",
    "ProviderCoherenceHook",
]

ID_BYTES = 4


@dataclasses.dataclass
class ProviderStats:
    local_reads: int = 0
    remote_reads: int = 0  # reads of non-local rows (pre-cache)
    cache_hits: int = 0
    cache_misses: int = 0
    invalidations: int = 0
    stale_payloads_dropped: int = 0
    bytes_fetched: int = 0  # remote bytes actually moved (post-cache)
    modeled_comm_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        r = self.remote_reads
        return self.cache_hits / r if r else 0.0


class DirectRowProvider:
    """Uncached baseline: every non-local row read pays the full modeled
    remote get; rows always come from the authoritative store."""

    def __init__(
        self,
        store,
        *,
        p: int = 1,
        rank: int = 0,
        network: Optional[NetworkModel] = None,
    ):
        self.store = store
        self.part: Partition1D = partition_1d(store.n, p)
        self.rank = int(rank)
        self.net = network or NetworkModel()
        self.stats = ProviderStats()

    def fetch_rows(self, vertices: Sequence[int]) -> Dict[int, np.ndarray]:
        """Sorted adjacency row per distinct vertex (callers dedup)."""
        out: Dict[int, np.ndarray] = {}
        st = self.stats
        for v in vertices:
            v = int(v)
            row = self.store.row(v)
            if int(self.part.owner(v)) == self.rank:
                st.local_reads += 1
            else:
                st.remote_reads += 1
                size = row.size * ID_BYTES
                st.cache_misses += 1
                st.bytes_fetched += size
                st.modeled_comm_s += self.net.remote(size)
            out[v] = row
        return out

    def notify_batch(self, changed_ids: Iterable[int]) -> None:
        pass  # always reads the authoritative store: nothing to invalidate

    def audit_freshness(self) -> tuple:
        """(cached_entries, stale_entries) — trivially (0, 0)."""
        return 0, 0


class CacheBackedRowProvider:
    """Degree-scored ``ClampiCache`` in front of the owner's rows, with
    real payloads (see module docstring for the coherence contract)."""

    def __init__(
        self,
        store,
        *,
        p: int = 4,
        rank: int = 0,
        capacity_bytes: int = 1 << 20,
        table_slots: Optional[int] = None,
        network: Optional[NetworkModel] = None,
        use_degree_score: bool = True,
    ):
        self.store = store
        self.part: Partition1D = partition_1d(store.n, p)
        self.rank = int(rank)
        self.net = network or NetworkModel()
        self.cache = ClampiCache(
            capacity_bytes,
            table_slots or max(1, store.n // 4),
            mode="always",
            network=self.net,
        )
        self.use_degree_score = use_degree_score
        self.stats = ProviderStats()
        # payloads mirror cache residency: key -> row copy at fetch time
        self._payloads: Dict[int, np.ndarray] = {}

    # ---------------- reads ----------------
    def fetch_rows(self, vertices: Sequence[int]) -> Dict[int, np.ndarray]:
        """Sorted adjacency row per distinct vertex (callers dedup).

        Local rows bypass the cache; remote rows go through ClampiCache
        admission and return the cached payload on hit."""
        out: Dict[int, np.ndarray] = {}
        st = self.stats
        deg = self.store.degrees
        for v in vertices:
            v = int(v)
            if int(self.part.owner(v)) == self.rank:
                st.local_reads += 1
                out[v] = self.store.row(v)
                continue
            st.remote_reads += 1
            d = int(deg[v])
            size = d * ID_BYTES
            score = float(d) if self.use_degree_score else None
            if self.cache.get(v, size, score=score):
                st.cache_hits += 1
                out[v] = self._payloads[v]
                continue
            st.cache_misses += 1
            st.bytes_fetched += size
            row = self.store.row(v).copy()
            if self.cache.contains(v):  # admitted after the miss
                self._payloads[v] = row
            else:
                self._payloads.pop(v, None)
            out[v] = row
        # single comm ledger: the cache already charges remote reads on
        # miss plus hit/insert probe costs (paper §IV-D1) — mirror it
        # instead of re-deriving a biased copy here.
        st.modeled_comm_s = self.cache.stats.comm_time
        return out

    # ---------------- coherence ----------------
    def notify_batch(self, changed_ids: Iterable[int]) -> None:
        """One applied update batch mutated the rows of ``changed_ids``:
        drop their cached payloads so the next read refetches fresh data.
        Keeps the verifiable staleness bound at zero applied-but-
        unobserved batches."""
        st = self.stats
        for v in changed_ids:
            v = int(v)
            if self.cache.invalidate(v):
                st.invalidations += 1
            if self._payloads.pop(v, None) is not None:
                st.stale_payloads_dropped += 1
        self._prune_evicted()

    def _prune_evicted(self) -> None:
        """Payloads of entries ClampiCache evicted on its own are dead
        weight (never returned — a future get misses); drop them."""
        dead = [k for k in self._payloads if not self.cache.contains(k)]
        for k in dead:
            del self._payloads[k]

    def audit_freshness(self) -> tuple:
        """(cached_entries, stale_entries): compare every resident payload
        against the authoritative store row. With coherence notifications
        wired up, stale_entries == 0 — the staleness bound, verified."""
        self._prune_evicted()
        stale = 0
        for v, row in self._payloads.items():
            if not np.array_equal(row, self.store.row(v)):
                stale += 1
        return len(self._payloads), stale


class ProviderCoherenceHook:
    """Minimal streaming-engine coherence hook (same ``on_batch``
    signature as ``StreamingCacheCoherence``) that only forwards
    mutations to row providers — for services that want freshness
    without the CLaMPI delta-replay simulation."""

    def __init__(self, *providers):
        self.providers = list(providers)

    def attach_provider(self, provider) -> None:
        self.providers.append(provider)

    def on_batch(self, ins: np.ndarray, dele: np.ndarray, store) -> None:
        pairs = np.concatenate([ins, dele], axis=0)
        if pairs.shape[0] == 0:
            return
        changed = np.unique(pairs.ravel())
        for p in self.providers:
            p.notify_batch(changed)
