"""Row providers: how the query engine reads adjacency rows.

A provider is a *view* of the shared ``ShardedRuntime`` pinned to one
rank: the runtime owns the 1D partition, the per-rank degree-scored
``ClampiCache`` instances (carrying real row payloads), the
``NetworkModel``, and the coherence fanout; the provider only says
*which rank is reading*. This is what removed the old rank-0-only
assumption — cross-rank serving instantiates p providers over one
runtime, and each query executes at its owner rank.

- ``DirectRowProvider`` — view of an uncached runtime: every non-local
  read pays the full modeled remote get; rows always come from the
  authoritative store (always fresh).
- ``CacheBackedRowProvider`` — view of a cached runtime. A cache hit
  returns the payload captured at fetch time, NOT the authoritative
  store row, so coherence is a correctness property: if the graph
  mutates and nobody calls ``notify_batch``, hits serve stale rows and
  query answers diverge from a recount. ``StreamingCacheCoherence``
  (or ``ProviderCoherenceHook``) delivers exactly that notification
  after every applied update batch, and the runtime fans it out only to
  the ranks that cached the touched rows — ``audit_freshness`` verifies
  the resulting staleness bound of zero applied-but-unobserved batches.

Point-query workloads are degree-skewed (a hub appears in the neighbor
lists of many queried vertices), which is the paper's Observation 3.1
reuse argument in its strongest form — the reason the cached runtime
exists.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..core.cache import NetworkModel
from ..core.runtime import FetchEvent, ProviderStats, ShardedRuntime

__all__ = [
    "ProviderStats",
    "RuntimeRowProvider",
    "DirectRowProvider",
    "CacheBackedRowProvider",
    "ProviderCoherenceHook",
]


class RuntimeRowProvider:
    """One rank's read path over a shared ``ShardedRuntime``."""

    def __init__(self, runtime: ShardedRuntime, rank: int = 0):
        self.runtime = runtime
        self.rank = int(rank)

    # ---------------- runtime views ----------------
    @property
    def store(self):
        return self.runtime.store

    @property
    def part(self):
        return self.runtime.part

    @property
    def net(self) -> NetworkModel:
        return self.runtime.net

    @property
    def cache(self):
        """This rank's ClampiCache (None on an uncached runtime)."""
        return (
            self.runtime.caches[self.rank]
            if self.runtime.caches is not None
            else None
        )

    @property
    def stats(self) -> ProviderStats:
        return self.runtime.stats[self.rank]

    @property
    def residency(self):
        """The device-resident hot-row tier serving THIS rank's reads
        (None when the tier is off; the rank's own hot set under
        ``device_scope="per_rank"``) — the engine routes resident-vertex
        pairs through the ``resident_intersect`` kernel against it."""
        return self.runtime.device_for(self.rank)

    # ---------------- reads ----------------
    def fetch_rows(
        self,
        vertices: Sequence[int],
        record: Optional[List[FetchEvent]] = None,
        tenants: Optional[Dict[int, str]] = None,
    ) -> Dict[int, np.ndarray]:
        """Sorted adjacency row per distinct vertex (callers dedup).
        ``record`` collects per-vertex ``FetchEvent`` resolutions for
        the SPMD executor's placement plan; ``tenants`` maps vertex ->
        tenant tag for per-tenant accounting + quota-aware caching."""
        return self.runtime.fetch_rows(self.rank, vertices, record=record,
                                       tenants=tenants)

    # ---------------- coherence ----------------
    def notify_batch(self, changed_ids: Iterable[int]) -> None:
        """Fan one applied update batch out through the runtime (only
        ranks that cached the touched rows are told)."""
        self.runtime.invalidate(changed_ids)

    def audit_freshness(self) -> tuple:
        """(cached_entries, stale_entries) for THIS rank's view."""
        return self.runtime.audit_rank(self.rank)


class DirectRowProvider(RuntimeRowProvider):
    """Uncached baseline: a rank view over an uncached runtime."""

    def __init__(
        self,
        store=None,
        *,
        p: int = 1,
        rank: int = 0,
        network: Optional[NetworkModel] = None,
        runtime: Optional[ShardedRuntime] = None,
    ):
        if runtime is None:
            runtime = ShardedRuntime(store, p, network=network, uncached=True)
        super().__init__(runtime, rank)


class CacheBackedRowProvider(RuntimeRowProvider):
    """Rank view over a cached runtime (degree-scored ClampiCache in
    front of the owner's rows, with real payloads — see the module
    docstring for the coherence contract)."""

    def __init__(
        self,
        store=None,
        *,
        p: int = 4,
        rank: int = 0,
        capacity_bytes: int = 1 << 20,
        table_slots: Optional[int] = None,
        network: Optional[NetworkModel] = None,
        use_degree_score: bool = True,
        runtime: Optional[ShardedRuntime] = None,
    ):
        if runtime is None:
            runtime = ShardedRuntime(
                store,
                p,
                cache_bytes=capacity_bytes,
                table_slots=table_slots,
                network=network,
                use_degree_score=use_degree_score,
            )
        super().__init__(runtime, rank)


class ProviderCoherenceHook:
    """Minimal streaming-engine coherence hook (same ``on_batch``
    signature as ``StreamingCacheCoherence``) that only forwards
    mutations to registered listeners (runtimes or providers) — for
    services that want freshness without the CLaMPI delta-replay
    simulation."""

    def __init__(self, *listeners):
        self.providers = list(listeners)

    def attach_provider(self, listener) -> None:
        self.providers.append(listener)

    def on_batch(self, ins: np.ndarray, dele: np.ndarray, store) -> None:
        pairs = np.concatenate([ins, dele], axis=0)
        if pairs.shape[0] == 0:
            return
        changed = np.unique(pairs.ravel())
        for p in self.providers:
            p.notify_batch(changed)
