"""LiveQueryService: queries and streaming updates over one shared graph.

Wires the pieces together so freshness is a property, not a hope:

- one ``DynamicCSR`` store, owned by a ``StreamingLCCEngine`` that keeps
  exact per-vertex triangle counts + LCC under update batches;
- one ``ShardedRuntime`` that owns the 1D partition, the per-rank
  degree-scored caches, and the row transport;
- either a single rank's view of that runtime (the classic single-rank
  service) or — with ``cross_rank=True`` — p ``QueryEngine``/provider
  instances routing every query to its owner rank
  (``ShardedQueryEngine``);
- a coherence hook on the streaming engine that, after every applied
  batch, fans invalidations out through the runtime to exactly the
  ranks that cached the mutated rows — so queries observe the live
  graph with a staleness bound of zero applied-but-unobserved batches
  (``verify()`` checks it across all ranks).

``apply_updates`` and ``flush`` must not interleave (single-writer
semantics — the scheduler drains fully between update batches), which is
exactly the batch-boundary observability the streaming layer defines.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from ..core.csr import CSRGraph
from ..core.runtime import ShardedRuntime
from ..obs import trace as obs_trace
from ..streaming.coherence import StreamingCacheCoherence
from ..streaming.incremental import BatchResult, StreamingLCCEngine
from ..streaming.updates import EdgeBatch
from .engine import QueryEngine, ShardedQueryEngine
from .provider import (
    CacheBackedRowProvider,
    DirectRowProvider,
    ProviderCoherenceHook,
)
from .requests import Query, QueryResult
from .scheduler import MicrobatchScheduler

__all__ = ["LiveQueryService"]


class LiveQueryService:
    def __init__(
        self,
        csr: CSRGraph,
        *,
        p: int = 4,
        rank: int = 0,
        cross_rank: bool = False,
        cache_bytes: int = 1 << 20,
        max_batch: int = 64,
        max_wait: Optional[float] = None,
        max_queue: Optional[int] = None,
        shed_wait: Optional[float] = None,
        device_slots: int = 0,
        device_width: Optional[int] = None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        coherence: Optional[StreamingCacheCoherence] = None,
        provider=None,
        uncached: bool = False,
        execution: str = "loop",
        pipeline: bool = False,
        device_scope: str = "replicated",
        stream_kw: Optional[dict] = None,
        slo=None,  # Optional[traffic.SLOPolicy]
        quotas=None,  # Optional[traffic.TenantQuotas]
        scorer=None,  # Optional[traffic.WorkloadScorer]
        clock=None,  # injectable time source (traffic clocks)
        partition=None,  # custom vertex partition (e.g. partition_hub)
    ):
        assert execution == "loop" or cross_rank, (
            "SPMD execution runs the p cross-rank views on devices — "
            "pass cross_rank=True"
        )
        assert not pipeline or execution == "spmd", (
            "pipeline double-buffers SPMD microbatches — pass "
            "execution='spmd'"
        )
        hook = coherence or ProviderCoherenceHook()
        self.stream = StreamingLCCEngine(
            csr,
            coherence=hook,
            use_kernel=bool(use_kernel),
            interpret=interpret,
            **(stream_kw or {}),
        )
        self.store = self.stream.store
        if provider is not None:
            # caller-supplied rank view: adopt its runtime
            self.runtime = provider.runtime
            self.runtime.bind_store(self.store)
        elif coherence is not None:
            # ONE runtime for all consumers: the coherence layer's
            # partition/caches also carry the serving reads (its p wins
            # over ours), so replay warmth, hit/miss stats, and the
            # invalidation-fanout ledger are shared, not split.
            self.runtime = coherence.runtime
            self.runtime.bind_store(self.store)
        else:
            self.runtime = ShardedRuntime(
                self.store, p, cache_bytes=cache_bytes, uncached=uncached,
                partition=partition,
            )
        if device_slots:
            # the device-resident hot-row tier below the host caches:
            # fetch_rows consults it first, the engines route resident
            # pairs through the resident_intersect gather, and the
            # coherence fanout below keeps it fresh per update batch.
            # scope="per_rank" gives each rank its own hot set of the
            # remote-heavy rows IT reads (own-block rows are excluded).
            self.runtime.enable_device_tier(
                device_slots, device_width, scope=device_scope
            )
        lcc_source = lambda: self.stream.lcc  # noqa: E731
        if cross_rank:
            assert provider is None, "cross_rank builds its own rank views"
            self.engine = ShardedQueryEngine(
                self.store,
                self.runtime,
                use_kernel=use_kernel,
                interpret=interpret,
                lcc_source=lcc_source,
                execution=execution,
                pipeline=pipeline,
            )
            self.providers = [e.provider for e in self.engine.engines]
            self.provider = self.providers[rank]
        else:
            if provider is None:
                provider = (
                    DirectRowProvider(runtime=self.runtime, rank=rank)
                    if uncached
                    else CacheBackedRowProvider(
                        runtime=self.runtime, rank=rank
                    )
                )
            self.provider = provider
            self.providers = [provider]
            self.engine = QueryEngine(
                self.store,
                self.provider,
                use_kernel=use_kernel,
                interpret=interpret,
                lcc_source=lcc_source,
            )
        self.cross_rank = cross_rank
        # one coherence registration for the whole runtime: the fanout
        # targets exactly the ranks holding each touched row. (When the
        # hook IS a StreamingCacheCoherence over this same runtime it
        # already invalidates it on every batch — don't register twice.)
        if getattr(hook, "runtime", None) is not self.runtime:
            hook.attach_provider(self.runtime)
        self.coherence = coherence
        # ---------------- traffic plane ----------------
        # live workload scoring: admissions through every rank cache use
        # the EWMA×degree blend, and the device tier re-ranks from the
        # same scorer on refresh_scores().
        self.scorer = scorer
        if scorer is not None:
            self.runtime.attach_scorer(scorer)
        # tenant cache shares: hard byte caps inside each rank's cache.
        # NOTE: shares steer eviction with state the access trace does
        # not record, so don't combine with --cache-trace replay gates.
        self.quotas = quotas
        if quotas is not None and self.runtime.caches is not None:
            shares = quotas.cache_shares()
            if shares:
                for c in self.runtime.caches:
                    c.set_tenant_shares(shares)
        self.scheduler = MicrobatchScheduler(
            self.engine,
            max_batch=max_batch,
            max_wait=max_wait,
            max_queue=max_queue,
            shed_wait=shed_wait,
            clock=clock,
            slo=slo,
            quotas=quotas,
        )

    # ---------------- write path ----------------
    def apply_updates(self, batch: EdgeBatch) -> BatchResult:
        assert self.scheduler.pending == 0, (
            "drain queries before applying updates (single-writer)"
        )
        with obs_trace.span("apply_updates", cat="write",
                            n=batch.u.size):
            return self.stream.apply_batch(batch)

    def refresh_scores(self) -> int:
        """Re-rank the device-resident tier under the live workload
        scores (between windows — rebuilds bump slot epochs). No-op
        without a scorer/tier; returns rebuilds performed."""
        assert self.scheduler.pending == 0, (
            "drain queries before re-ranking residency (epoch bumps "
            "would fault in-flight handles)"
        )
        return self.runtime.refresh_device_scores()

    # ---------------- read path ----------------
    def submit(self, query: Query, *, urgent: bool = False,
               at: Optional[float] = None) -> bool:
        """False when admission control shed the query (tenant quota or
        queue depth). ``at`` stamps the arrival time (open-loop)."""
        return self.scheduler.submit(query, urgent=urgent, at=at)

    def submit_many(self, queries: Sequence[Query]) -> int:
        """Number of queries admitted (the rest were shed)."""
        return self.scheduler.submit_many(queries)

    def flush(self) -> List[QueryResult]:
        return self.scheduler.flush()

    def query(self, query: Query) -> QueryResult:
        """Synchronous single query (no microbatching)."""
        return self.engine.execute_batch([query])[0]

    # ---------------- observability ----------------
    def metrics_registry(self, *, tracer=None):
        """One queryable snapshot of every ledger this service owns:
        per-rank provider/cache stats, device tier, serve matrix +
        placement gauges, serving latency (overall and per SLO class),
        and — under SPMD execution — the measured ``CollectiveLedger``
        with the measured-vs-modeled RMA reconciliation. Pass the
        active ``Tracer`` to fold per-phase wall time in too."""
        from ..obs.metrics import (
            MetricRegistry,
            fold_trace,
            record_collective_ledger,
            record_coherence_report,
            record_latency,
            record_reconciliation,
            record_runtime,
            record_tenancy,
        )

        reg = MetricRegistry()
        record_runtime(reg, self.runtime)
        record_latency(reg, self.scheduler.recorder)
        if self.quotas is not None:
            record_tenancy(reg, self.quotas, self.runtime)
        spmd = getattr(self.engine, "spmd", None)
        if spmd is not None:
            record_collective_ledger(reg, spmd.ledger)
            record_reconciliation(reg, self.runtime, spmd.ledger)
        if self.coherence is not None:
            record_coherence_report(reg, self.coherence.report)
        if tracer is not None:
            fold_trace(reg, tracer)
        return reg

    # ---------------- invariants ----------------
    @property
    def triangle_count(self) -> int:
        return self.stream.triangle_count

    def verify(self) -> None:
        """Streaming state bit-exact vs recount AND zero stale cached
        rows on every runtime rank — the service-level freshness
        contract."""
        self.stream.verify()
        cached, stale = self.runtime.audit_freshness()
        if stale:
            raise AssertionError(
                f"provider staleness bound violated: {stale}/{cached} "
                "cached rows diverge from the store"
            )
