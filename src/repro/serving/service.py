"""LiveQueryService: queries and streaming updates over one shared graph.

Wires the pieces together so freshness is a property, not a hope:

- one ``DynamicCSR`` store, owned by a ``StreamingLCCEngine`` that keeps
  exact per-vertex triangle counts + LCC under update batches;
- a row provider (cache-backed by default) that the ``QueryEngine``
  reads through;
- a coherence hook on the streaming engine that, after every applied
  batch, invalidates the provider's cached copies of every mutated row —
  so queries observe the live graph with a staleness bound of zero
  applied-but-unobserved batches (``verify()`` checks it).

``apply_updates`` and ``flush`` must not interleave (single-writer
semantics — the scheduler drains fully between update batches), which is
exactly the batch-boundary observability the streaming layer defines.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from ..core.csr import CSRGraph
from ..streaming.coherence import StreamingCacheCoherence
from ..streaming.incremental import BatchResult, StreamingLCCEngine
from ..streaming.updates import EdgeBatch
from .engine import QueryEngine
from .provider import (
    CacheBackedRowProvider,
    DirectRowProvider,
    ProviderCoherenceHook,
)
from .requests import Query, QueryResult
from .scheduler import MicrobatchScheduler

__all__ = ["LiveQueryService"]


class LiveQueryService:
    def __init__(
        self,
        csr: CSRGraph,
        *,
        p: int = 4,
        rank: int = 0,
        cache_bytes: int = 1 << 20,
        max_batch: int = 64,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        coherence: Optional[StreamingCacheCoherence] = None,
        provider=None,
        uncached: bool = False,
        stream_kw: Optional[dict] = None,
    ):
        hook = coherence or ProviderCoherenceHook()
        self.stream = StreamingLCCEngine(
            csr,
            coherence=hook,
            use_kernel=bool(use_kernel),
            interpret=interpret,
            **(stream_kw or {}),
        )
        self.store = self.stream.store
        if provider is None:
            provider = (
                DirectRowProvider(self.store, p=p, rank=rank)
                if uncached
                else CacheBackedRowProvider(
                    self.store, p=p, rank=rank, capacity_bytes=cache_bytes
                )
            )
        self.provider = provider
        hook.attach_provider(self.provider)
        self.coherence = coherence
        self.engine = QueryEngine(
            self.store,
            self.provider,
            use_kernel=use_kernel,
            interpret=interpret,
            lcc_source=lambda: self.stream.lcc,
        )
        self.scheduler = MicrobatchScheduler(self.engine, max_batch=max_batch)

    # ---------------- write path ----------------
    def apply_updates(self, batch: EdgeBatch) -> BatchResult:
        assert self.scheduler.pending == 0, (
            "drain queries before applying updates (single-writer)"
        )
        return self.stream.apply_batch(batch)

    # ---------------- read path ----------------
    def submit(self, query: Query) -> None:
        self.scheduler.submit(query)

    def submit_many(self, queries: Sequence[Query]) -> None:
        self.scheduler.submit_many(queries)

    def flush(self) -> List[QueryResult]:
        return self.scheduler.flush()

    def query(self, query: Query) -> QueryResult:
        """Synchronous single query (no microbatching)."""
        return self.engine.execute_batch([query])[0]

    # ---------------- invariants ----------------
    @property
    def triangle_count(self) -> int:
        return self.stream.triangle_count

    def verify(self) -> None:
        """Streaming state bit-exact vs recount AND zero stale cached
        rows in the provider — the service-level freshness contract."""
        self.stream.verify()
        cached, stale = self.provider.audit_freshness()
        if stale:
            raise AssertionError(
                f"provider staleness bound violated: {stale}/{cached} "
                "cached rows diverge from the store"
            )
