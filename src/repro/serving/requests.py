"""Query/result types for the online graph query service.

A ``Query`` is a point or batch request against the live graph:

- ``lcc(v)``                — local clustering coefficient of one vertex
- ``triangles(v)``          — triangle count through one vertex
- ``common_neighbors(u,v)`` — |adj(u) ∩ adj(v)| plus the neighbor ids
- ``top_k_lcc(k)``          — the k vertices with the highest LCC

Point queries are answered from adjacency rows fetched through the row
provider (and are therefore bit-exact against a from-scratch recount of
the provider's view of the graph); ``top_k_lcc`` reads the exact
per-vertex LCC array the streaming engine maintains incrementally.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

__all__ = ["QueryKind", "Query", "QueryResult"]


class QueryKind(enum.IntEnum):
    LCC = 0
    TRIANGLES = 1
    COMMON_NEIGHBORS = 2
    TOP_K_LCC = 3


@dataclasses.dataclass(frozen=True)
class Query:
    kind: QueryKind
    u: int = -1
    v: int = -1
    k: int = 0
    # multi-tenant serving: admission (token buckets) and cache-share
    # accounting key on this tag; "" = untagged (single-tenant path,
    # never rate-limited). Tag with dataclasses.replace or
    # traffic.assign_tenants.
    tenant: str = ""

    @staticmethod
    def lcc(v: int) -> "Query":
        return Query(QueryKind.LCC, u=int(v))

    @staticmethod
    def triangles(v: int) -> "Query":
        return Query(QueryKind.TRIANGLES, u=int(v))

    @staticmethod
    def common_neighbors(u: int, v: int) -> "Query":
        return Query(QueryKind.COMMON_NEIGHBORS, u=int(u), v=int(v))

    @staticmethod
    def top_k_lcc(k: int) -> "Query":
        return Query(QueryKind.TOP_K_LCC, k=int(k))


@dataclasses.dataclass
class QueryResult:
    """Answer + serving metadata for one query.

    value: LCC (float), triangle count (int), or common-neighbor count.
    ids/values: for ``common_neighbors`` the shared neighbor ids; for
        ``top_k_lcc`` the top-k vertex ids and their LCC scores.
    latency_s: submit-to-completion time, filled by the scheduler.
    """

    query: Query
    value: float
    ids: Optional[np.ndarray] = None
    values: Optional[np.ndarray] = None
    latency_s: float = 0.0
